"""Sharding rules: parameter / input / activation PartitionSpecs.

Mesh axes (launch/mesh.py):
  * ``data``  — DP batch axis; doubles as the FSDP/ZeRO-3 axis in training.
  * ``model`` — TP/EP axis (heads, d_ff hidden, vocab, experts).
  * ``pod``   — optional leading multi-pod axis: extra DP (default) or the
    pipeline axis (distributed/pipeline.py).

Rules are *logical*: each parameter leaf is matched by the suffix of its
tree path to a template over trailing dims; leading dims added by
scan-over-layers stacking are padded with ``None`` automatically.  An axis
is only applied when the dim size is divisible by the mesh axis size —
non-divisible cases (e.g. whisper's 20 heads over model=16) degrade to
replication of that dim instead of relying on GSPMD padding, keeping
memory analysis exact.

Serving mode drops the FSDP ``data`` axis from weights (pure TP — weights
replicated across DP so decode never all-gathers them) unless the config
opts in via ``serve_keep_fsdp`` (llama4-400B cannot fit TP-only).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec

# Logical axis names used in rule templates.
FSDP = "fsdp"      # -> "data" (train) / dropped (serve, unless keep_fsdp)
TP = "tp"          # -> "model"
EP = "ep"          # -> "model" (experts); "data" when serve_keep_fsdp moe
DP = "dp"          # -> ("pod", "data") batch sharding


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Resolved mapping logical axis -> mesh axis (or None)."""
    fsdp: Optional[str] = "data"
    tp: Optional[str] = "model"
    ep: Optional[str] = "model"

    def resolve(self, logical: Optional[str]) -> Optional[str]:
        if logical is None:
            return None
        return {FSDP: self.fsdp, TP: self.tp, EP: self.ep}[logical]


TRAIN_RULES = ShardingRules(fsdp="data", tp="model", ep="model")
SERVE_RULES = ShardingRules(fsdp=None, tp="model", ep="model")
# llama4-400B serving: experts sharded over data, expert hidden over model.
SERVE_FSDP_RULES = ShardingRules(fsdp=None, tp="model", ep="data")


# ---------------------------------------------------------------------------
# rule table: ordered (path-regex, template-over-trailing-dims)
# ---------------------------------------------------------------------------
# The regex is matched against "/"-joined tree paths like
# "groups/0/mixer/wq" or "front/1/moe/w_down".  First match wins.
_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # --- embeddings / head ---------------------------------------------------
    # embed d-dim deliberately UNSHARDED: an FSDP 'data' entry there makes
    # the lookup-gather output d-sharded over the batch axis, and SPMD
    # resolves the conflict by replicating the activations — silently
    # 16x-ing all downstream compute (EXPERIMENTS.md §Perf iteration 1).
    (r"(^|/)embed$",                 (TP, None)),     # (vocab, d)
    (r"(^|/)lm_head$",               (None, TP)),     # (d, vocab)
    # --- MoE (before generic mlp names; expert weights are rank-3) ----------
    (r"moe/router$",                 (FSDP, None)),   # (d, E)
    (r"moe/shared/w_(gate|up)$",     (FSDP, TP)),
    (r"moe/shared/w_down$",          (TP, FSDP)),
    (r"moe/w_(gate|up)$",            (EP, FSDP, TP)),  # (E, d, f)
    (r"moe/w_down$",                 (EP, TP, FSDP)),  # (E, f, d)
    # --- MLA -----------------------------------------------------------------
    (r"mixer/w_dkv$",                (FSDP, None)),   # (d, rank+rope)
    (r"mixer/w_u[kv]$",              (None, TP)),     # (rank, H*hd)
    (r"mixer/kv_norm$",              (None,)),
    # --- attention (also matches encdec "cross/") ----------------------------
    (r"(mixer|cross)/w[qkv]$",       (FSDP, TP)),     # (d, proj)
    (r"(mixer|cross)/wo$",           (TP, FSDP)),     # (proj, d)
    (r"mixer/b[qkv]$",               (TP,)),
    (r"mixer/[qk]_norm$",            (None,)),
    # --- SSD (mamba2) ---------------------------------------------------------
    (r"mixer/w_[zx]$",               (FSDP, TP)),     # (d, d_in)
    (r"mixer/w_[BC]$",               (FSDP, None)),   # (d, G*N) small
    (r"mixer/w_dt$",                 (FSDP, TP)),     # (d, H)
    (r"mixer/conv_x_w$",             (None, TP)),
    (r"mixer/conv_x_b$",             (TP,)),
    (r"mixer/conv_[BC]_[wb]$",       (None, None)),   # trailing dims padded
    (r"mixer/(A_log|D|dt_bias)$",    (TP,)),
    (r"mixer/gate_norm$",            (TP,)),
    (r"mixer/out_proj$",             (TP, FSDP)),     # (d_in, d)
    # --- RG-LRU ----------------------------------------------------------------
    (r"mixer/w_gate$",               (FSDP, TP)),     # (d, w)
    (r"mixer/w_x$",                  (FSDP, TP)),
    (r"mixer/conv_w$",               (None, TP)),
    (r"mixer/conv_b$",               (TP,)),
    (r"mixer/(lambda_|[ai]_gate_[wb])$", (TP,)),
    (r"mixer/w_out$",                (TP, FSDP)),     # (w, d)
    # --- dense MLP --------------------------------------------------------------
    (r"mlp/w_(gate|up)$",            (FSDP, TP)),     # (d, f)
    (r"mlp/w_down$",                 (TP, FSDP)),     # (f, d)
    # --- norms & everything small ------------------------------------------------
    (r"norm",                        (None,)),
    (r".",                           ()),             # default: replicate
)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        else:  # pragma: no cover
            parts.append(str(k))
    return "/".join(parts)


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        n = 1
        for a in name:
            n *= mesh.shape.get(a, 1)
        return n
    return mesh.shape.get(name, 1)


def _spec_for_leaf(path_s: str, shape: Tuple[int, ...], mesh: Mesh,
                   rules: ShardingRules) -> P:
    for pat, template in _PARAM_RULES:
        if re.search(pat, path_s):
            tmpl = template
            break
    else:  # pragma: no cover — final rule always matches
        tmpl = ()
    ndim = len(shape)
    k = min(len(tmpl), ndim)
    trailing = tmpl[len(tmpl) - k:] if k else ()
    spec: list = [None] * (ndim - k)
    used: set = set()
    for dim_size, logical in zip(shape[ndim - k:], trailing):
        axis = rules.resolve(logical)
        members = (set(axis) if isinstance(axis, tuple)
                   else {axis} if axis else set())
        # first-wins dedup: a mesh axis shards at most one dim (e.g. MoE
        # (E,d,f) in train: EP takes 'model', so TP on f degrades to None)
        if axis is not None and not (members & used) \
                and dim_size % _axis_size(mesh, axis) == 0 \
                and _axis_size(mesh, axis) > 1:
            # drop tuple components absent from this mesh
            if isinstance(axis, tuple):
                axis = tuple(a for a in axis if mesh.shape.get(a, 1) > 1)
                axis = axis if len(axis) > 1 else (axis[0] if axis else None)
            spec.append(axis)
            used |= members
        else:
            spec.append(None)
    # embed fallback: vocab not divisible by the TP axis (mamba2 50280,
    # whisper 51866) -> keep the table fully replicated.  (Sharding d over
    # 'model' instead trips an XLA SPMD partitioner bug when the grad-
    # accumulation scan dynamic-slices the gathered embeddings; the
    # replicated table costs ~0.2 GB/device for these vocabs.)
    return P(*spec)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
def rules_for(cfg: ModelConfig, mode: str,
              mesh: Optional[Mesh] = None) -> ShardingRules:
    if mode == "train":
        # multipod: FSDP spans (pod, data) so 400B-class params/grads
        # shard over every DP chip, not just within one pod
        if mesh is not None and mesh.shape.get("pod", 1) > 1:
            return ShardingRules(fsdp=("pod", "data"), tp="model",
                                 ep="model")
        return TRAIN_RULES
    if cfg.serve_keep_fsdp:
        return SERVE_FSDP_RULES
    return SERVE_RULES


def param_pspecs(cfg: ModelConfig, params: Any, mesh: Mesh,
                 mode: str = "train") -> Any:
    """Tree of PartitionSpec matching ``params`` (arrays or ShapeDtypeStruct)."""
    rules = rules_for(cfg, mode, mesh)

    def leaf(path, x):
        return _spec_for_leaf(_path_str(path), tuple(x.shape), mesh, rules)

    return jax.tree_util.tree_map_with_path(leaf, params)


def batch_axes(mesh: Mesh, n: Optional[int] = None):
    """Dim-0 spec entry for batch sharding: 'data', ('pod','data'), or None.

    If ``n`` is given, the largest divisible prefix of the DP axes is used
    (e.g. batch=128 on (pod=2, data=16): 'data' only would be dropped too;
    we try ('pod','data'), then 'data', then 'pod', then None).
    """
    cands = [("pod", "data"), ("data",), ("pod",)]
    for c in cands:
        axes = tuple(a for a in c if _axis_size(mesh, a) > 1)
        if not axes:
            continue
        if n is None or n % _prod(mesh, axes) == 0:
            return axes if len(axes) > 1 else axes[0]
    return None


def _prod(mesh: Mesh, axes) -> int:
    total = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        total *= _axis_size(mesh, a)
    return total


def batch_pspec(mesh: Mesh, n: Optional[int] = None) -> P:
    return P(batch_axes(mesh, n))


def input_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                 kind: Optional[str] = None) -> Dict[str, P]:
    """PartitionSpecs for the batch dict fed to train/serve steps."""
    kind = kind or shape.kind
    entry = batch_axes(mesh, shape.global_batch)
    specs: Dict[str, P] = {}
    names = ("tokens", "labels") if kind == "train" else ("tokens",)
    for n in names:
        specs[n] = P(entry, None)
    if cfg.frontend_stub and kind == "train":
        specs["vis_embeds"] = P(entry, None, None)
        specs["vis_mask"] = P(entry, None)
    if cfg.is_encoder_decoder and kind == "train":
        specs["frames"] = P(entry, None, None)
    return specs


# ---------------------------------------------------------------------------
# activation constraints (set once by the trainer / serve builder): SPMD
# sharding propagation through while-loop (scan) carries is unreliable —
# without an in-body anchor the batch sharding dissolves and XLA replicates
# the whole layer stack (EXPERIMENTS.md §Perf iteration 1).
# ---------------------------------------------------------------------------
_ACT_MESH: Optional[Mesh] = None
_ACT_SEQ_AXIS: Optional[str] = None


def set_activation_mesh(mesh: Optional[Mesh],
                        seq_axis: Optional[str] = None) -> None:
    """seq_axis='model' enables sequence parallelism: the residual stream
    is anchored (B, S/model, d) between blocks, so GSPMD replaces the TP
    all-reduces with reduce-scatter + all-gather pairs and S-shards the
    norm/residual memory (EXPERIMENTS.md §Perf iteration 7)."""
    global _ACT_MESH, _ACT_SEQ_AXIS
    _ACT_MESH = mesh
    _ACT_SEQ_AXIS = seq_axis


def constrain_acts(x: jnp.ndarray) -> jnp.ndarray:
    """Anchor (B, S, ...) activations to batch-over-DP inside scan bodies."""
    if _ACT_MESH is None:
        return x
    spec = [batch_axes(_ACT_MESH, x.shape[0])] + [None] * (x.ndim - 1)
    if _ACT_SEQ_AXIS is not None and x.ndim == 3 and x.shape[1] > 1:
        spec[1] = _ACT_SEQ_AXIS
    return constrain(x, _ACT_MESH, *spec)


def constrain(x: jnp.ndarray, mesh: Mesh, *spec) -> jnp.ndarray:
    """with_sharding_constraint that silently drops unknown/undivisible axes."""
    fixed = []
    for dim, s in zip(x.shape, spec):
        if s is None:
            fixed.append(None)
            continue
        axes = s if isinstance(s, tuple) else (s,)
        axes = tuple(a for a in axes if _axis_size(mesh, a) > 1)
        if axes and dim % _prod(mesh, axes) == 0:
            fixed.append(axes if len(axes) > 1 else axes[0])
        else:
            fixed.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))


def logical_to_pspec(template, shape, mesh, rules: ShardingRules) -> P:
    spec = []
    for dim, logical in zip(shape, template):
        axis = rules.resolve(logical)
        if axis and dim % _axis_size(mesh, axis) == 0 \
                and _axis_size(mesh, axis) > 1:
            spec.append(axis)
        else:
            spec.append(None)
    return P(*spec)


def shardings_for(tree_of_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# cache sharding (serving): batch over data; kv-heads/length placement
# ---------------------------------------------------------------------------
def cache_pspecs(cfg: ModelConfig, cache: Any, mesh: Mesh,
                 shard_length: bool = False) -> Any:
    """KV-cache placement.

    Default: batch(slot) dim over ``data``; kv-heads over ``model`` when
    divisible, else the *length* dim over ``model`` (GQA kv=8 on a 16-way
    TP axis — e.g. qwen3/llama4 decode — shards the 32k context instead).
    ``shard_length`` (long_500k, batch=1): length over ``data`` too.

    Cache leaves are (B,T,H,D) k/v/xk/xv, (B,T,r) ckv/krope, (B,T) pos,
    (B,W-1,C) conv, (B,H,P,N) ssd state, (B,W) rglru h — possibly under
    leading scan-stack dims; the trailing structure is keyed by leaf name.
    """
    data = "data" if _axis_size(mesh, "data") > 1 else None
    model = "model" if _axis_size(mesh, "model") > 1 else None

    def leaf(path, x):
        p = _path_str(path)
        last = p.rsplit("/", 1)[-1]
        shape = tuple(x.shape)
        nd = len(shape)
        spec: list = [None] * nd
        tdim = hdim = None
        if last == "pos":
            bdim = nd - 2
            tdim = nd - 1
        elif last.startswith("conv"):
            bdim = nd - 3
        elif last == "state":
            bdim = nd - 4
            hdim = nd - 3
        elif last in ("ckv", "krope"):
            bdim = nd - 3
            tdim = nd - 2
        elif last == "h":
            bdim = nd - 2
        else:  # k / v / xk / xv
            bdim = nd - 4
            tdim = nd - 3
            hdim = nd - 2
        bdim = max(bdim, 0)

        def fits(dim, axis):
            return (dim is not None and axis is not None
                    and shape[dim] % _axis_size(mesh, axis) == 0)

        if not shard_length and fits(bdim, data):
            spec[bdim] = data
        elif shard_length and fits(tdim, data):
            spec[tdim] = data
        if fits(hdim, model):
            spec[hdim] = model
        elif tdim is not None and spec[tdim] is None and fits(tdim, model):
            spec[tdim] = model
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf, cache)
