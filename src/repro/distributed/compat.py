"""Version shims for the jax distributed API surface.

``jax.shard_map`` (whose replication-check kwarg is ``check_vma``) only
exists on newer jax releases; older ones ship the same transform as
``jax.experimental.shard_map.shard_map`` with the kwarg spelled
``check_rep``.  Every shard_map call site in this repo and its tests
goes through this wrapper so both spellings work unchanged.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KWARG = "check_vma"
else:  # older jax: experimental module, kwarg named check_rep
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KWARG = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the modern signature on any jax version."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_CHECK_KWARG: check_vma})


def axis_size(axis_name: str) -> int:
    """Static size of a mapped mesh axis.  ``jax.lax.axis_size`` is
    recent; ``psum(1, axis)`` is the old idiom and constant-folds to a
    Python int, so either way the result can drive ``range()``."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)
