"""Overlap-friendly collectives (shard_map level).

``collective_matmul_ag`` implements the all-gather <-> matmul overlap
("collective matmul", Wang et al.): instead of all-gathering the
row-sharded LHS and then multiplying, each step multiplies the locally
resident shard while ``ppermute`` rotates the next shard around the ring —
compute hides the ICI transfer.  Used by the beyond-paper perf path for
FSDP weight gathering (EXPERIMENTS.md §Perf) and exercised by tests on a
host-device mesh.

``reduce_scatter_matmul`` is the mirrored pattern for the output
projection: psum_scatter interleaved with the per-shard matmuls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.distributed.compat import axis_size


def _ring_perm(n: int, shift: int = 1):
    return [(i, (i + shift) % n) for i in range(n)]


def collective_matmul_ag(x: jnp.ndarray, w_shard: jnp.ndarray,
                         axis_name: str) -> jnp.ndarray:
    """Computes ``x @ all_gather(w_shard, axis)`` with compute/comm overlap.

    Inside shard_map: ``w_shard`` is this device's (d_in/n, d_out) slice of
    a row-sharded weight; x is (..., d_in) fully replicated along
    ``axis_name``.  Each iteration multiplies the currently-held shard
    against the matching x columns while rotating shards ring-wise.
    """
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    blk = w_shard.shape[0]

    def step(i, carry):
        acc, w_cur = carry
        # perm (r -> r+1): after i rotations this rank holds the shard that
        # originated at rank (idx - i) mod n
        src = (idx - i) % n
        x_blk = jax.lax.dynamic_slice_in_dim(x, src * blk, blk, axis=-1)
        acc = acc + x_blk @ w_cur
        w_nxt = jax.lax.ppermute(w_cur, axis_name, _ring_perm(n))
        return acc, w_nxt

    out_shape = x.shape[:-1] + (w_shard.shape[1],)
    acc0 = jnp.zeros(out_shape, w_shard.dtype)
    # unrolled fori so ppermute of the last iteration is dead-code-eliminated
    acc, w = acc0, w_shard
    for i in range(n - 1):
        acc, w = step(i, (acc, w))
    src = (idx - (n - 1)) % n
    x_blk = jax.lax.dynamic_slice_in_dim(x, src * blk, blk, axis=-1)
    return acc + x_blk @ w


def reduce_scatter_matmul(x_shard: jnp.ndarray, w_shard: jnp.ndarray,
                          axis_name: str) -> jnp.ndarray:
    """Row-parallel matmul with ring reduce-scatter overlap.

    x_shard: (..., d_in/n) — the contraction dim is sharded; w_shard:
    (d_in/n, d_out) matching rows.  Each rank's ``x_shard @ w_shard`` is a
    full-width partial sum; instead of an all-reduce, the partials are
    ring-reduce-scattered so each rank ends with its fully-reduced
    (..., d_out/n) column slot — and each matmul chunk overlaps with the
    neighbour transfer.  Equivalent to psum_scatter(x @ w) over the last
    dim.
    """
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    part = x_shard @ w_shard                             # (..., d_out)
    d_out = part.shape[-1]
    blk = d_out // n

    def chunk(j):
        return jax.lax.dynamic_slice_in_dim(part, j * blk, blk, axis=-1)

    if n == 1:
        return part
    # ring reduce-scatter (perm r -> r+1): rank q initiates the buffer for
    # slot (q-1); a buffer reaching rank r at step s was initiated by rank
    # (r-s) for slot (r-s-1), so rank r adds chunk((r-s-1) % n).  After
    # n-1 steps rank r holds the fully-reduced chunk r.
    buf = chunk((idx - 1) % n)
    for s in range(1, n):
        buf = jax.lax.ppermute(buf, axis_name, _ring_perm(n))
        buf = buf + chunk((idx - s - 1) % n)
    return buf


def all_gather_interleaved(shard: jnp.ndarray, axis_name: str,
                           tile_fn) -> jnp.ndarray:
    """Generic overlap driver: applies ``tile_fn(i, shard_i)`` as shards
    arrive ring-wise and sums the results."""
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    out = tile_fn((idx + 0) % n, shard)
    cur = shard
    for i in range(1, n):
        cur = jax.lax.ppermute(cur, axis_name, _ring_perm(n))
        out = out + tile_fn((idx + i) % n, cur)
    return out


def psum_pods_then_data(x: jnp.ndarray, mesh: Mesh) -> jnp.ndarray:
    """Hierarchical gradient all-reduce: reduce within the pod first (fast
    ICI), then across pods (slow DCN/ICI link) — one value crosses the pod
    boundary per element instead of the full DP fan-in."""
    if "data" in mesh.shape:
        x = jax.lax.psum(x, "data")
    if "pod" in mesh.shape:
        x = jax.lax.psum(x, "pod")
    return x
