"""Gradient compression with error feedback (distributed-optimization trick).

Int8 block-quantized all-reduce: gradients are quantized to int8 with a
per-block fp32 scale before crossing the slow inter-pod link, and the
quantization residual is carried to the next step (error feedback), which
keeps SGD/Adam convergence unbiased in expectation.  4x fewer bytes on the
wire for the pod-axis all-reduce; within-pod reduction stays bf16/fp32.

Pure-functional API so it drops into the train step:

    comp, new_err = compress_with_feedback(grad, err)
    grad_sync     = psum(decompress(comp))              # 1/4 wire bytes
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


@dataclasses.dataclass(frozen=True)
class Compressed:
    q: jnp.ndarray        # int8 payload, shape = padded flat
    scale: jnp.ndarray    # (nblocks,) fp32
    shape: Tuple[int, ...]
    pad: int


jax.tree_util.register_pytree_node(
    Compressed,
    lambda c: ((c.q, c.scale), (c.shape, c.pad)),
    lambda aux, ch: Compressed(ch[0], ch[1], aux[0], aux[1]),
)


def quantize(x: jnp.ndarray) -> Compressed:
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0           # (nb,)
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return Compressed(q.reshape(-1), scale, tuple(x.shape), pad)


def dequantize(c: Compressed) -> jnp.ndarray:
    blocks = c.q.reshape(-1, BLOCK).astype(jnp.float32) * c.scale[:, None]
    flat = blocks.reshape(-1)
    if c.pad:
        flat = flat[: flat.shape[0] - c.pad]
    return flat.reshape(c.shape)


def compress_with_feedback(grad: Any, err: Any) -> Tuple[Any, Any]:
    """Per-leaf: quantize (grad + carried error); new error = residual."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        c = quantize(g32)
        return c, g32 - dequantize(c)

    flat_g, tree = jax.tree.flatten(grad)
    flat_e = jax.tree.leaves(err)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    comp = jax.tree.unflatten(tree, [p[0] for p in pairs])
    new_err = jax.tree.unflatten(tree, [p[1] for p in pairs])
    return comp, new_err


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def psum_compressed(comp: Any, axis_name: str) -> Any:
    """Mean across ``axis_name`` with int8 wire traffic, *exact* given the
    shared scale: a tiny fp32 pmax pre-pass agrees a per-block global
    scale, every rank re-quantizes to it, and the int8 payloads psum in
    int32 — Σ q_i · s == quantize-then-sum with no cross-rank scale error.
    The local requantization residual goes back to the caller's error
    feedback via ``requantize_residual``.
    """
    n = jax.lax.psum(1, axis_name)

    def one(c: Compressed) -> jnp.ndarray:
        s_glob = jax.lax.pmax(c.scale, axis_name)            # (nb,)
        # re-express local payload under the shared scale
        vals = c.q.reshape(-1, BLOCK).astype(jnp.float32) * c.scale[:, None]
        q2 = jnp.clip(jnp.round(vals / s_glob[:, None]), -127, 127)
        qsum = jax.lax.psum(q2.astype(jnp.int32), axis_name)
        blocks = qsum.astype(jnp.float32) * s_glob[:, None] / n
        flat = blocks.reshape(-1)
        if c.pad:
            flat = flat[: flat.shape[0] - c.pad]
        return flat.reshape(c.shape)

    return jax.tree.map(one, comp,
                        is_leaf=lambda x: isinstance(x, Compressed))
