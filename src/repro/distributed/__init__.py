"""Distribution layer: mesh-aware sharding rules, overlap-friendly
collectives, gradient compression, and pipeline parallelism."""
from repro.distributed.compat import shard_map
from repro.distributed.sharding import (
    batch_pspec, constrain, input_pspecs, logical_to_pspec, param_pspecs,
    shardings_for, ShardingRules,
)

__all__ = [
    "batch_pspec", "constrain", "input_pspecs", "logical_to_pspec",
    "param_pspecs", "shard_map", "shardings_for", "ShardingRules",
]
