"""GPipe-style pipeline parallelism over the ``pod`` mesh axis.

The multi-pod default is DP over ``pod`` (gradient all-reduce is infrequent
and overlappable).  For models whose weights do not fit one pod, or when
the inter-pod link is too slow for per-step DP all-reduce, the ``pod`` axis
can instead carry pipeline stages: layers are partitioned into
``num_stages`` contiguous chunks and microbatches stream through with the
standard GPipe schedule (fill, steady state, drain) implemented as a
shard_map over ``pod`` with ppermute stage-to-stage handoff.

This module is deliberately self-contained: it pipelines any per-stage
``apply_fn(stage_params, x) -> x`` and is exercised by
tests/test_pipeline.py on a host-device mesh against the sequential
reference.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.compat import shard_map


def stage_params(params_stacked: Any, num_stages: int) -> Any:
    """Split layer-stacked params (leading dim = layers) into per-stage
    stacks with leading dim = layers_per_stage, stacked on a new stage axis
    (so the ``pod`` axis shards stage dim 0)."""
    def split(x):
        L = x.shape[0]
        assert L % num_stages == 0, (L, num_stages)
        return x.reshape(num_stages, L // num_stages, *x.shape[1:])
    return jax.tree.map(split, params_stacked)


def gpipe(apply_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
          mesh: Mesh, axis: str = "pod"):
    """Returns pipelined(params_staged, x_microbatches) running under
    shard_map over ``axis``.

    x_microbatches: (M, mb, ...) microbatch-major input.  Each device holds
    the stage of ``params_staged`` matching its ``axis`` index.  The GPipe
    schedule runs M + S - 1 ticks; tick t processes microbatch (t - stage)
    on each stage, with ppermute handoff between ticks.  Bubble fraction =
    (S-1)/(M+S-1), reported by ``bubble_fraction``.
    """
    S = mesh.shape[axis]

    def _stage_fn(params_s, xs):
        # params_s: this device's (1, Lps, ...) stage stack; xs: (M, mb, ...)
        params_local = jax.tree.map(lambda p: p[0], params_s)
        stage = jax.lax.axis_index(axis)
        M = xs.shape[0]
        ticks = M + S - 1
        perm = [(i, (i + 1) % S) for i in range(S)]

        def tick(carry, t):
            inflight, outputs = carry
            # stage 0 injects microbatch t (if in range); others use handoff
            mb_idx = jnp.clip(t, 0, M - 1)
            inject = xs[mb_idx]
            x_in = jnp.where(stage == 0, inject, inflight)
            y = apply_fn(params_local, x_in)
            # last stage records its finished microbatch (t - (S-1))
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            valid = (t >= S - 1)
            outputs = jax.lax.cond(
                valid,
                lambda o: o.at[out_idx].set(
                    jnp.where(stage == S - 1, y, o[out_idx])),
                lambda o: o, outputs)
            # hand y to the next stage (ring; stage S-1 -> 0 is ignored)
            nxt = jax.lax.ppermute(y, axis, perm)
            return (nxt, outputs), None

        out0 = jnp.zeros_like(xs)
        inflight0 = jnp.zeros_like(xs[0])
        (_, outputs), _ = jax.lax.scan(
            tick, (inflight0, out0), jnp.arange(ticks))
        # only stage S-1 holds real outputs; broadcast via masked psum
        # (ppermute cannot multicast one source to every destination)
        if S > 1:
            outputs = jax.lax.psum(
                jnp.where(stage == S - 1, outputs,
                          jnp.zeros_like(outputs)), axis)
        return outputs

    pipelined = shard_map(
        _stage_fn, mesh=mesh,
        in_specs=(P(axis), P()),     # stage stacks sharded; x replicated
        out_specs=P(),
        check_vma=False)
    return pipelined


def bubble_fraction(num_microbatches: int, num_stages: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)
