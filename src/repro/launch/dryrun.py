import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.
# The 512 host devices exist only for this dry-run driver; tests and
# benchmarks see the real single CPU device.
"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

For each cell this driver lowers the real jitted program (train_step for
train shapes, full-prompt prefill for prefill shapes, serve decode for
decode shapes) against ShapeDtypeStruct stand-ins on the production mesh
(16x16 single-pod / 2x16x16 multi-pod), compiles it, and records:

  * memory_analysis()  — per-device argument/output/temp bytes (fits HBM?)
  * cost_analysis()    — per-device HLO FLOPs + bytes accessed
  * collective bytes   — parsed from the post-SPMD HLO: operand bytes of
    every all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute, per primitive

Records are JSON files under benchmarks/results/dryrun/ consumed by
benchmarks/roofline.py.  Usage:

    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    python -m repro.launch.dryrun --all             # 40 cells x 2 meshes
    python -m repro.launch.dryrun --all --mesh single
"""
import argparse
import dataclasses
import json
import re
import subprocess
import sys
import time
from typing import Dict, Optional

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s+=\s+(.*)$")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective operand bytes from post-SPMD (per-device) HLO."""
    table: Dict[str, int] = {}
    pending = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        paren = rest.find("(")
        head = rest[:paren] if paren >= 0 else rest
        table[name] = _shape_bytes(head)
        opcode = head.strip().split()[-1] if head.strip() else ""
        # opcode variants like "all-gather-start" / "-done" count once
        base = next((c for c in _COLLECTIVES
                     if opcode == c or opcode == c + "-start"), None)
        if base is not None and paren >= 0:
            depth, end = 0, paren
            for i, ch in enumerate(rest[paren:], paren):
                depth += (ch == "(") - (ch == ")")
                if depth == 0:
                    end = i
                    break
            operands = re.findall(r"%([\w\.\-]+)", rest[paren:end + 1])
            pending.append((base, operands))
    out: Dict[str, Dict[str, float]] = {
        c: {"bytes": 0.0, "count": 0} for c in _COLLECTIVES}
    for base, operands in pending:
        b = sum(table.get(o, 0) for o in operands)
        out[base]["bytes"] += float(b)
        out[base]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    return out


# ---------------------------------------------------------------------------
# per-kind lowering
# ---------------------------------------------------------------------------
def _train_grad_accum(cfg, shape) -> int:
    # keep per-chip microbatch small enough that fp32 logits fit comfortably
    accum = 8 if shape.global_batch >= 64 else 1
    while shape.global_batch % accum:
        accum //= 2
    return max(accum, 1)


def lower_train(cfg, shape, mesh, moe_impl: str,
                seq_parallel: bool = False):
    import jax
    from jax.sharding import NamedSharding
    from repro.launch.mesh import input_specs
    from repro.training.trainer import build_trainer
    from repro.training.train_state import TrainState

    trainer = build_trainer(cfg, mesh, grad_accum=_train_grad_accum(cfg, shape),
                            moe_impl=moe_impl, donate=True,
                            seq_parallel=seq_parallel)
    params_sds = jax.eval_shape(trainer.model.init, jax.random.PRNGKey(0))
    state_sds = jax.eval_shape(
        lambda p: TrainState.create(p, trainer.optimizer), params_sds)
    state_sds = jax.tree.map(
        lambda s, spec: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, spec)),
        state_sds, trainer.state_pspecs)
    batch_sds = input_specs(cfg, shape, mesh)
    return trainer.train_step.lower(state_sds, batch_sds)


def _serve_params_sds(model, cfg, mesh):
    """Serving weights are a bf16 copy of the fp32 training params."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro.distributed import sharding as SH
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = SH.param_pspecs(cfg, params_sds, mesh, "serve")
    dt = jnp.dtype(cfg.dtype)

    def cast(s, spec):
        d = dt if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype
        return jax.ShapeDtypeStruct(s.shape, d,
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree.map(cast, params_sds, pspecs)


def lower_decode(cfg, shape, mesh, moe_impl: str):
    import jax
    import jax.numpy as jnp
    from repro.launch.mesh import cache_specs, input_specs
    from repro.serving.serve_step import build_serve_fns

    fns = build_serve_fns(cfg, mesh, batch=shape.global_batch,
                          max_len=shape.seq_len, moe_impl=moe_impl,
                          shard_cache_length=(shape.global_batch == 1))
    params_sds = _serve_params_sds(fns.model, cfg, mesh)
    cache_sds = cache_specs(cfg, shape, mesh, model=fns.model)
    inp = input_specs(cfg, shape, mesh)
    active = jax.ShapeDtypeStruct(inp["lengths"].shape, jnp.bool_,
                                  sharding=inp["lengths"].sharding)
    return fns.decode.lower(params_sds, cache_sds, inp["tokens"],
                            inp["lengths"], active)


def lower_prefill(cfg, shape, mesh, moe_impl: str):
    import jax
    import jax.numpy as jnp
    from repro.launch.mesh import cache_specs, input_specs
    from repro.serving.serve_step import build_serve_fns

    fns = build_serve_fns(cfg, mesh, batch=shape.global_batch,
                          max_len=shape.seq_len, moe_impl=moe_impl,
                          prefill_chunk=shape.seq_len)
    params_sds = _serve_params_sds(fns.model, cfg, mesh)
    cache_sds = cache_specs(cfg, shape, mesh, model=fns.model)
    inp = input_specs(cfg, shape, mesh)
    valid_n = jax.ShapeDtypeStruct(inp["lengths"].shape, jnp.int32,
                                   sharding=inp["lengths"].sharding)
    if cfg.is_encoder_decoder:
        # whisper: prefill carries the (stubbed) encoder frames
        def pf(params, cache, tokens, lengths, valid_n, frames):
            B, C = tokens.shape
            valid = jnp.arange(C)[None, :] < valid_n[:, None]
            logits, cache = fns.model.prefill(params, tokens, cache,
                                              lengths, valid=valid,
                                              frames=frames)
            return logits[:, -1], cache
        return jax.jit(pf, donate_argnums=(1,)).lower(
            params_sds, cache_sds, inp["tokens"], inp["lengths"], valid_n,
            inp["frames"])
    return fns.prefill_chunk.lower(params_sds, cache_sds, inp["tokens"],
                                   inp["lengths"], valid_n)


# ---------------------------------------------------------------------------
# cell driver
# ---------------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             moe_impl: str = "gshard", save: bool = True,
             attn_impl: Optional[str] = None,
             seq_parallel: bool = False,
             tag: str = "") -> Dict:
    import jax
    from repro.configs import SHAPES, cell_supported, get_config, param_count
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    if attn_impl:
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    shape = SHAPES[shape_name]
    mesh_name = "multipod" if multi_pod else "singlepod"
    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "kind": shape.kind, "moe_impl": moe_impl, "tag": tag,
                 "params": param_count(cfg)}
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        rec["skipped"] = reason
        if save:
            _save(rec, tag)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rec["devices"] = int(mesh.size)
    t0 = time.time()
    if shape.kind == "train":
        lowered = lower_train(cfg, shape, mesh, moe_impl, seq_parallel)
    elif shape.kind == "prefill":
        lowered = lower_prefill(cfg, shape, mesh, moe_impl)
    else:
        lowered = lower_decode(cfg, shape, mesh, moe_impl)
    rec["lower_s"] = round(time.time() - t0, 1)

    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes_est": int(ma.argument_size_in_bytes
                              + ma.output_size_in_bytes
                              + ma.temp_size_in_bytes
                              - ma.alias_size_in_bytes),
    }
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):    # older jax wraps the dict in a list
        ca = ca[0] if ca else {}
    # raw XLA numbers (while bodies counted ONCE — kept for reference)
    rec["cost_xla_once"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
    # trip-count-aware walk of the post-SPMD HLO (launch/hlo_stats.py):
    # the numbers the roofline actually uses
    from repro.launch.hlo_stats import analyze as hlo_analyze
    hs = hlo_analyze(compiled.as_text())
    rec["cost"] = {"flops": hs["flops"], "bytes_accessed": hs["bytes"]}
    rec["collectives"] = {
        **{c: {"bytes": hs[c], "count": int(hs[c + "_count"])}
           for c in ("all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute")},
        "total_bytes": hs["collective_bytes"],
    }
    if save:
        _save(rec, tag)
    return rec


def _save(rec: Dict, tag: str = "") -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fname = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json"
    with open(os.path.join(RESULTS_DIR, fname), "w") as f:
        json.dump(rec, f, indent=1)


def _print_rec(rec: Dict) -> None:
    if "skipped" in rec:
        print(f"[skip] {rec['arch']} x {rec['shape']} x {rec['mesh']}: "
              f"{rec['skipped']}")
        return
    m = rec["memory"]
    c = rec["collectives"]
    print(f"[ ok ] {rec['arch']} x {rec['shape']} x {rec['mesh']} "
          f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s)")
    print(f"       mem/device: args {m['argument_bytes']/2**30:.2f} GiB, "
          f"temp {m['temp_bytes']/2**30:.2f} GiB, "
          f"out {m['output_bytes']/2**30:.2f} GiB")
    print(f"       flops/device: {rec['cost']['flops']:.3e}   "
          f"collective bytes/device: {c['total_bytes']:.3e}")
    per = {k: v for k, v in c.items()
           if isinstance(v, dict) and v["count"]}
    if per:
        print("       " + "  ".join(
            f"{k}:{v['count']}x/{v['bytes']:.2e}B" for k, v in per.items()))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--moe-impl", default="gshard")
    ap.add_argument("--attn-impl", default=None)
    ap.add_argument("--seq-parallel", action="store_true",
                    help="sequence-parallel residual stream (train cells)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--subprocess-per-cell", action="store_true",
                    help="isolate each cell in a fresh process (RAM hygiene)")
    args = ap.parse_args(argv)

    from repro.configs import SHAPES, list_archs
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    if args.all:
        cells = [(a, s) for a in list_archs() for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            if args.subprocess_per_cell and len(cells) > 1:
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--mesh", "multi" if mp else "single",
                       "--moe-impl", args.moe_impl]
                if args.attn_impl:
                    cmd += ["--attn-impl", args.attn_impl]
                if args.seq_parallel:
                    cmd += ["--seq-parallel"]
                if args.tag:
                    cmd += ["--tag", args.tag]
                r = subprocess.run(cmd)
                failures += (r.returncode != 0)
                continue
            try:
                rec = run_cell(arch, shape, mp, moe_impl=args.moe_impl,
                               attn_impl=args.attn_impl,
                               seq_parallel=args.seq_parallel, tag=args.tag)
                _print_rec(rec)
            except Exception as e:  # noqa: BLE001
                failures += 1
                print(f"[FAIL] {arch} x {shape} x "
                      f"{'multipod' if mp else 'singlepod'}: {e!r}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
