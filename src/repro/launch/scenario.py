"""Scenario CLI: run any registered scenario on either backend and dump
the portable RunReport (DESIGN.md §7).

    PYTHONPATH=src python -m repro.launch.scenario --list
    PYTHONPATH=src python -m repro.launch.scenario fig9_congestor_victim \
        --backend sim --json /tmp/fig9.json
    PYTHONPATH=src python -m repro.launch.scenario qos_closed_loop \
        --backend serve
    PYTHONPATH=src python -m repro.launch.scenario --all --fast \
        --out-dir benchmarks/results/run_reports

Scenario parameters are overridable with ``--set key=value`` (repeat as
needed); values parse as JSON where possible (``--set scheduler=rr``,
``--set duration_us=60``).  ``--backend serve`` runs the scheduling-only
NullExecutor unless ``--arch`` selects a real model.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _parse_sets(pairs):
    out = {}
    for p in pairs:
        if "=" not in p:
            raise SystemExit(f"--set expects key=value, got {p!r}")
        k, v = p.split("=", 1)
        try:
            out[k] = json.loads(v)
        except json.JSONDecodeError:
            out[k] = v
    return out


def run_one(name: str, backend: str, params, *, arch: str = "",
            smoke: bool = False, fast: bool = False,
            export_dir: str = "", dash: bool = False):
    """Build + run one scenario; returns the validated RunReport.

    With ``arch`` (serve backend only), the registered spec's engine
    shape — via ``ServeRuntime.from_spec``, the single owner of the
    ServeSpec→EngineConfig mapping — also configures a real
    ``ModelExecutor`` data plane.

    ``export_dir`` attaches the metrics bus with the OpenMetrics +
    JSONL exporters (files ``<dir>/<name>.<backend>.om.txt`` and
    ``.jsonl``); ``dash`` attaches the live terminal dashboard
    (DESIGN.md §11).
    """
    from repro.api import get_scenario, run_scenario
    from repro.api.registry import scenario_params
    accepted = scenario_params(name)
    unknown = set(params) - accepted
    if unknown:
        raise SystemExit(
            f"scenario {name!r} takes no parameter(s) "
            f"{', '.join(sorted(unknown))} (accepted: "
            f"{', '.join(sorted(accepted)) or 'none'})")
    spec = get_scenario(name, **params)
    if fast and not spec.analytic:
        kw = {"duration_us": min(spec.duration_us, 60.0)}
        if spec.horizon_us:
            kw["horizon_us"] = min(spec.horizon_us, 60.0)
        spec = spec.replace(**kw)
    if backend not in spec.backends and not spec.analytic:
        raise SystemExit(
            f"scenario {name!r} does not support backend {backend!r} "
            f"(supported: {', '.join(spec.backends)})")

    bus = None
    om_sink = None
    if (export_dir or dash) and not spec.analytic:
        from repro.telemetry.bus import MetricsBus
        bus = MetricsBus()
        names = {i: t.name for i, t in enumerate(spec.tenants)}
        if export_dir:
            os.makedirs(export_dir, exist_ok=True)
            from repro.telemetry.export import attach_exporters
            om_sink, _ = attach_exporters(
                bus, os.path.join(export_dir, f"{name}.{backend}"),
                names=names)
        if dash:
            from repro.launch.dash import Dashboard
            bus.add_sink(Dashboard(names=names))

    from repro.fleet.spec import FleetSpec
    if isinstance(spec, FleetSpec) and not spec.analytic:
        # fleet scenarios: N per-NIC engines over the modeled switch,
        # publishing per-NIC frames onto the one shared bus; the fabric
        # gauges ride into the OpenMetrics exposition as extra rows
        from repro.fleet.engine import fleet_metric_rows, run_fleet
        try:
            rep = run_fleet(spec, backend, bus=bus)
            if om_sink is not None:
                om_sink.extra_rows = fleet_metric_rows(
                    rep.extras["fleet"], backend=backend)
            return rep
        finally:
            if bus is not None:
                bus.close()

    if backend == "serve" and arch and not spec.analytic:
        from repro.api import ServeRuntime
        from repro.configs import get_config, smoke_config
        from repro.serving.engine import ModelExecutor
        cfg = smoke_config(arch) if smoke else get_config(arch)
        rt = ServeRuntime.from_spec(
            spec, executor=lambda ecfg: ModelExecutor(
                cfg, ecfg, rng_seed=spec.seed))
    elif bus is not None:
        from repro.api.runtime import make_runtime
        rt = make_runtime(spec, backend)
    else:
        return run_scenario(spec, backend)
    if bus is not None:
        rt.attach_bus(bus)
    try:
        return rt.run(spec).validate()
    finally:
        if bus is not None:
            bus.close()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="run a registered OSMOSIS scenario -> RunReport")
    ap.add_argument("scenario", nargs="?", default="",
                    help="registered scenario name (see --list)")
    ap.add_argument("--backend", default="sim", choices=["sim", "serve"])
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    ap.add_argument("--all", action="store_true",
                    help="run every registered scenario on every backend "
                         "it supports")
    ap.add_argument("--fast", action="store_true",
                    help="cap sim durations at 60us (CI smoke)")
    ap.add_argument("--set", action="append", default=[], metavar="K=V",
                    help="override a scenario parameter (repeatable)")
    ap.add_argument("--json", default="",
                    help="dump the RunReport JSON to this path")
    ap.add_argument("--export", default="", metavar="DIR",
                    help="attach the metrics bus and write OpenMetrics "
                         "(<scenario>.<backend>.om.txt) + JSONL exports "
                         "into DIR")
    ap.add_argument("--dash", action="store_true",
                    help="live terminal dashboard during the run "
                         "(plain ANSI; see repro.launch.dash)")
    ap.add_argument("--out-dir", default="",
                    help="with --all: write one RunReport JSON per run")
    ap.add_argument("--arch", default="",
                    help="serve backend: run a real model (default: "
                         "scheduling-only NullExecutor)")
    ap.add_argument("--smoke", action="store_true",
                    help="with --arch: shrink the model to smoke size")
    args = ap.parse_args(argv)

    from repro.api import list_scenarios

    if args.list:
        for s in list_scenarios():
            kind = "analytic" if s["analytic"] else ",".join(s["backends"])
            print(f"{s['name']:<24} [{kind:>9}] T={s['tenants']}  "
                  f"{s['description']}")
        return 0

    params = _parse_sets(args.set)

    if args.all:
        if not args.out_dir:
            raise SystemExit("--all requires --out-dir")
        os.makedirs(args.out_dir, exist_ok=True)
        from repro.api.registry import scenario_params
        failures = []
        for s in list_scenarios():
            backends = ["sim"] if s["analytic"] else s["backends"]
            # --set overrides apply wherever a factory accepts the key
            applicable = {k: v for k, v in params.items()
                          if k in scenario_params(s["name"])}
            for backend in backends:
                tag = f"{s['name']}.{backend}"
                try:
                    rep = run_one(s["name"], backend, applicable,
                                  fast=args.fast)
                except Exception as exc:  # noqa: BLE001 — smoke must report all
                    failures.append((tag, repr(exc)))
                    print(f"FAIL {tag}: {exc!r}")
                    continue
                path = os.path.join(args.out_dir, f"{tag}.json")
                rep.save(path)
                print(f"ok   {tag:<36} -> {path}")
        if failures:
            print(f"{len(failures)} scenario run(s) failed")
            return 1
        return 0

    if not args.scenario:
        raise SystemExit("scenario name required (or --list / --all)")

    rep = run_one(args.scenario, args.backend, params, arch=args.arch,
                  smoke=args.smoke, fast=args.fast,
                  export_dir=args.export, dash=args.dash)
    print(rep.summary())
    if rep.extras.get("analytic"):
        cols = rep.extras["columns"]
        print(",".join(cols))
        for row in rep.extras["table"]:
            print(",".join(str(x) for x in row))
    if args.json:
        rep.save(args.json)
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
