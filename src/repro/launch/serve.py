"""Serving driver: multi-tenant OSMOSIS engine over a real model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --tenants 3 --requests 12 --scheduler wlbvt

Spins up the engine, admits tenants with different SLO priorities, feeds a
mixed workload (long-prompt congestor + short-prompt victims) and prints
per-tenant FCT + Jain fairness — the serving analogue of paper Figs. 12-13.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--scheduler", default="wlbvt",
                    choices=["wlbvt", "rr"])
    ap.add_argument("--arbiter", default="dwrr", choices=["dwrr", "fifo"])
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry-report", action="store_true",
                    help="print the per-tenant telemetry plane report")
    args = ap.parse_args(argv)

    from repro.configs import get_config, smoke_config
    from repro.core.slo import SLOPolicy
    from repro.serving.engine import Engine, EngineConfig, ModelExecutor
    from repro.serving.request import Request

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    ecfg = EngineConfig(max_slots=args.max_slots, max_len=args.max_len,
                        prefill_chunk=args.prefill_chunk,
                        scheduler=args.scheduler, arbiter=args.arbiter,
                        max_tenants=max(args.tenants, 2))
    exe = ModelExecutor(cfg, ecfg, rng_seed=args.seed)
    eng = Engine(ecfg, executor=exe)

    rng = np.random.RandomState(args.seed)
    quota = args.max_len * max(2, args.max_slots // args.tenants)
    for t in range(args.tenants):
        prio = 2.0 if t == 0 else 1.0
        eng.create_ectx(t, SLOPolicy(priority=prio, kv_quota_tokens=quota),
                        name=f"tenant{t}")
    for i in range(args.requests):
        t = i % args.tenants
        # tenant 1 is the congestor: long prompts + long generations
        plen = args.max_len // 2 if t == 1 else 8
        new = 32 if t == 1 else 8
        prompt = rng.randint(1, cfg.vocab_size, size=plen).astype(np.int32)
        eng.submit(Request(t, prompt, max_new_tokens=new))

    eng.run_until_idle()
    m = eng.metrics()
    print(f"steps={m['steps']}  Jain(time-avg)={m['jain_timeavg']:.3f}  "
          f"prefill_chunks={m['prefill_chunks']}  "
          f"decode_steps={m['decode_steps']}")
    for t in sorted(m["tenants"]):
        d = m["tenants"][t]
        print(f"  tenant{t}: done={d['done']} killed={d['killed']} "
              f"mean_fct={d['mean_fct']:.1f} steps")
    if args.telemetry_report:
        from repro.telemetry import format_console
        print(format_console(eng.telemetry_report()))
    return 0


if __name__ == "__main__":
    sys.exit(main())
