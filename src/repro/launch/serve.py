"""Serving driver: multi-tenant OSMOSIS engine over a real model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --tenants 3 --requests 12 --scheduler wlbvt

Runs a registered serving ScenarioSpec (default ``serve_mixed_slo``:
a 2x-priority tenant, a long-prompt congestor, interactive victims)
through the unified runtime API over a real model executor, and prints
the portable RunReport — the serving analogue of paper Figs. 12-13.

    --scenario serve_three_class   # any registered serve-backend scenario
    --json report.json             # dump the RunReport
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--scenario", default="serve_mixed_slo",
                    help="registered serving scenario to run")
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--scheduler", default="wlbvt",
                    choices=["wlbvt", "rr"])
    ap.add_argument("--arbiter", default="dwrr", choices=["dwrr", "fifo"])
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="",
                    help="dump the RunReport JSON to this path")
    ap.add_argument("--telemetry-report", action="store_true",
                    help="print the per-tenant telemetry plane report")
    args = ap.parse_args(argv)

    from repro.api import ServeRuntime, get_scenario
    from repro.api.registry import scenario_params
    from repro.configs import get_config, smoke_config
    from repro.serving.engine import ModelExecutor

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    # forward each driver knob only if the scenario's factory takes it;
    # warn when an explicitly-set flag has no effect on this scenario
    knobs = dict(scheduler=args.scheduler, arbiter=args.arbiter,
                 seed=args.seed, tenants=args.tenants,
                 requests=args.requests, max_slots=args.max_slots,
                 max_len=args.max_len, prefill_chunk=args.prefill_chunk,
                 vocab=cfg.vocab_size)
    accepted = scenario_params(args.scenario)
    params = {k: v for k, v in knobs.items() if k in accepted}
    for k in sorted(set(knobs) - accepted - {"vocab"}):
        if getattr(args, k) != ap.get_default(k):
            print(f"warning: --{k.replace('_', '-')} is ignored by "
                  f"scenario {args.scenario!r}")
    spec = get_scenario(args.scenario, **params)
    if "serve" not in spec.backends:
        raise SystemExit(f"scenario {args.scenario!r} has no serving "
                         f"projection (backends: {spec.backends})")

    rt = ServeRuntime.from_spec(
        spec, executor=lambda ecfg: ModelExecutor(cfg, ecfg,
                                                  rng_seed=args.seed))
    rep = rt.run(spec).validate()

    print(rep.summary())
    print(f"  prefill_chunks={rep.extras['prefill_chunks']}  "
          f"decode_steps={rep.extras['decode_steps']}")
    for t in sorted(rep.tenants):
        r = rep.tenants[t]
        print(f"  {r.name}: done={r.completed} killed={r.killed} "
              f"mean_fct={r.extra['mean_fct']:.1f} steps")
    if args.json:
        rep.save(args.json)
        print(f"wrote {args.json}")
    if args.telemetry_report:
        from repro.telemetry import format_console
        print(format_console(rt.engine.telemetry_report(),
                             time_unit=rep.time_unit))
    return 0


if __name__ == "__main__":
    sys.exit(main())
