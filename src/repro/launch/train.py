"""Training driver: data pipeline -> train loop -> checkpoints -> resume.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt --ckpt-every 20

Fault tolerance: async sharded checkpoints with atomic commit; on restart
the driver resumes from LATEST (params+optimizer+data-iterator state), on
an arbitrary mesh (elastic resharding in training/checkpoint.py).  A
preemption signal (SIGTERM) triggers a final synchronous save.
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--mesh", default="none",
                    help="none | dxm grid like 2x4 (data x model)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    from repro.configs import get_config, smoke_config
    from repro.training import checkpoint as CKPT
    from repro.training.data import make_pipeline
    from repro.training.trainer import build_trainer

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = None
    if args.mesh != "none":
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))

    trainer = build_trainer(cfg, mesh=mesh, total_steps=args.steps,
                            grad_accum=args.grad_accum)
    pipe = make_pipeline(cfg, args.seq_len, args.global_batch,
                         seed=args.seed)
    state = trainer.init_state(jax.random.PRNGKey(args.seed))

    start_step = 0
    ckpt = CKPT.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and args.ckpt_dir:
        latest = CKPT.latest_step(args.ckpt_dir)
        if latest is not None:
            shardings = (jax.tree.map(lambda x: x.sharding, state)
                         if mesh is not None else None)
            state, extra = CKPT.load(args.ckpt_dir, state,
                                     shardings=shardings)
            pipe.restore(extra["data"])
            start_step = int(extra["step"])
            print(f"resumed from step {start_step}")

    stop = {"flag": False}
    signal.signal(signal.SIGTERM, lambda *_: stop.update(flag=True))

    bshard = None
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.sharding import batch_axes
        bshard = NamedSharding(mesh, P(batch_axes(mesh, args.global_batch),
                                       None))

    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        if bshard is not None:
            batch = {k: jax.device_put(v, bshard) for k, v in batch.items()}
        state, metrics = trainer.train_step(state, batch)
        if (step + 1) % args.log_every == 0 or step + 1 == args.steps:
            loss = float(metrics["loss"])
            gn = float(metrics["grad_norm"])
            tps = args.global_batch * args.seq_len * (step + 1 - start_step) \
                / max(time.time() - t0, 1e-9)
            print(f"step {step+1:5d}  loss {loss:.4f}  gnorm {gn:.3f}  "
                  f"tok/s {tps:,.0f}")
        if ckpt and ((step + 1) % args.ckpt_every == 0 or stop["flag"]
                     or step + 1 == args.steps):
            ckpt.save(state, step + 1,
                      extra={"step": step + 1, "data": pipe.state()})
        if stop["flag"]:
            print("preempted: final checkpoint committed")
            break
    if ckpt:
        ckpt.wait()
    return 0


if __name__ == "__main__":
    sys.exit(main())
