"""Sweep CLI: accelerator-resident scenario sweeps (DESIGN.md §13).

Expand a registered scenario into a ``SweepSpec`` (knob axes × seeds),
run every replica in one jit/scan launch on the device datapath, and
dump per-replica summary rows.

    PYTHONPATH=src python -m repro.launch.sweep fig9_congestor_victim \
        --axis tenants.0.priority=1,2,4 --seeds 8 --out /tmp/sweep.json
    PYTHONPATH=src python -m repro.launch.sweep qos_fifo_pressure \
        --axis fifo_capacity=16,64,256,4096 --axis scheduler='"rr"' \
        --seeds 32 --precision fast
    PYTHONPATH=src python -m repro.launch.sweep --spec /tmp/plan.json

Axis values parse as JSON where possible (``--axis scheduler='"rr"'``
sweeps a string knob; bare numbers need no quoting).  ``--spec`` loads a
serialized ``SweepSpec`` instead of expanding one from the registry.
Timelines are a host-observability feature, so the base spec always runs
with ``record_timeline=False`` here.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

# Must precede any import that pulls in jax: the sweep inner loop is
# thunk-dispatch bound on CPU without the legacy emitter.
from repro.xlaenv import tune_cpu_for_scan_sweeps


def _parse_axis(arg: str):
    """``path=v1,v2,...`` -> SweepAxis; each value JSON-parsed if valid."""
    from repro.api import SweepAxis
    if "=" not in arg:
        raise SystemExit(f"--axis expects path=v1,v2,..., got {arg!r}")
    knob, raw = arg.split("=", 1)
    values = []
    for tok in raw.split(","):
        try:
            values.append(json.loads(tok))
        except json.JSONDecodeError:
            values.append(tok)
    if not values:
        raise SystemExit(f"--axis {knob!r} has no values")
    return SweepAxis(knob=knob, values=tuple(values))


def build_sweep(name: str, params, axes, seeds: int):
    """Registry scenario + parsed axes -> SweepSpec (timeline off)."""
    from repro.api import SweepSpec, get_scenario
    from repro.api.registry import scenario_params
    unknown = set(params) - scenario_params(name)
    if unknown:
        raise SystemExit(
            f"scenario {name!r} takes no parameter(s) "
            f"{', '.join(sorted(unknown))}")
    base = get_scenario(name, **params).replace(record_timeline=False)
    return SweepSpec(name=name, base=base, axes=tuple(axes),
                     seeds=tuple(range(seeds)))


def run_sweep(sweep, *, impl: str = "", precision: str = "exact"):
    """Expand + launch; returns ``(summary_rows, elapsed_seconds)``.

    One device launch per (tenant-count, scheduler) group — a
    ``scheduler`` axis compiles one launch per value; every other knob
    shares a single launch.  Row order follows ``replicas()``.
    """
    from repro.sim.devicepath import device_eligible, run_sweep_specs
    why = device_eligible(sweep.base)
    if why is not None:
        raise SystemExit(f"sweep base not device-eligible: {why}")
    pairs = list(sweep.replicas())
    groups = {}
    for idx, (_, spec) in enumerate(pairs):
        groups.setdefault((len(spec.tenants), spec.scheduler),
                          []).append(idx)
    rows = [None] * len(pairs)
    t0 = time.perf_counter()
    for idxs in groups.values():
        results = run_sweep_specs([pairs[i][1] for i in idxs],
                                  impl=impl, precision=precision)
        for i, res in zip(idxs, results):
            rows[i] = res.summary_row(pairs[i][0])
    elapsed = time.perf_counter() - t0
    return rows, elapsed


def main(argv=None) -> int:
    tune_cpu_for_scan_sweeps()
    ap = argparse.ArgumentParser(
        description="run a scenario sweep on the device datapath")
    ap.add_argument("scenario", nargs="?", default="",
                    help="registered base scenario name")
    ap.add_argument("--spec", default="", metavar="JSON",
                    help="load a serialized SweepSpec instead of a "
                         "registry scenario")
    ap.add_argument("--axis", action="append", default=[],
                    metavar="PATH=V1,V2,...",
                    help="sweep a dotted knob path over values "
                         "(repeatable; e.g. tenants.0.priority=1,2,4)")
    ap.add_argument("--seeds", type=int, default=1,
                    help="seeds 0..N-1 per axis combination (default 1)")
    ap.add_argument("--set", action="append", default=[], metavar="K=V",
                    help="scenario factory parameter (repeatable)")
    ap.add_argument("--impl", default="",
                    choices=["", "jnp", "jnp_ref", "pallas"],
                    help="WLBVT select kernel impl (default: auto)")
    ap.add_argument("--precision", default="exact",
                    choices=["exact", "fast"],
                    help="exact = f64 host-parity, fast = f32")
    ap.add_argument("--out", default="",
                    help="write the sweep summary JSON to this path")
    args = ap.parse_args(argv)

    from repro.api import SweepSpec
    from repro.launch.scenario import _parse_sets

    if args.spec:
        with open(args.spec) as f:
            sweep = SweepSpec.from_dict(json.load(f))
    elif args.scenario:
        sweep = build_sweep(args.scenario, _parse_sets(args.set),
                            [_parse_axis(a) for a in args.axis],
                            args.seeds)
    else:
        raise SystemExit("scenario name or --spec required")

    n = len(sweep)
    axes_desc = " x ".join(f"{ax.knob}[{len(ax.values)}]"
                           for ax in sweep.axes) or "1 combo"
    print(f"sweep {sweep.name}: {n} replica(s) = "
          f"{axes_desc} x {len(sweep.seeds)} seed(s)")
    rows, elapsed = run_sweep(sweep, impl=args.impl,
                              precision=args.precision)
    rate = n / elapsed if elapsed > 0 else float("inf")
    print(f"{n} scenario(s) in {elapsed:.3f}s = {rate:.1f} scenarios/sec "
          f"(includes compile)")
    doc = {"sweep": sweep.name, "replicas": n, "elapsed_s": elapsed,
           "scenarios_per_sec": rate, "impl": args.impl,
           "precision": args.precision, "rows": rows}
    if args.out:
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        print(f"wrote {args.out}")
    else:
        for row in rows[:8]:
            print(json.dumps(row, sort_keys=True))
        if len(rows) > 8:
            print(f"... {len(rows) - 8} more row(s) (use --out)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
