"""Trace CLI: run a scenario with the flight recorder on and export the
packet-lifecycle trace (DESIGN.md §10).

    PYTHONPATH=src python -m repro.launch.trace \
        --scenario qos_closed_loop --out trace.json
    PYTHONPATH=src python -m repro.launch.trace \
        --scenario fig9_congestor_victim --backend sim --console
    PYTHONPATH=src python -m repro.launch.trace \
        --scenario fig9_congestor_victim --out tail.json --last 1000

``--out`` writes Chrome/Perfetto ``trace_event`` JSON — open it in
ui.perfetto.dev (or chrome://tracing).  ``--last N`` exports only the
newest N retained span rows (ring tail); ``--console`` prints a
waterfall of the top-k slowest packets instead of / in addition to the
file.  Scenario parameters are overridable with ``--set key=value``
exactly as in ``repro.launch.scenario``.
"""
from __future__ import annotations

import argparse
import sys

from repro.launch.scenario import _parse_sets


def run_traced(name: str, backend: str, params, *, fast: bool = False,
               depth: int = 65536, decision_depth: int = 8192):
    """Run one registered scenario with tracing on.

    Returns ``(RunReport, TraceRecorder)`` — the recorder is flushed
    (open spans written with disposition OPEN) and committed.
    """
    from repro.api import get_scenario
    from repro.api.registry import scenario_params
    from repro.api.runtime import make_runtime
    accepted = scenario_params(name)
    unknown = set(params) - accepted
    if unknown:
        raise SystemExit(
            f"scenario {name!r} takes no parameter(s) "
            f"{', '.join(sorted(unknown))} (accepted: "
            f"{', '.join(sorted(accepted)) or 'none'})")
    spec = get_scenario(name, **params)
    if spec.analytic:
        raise SystemExit(f"scenario {name!r} is analytic — nothing to trace")
    if fast:
        kw = {"duration_us": min(spec.duration_us, 60.0)}
        if spec.horizon_us:
            kw["horizon_us"] = min(spec.horizon_us, 60.0)
        spec = spec.replace(**kw)
    if backend not in spec.backends:
        raise SystemExit(
            f"scenario {name!r} does not support backend {backend!r} "
            f"(supported: {', '.join(spec.backends)})")
    rt = make_runtime(spec, backend, trace=True, trace_depth=depth,
                      trace_decision_depth=decision_depth)
    rep = rt.run(spec)
    rt.flush_trace()
    return rep, rt.trace, spec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="run a scenario with the packet-lifecycle flight "
                    "recorder on and export a Perfetto trace")
    ap.add_argument("--scenario", required=True,
                    help="registered scenario name "
                         "(repro.launch.scenario --list)")
    ap.add_argument("--backend", default="sim", choices=["sim", "serve"])
    ap.add_argument("--set", action="append", default=[], metavar="K=V",
                    help="override a scenario parameter (repeatable)")
    ap.add_argument("--fast", action="store_true",
                    help="cap sim durations at 60us (CI smoke)")
    ap.add_argument("--out", default="",
                    help="write Perfetto trace_event JSON here")
    ap.add_argument("--last", type=int, default=0, metavar="N",
                    help="export only the newest N span rows (ring tail)")
    ap.add_argument("--console", action="store_true",
                    help="print a waterfall of the slowest packets")
    ap.add_argument("--top-k", type=int, default=10,
                    help="waterfall size for --console")
    ap.add_argument("--depth", type=int, default=65536,
                    help="span ring depth")
    ap.add_argument("--decision-depth", type=int, default=8192,
                    help="decision-provenance ring depth")
    args = ap.parse_args(argv)

    rep, tr, spec = run_traced(
        args.scenario, args.backend, _parse_sets(args.set),
        fast=args.fast, depth=args.depth,
        decision_depth=args.decision_depth)

    print(rep.summary())
    s = tr.trace_summary()
    print(f"trace: {s['spans_recorded']} spans recorded "
          f"({s['spans_retained']} retained, depth {s['span_depth']}), "
          f"{s['decisions_recorded']} decisions recorded "
          f"({s['decisions_retained']} retained)")
    if args.console:
        from repro.telemetry import console_waterfall
        print(console_waterfall(tr, top_k=args.top_k,
                                time_unit=rep.time_unit))
    if args.out:
        from repro.telemetry import write_perfetto
        names = {i: t.name for i, t in enumerate(spec.tenants)}
        doc = write_perfetto(tr, args.out, time_unit=rep.time_unit,
                             last=args.last or None, tenant_names=names)
        print(f"wrote {args.out} ({len(doc['traceEvents'])} events) — "
              f"open in ui.perfetto.dev")
    return 0


if __name__ == "__main__":
    sys.exit(main())
