"""Live terminal run dashboard over the metrics bus (DESIGN.md §11.4).

A plain-ANSI, dependency-free view of a running scenario: one row per
active tenant with interval rate, p99 sojourn, live scheduler weight,
admission state and burn-rate alert markers, plus the engine-global
Jain index.  Attach with ``--dash`` on ``repro.launch.scenario``.

The dashboard is a bus *sink* (synchronous ``on_frame``), but all
drawing goes through the pure ``render(frame) -> str`` so CI can smoke
one headless frame without a tty:

    PYTHONPATH=src python -m repro.launch.dash --headless
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, Optional

import numpy as np

from repro.telemetry.metrics import C_IDX

CLEAR = "\x1b[2J\x1b[H"
BOLD = "\x1b[1m"
RED = "\x1b[31m"
YELLOW = "\x1b[33m"
RESET = "\x1b[0m"

_HEADER = ("tenant", "rate/int", "p99", "weight", "admit", "burn",
           "alerts")
_WIDTHS = (12, 9, 12, 7, 6, 5, 7)


def _row(cells) -> str:
    return "  ".join(str(c).ljust(w) for c, w in zip(cells, _WIDTHS))


class Dashboard:
    """Bus sink rendering a refreshing status panel."""

    def __init__(self, *, names: Optional[Dict[int, str]] = None,
                 out=None, color: Optional[bool] = None,
                 refresh_every: int = 1):
        self.names = names or {}
        self.out = out if out is not None else sys.stdout
        isatty = bool(getattr(self.out, "isatty", lambda: False)())
        self.color = isatty if color is None else color
        self.refresh_every = max(1, int(refresh_every))
        self.frames = 0
        self._alert_totals: Dict[int, int] = {}
        self._alert_seen: Dict[int, str] = {}   # tenant -> last window kind

    # -- pure rendering ------------------------------------------------------
    def render(self, frame) -> str:
        """One full panel for ``frame`` (no escape codes unless color)."""
        sig = frame.signals
        active = np.nonzero(frame.counts.sum(axis=1) > 0)[0]
        lines = []
        c = (lambda code, s: f"{code}{s}{RESET}") if self.color \
            else (lambda code, s: s)
        lines.append(c(BOLD,
                       f"OSMOSIS live  backend={frame.backend}  "
                       f"t={frame.t:g} {frame.time_unit}  "
                       f"frame={frame.seq}"))
        lines.append(_row(_HEADER))
        for i in active:
            i = int(i)
            name = self.names.get(i, f"tenant{i}")
            rate = int(frame.interval_counts[i, C_IDX["completed"]])
            p99 = sig.p99[i]
            mark = self._alert_seen.get(i, "")
            burn = {"fast": "!F", "slow": "!S"}.get(mark, "-")
            row = _row((name[:_WIDTHS[0]], rate, f"{p99:g}",
                        f"{frame.weights[i]:.3g}",
                        "yes" if frame.admit[i] else "NO",
                        burn, self._alert_totals.get(i, 0)))
            if mark:
                row = c(RED, row)
            elif not frame.admit[i]:
                row = c(YELLOW, row)
            lines.append(row)
        lines.append(f"jain={sig.jain_weighted:.4f}  "
                     f"alerts_total={sum(self._alert_totals.values())}")
        for a in frame.alerts:
            lines.append(c(RED,
                           f"  ALERT {self.names.get(a.tenant, a.tenant)}: "
                           f"{a.window} burn={a.burn_rate:.3g} "
                           f"p99={a.p99:g} > target={a.target:g}"))
        return "\n".join(lines) + "\n"

    # -- bus sink ------------------------------------------------------------
    def on_frame(self, frame) -> None:
        for a in frame.alerts:
            self._alert_totals[a.tenant] = \
                self._alert_totals.get(a.tenant, 0) + 1
            self._alert_seen[a.tenant] = a.window
        self.frames += 1
        if self.frames % self.refresh_every:
            return
        text = self.render(frame)
        if self.color:
            self.out.write(CLEAR)
        self.out.write(text)
        self.out.flush()

    def close(self) -> None:
        pass


def demo_frame():
    """A small synthetic BusFrame for the headless CI smoke."""
    from repro.telemetry.bus import BusFrame
    from repro.telemetry.metrics import COUNTERS
    from repro.telemetry.signals import SignalFrame
    from repro.telemetry.slo_audit import SLOAlert
    T = 2
    counts = np.zeros((T, len(COUNTERS)), np.int64)
    counts[:, C_IDX["arrivals"]] = (40, 28)
    counts[:, C_IDX["completed"]] = (40, 9)
    z = np.zeros(T)
    sig = SignalFrame(p50=np.array([900.0, 5200.0]),
                      p99=np.array([1800.0, 9800.0]),
                      ecn_rate=z, drop_rate=z, service_debt=z,
                      kv_pressure=z, occupancy_mean=np.array([0.7, 0.2]),
                      queue_mean=np.array([1.0, 6.0]),
                      jain_weighted=0.8123,
                      lat_samples=np.array([40.0, 9.0]))
    alert = SLOAlert(t=4000.0, tenant=1, window="fast", burn_rate=10.0,
                     p99=9800.0, target=4000.0)
    return BusFrame(t=4000.0, seq=1, time_unit="ns", backend="sim",
                    signals=sig, counts=counts,
                    interval_counts=counts.copy(),
                    weights=np.array([1.0, 2.0]),
                    admit=np.array([True, True]), alerts=(alert,))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="headless dashboard smoke")
    ap.add_argument("--headless", action="store_true",
                    help="render one synthetic frame and exit")
    args = ap.parse_args(argv)
    if not args.headless:
        ap.error("interactive mode runs via repro.launch.scenario --dash; "
                 "use --headless here")
    dash = Dashboard(names={0: "aggressor", 1: "victim"}, color=False)
    dash.on_frame(demo_frame())
    return 0


if __name__ == "__main__":
    sys.exit(main())
