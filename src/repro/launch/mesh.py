"""Production mesh + ShapeDtypeStruct input specs for the dry-run.

``make_production_mesh`` is a *function* (not a module constant) so
importing this module never touches jax device state — the dry-run driver
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
jax import and only then builds the mesh.

Target: TPU v5e pods.  Single pod = 16x16 = 256 chips, mesh
(data=16, model=16).  Multi-pod = 2 pods = 512 chips, mesh
(pod=2, data=16, model=16); the ``pod`` axis carries extra DP by default
or pipeline stages (distributed/pipeline.py).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed import sharding as SH

# v5e hardware constants used by the roofline analysis (benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape: Tuple[int, ...] = (2, 4),
                   axes: Tuple[str, ...] = ("data", "model")) -> Mesh:
    """Small mesh over forced host devices (tests)."""
    return jax.make_mesh(shape, axes)


# ---------------------------------------------------------------------------
# input specs: ShapeDtypeStructs with shardings attached — no allocation
# ---------------------------------------------------------------------------
def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Stand-ins for every model input of the (arch, shape) cell.

    train:   {tokens, labels} (+frames / vis_embeds+vis_mask stubs)
    prefill: {tokens, lengths}
    decode:  {tokens (B,), lengths (B,)} — one new token against a KV cache
             of shape.seq_len (the cache itself comes from cache_specs()).
    """
    B, S = shape.global_batch, shape.seq_len
    bentry = SH.batch_axes(mesh, B)
    bspec = P(bentry)
    bspec2 = P(bentry, None)
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        out["tokens"] = _sds((B, S), jnp.int32, mesh, bspec2)
        out["labels"] = _sds((B, S), jnp.int32, mesh, bspec2)
        if cfg.is_encoder_decoder:
            out["frames"] = _sds((B, cfg.num_audio_frames, cfg.d_model),
                                 jnp.float32, mesh, P(bentry, None, None))
        elif cfg.frontend_stub:
            out["vis_embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16,
                                     mesh, P(bentry, None, None))
            out["vis_mask"] = _sds((B, S), jnp.bool_, mesh, bspec2)
    elif shape.kind == "prefill":
        out["tokens"] = _sds((B, S), jnp.int32, mesh, bspec2)
        out["lengths"] = _sds((B,), jnp.int32, mesh, bspec)
        if cfg.is_encoder_decoder:
            out["frames"] = _sds((B, cfg.num_audio_frames, cfg.d_model),
                                 jnp.float32, mesh, P(bentry, None, None))
    else:  # decode
        out["tokens"] = _sds((B,), jnp.int32, mesh, bspec)
        out["lengths"] = _sds((B,), jnp.int32, mesh, bspec)
    return out


def cache_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                model=None) -> Dict:
    """ShapeDtypeStructs for the KV cache of a decode cell."""
    import functools
    from repro.models.registry import build_model
    model = model or build_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    sds = jax.eval_shape(functools.partial(model.init_cache, B, S))
    shard_len = (B == 1)      # long_500k: batch=1 -> shard cache length
    specs = SH.cache_pspecs(cfg, sds, mesh, shard_length=shard_len)
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                          sharding=NamedSharding(mesh, s)),
        sds, specs, is_leaf=lambda x: hasattr(x, "shape"))
