"""Post-optimization HLO analyzer with while-loop trip-count multipliers.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so any
scan-over-layers / grad-accumulation program under-reports FLOPs, bytes
and collectives by the trip count.  This walker parses ``as_text()`` and
evaluates the entry computation recursively:

  * ``while``  -> body + cond cost x known_trip_count (backend_config)
  * ``fusion``/``call`` -> called computation (FLOPs/collectives); fusion
    HBM bytes = the fusion's own operands + result (interior values live
    in registers/VMEM — the fused proxy for HBM traffic)
  * ``dot``    -> 2 x result_elems x prod(contracting dims)
  * ``convolution`` -> 2 x result_elems x window x in_features / groups
  * elementwise/reduce -> 1 flop per element (matches HloCostAnalysis)
  * collectives -> operand bytes per base opcode, multiplied through

Returns per-device totals (the module is the post-SPMD partitioned one).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}
_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "select", "compare", "and", "or", "xor", "not", "convert", "floor",
    "ceil", "round-nearest-even", "sign", "cosine", "sine", "atan2",
    "logistic", "exponential-minus-one", "log-plus-one", "clamp",
    "remainder", "cbrt", "erf",
}
_FREE = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator",
}


def _shape_elems_bytes(s: str) -> Tuple[int, int]:
    elems = bytes_ = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    operands: List[str]
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    table: Dict[str, str]          # value name -> result type string


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")


def _split_type_opcode(rest: str) -> Tuple[str, str, int]:
    """rest = '<type> <opcode>(...' -> (type_str, opcode, paren_idx)."""
    rest = rest.strip()
    if rest.startswith("("):                      # tuple type
        depth = 0
        for i, ch in enumerate(rest):
            depth += (ch == "(") - (ch == ")")
            if depth == 0:
                break
        type_str = rest[:i + 1]
        tail = rest[i + 1:].lstrip()
    else:
        sp = rest.find(" ")
        type_str = rest[:sp]
        tail = rest[sp + 1:].lstrip()
    par = tail.find("(")
    opcode = tail[:par].strip()
    return type_str, opcode, len(rest) - len(tail) + par


def _operand_names(rest: str, paren_idx: int) -> Tuple[List[str], str]:
    depth, end = 0, paren_idx
    for i in range(paren_idx, len(rest)):
        depth += (rest[i] == "(") - (rest[i] == ")")
        if depth == 0:
            end = i
            break
    inside = rest[paren_idx + 1:end]
    names = re.findall(r"%([\w\.\-]+)", inside)
    return names, rest[end + 1:]


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        m = _COMP_HDR.match(line)
        if m:
            cur = Computation(m.group(1), [], {})
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            # register parameters from the header signature
            hdr = line[line.find("(") + 1:]
            for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^)]*\))|(?:[a-z]\w*\[[\d,]*\]))", hdr):
                cur.table[pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        mi = _INSTR.match(line)
        if not mi:
            continue
        name, rest = mi.group(1), mi.group(2)
        try:
            type_str, opcode, par = _split_type_opcode(rest)
            operands, attrs = _operand_names(rest, par)
        except Exception:      # pragma: no cover — defensive
            continue
        cur.table[name] = type_str
        cur.instrs.append(Instr(name, opcode, type_str, operands, attrs))
    return comps, entry


def _dot_flops(ins: Instr, table: Dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(ins.result_type)
    lhs_type = table.get(ins.operands[0], "") if ins.operands else ""
    mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    if not mm or not lhs_type:
        return 0.0
    dims = [int(x) for x in mm.group(1).split(",") if x]
    sm = _SHAPE_RE.search(lhs_type)
    if not sm:
        return 0.0
    lhs_dims = [int(x) for x in sm.group(2).split(",") if x]
    k = 1
    for d in dims:
        if d < len(lhs_dims):
            k *= lhs_dims[d]
    return 2.0 * out_elems * k


def _conv_flops(ins: Instr, table: Dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(ins.result_type)
    wm = re.search(r"window=\{[^}]*size=([\dx]+)", ins.rest)
    win = 1
    if wm:
        for x in wm.group(1).split("x"):
            win *= int(x)
    groups = 1
    gm = re.search(r"feature_group_count=(\d+)", ins.rest)
    if gm:
        groups = int(gm.group(1))
    in_feat = 1
    if len(ins.operands) >= 1:
        lhs_type = table.get(ins.operands[0], "")
        dl = re.search(r"dim_labels=(\w+)_", ins.rest)
        sm = _SHAPE_RE.search(lhs_type)
        if dl and sm:
            labels = dl.group(1)
            dims = [int(x) for x in sm.group(2).split(",") if x]
            if "f" in labels and labels.index("f") < len(dims):
                in_feat = dims[labels.index("f")]
    return 2.0 * out_elems * win * in_feat / max(groups, 1)


def _trip_count(rest: str) -> int:
    m = re.search(r'known_trip_count[^\d]*(\d+)', rest)
    return int(m.group(1)) if m else 1


# ops that move/relabel data without materializing new HBM traffic once
# fused on the target (convert pairs are CPU float-normalization artifacts
# — TPU is bf16-native; real reads are counted at the consuming compute op)
_MOVEMENT = {
    "parameter", "constant", "convert", "bitcast", "reshape", "transpose",
    "copy", "broadcast", "tuple", "get-tuple-element", "iota",
    "dynamic-slice", "slice",
}


def _dus_update_bytes(comp: Optional[Computation]) -> Optional[int]:
    """If the computation is an in-place buffer update — one
    dynamic-update-slice / scatter surrounded only by data-movement ops
    (the CPU float-normalization wraps the DUS in convert pairs) —
    return the update operand's bytes."""
    if comp is None or not comp.instrs:
        return None
    upd = None
    for ins in comp.instrs:
        if ins.opcode == "dynamic-update-slice" and len(ins.operands) >= 2:
            if upd is not None:
                return None          # more than one update: bail out
            upd = _shape_elems_bytes(comp.table.get(ins.operands[1], ""))[1]
        elif ins.opcode == "scatter" and len(ins.operands) >= 3:
            if upd is not None:
                return None
            upd = _shape_elems_bytes(comp.table.get(ins.operands[2], ""))[1]
        elif ins.opcode not in _MOVEMENT:
            return None
    return upd


def _movement_only(comp: Optional[Computation]) -> bool:
    if comp is None:
        return False
    return all(i.opcode in _MOVEMENT for i in comp.instrs)


ZERO = {"flops": 0.0, "bytes": 0.0,
        **{c: 0.0 for c in _COLLECTIVES}, "collective_bytes": 0.0,
        **{c + "_count": 0.0 for c in _COLLECTIVES}}


def _add(a: dict, b: dict, scale: float = 1.0) -> None:
    for k, v in b.items():
        a[k] = a.get(k, 0.0) + v * scale


def analyze(text: str) -> Dict[str, float]:
    comps, entry = parse_module(text)
    memo: Dict[str, dict] = {}

    def ev(cname: str) -> dict:
        if cname in memo:
            return memo[cname]
        total = dict(ZERO)
        comp = comps.get(cname)
        if comp is None:
            memo[cname] = total
            return total
        memo[cname] = total          # guard against cycles
        for ins in comp.instrs:
            op = ins.opcode
            _, out_bytes = _shape_elems_bytes(ins.result_type)
            opd_bytes = sum(_shape_elems_bytes(comp.table.get(o, ""))[1]
                            for o in ins.operands)
            if op == "while":
                bm = re.search(r"body=%([\w\.\-]+)", ins.rest)
                cm = re.search(r"condition=%([\w\.\-]+)", ins.rest)
                trip = _trip_count(ins.rest)
                if bm:
                    _add(total, ev(bm.group(1)), trip)
                if cm:
                    _add(total, ev(cm.group(1)), trip)
                continue
            if op == "fusion":
                fm = re.search(r"calls=%([\w\.\-]+)", ins.rest)
                if fm:
                    called = comps.get(fm.group(1))
                    inner = ev(fm.group(1))
                    # flops + collectives from the interior; HBM bytes
                    # only from the fusion boundary
                    _add(total, {k: v for k, v in inner.items()
                                 if k != "bytes"})
                    # in-place update fusions (root = DUS/scatter on a
                    # donated buffer): traffic = the written slice only
                    upd = _dus_update_bytes(called)
                    if upd is not None and ins.operands:
                        big = max(_shape_elems_bytes(
                            comp.table.get(o, ""))[1]
                            for o in ins.operands)
                        if big == out_bytes:
                            total["bytes"] += max(
                                opd_bytes - big, 0) + 2 * upd
                            continue
                    # pure data-movement fusions (convert/bitcast/slice
                    # chains): CPU float-normalization artifacts; the real
                    # read is counted at the consuming compute op
                    if _movement_only(called):
                        continue
                total["bytes"] += out_bytes + opd_bytes
                continue
            if op in ("dynamic-update-slice", "scatter"):
                # in-place: read+write the update region only
                ui = 1 if op == "dynamic-update-slice" else 2
                upd = (_shape_elems_bytes(comp.table.get(
                    ins.operands[ui], ""))[1]
                    if len(ins.operands) > ui else 0)
                total["bytes"] += 2 * upd
                continue
            if op in ("call", "async-start"):
                fm = re.search(r"(?:to_apply|calls|called_computation)=%([\w\.\-]+)",
                               ins.rest)
                if fm:
                    _add(total, ev(fm.group(1)))
                continue
            if op == "conditional":
                branches = re.findall(
                    r"(?:branch_computations=\{([^}]*)\}|"
                    r"(?:true|false)_computation=%([\w\.\-]+))", ins.rest)
                names: List[str] = []
                for grp, single in branches:
                    if grp:
                        names += re.findall(r"%([\w\.\-]+)", grp)
                    if single:
                        names.append(single)
                if names:   # conservatively take the max-cost branch
                    best = max((ev(n) for n in names),
                               key=lambda d: d["flops"] + d["bytes"])
                    _add(total, best)
                continue
            base = next((c for c in _COLLECTIVES
                         if op == c or op == c + "-start"), None)
            if base is not None:
                total[base] += opd_bytes
                total[base + "_count"] += 1
                total["collective_bytes"] += opd_bytes
                total["bytes"] += out_bytes + opd_bytes
                continue
            if op in _FREE or op.endswith("-done"):
                continue
            # generic instruction: memory proxy
            total["bytes"] += out_bytes + opd_bytes
            if op == "dot":
                total["flops"] += _dot_flops(ins, comp.table)
            elif op == "convolution":
                total["flops"] += _conv_flops(ins, comp.table)
            elif op in _ELEMENTWISE:
                oe, _ = _shape_elems_bytes(ins.result_type)
                total["flops"] += oe
            elif op in ("reduce", "reduce-window"):
                ie = sum(_shape_elems_bytes(comp.table.get(o, ""))[0]
                         for o in ins.operands[: len(ins.operands) // 2])
                total["flops"] += ie
        memo[cname] = total
        return total

    if entry is None:      # pragma: no cover
        return dict(ZERO)
    return dict(ev(entry))
