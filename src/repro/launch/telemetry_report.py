"""Per-tenant telemetry report driver (DESIGN.md §6).

Runs a congestor-vs-victim scenario on either execution surface, with or
without the closed-loop QoS controller, and dumps the telemetry plane as
a console table + JSON:

    PYTHONPATH=src python -m repro.launch.telemetry_report \
        --surface sim --controller --json /tmp/telemetry.json

``--surface serving`` drives the scheduling-only serving engine
(NullExecutor) so the report renders without a model; latency units are
engine steps there, nanoseconds on the simulator.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def _sim_report(args) -> dict:
    from repro.sim.scenarios import run_qos_closed_loop
    from repro.telemetry import compute_signals, tenant_report
    res = run_qos_closed_loop(args.controller,
                              duration_us=args.duration_us, seed=args.seed)
    sim_tel = res.telemetry
    ss = res.sched_state
    sig = compute_signals(sim_tel, prio=ss["prio"],
                          total_occup=ss["total_occup"], bvt=ss["bvt"],
                          kv_pressure=ss["kv_pressure"])
    rep = tenant_report(sim_tel, names={0: "congestor", 1: "victim"},
                        signals=sig)
    rep["surface"] = "sim"
    rep["jain_pu_timeavg"] = res.jain_pu_timeavg
    rep["latency_unit"] = "ns"
    return rep


def _serving_report(args) -> dict:
    from repro.core.slo import SLOPolicy
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.request import Request
    from repro.telemetry import QoSController
    ecfg = EngineConfig(max_slots=8, max_len=256, prefill_chunk=32,
                        max_tenants=4, kv_overcommit=2.0,
                        qos_interval=16 if args.controller else 0)
    eng = Engine(ecfg)
    eng.create_ectx(0, SLOPolicy(kv_quota_tokens=256 * 8), name="congestor")
    eng.create_ectx(1, SLOPolicy(kv_quota_tokens=256 * 8), name="victim")
    if args.controller:
        eng.attach_controller(QoSController(
            base_weights=np.ones(ecfg.max_tenants),
            p99_targets=[0.0, 40.0] + [0.0] * (ecfg.max_tenants - 2)))
    rng = np.random.RandomState(args.seed)
    for i in range(48):
        t = i % 2
        plen = 160 if t == 0 else 16
        new = 48 if t == 0 else 8
        eng.submit(Request(t, rng.randint(1, 90, plen).astype(np.int32),
                           max_new_tokens=new))
    eng.run_until_idle()
    rep = eng.telemetry_report()
    rep["surface"] = "serving"
    rep["jain_timeavg"] = eng.metrics()["jain_timeavg"]
    rep["latency_unit"] = "steps"
    return rep


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--surface", default="sim", choices=["sim", "serving"])
    ap.add_argument("--controller", action="store_true",
                    help="enable the closed-loop QoS controller")
    ap.add_argument("--duration-us", type=float, default=150.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="",
                    help="also dump the report to this path")
    args = ap.parse_args(argv)

    from repro.telemetry import dump_json, format_console
    rep = (_sim_report(args) if args.surface == "sim"
           else _serving_report(args))
    print(f"surface={rep['surface']}  controller={args.controller}  "
          f"latency_unit={rep['latency_unit']}")
    print(format_console(rep, time_unit=rep["latency_unit"]))
    if args.json:
        dump_json(rep, args.json, overwrite=True)  # explicit CLI target
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
