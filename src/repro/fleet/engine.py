"""Fleet engine: N per-NIC simulators co-stepped over the modeled
switch fabric (DESIGN.md §12).

Layering (bottom up):

  * N ``SimRuntime``s — each runs the FleetSpec's single-NIC twin
    (``spec.plain()``) on either sim datapath, with its own AIMD
    controller / SLO audit, publishing per-window ``BusFrame``s tagged
    ``nic=nic<k>`` onto one shared MetricsBus;
  * one ``CrossbarSwitch`` — tenant ``i`` enters the fabric at input
    port ``i % N`` and exits at ``placement[i]``, picking up VOQ wait
    + serialization + propagation delay;
  * the epoch loop — inject the trace slice for ``[t, t+epoch_ns)``
    into the fabric, advance it, feed its deliveries to the
    destination engines, step every engine to the epoch edge;
  * the ``GlobalQoS`` tier — every ``interval_epochs`` epochs it reads
    the freshest frame per NIC and either rescales scheduler base
    weights or live-migrates a tenant (drain source FMQ, bump
    placement, replay the drained packets through the fabric after
    ``migration_delay_ns``, bracketed by MIGRATE_START/MIGRATE_DONE
    EQ events).

The N=1 ideal-fabric configuration short-circuits the epoch loop and
replays ``SimRuntime.run``'s exact single-shot sequence, so its
per-NIC report is bit-identical to ``run_scenario(spec.plain())``
(pinned in tests/test_fleet.py).  All fleet-tier decisions read only
drift-free signals, keeping the aggregated report byte-identical
across the event and batched datapaths.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.api.report import (TIME_UNITS, RunReport, TenantReport, _jsonify)
from repro.api.runtime import (SimRuntime, _build_audit, _events_block,
                               build_traces)
from repro.core.accounting import jain_fairness
from repro.core.events import Event, EventKind
from repro.fleet.qos import GlobalQoS
from repro.fleet.spec import FleetSpec
from repro.fleet.switch import CrossbarSwitch

NS_UNIT = TIME_UNITS[0]

# contract of RunReport.extras["fleet"]; report.validate() checks these
FLEET_EXTRAS_KEYS = (
    "num_nics", "epoch_ns", "epochs", "placement_initial",
    "placement_final", "switch", "migrations", "migrations_total",
    "jain_fleet", "sojourn_p99", "global_qos", "per_nic")

_GQ_SUB_DEPTH = 8192   # frames buffered between global-QoS ticks


def _nic_label(k: int) -> str:
    return f"nic{k}"


class FleetEngine:
    """One fleet run: construction wires the engines/fabric/control
    tier; ``run()`` drives the co-simulation and returns the
    aggregated ``RunReport``."""

    def __init__(self, spec: FleetSpec, *, bus=None,
                 track_switch_ids: bool = False):
        if not isinstance(spec, FleetSpec):
            raise TypeError("FleetEngine needs a FleetSpec")
        if spec.num_nics < 1:
            raise ValueError("num_nics must be >= 1")
        if spec.epoch_ns <= 0:
            raise ValueError("epoch_ns must be > 0")
        self.spec = spec
        self.plain = spec.plain()
        N, T = spec.num_nics, len(spec.tenants)
        self.N, self.T = N, T
        self.placement = list(spec.initial_placement())
        if len(self.placement) != T or any(
                not 0 <= p < N for p in self.placement):
            raise ValueError(f"placement must map {T} tenants to [0,{N})")
        gq = spec.global_qos
        if gq is not None and gq.rebalance and spec.controller is None:
            raise ValueError("global_qos.rebalance needs a per-NIC "
                             "ControllerSpec to re-apply base weights")

        self._own_bus = False
        if bus is None and gq is not None:
            from repro.telemetry.bus import MetricsBus
            bus = MetricsBus()
            self._own_bus = True
        self.bus = bus

        self.tracer = None
        if spec.trace_fleet:
            from repro.telemetry.trace import TraceRecorder
            self.tracer = TraceRecorder(max(T, 1))
        self.switch = CrossbarSwitch(
            N, num_tenants=T, link_gbps=spec.link_gbps,
            prop_delay_ns=spec.prop_delay_ns, voq_depth=spec.voq_depth,
            arbiter=spec.switch_arbiter, quantum_bytes=spec.quantum_bytes,
            tracer=self.tracer, track_ids=track_switch_ids)

        # per-NIC engines: every NIC hosts the full dense tenant table
        # (sim tenant ids are dense; a tenant placed elsewhere simply
        # never receives traffic here until a migration lands it)
        self.runtimes: List[SimRuntime] = []
        self._base0: List[Tuple[np.ndarray, ...]] = []
        for k in range(N):
            rt = SimRuntime.from_spec(self.plain)
            for i, t in enumerate(self.plain.tenants):
                rt.create_tenant(i, t.slo(), name=t.name,
                                 workload=t.workload.build())
            if self.plain.controller is not None:
                from repro.telemetry import QoSController
                rt.attach_controller(QoSController(
                    base_weights=np.ones(T),
                    p99_targets=self.plain.controller.p99_targets(
                        self.plain.tenants, "sim", T)))
            audit = _build_audit(self.plain, "sim", T, NS_UNIT)
            if audit is not None:
                rt.attach_slo_audit(audit)
            if self.bus is not None:
                rt.attach_bus(self.bus)
            sim = rt._seal()
            sim.obs_nic = _nic_label(k)
            self.runtimes.append(rt)
            self._base0.append(tuple(a.copy() for a in sim._sched_base))

        self.gq: Optional[GlobalQoS] = None
        self._gq_sub = None
        if gq is not None:
            targets = np.zeros(T)
            for i, t in enumerate(spec.tenants):
                targets[i] = float(getattr(t, "p99_target", 0.0) or 0.0)
            self.gq = GlobalQoS(gq, num_tenants=T, num_nics=N,
                                p99_targets=targets)
            self._gq_sub = self.bus.subscribe(maxlen=_GQ_SUB_DEPTH,
                                              name="fleet-global-qos")

        self.fleet_events: List[Event] = []
        self.migrations: List[Dict] = []
        self.epochs = 0
        self._mig_uid = 1 << 40   # synthetic span uids, clear of packets
        self._single_shot = (N == 1 and self.switch.passthrough
                             and gq is None)

    # ------------------------------------------------------------ run

    def run(self) -> RunReport:
        horizon = (self.spec.horizon_us * 1e3
                   if self.spec.horizon_us else None)
        if self._single_shot:
            self._run_single_shot(horizon)
        else:
            self._run_epochs(horizon)
        if self._own_bus:
            self.bus.close()
        return self._report()

    def _run_single_shot(self, horizon: Optional[float]) -> None:
        """The exact ``SimRuntime.run`` sequence (one inject, one run),
        so the per-NIC report is bit-identical to ``run_scenario`` on
        the plain twin.  The ideal fabric reduces to counters."""
        rt = self.runtimes[0]
        ta = build_traces(self.spec, arrays=True)
        tens = np.asarray(ta.tenants, np.int64)
        self.switch.bulk_passthrough(tens, tens % self.N,
                                     np.zeros(len(tens), np.int64))
        work = ta if self.plain.datapath == "batched" else ta.to_packets()
        rt.inject(work)
        rt.run_until(horizon)

    def _run_epochs(self, horizon: Optional[float]) -> None:
        spec, sw = self.spec, self.switch
        ta = build_traces(spec, arrays=True)
        times = np.asarray(ta.times, np.float64)
        tens = np.asarray(ta.tenants, np.int64)
        sizes = np.asarray(ta.sizes, np.int64)
        n_pkts = len(times)
        cursor = 0
        t_cur = 0.0
        E = float(spec.epoch_ns)
        # with the control plane off nothing observes epoch boundaries —
        # traffic is open-loop (trace -> switch -> engines, no feedback),
        # and deliveries carry exact timestamps — so one big slice over
        # the remaining trace replaces the epoch loop; epoch-sized steps
        # remain only to drain the fabric tail without inflating
        # ``switch.now`` (and the utilization denominator) past the run
        one_slice = self.gq is None
        latest: Dict[int, object] = {}
        while True:
            t_next = t_cur + E
            if one_slice and cursor < n_pkts:
                t_next = max(t_next, float(times[n_pkts - 1]))
            if horizon is not None:
                t_next = min(t_next, horizon)
            hi = int(np.searchsorted(times, t_next, side="right"))
            if one_slice and hi > cursor:
                # placement is immutable without the control plane, so
                # the whole slice maps to ports in one array pass
                plc = np.asarray(self.placement, np.int64)
                ts = tens[cursor:hi]
                sw.inject_bulk(times[cursor:hi], ts % self.N, plc[ts],
                               ts, sizes[cursor:hi])
            else:
                for j in range(cursor, hi):
                    i = int(tens[j])
                    sw.inject(float(times[j]), i % self.N,
                              self.placement[i], i, int(sizes[j]))
            cursor = hi
            self._deliver(sw.advance(t_next))
            for rt in self.runtimes:
                rt.run_until(t_next)
            self.epochs += 1
            t_cur = t_next
            if self.gq is not None and \
                    self.epochs % self.gq.cfg.interval_epochs == 0:
                self._gq_tick(t_cur, latest)
            if horizon is not None and t_cur >= horizon:
                break
            if cursor >= n_pkts and sw.idle:
                break
        # drain whatever is still queued inside the engines (or replay
        # the final no-op slice when a horizon cut the run)
        for rt in self.runtimes:
            rt.run_until(horizon)

    def _deliver(self, deliveries) -> None:
        if not deliveries:
            return
        from repro.sim.traffic import TraceArrays
        per: List[List[tuple]] = [[] for _ in range(self.N)]
        for (t, tenant, size, dst) in deliveries:
            per[dst].append((t, tenant, size))
        for k, rows in enumerate(per):
            if not rows:
                continue
            bundle = TraceArrays(
                times=np.array([r[0] for r in rows], np.float64),
                tenants=np.array([r[1] for r in rows], np.int64),
                sizes=np.array([r[2] for r in rows], np.int64))
            rt = self.runtimes[k]
            if self.plain.datapath == "batched":
                rt.inject(bundle)
            else:
                rt.inject(bundle.to_packets())

    # ------------------------------------------------- global QoS tier

    def _gq_tick(self, t: float, latest: Dict[int, object]) -> None:
        for f in self._gq_sub.drain():
            if f.nic.startswith("nic"):
                latest[int(f.nic[3:])] = f
        plans, boost_changed = self.gq.tick(self.epochs, latest,
                                            self.placement)
        if boost_changed:
            self._apply_gboost()
        for (tenant, src, dst) in plans:
            self._migrate(tenant, src, dst, t)

    def _apply_gboost(self) -> None:
        """Fold the fleet boost into every NIC's scheduler *base* rows;
        each NIC's AIMD controller re-applies live = base x local boost
        at its next qos tick (global floor, local trim)."""
        g = self.gq.gboost
        for k, rt in enumerate(self.runtimes):
            for arr, orig in zip(rt._sim._sched_base, self._base0[k]):
                arr[:] = orig * g

    def _migrate(self, tenant: int, src: int, dst: int, t: float) -> None:
        drained = self.runtimes[src]._sim.drain_tenant_queue(tenant)
        self.placement[tenant] = dst
        detail = f"{_nic_label(src)}->{_nic_label(dst)}"
        self.fleet_events.append(Event(
            tenant, EventKind.MIGRATE_START, t,
            detail=f"{detail} drained={len(drained)}"))
        # handoff = fixed drain/replay cost + serialized state transfer:
        # the drained queue's bytes cross the migration link (1 Gbps =
        # 1 bit/ns).  migration_gbps == 0 keeps the legacy fixed delay.
        delay = float(self.spec.migration_delay_ns)
        if self.spec.migration_gbps > 0:
            drained_bytes = sum(int(size) for (_a, size) in drained)
            delay += drained_bytes * 8.0 / float(self.spec.migration_gbps)
        t_rep = t + delay
        for (_arrival, size) in drained:
            self.switch.inject(t_rep, src, dst, tenant, int(size),
                               replay=True)
        self.fleet_events.append(Event(
            tenant, EventKind.MIGRATE_DONE, t_rep,
            detail=f"{detail} replayed={len(drained)}"))
        self.migrations.append({
            "t": float(t), "tenant": int(tenant), "src": int(src),
            "dst": int(dst), "packets": len(drained),
            "done_t": float(t_rep)})
        if self.tracer is not None:
            from repro.telemetry import trace as TR
            uid = self._mig_uid
            self._mig_uid += 1
            self.tracer.span(TR.ST_SWITCH, uid, tenant, t, t_rep,
                             disp=TR.D_OK)
            self.tracer.decision(t, TR.K_FLEET_MIGRATE, tenant,
                                 TR.R_MIGRATION, n_elig=len(drained))

    # --------------------------------------------------------- report

    def _report(self) -> RunReport:
        spec = self.spec
        reps = [rt.report(self.plain) for rt in self.runtimes]
        tenants: Dict[int, TenantReport] = {}
        served = np.zeros(self.T)
        for i, tn in enumerate(spec.tenants):
            rows = [r.tenants[i] for r in reps]
            home = rows[self.placement[i]]
            for r in rows:
                served[i] += float(r.extra.get("served_payload_bytes", 0.0))
            extra = dict(home.extra)
            extra["nic"] = _nic_label(self.placement[i])
            extra["per_nic_completed"] = [int(r.completed) for r in rows]
            extra["switch_drops"] = int(self.switch.dropped[i])
            tenants[i] = TenantReport(
                tenant_id=i, name=tn.name,
                arrivals=sum(r.arrivals for r in rows),
                completed=sum(r.completed for r in rows),
                killed=sum(r.killed for r in rows),
                drops=sum(r.drops for r in rows),
                rejected=sum(r.rejected for r in rows),
                ecn_marks=sum(r.ecn_marks for r in rows),
                bytes_in=float(sum(r.bytes_in for r in rows)),
                bytes_out=float(sum(r.bytes_out for r in rows)),
                throughput=float(sum(r.throughput for r in rows)),
                p50_latency=float(home.p50_latency),
                p99_latency=float(home.p99_latency),
                latency_samples=sum(r.latency_samples for r in rows),
                extra=_jsonify(extra))

        events: List[Event] = []
        for rt in self.runtimes:
            events.extend(rt._events)
        events.extend(self.fleet_events)
        events.extend(self.switch.events)
        events.sort(key=lambda e: (e.time, e.tenant, e.kind.value))

        prio = np.array([t.priority for t in spec.tenants], np.float64)
        x = served / np.maximum(prio, 1e-12)
        jain_fleet = jain_fairness(x[x > 0]) if (x > 0).any() else 1.0

        # per-tenant arrival->completion p99 on the tenant's *final* NIC
        # (the kernel-time percentile in TenantReport.p99_latency excludes
        # FMQ wait, so migrations are invisible to it; this is the signal
        # the global QoS tier actually acts on)
        from repro.telemetry.metrics import hist_quantile
        nic_p99 = [hist_quantile(rt._sim.tel.snapshot()["hist"], 0.99, np)
                   for rt in self.runtimes]
        sojourn_p99 = [float(nic_p99[self.placement[i]][i])
                       for i in range(self.T)]

        extras: dict = {}
        if self.tracer is not None:
            self.tracer.commit()
            extras["trace_summary"] = self.tracer.trace_summary()
        ev_block = _events_block(events, extras)
        extras["fleet"] = {
            "num_nics": self.N,
            "epoch_ns": float(spec.epoch_ns),
            "epochs": int(self.epochs),
            "placement_initial": list(spec.initial_placement()),
            "placement_final": list(self.placement),
            "switch": self.switch.stats(),
            "migrations": self.migrations,
            "migrations_total": len(self.migrations),
            "jain_fleet": float(jain_fleet),
            "sojourn_p99": sojourn_p99,
            "global_qos": (self.gq.summary() if self.gq is not None
                           else None),
            "per_nic": [r.to_dict() for r in reps],
        }
        duration = max([self.switch.now] + [r.duration for r in reps])
        jain_pu = float(np.clip(np.mean([r.jain_pu for r in reps]), 0, 1))
        jain_io = float(np.clip(np.mean([r.jain_io for r in reps]), 0, 1))
        return RunReport(
            scenario=spec.name, backend="sim", time_unit=NS_UNIT,
            duration=float(duration), scheduler=self.plain.scheduler,
            arbiter=self.plain.arbiter, seed=int(spec.seed),
            jain_pu=jain_pu, jain_io=jain_io,
            tenants=tenants, events=ev_block, telemetry=None,
            spec=_jsonify(spec.to_dict()), extras=_jsonify(extras))


def run_fleet(spec: FleetSpec, backend: str = "sim", *, bus=None,
              validate: bool = True,
              track_switch_ids: bool = False) -> RunReport:
    """Run a fleet scenario end to end and return the aggregated
    report (per-NIC reports ride in ``extras['fleet']['per_nic']``)."""
    if backend != "sim":
        raise ValueError(f"fleet scenarios run on the sim backend "
                         f"(got {backend!r})")
    eng = FleetEngine(spec, bus=bus, track_switch_ids=track_switch_ids)
    rep = eng.run()
    if validate:
        rep.validate()
    return rep


def fleet_metric_rows(fleet_block: Dict, backend: str = "sim"
                      ) -> List[Tuple[str, Dict[str, str], float]]:
    """Project a report's fleet block onto the fleet MetricSpecs
    (telemetry/export.py) as explicit ``(name, labels, value)`` rows —
    the OpenMetrics writer merges them into its rendered exposition."""
    rows: List[Tuple[str, Dict[str, str], float]] = []
    sw = fleet_block["switch"]
    migr_in = [0] * int(fleet_block["num_nics"])
    for m in fleet_block["migrations"]:
        migr_in[int(m["dst"])] += 1
    for k in range(int(fleet_block["num_nics"])):
        lab = {"backend": backend, "nic": _nic_label(k)}
        rows.append(("osmosis_switch_voq_depth_count", lab,
                     float(sw["voq_peak_out"][k])))
        rows.append(("osmosis_link_utilization_ratio", lab,
                     float(sw["link_utilization"][k])))
        rows.append(("osmosis_migrations_total", lab, float(migr_in[k])))
    return rows
