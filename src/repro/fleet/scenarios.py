"""Fleet-plane scenario catalog: multi-NIC runs over the modeled
VOQ/crossbar fabric (DESIGN.md §12).

These are the *fabric* family — N engines exchanging traffic through
``CrossbarSwitch`` — as opposed to ``fleet_sweep`` in the base catalog,
which packs many tenants onto ONE simulated NIC.  Imported from
``repro.api.scenarios`` so the registry sees both families.

    PYTHONPATH=src python -m repro.launch.scenario fleet_fabric
"""
from __future__ import annotations

from repro.api.registry import register_scenario
from repro.api.spec import (ArrivalSpec, ControllerSpec, TenantSpec,
                            WorkloadSpec)
from repro.fleet.spec import FleetSpec, GlobalQoSSpec


def _spin(name: str, cpb: float, base: float = 40.0) -> WorkloadSpec:
    return WorkloadSpec(name=name, compute_base=base, compute_per_byte=cpb)


@register_scenario("fleet_fabric")
def fleet_fabric(*, num_nics: int = 4, duration_us: float = 120.0,
                 pkt_size: int = 1024, link_gbps: float = 400.0,
                 switch_arbiter: str = "mdrr", datapath: str = "event",
                 seed: int = 0) -> FleetSpec:
    """All-to-all fleet baseline: ``num_nics`` NICs, two tenants homed
    per NIC — one serving local traffic (ingress port == home NIC), one
    whose flow crosses the crossbar to the next NIC over.  Every link
    carries cross-traffic, no output saturates: the steady-state
    sanity scenario for VOQ occupancy, MDRR grants and per-link
    serialization accounting."""
    n = num_nics
    tenants = []
    placement = []
    for k in range(n):
        tenants.append(TenantSpec(
            f"local{k}", workload=_spin(f"local{k}", 1.0),
            arrival=ArrivalSpec(size=pkt_size, share=0.12, seed_offset=k)))
        placement.append(k)                   # ingress k -> home k
    for k in range(n):
        tenants.append(TenantSpec(
            f"cross{k}", workload=_spin(f"cross{k}", 1.0),
            arrival=ArrivalSpec(size=pkt_size // 2, share=0.10,
                                seed_offset=n + k)))
        placement.append((k + 1) % n)         # ingress k -> home k+1
    return FleetSpec(
        name="fleet_fabric",
        description=f"{n}-NIC fabric baseline: local + cross flows on "
                    "every link (DESIGN.md §12)",
        tenants=tuple(tenants), placement=tuple(placement),
        num_nics=n, link_gbps=link_gbps, switch_arbiter=switch_arbiter,
        datapath=datapath, duration_us=duration_us, seed=seed)


@register_scenario("fleet_incast")
def fleet_incast(*, num_nics: int = 16, duration_us: float = 80.0,
                 pkt_size: int = 1024, sender_share: float = 0.09,
                 quiet_share: float = 0.03, voq_depth: int = 512,
                 datapath: str = "event", seed: int = 0) -> FleetSpec:
    """The VOQ/HoL-blocking pin (ISSUE acceptance): ``num_nics - 1``
    senders, one per ingress port, all homed on NIC 0 — the classic
    incast that oversubscribes output link 0 (~1.35x at defaults).
    Tenant ``num_nics - 1`` stays on its own NIC, so its (N-1, N-1)
    fabric pair shares *nothing* with the hot output.  With per-output
    VOQs its latency stays at serialization + propagation while link 0
    saturates; a single shared input FIFO would have stalled it behind
    the incast (tests/test_fleet.py pins the separation)."""
    n = num_nics
    tenants = []
    for k in range(n - 1):
        tenants.append(TenantSpec(
            f"incast{k}", workload=_spin(f"incast{k}", 0.5),
            arrival=ArrivalSpec(size=pkt_size, share=sender_share,
                                seed_offset=k)))
    tenants.append(TenantSpec(
        "quiet", workload=_spin("quiet", 0.5),
        arrival=ArrivalSpec(size=pkt_size // 2, share=quiet_share,
                            seed_offset=n - 1)))
    placement = tuple([0] * (n - 1) + [n - 1])
    return FleetSpec(
        name="fleet_incast",
        description=f"{n}-NIC incast onto output 0; VOQ keeps the "
                    "quiet pair's latency flat (DESIGN.md §12.2)",
        tenants=tuple(tenants), placement=placement,
        num_nics=n, voq_depth=voq_depth, switch_arbiter="rr",
        datapath=datapath, duration_us=duration_us, seed=seed)


@register_scenario("fleet_migrate")
def fleet_migrate(*, duration_us: float = 240.0, epoch_ns: float = 8000.0,
                  p99_target_ns: float = 1000.0, migrate: bool = True,
                  rebalance: bool = True, datapath: str = "event",
                  seed: int = 0) -> FleetSpec:
    """The live-migration pin (ISSUE acceptance): NIC 0 hosts two heavy
    congestors plus a latency-SLO victim; NIC 1 hosts one light tenant.
    Each NIC runs its own AIMD controller, and the global QoS tier
    watches the per-NIC frames: the victim's p99 blows through target
    on NIC 0, so the tier drains its FMQ, replays the queue across the
    fabric, and re-homes it on NIC 1 (MIGRATE_START/MIGRATE_DONE in
    the EQ stream).  ``migrate=False`` is the control arm the test
    compares against: victim p99 improves, fleet Jain holds."""
    return FleetSpec(
        name="fleet_migrate",
        description="global QoS migrates an SLO victim off a congested "
                    "NIC; p99 recovers, Jain holds (DESIGN.md §12.4)",
        tenants=(
            TenantSpec("congestor0", workload=_spin("congestor0", 2.0),
                       arrival=ArrivalSpec(size=1024, share=0.25)),
            TenantSpec("congestor1", workload=_spin("congestor1", 2.0),
                       arrival=ArrivalSpec(size=1024, share=0.20,
                                           seed_offset=1)),
            TenantSpec("victim", workload=_spin("victim", 2.0),
                       arrival=ArrivalSpec(size=256, share=0.06,
                                           seed_offset=2),
                       p99_target=p99_target_ns),
            TenantSpec("light", workload=_spin("light", 1.0),
                       arrival=ArrivalSpec(size=512, share=0.05,
                                           seed_offset=3)),
        ),
        placement=(0, 0, 0, 1), num_nics=2,
        controller=ControllerSpec(interval_ns=8000.0),
        global_qos=GlobalQoSSpec(interval_epochs=2, migrate=migrate,
                                 rebalance=rebalance, rebalance_gain=1.3,
                                 boost_cap=4.0, max_migrations=2,
                                 cooldown_epochs=4, load_margin=1.1),
        epoch_ns=epoch_ns, datapath=datapath,
        duration_us=duration_us, seed=seed)
