"""Fleet-plane scenario specs (DESIGN.md §12).

``FleetSpec`` extends ``ScenarioSpec`` with the fabric half of a
multi-NIC deployment: how many per-NIC engines to instantiate, where
each tenant's flow terminates (the placement map), the modeled switch
(VOQ depth, crossbar arbiter, per-link serialization + propagation
delay), the co-simulation epoch, and the global QoS tier that sits
above the per-NIC AIMD controllers.

Like its base class it is a frozen dataclass of plain scalars/tuples:
hashable, JSON round-trippable, and ``replace``-derivable (so the
launch CLI's ``--fast`` duration cap works unchanged).  ``plain()``
projects the fleet spec down to the single-NIC ``ScenarioSpec`` twin
that each per-NIC engine runs — the N=1 zero-delay fleet is
bit-identical to running that twin through ``run_scenario`` directly
(pinned in tests/test_fleet.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.api.spec import ScenarioSpec


@dataclasses.dataclass(frozen=True)
class GlobalQoSSpec:
    """The fleet-level control loop (DESIGN.md §12.4).

    Runs every ``interval_epochs`` co-sim epochs on the per-NIC
    ``SignalFrame``s read off the MetricsBus.  Two actuators, both
    *above* the per-NIC AIMD controllers:

      * weight rebalancing — scale the per-NIC scheduler *base*
        weights of SLO-violating tenants by ``rebalance_gain`` (the
        per-NIC controller keeps applying its own AIMD boost on top);
      * live migration — move the worst violating tenant off the
        most-loaded NIC onto the least-loaded one (drain + replay
        through the fabric), at most ``max_migrations`` per run and
        once per ``cooldown_epochs`` per tenant.

    Decisions read only drift-free signals (p99, queue_mean), so the
    event and batched datapaths take identical actions.
    """
    interval_epochs: int = 2
    rebalance: bool = False          # requires a per-NIC ControllerSpec
    rebalance_gain: float = 1.5
    boost_cap: float = 8.0
    migrate: bool = True
    max_migrations: int = 4          # total over the run
    cooldown_epochs: int = 4         # per-tenant re-migration spacing
    load_margin: float = 1.2         # migrate only if src load > margin*dst


@dataclasses.dataclass(frozen=True)
class FleetSpec(ScenarioSpec):
    """A multi-NIC scenario: N per-NIC engines over a modeled switch.

    ``placement`` maps tenant id -> home NIC (empty = ``i % num_nics``);
    a tenant's ingress port on the fabric is always ``i % num_nics``,
    so placement alone decides which flows cross the crossbar.
    ``link_gbps == 0`` together with ``prop_delay_ns == 0`` selects the
    ideal (passthrough) fabric: injections deliver verbatim, which is
    the N=1 bit-identity configuration.
    """
    num_nics: int = 2
    placement: Tuple[int, ...] = ()      # tenant -> home NIC
    link_gbps: float = 400.0             # 0 = ideal link (no serialization)
    prop_delay_ns: float = 50.0
    voq_depth: int = 1024                # per-(input,output) VOQ bound
    switch_arbiter: str = "rr"           # "rr" | "mdrr"
    quantum_bytes: int = 4096            # mdrr per-round credit
    epoch_ns: float = 8000.0             # co-sim step (multiple of the
    #                                      engines' 2000ns IO window)
    migration_delay_ns: float = 2000.0   # fixed drain -> replay handoff
    migration_gbps: float = 0.0          # state-transfer link: > 0 adds
    #                                      drained_bytes * 8 / gbps ns to
    #                                      the handoff (0 = fixed only)
    global_qos: Optional[GlobalQoSSpec] = None
    trace_fleet: bool = False            # switch-traversal + migration
    #                                      spans into a fleet TraceRecorder

    def nic_of(self, tenant: int) -> int:
        """Initial home NIC of a tenant (before any migration)."""
        if self.placement:
            return self.placement[tenant]
        return tenant % self.num_nics

    def initial_placement(self) -> Tuple[int, ...]:
        return tuple(self.nic_of(i) for i in range(len(self.tenants)))

    def plain(self) -> ScenarioSpec:
        """The single-NIC ``ScenarioSpec`` twin each per-NIC engine
        runs: every base field verbatim, no fleet fields."""
        base = {f.name: getattr(self, f.name)
                for f in dataclasses.fields(ScenarioSpec)}
        return ScenarioSpec(**base)

    @classmethod
    def from_dict(cls, d: Dict) -> "FleetSpec":
        d = dict(d)
        d["placement"] = tuple(d.get("placement", ()))
        if d.get("global_qos") is not None:
            d["global_qos"] = GlobalQoSSpec(**d["global_qos"])
        return super().from_dict(d)
