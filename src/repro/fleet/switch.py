"""Modeled VOQ/crossbar switch connecting the per-NIC engines.

The fabric model follows the classic input-queued switch shape
(SNIPPETS.md §1/§3): each of the N input ports keeps one bounded
virtual output queue *per output port*, so a saturated output can only
back up its own VOQs — packets headed to an uncongested output are
never stuck behind them (no head-of-line blocking; pinned by the
``fleet_incast`` test).  Each output port runs its own arbiter over
the N inputs:

  * ``rr``   — round-robin pointer scan (same grant order as
               ``wlbvt.select_rr``, inlined for the per-packet path);
  * ``mdrr`` — modified deficit round robin over the VOQ head sizes,
               reusing ``wlbvt.DWRRState``/``dwrr_select`` verbatim.

A granted packet occupies its output link for ``size*8/link_gbps`` ns
of serialization and lands ``prop_delay_ns`` later.  ``link_gbps == 0``
and ``prop_delay_ns == 0`` select the ideal passthrough fabric
(deliveries are the injections, verbatim) used for the N=1
bit-identity configuration.

All state advances through a resumable ``advance(t)`` so the fleet
engine can co-step the switch with the per-NIC engines in epochs; the
same conservation law the property tests pin holds at every instant:

    injected + replayed == delivered + dropped + in-flight
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core import wlbvt as W
from repro.core.events import Event, EventKind

_K_IN = 0    # packet reaches its input port's VOQ stage
_K_OUT = 1   # output link finishes serializing the granted packet


class CrossbarSwitch:
    """N-port input-queued crossbar with bounded VOQs.

    Packets are ``(arrival, src, dst, tenant, size)``; ``inject`` is
    resumable/out-of-order-safe as long as times within one epoch are
    fed before ``advance`` crosses them (the fleet engine guarantees
    this by injecting each epoch's trace slice before advancing).
    """

    def __init__(self, num_ports: int, *, num_tenants: int,
                 link_gbps: float = 400.0, prop_delay_ns: float = 50.0,
                 voq_depth: int = 1024, arbiter: str = "rr",
                 quantum_bytes: int = 4096, tracer=None,
                 track_ids: bool = False):
        if num_ports < 1:
            raise ValueError("num_ports must be >= 1")
        if arbiter not in ("rr", "mdrr"):
            raise ValueError(f"unknown switch arbiter {arbiter!r}")
        self.n = num_ports
        self.num_tenants = num_tenants
        self.link_gbps = float(link_gbps)
        self.prop_delay_ns = float(prop_delay_ns)
        self.voq_depth = int(voq_depth)
        self.arbiter = arbiter
        self.quantum = int(quantum_bytes)
        self.passthrough = self.link_gbps == 0.0 and self.prop_delay_ns == 0.0
        self.tracer = tracer
        self.now = 0.0

        n = num_ports
        # voq[src][dst] -> list of (t_in, tenant, size, uid) FIFOs
        self._voq: List[List[List[tuple]]] = [
            [[] for _ in range(n)] for _ in range(n)]
        self._voq_head: List[List[int]] = [[0] * n for _ in range(n)]
        self.voq_len = np.zeros((n, n), np.int64)
        self.voq_peak = np.zeros((n, n), np.int64)
        self._rr_ptr = [0] * n                     # per-output input scan
        self._out_pending = [0] * n                # queued pkts per output
        self._dwrr = [W.DWRRState.create(np.ones(n)) for _ in range(n)]
        self._busy = [False] * n                   # output link serializing
        self._tx: List[Optional[tuple]] = [None] * n
        self._heap: List[tuple] = []               # (t, seq, code, port)
        self._deliv: List[tuple] = []              # (t_out, seq, tenant,
        #                                             size, dst, src, t_in)
        self._seq = 0
        self._uid = 0
        # bulk arrival stream (inject_bulk): consumed by advance()
        self._in_t = np.empty(0, np.float64)
        self._in_src = self._in_dst = self._in_ten = self._in_sz = \
            np.empty(0, np.int64)
        self._in_idx = 0
        self._in_uid0 = 0

        t = num_tenants
        self.injected = np.zeros(t, np.int64)
        self.replayed = np.zeros(t, np.int64)      # migration re-injections
        self.delivered = np.zeros(t, np.int64)
        self.dropped = np.zeros(t, np.int64)
        self.busy_ns = np.zeros(n, np.float64)
        self.pair_lat_sum = np.zeros((n, n), np.float64)
        self.pair_count = np.zeros((n, n), np.int64)
        self.events: List[Event] = []

        self.track_ids = track_ids
        self.injected_ids: Set[int] = set()
        self.delivered_ids: Set[int] = set()
        self.dropped_ids: Set[int] = set()

    # ---------------------------------------------------------- inject

    def inject(self, t: float, src: int, dst: int, tenant: int, size: int,
               *, replay: bool = False) -> None:
        uid = self._uid
        self._uid += 1
        if replay:
            self.replayed[tenant] += 1
        else:
            self.injected[tenant] += 1
        if self.track_ids:
            self.injected_ids.add(uid)
        if self.passthrough:
            self._seq += 1
            heapq.heappush(self._deliv,
                           (t, self._seq, tenant, size, dst, src, t))
            return
        self._seq += 1
        heapq.heappush(self._heap,
                       (t, self._seq, _K_IN, (src, dst, tenant, size, uid)))

    def inject_bulk(self, times: np.ndarray, srcs: np.ndarray,
                    dsts: np.ndarray, tenants: np.ndarray,
                    sizes: np.ndarray) -> None:
        """Array fast path for a time-sorted arrival stream (the
        control-plane-off slice): one call replaces ``len(times)``
        ``inject()``s — identical uids, counters and arrival order,
        minus the per-packet call + heap churn.  ``advance`` merges the
        stream with the serialization-event heap."""
        if self._in_idx < len(self._in_t):
            raise RuntimeError("bulk arrival stream still pending")
        if self.passthrough:
            for j in range(len(times)):
                self.inject(float(times[j]), int(srcs[j]), int(dsts[j]),
                            int(tenants[j]), int(sizes[j]))
            return
        n = len(times)
        self.injected += np.bincount(tenants, minlength=self.num_tenants)
        uid0 = self._uid
        self._uid += n
        if self.track_ids:
            self.injected_ids.update(range(uid0, uid0 + n))
        self._in_t = np.asarray(times, np.float64)
        self._in_src = np.asarray(srcs, np.int64)
        self._in_dst = np.asarray(dsts, np.int64)
        self._in_ten = np.asarray(tenants, np.int64)
        self._in_sz = np.asarray(sizes, np.int64)
        self._in_idx = 0
        self._in_uid0 = uid0

    def bulk_passthrough(self, tenants: np.ndarray, srcs: np.ndarray,
                         dsts: np.ndarray) -> None:
        """Counter-only fast path for the single-shot (N=1 ideal
        fabric) configuration: every injection is its own delivery."""
        self.injected += np.bincount(tenants, minlength=self.num_tenants)
        self.delivered += np.bincount(tenants, minlength=self.num_tenants)
        np.add.at(self.pair_count, (srcs, dsts), 1)

    # --------------------------------------------------------- advance

    def advance(self, t: float) -> List[Tuple[float, int, int, int]]:
        """Run the fabric up to (and including) virtual time ``t``;
        return the chronological ``(t_deliver, tenant, size, dst)``
        deliveries that have landed by then.  Later deliveries stay
        buffered for the next call."""
        heap, deliv = self._heap, self._deliv
        it, idx, n_in = self._in_t, self._in_idx, len(self._in_t)
        isrc, idst = self._in_src, self._in_dst
        iten, isz, uid0 = self._in_ten, self._in_sz, self._in_uid0
        while True:
            # merge the sorted bulk stream with the event heap; at equal
            # times arrivals win, matching inject()'s seq ordering
            t_in = it[idx] if idx < n_in else None
            if (t_in is not None and t_in <= t
                    and (not heap or t_in <= heap[0][0])):
                self._arrive(float(t_in),
                             (int(isrc[idx]), int(idst[idx]),
                              int(iten[idx]), int(isz[idx]), uid0 + idx))
                idx += 1
            elif heap and heap[0][0] <= t:
                et, _, code, payload = heapq.heappop(heap)
                if code == _K_IN:
                    self._arrive(et, payload)
                else:
                    self._tx_done(et, payload)
            else:
                break
        self._in_idx = idx
        out: List[Tuple[float, int, int, int]] = []
        while deliv and deliv[0][0] <= t:
            dt_, _, tenant, size, dst, src, t_in = heapq.heappop(deliv)
            self.delivered[tenant] += 1
            self.pair_lat_sum[src, dst] += dt_ - t_in
            self.pair_count[src, dst] += 1
            out.append((dt_, tenant, size, dst))
        if t > self.now:
            self.now = t
        return out

    @property
    def idle(self) -> bool:
        """No queued fabric events and no undelivered packets."""
        return (not self._heap and not self._deliv
                and self._in_idx >= len(self._in_t))

    @property
    def inflight(self) -> int:
        voq = int(self.voq_len.sum())
        tx = sum(1 for p in self._tx if p is not None)
        pending = len(self._in_t) - self._in_idx
        return voq + tx + len(self._deliv) + pending

    # ----------------------------------------------------- event paths

    def _arrive(self, t: float, payload: tuple) -> None:
        src, dst, tenant, size, uid = payload
        if (not self._busy[dst] and self._out_pending[dst] == 0
                and self.arbiter == "rr" and self.voq_depth >= 1):
            # uncontended fast path: idle output, empty VOQ column —
            # the append + immediate-grant sequence collapses to a
            # direct grant with identical externally visible state
            # (RR pointer advanced past src, peak depth 1, same OUT
            # event).  MDRR keeps the slow path: its deficit counters
            # mutate on every select.
            if self.voq_peak[src, dst] == 0:
                self.voq_peak[src, dst] = 1
            self._rr_ptr[dst] = (src + 1) % self.n
            ser = size * 8.0 / self.link_gbps if self.link_gbps > 0 else 0.0
            self._busy[dst] = True
            self._tx[dst] = (t, tenant, size, uid, src)
            self.busy_ns[dst] += ser
            self._seq += 1
            heapq.heappush(self._heap, (t + ser, self._seq, _K_OUT, dst))
            return
        q = self._voq[src][dst]
        head = self._voq_head[src][dst]
        if len(q) - head >= self.voq_depth:
            self.dropped[tenant] += 1
            if self.track_ids:
                self.dropped_ids.add(uid)
            self.events.append(Event(
                tenant, EventKind.SWITCH_DROP, t,
                detail=f"voq[{src}->{dst}] full ({self.voq_depth})"))
            if self.tracer is not None:
                from repro.telemetry.trace import D_DROP, ST_SWITCH
                self.tracer.span(ST_SWITCH, uid, tenant, t, t, disp=D_DROP)
            return
        q.append((t, tenant, size, uid))
        self._out_pending[dst] += 1
        depth = len(q) - head
        self.voq_len[src, dst] = depth
        if depth > self.voq_peak[src, dst]:
            self.voq_peak[src, dst] = depth
        if not self._busy[dst]:
            self._grant(dst, t)

    def _tx_done(self, t: float, out_port: int) -> None:
        t_in, tenant, size, uid, src = self._tx[out_port]
        self._tx[out_port] = None
        self._busy[out_port] = False
        self._seq += 1
        heapq.heappush(self._deliv,
                       (t + self.prop_delay_ns, self._seq, tenant, size,
                        out_port, src, t_in))
        if self.track_ids:
            self.delivered_ids.add(uid)
        if self.tracer is not None:
            from repro.telemetry.trace import D_OK, ST_SWITCH
            self.tracer.span(ST_SWITCH, uid, tenant, t_in,
                             t + self.prop_delay_ns, disp=D_OK)
        self._grant(out_port, t)

    def _grant(self, out_port: int, t: float) -> None:
        """Arbitrate among the inputs holding traffic for ``out_port``
        and start serializing the winner's VOQ head."""
        src = self._pick_input(out_port)
        if src < 0:
            return
        q = self._voq[src][out_port]
        head = self._voq_head[src][out_port]
        t_in, tenant, size, uid = q[head]
        head += 1
        if head > 64 or head == len(q):          # amortized FIFO compaction
            del q[:head]
            head = 0
        self._voq_head[src][out_port] = head
        self.voq_len[src, out_port] = len(q) - head
        self._out_pending[out_port] -= 1
        ser = size * 8.0 / self.link_gbps if self.link_gbps > 0 else 0.0
        self._busy[out_port] = True
        self._tx[out_port] = (t_in, tenant, size, uid, src)
        self.busy_ns[out_port] += ser
        self._seq += 1
        heapq.heappush(self._heap, (t + ser, self._seq, _K_OUT, out_port))

    def _pick_input(self, out_port: int) -> int:
        n = self.n
        col = self.voq_len[:, out_port]
        if self.arbiter == "rr":
            # same semantics as wlbvt.select_rr, inlined: scan from the
            # pointer, grant the first non-empty VOQ, advance past it
            ptr = self._rr_ptr[out_port]
            for off in range(n):
                i = (ptr + off) % n
                if col[i] > 0:
                    self._rr_ptr[out_port] = (i + 1) % n
                    return i
            return -1
        pending = col > 0
        if not pending.any():
            return -1
        heads = np.zeros(n, np.float64)
        for i in range(n):
            if pending[i]:
                q = self._voq[i][out_port]
                heads[i] = q[self._voq_head[i][out_port]][2]
        return int(W.dwrr_select(self._dwrr[out_port], heads, pending,
                                 float(self.quantum)))

    # ----------------------------------------------------------- stats

    def conservation_ok(self) -> bool:
        counts = (int(self.injected.sum()) + int(self.replayed.sum())
                  == int(self.delivered.sum()) + int(self.dropped.sum())
                  + self.inflight)
        if not self.track_ids:
            return counts
        inflight_ids = (self.injected_ids - self.delivered_ids
                        - self.dropped_ids)
        return (counts
                and not (self.delivered_ids & self.dropped_ids)
                and len(inflight_ids) == self.inflight
                and len(self.delivered_ids) + len(self.dropped_ids)
                + len(inflight_ids) == len(self.injected_ids))

    def stats(self) -> Dict:
        elapsed = self.now if self.now > 0 else 1.0
        util = np.clip(self.busy_ns / elapsed, 0.0, 1.0)
        with np.errstate(invalid="ignore"):
            lat = np.where(self.pair_count > 0,
                           self.pair_lat_sum / np.maximum(self.pair_count, 1),
                           0.0)
        return {
            "ports": self.n,
            "arbiter": self.arbiter,
            "passthrough": self.passthrough,
            "injected": self.injected.tolist(),
            "replayed": self.replayed.tolist(),
            "delivered": self.delivered.tolist(),
            "dropped": self.dropped.tolist(),
            "drops_total": int(self.dropped.sum()),
            "inflight": self.inflight,
            "voq_peak": self.voq_peak.tolist(),
            "voq_peak_out": self.voq_peak.max(axis=0).tolist(),
            "voq_now": self.voq_len.tolist(),
            "link_busy_ns": self.busy_ns.tolist(),
            "link_utilization": util.tolist(),
            "pair_latency_mean": lat.tolist(),
            "pair_count": self.pair_count.tolist(),
        }
