"""Fleet plane: multi-NIC co-simulation over a modeled VOQ/crossbar
switch fabric, with tenant placement, live migration, and a global QoS
tier above the per-NIC controllers (DESIGN.md §12)."""
from repro.fleet.engine import (FLEET_EXTRAS_KEYS, FleetEngine,
                                fleet_metric_rows, run_fleet)
from repro.fleet.qos import GlobalQoS
from repro.fleet.spec import FleetSpec, GlobalQoSSpec
from repro.fleet.switch import CrossbarSwitch

__all__ = [
    "CrossbarSwitch", "FLEET_EXTRAS_KEYS", "FleetEngine", "FleetSpec",
    "GlobalQoS", "GlobalQoSSpec", "fleet_metric_rows", "run_fleet",
]
