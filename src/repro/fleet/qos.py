"""Global QoS tier: the fleet-level control loop above per-NIC AIMD.

Reads the latest per-NIC ``BusFrame`` (one per engine, distinguished
by ``frame.nic``) off a shared MetricsBus subscription and decides two
kinds of action per tick (every ``GlobalQoSSpec.interval_epochs``
co-sim epochs):

  * a per-tenant base-weight boost vector (``gboost``) — the fleet
    engine multiplies it into every NIC's scheduler *base* rows, and
    each NIC's local AIMD controller keeps layering its own boost on
    top at its next qos tick (global sets the floor, local the fine
    trim);
  * migration plans ``(tenant, src_nic, dst_nic)`` — move the worst
    SLO violator off the most-loaded NIC onto the least-loaded one.

Only drift-free signals are read (p99, queue_mean): both sim datapaths
publish bit-identical values for those, so fleet decisions — and hence
the whole fleet RunReport — stay byte-identical across event and
batched engines (pinned in tests/test_fleet.py).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.fleet.spec import GlobalQoSSpec


class GlobalQoS:
    def __init__(self, cfg: GlobalQoSSpec, *, num_tenants: int,
                 num_nics: int, p99_targets) -> None:
        self.cfg = cfg
        self.T = int(num_tenants)
        self.N = int(num_nics)
        self.targets = np.asarray(p99_targets, np.float64)
        self.gboost = np.ones(self.T, np.float64)
        self._last_migrated = np.full(self.T, -(10 ** 9), np.int64)
        self.migrations_planned = 0
        self.weight_actions = 0

    def tick(self, epoch: int, frames: Dict[int, object],
             placement: List[int]) -> Tuple[List[Tuple[int, int, int]], bool]:
        """One control decision. ``frames`` maps nic index -> latest
        BusFrame (NICs that have not published yet are simply absent).
        Returns ``(migration_plans, gboost_changed)``."""
        load = np.zeros(self.N, np.float64)
        p99 = np.zeros(self.T, np.float64)
        have = np.zeros(self.T, bool)
        for k in range(self.N):
            f = frames.get(k)
            if f is None:
                continue
            qm = np.asarray(f.signals.queue_mean, np.float64)
            fp99 = np.asarray(f.signals.p99, np.float64)
            for i in range(self.T):
                if placement[i] == k:
                    load[k] += float(qm[i])
                    p99[i] = float(fp99[i])
                    have[i] = True

        changed = False
        viol = have & (self.targets > 0) & (p99 > self.targets)
        if self.cfg.rebalance:
            new = self.gboost.copy()
            new[viol] = np.minimum(new[viol] * self.cfg.rebalance_gain,
                                   self.cfg.boost_cap)
            relax = have & (self.targets > 0) & ~viol
            new[relax] = np.maximum(new[relax] / self.cfg.rebalance_gain, 1.0)
            if not np.array_equal(new, self.gboost):
                self.gboost = new
                self.weight_actions += 1
                changed = True

        plans: List[Tuple[int, int, int]] = []
        if self.cfg.migrate and self.migrations_planned < self.cfg.max_migrations:
            src = int(np.argmax(load))
            dst = int(np.argmin(load))
            if dst != src and load[src] > self.cfg.load_margin * load[dst] + 1e-12:
                best, best_ratio = -1, 0.0
                for i in range(self.T):
                    if (placement[i] == src and viol[i]
                            and epoch - self._last_migrated[i]
                            >= self.cfg.cooldown_epochs):
                        ratio = p99[i] / self.targets[i]
                        if ratio > best_ratio:
                            best, best_ratio = i, ratio
                if best >= 0:
                    plans.append((best, src, dst))
                    self._last_migrated[best] = epoch
                    self.migrations_planned += 1
        return plans, changed

    def summary(self) -> Dict:
        return {
            "gboost": self.gboost.tolist(),
            "weight_actions": int(self.weight_actions),
            "migrations_planned": int(self.migrations_planned),
        }
