"""OSMOSIS multi-tenant serving engine (the paper's §5 on a TPU pod).

Control plane (host, this module)      | Data plane (jitted XLA programs)
---------------------------------------+----------------------------------
ECTX admission + static KV quotas (R3) | batched chunked prefill
WLBVT slot scheduler          (R1, R4) | batched decode (1 token/step)
DWRR prefill-token arbitration    (R2) | slot-cache reset
watchdog budgets + EQ events      (R5) |
priority SLO knobs                (R6) |

Mapping (DESIGN.md §2): packet = request chunk; PU = batch slot; kernel =
the model's execution for that chunk (cost unknown a priori — prompt and
output lengths differ per tenant, exactly the paper's unpredictable-kernel
problem); DMA fragmentation = chunked prefill; egress WRR = per-step
prefill token budget.  Scheduling state is the *same* WLBVT/DWRR code the
PsPIN simulator uses (core/wlbvt.py) — the contribution is shared, not
re-implemented.

Run-to-completion: one scheduled chunk = one XLA program invocation; the
engine never preempts inside a step (paper §5.3).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import wlbvt as W
from repro.core.accounting import TimeAveragedJain
from repro.core.admission import AdmissionError
from repro.core.engine_base import EngineBase
from repro.core.events import Event, EventKind
from repro.core.slo import ECTX, SLOPolicy
from repro.serving.kv_cache import SlotManager
from repro.serving.request import Request, RequestStatus
from repro.telemetry import G_IDX, GAUGES, tenant_report
from repro.telemetry import trace as TR


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 8                # "PUs": concurrent batch slots
    max_len: int = 512                # KV tokens per slot
    prefill_chunk: int = 64           # fragmentation grain (R2)
    prefill_slots_per_step: int = 2   # per-step prefill budget (PPB analog)
    scheduler: str = "wlbvt"          # "wlbvt" | "rr" (baseline)
    arbiter: str = "dwrr"             # "dwrr" | "fifo" (baseline)
    max_tenants: int = 128            # FMQ table size; decisions are O(T)
    #                                   vectorized so headroom is cheap
    kv_overcommit: float = 1.0        # R3: 1.0 = strict static reservation
    telemetry: bool = True            # per-tenant metric plane (DESIGN.md §6)
    telemetry_backend: str = "numpy"  # "numpy" | "jnp" (jitted commits)
    qos_interval: int = 0             # steps between QoS control updates;
    #                                   0 = static weights (no control loop)
    observe_interval: int = 0         # steps between metrics-bus frames;
    #                                   0 = follow qos_interval (or 16
    #                                   without a controller).  Only paid
    #                                   when a bus/SLO audit is attached.
    trace: bool = False               # packet-lifecycle flight recorder
    trace_depth: int = 65536          # span ring depth (DESIGN.md §10)
    trace_decision_depth: int = 8192  # decision-provenance ring depth


class NullExecutor:
    """Scheduling-only backend (no model): deterministic fake tokens."""

    def __init__(self, cfg: EngineConfig):
        self.B = cfg.max_slots

    def prefill(self, tokens, lengths, valid_n):
        return np.zeros(self.B, np.int32)

    def decode(self, tokens, lengths, active):
        return (tokens + 1).astype(np.int32) % 97

    def reset(self, keep):
        pass


class ModelExecutor:
    """Real data plane: jitted prefill/decode/reset over a Model."""

    def __init__(self, model_cfg: ModelConfig, ecfg: EngineConfig,
                 params=None, mesh=None, rng_seed: int = 0,
                 temperature: float = 0.0):
        import jax
        import jax.numpy as jnp
        from repro.serving.serve_step import build_serve_fns
        self.jnp = jnp
        self.fns = build_serve_fns(
            model_cfg, mesh, batch=ecfg.max_slots, max_len=ecfg.max_len,
            prefill_chunk=ecfg.prefill_chunk, temperature=temperature)
        self.params = (params if params is not None
                       else self.fns.init_params(jax.random.PRNGKey(rng_seed)))
        self.cache = self.fns.init_cache()

    def prefill(self, tokens, lengths, valid_n):
        nxt, _, self.cache = self.fns.prefill_chunk(
            self.params, self.cache, self.jnp.asarray(tokens),
            self.jnp.asarray(lengths), self.jnp.asarray(valid_n))
        return np.asarray(nxt)

    def decode(self, tokens, lengths, active):
        nxt, self.cache = self.fns.decode(
            self.params, self.cache, self.jnp.asarray(tokens),
            self.jnp.asarray(lengths), self.jnp.asarray(active))
        return np.asarray(nxt)

    def reset(self, keep):
        self.cache = self.fns.reset_slots(self.cache,
                                          self.jnp.asarray(keep))


class Engine(EngineBase):
    OBS_BACKEND = "serve"

    def __init__(self, ecfg: EngineConfig, executor=None):
        # tenant/budget/EQ/telemetry plumbing is the shared engine-core
        # layer (core/engine_base.py, DESIGN.md §8) — the same stack the
        # cycle simulator runs on
        T = ecfg.max_tenants
        super().__init__(T, shared_eq=False, telemetry=ecfg.telemetry,
                         telemetry_backend=ecfg.telemetry_backend,
                         trace=ecfg.trace, trace_depth=ecfg.trace_depth,
                         trace_decision_depth=ecfg.trace_decision_depth,
                         trace_pus=ecfg.max_slots)
        self.cfg = ecfg
        self.exe = executor or NullExecutor(ecfg)
        self.ectx = self.ectxs          # legacy aliases for the public
        self.eq = self.eqhub.queues     # surface (dict views, shared state)
        self.tokens_used = self.budget.spent
        self.slots = SlotManager(ecfg.max_slots, ecfg.max_len,
                                 overcommit=ecfg.kv_overcommit)
        self.queues: Dict[int, deque] = {}
        self.st = W.WLBVTState.create(np.ones(T))
        self.rr_ptr = 0
        self.dwrr = W.DWRRState.create(np.ones(T))
        # slot state (numpy mirrors of device state)
        S = ecfg.max_slots
        self.slot_req: List[Optional[Request]] = [None] * S
        self.lengths = np.zeros(S, np.int32)
        self.last_tok = np.zeros(S, np.int32)
        self.step_count = 0
        self._next_rid = 0
        self._control: deque = deque()
        self.fairness = TimeAveragedJain()
        self.done: List[Request] = []
        self.decode_steps = 0
        self.prefill_chunks = 0
        # SLO-configured base weights per knob (tracked through ECTX
        # create/destroy); the controller scales these, never overwrites
        self._prio_base = np.ones(T)
        self._dwrr_base = np.ones(T)
        # flight-recorder bookkeeping (DESIGN.md §10): packet uid =
        # submission order; rid -> uid survives until EQ_COMPLETE
        self._tr_uid = 0
        self._tr_uid_by_rid: Dict[int, int] = {}

    # ------------------------------------------------------------------
    # control plane (R5: processed before data-path work each step)
    # ------------------------------------------------------------------
    def create_ectx(self, tenant_id: int, slo: SLOPolicy,
                    name: str = "") -> ECTX:
        """Admission: static KV segment + FMQ install.  Raises
        AdmissionError when the quota does not fit (R3)."""
        if tenant_id in self.ectx:
            raise AdmissionError(f"tenant {tenant_id} already admitted")
        if tenant_id >= self.cfg.max_tenants:
            raise AdmissionError("FMQ table full")
        self.slots.admit(tenant_id, slo.kv_quota_tokens)
        e = ECTX(tenant_id=tenant_id, name=name or f"tenant{tenant_id}",
                 slo=slo)
        self.queues[tenant_id] = deque()
        self.st.prio[tenant_id] = slo.priority
        self.dwrr.weights[tenant_id] = slo.dma_priority
        self._prio_base[tenant_id] = slo.priority
        self._dwrr_base[tenant_id] = slo.dma_priority
        return self.register_tenant(e, fmq_index=tenant_id, announce=True,
                                    now=self.step_count)

    def destroy_ectx(self, tenant_id: int) -> List[Event]:
        """Tear down a tenant: kill in-flight requests, reject queued ones
        (each with an event), release the KV segment, and retire the
        tenant's EventQueue.  Returns the final drained event list — the
        queue itself is removed, so this is the last chance to observe
        the tenant's events."""
        for s, r in enumerate(self.slot_req):
            if r is not None and r.tenant_id == tenant_id:
                self._finish(s, RequestStatus.KILLED)
        eq = self.eqhub.retire(tenant_id)
        for req in self.queues.pop(tenant_id, ()):
            req.status = RequestStatus.REJECTED
            req.finish_step = self.step_count
            if self.trace is not None:
                uid = self._tr_uid_by_rid.pop(req.rid, -1)
                self.trace.span_abandon(TR.ST_FMQ, uid,
                                        float(self.step_count),
                                        TR.D_REJECT)
            self.done.append(req)
            if eq is not None:
                eq.push(Event(tenant_id, EventKind.EVICTED, self.step_count,
                              f"rid={req.rid} rejected: ectx destroyed"))
        self.slots.evict(tenant_id)
        # registry row, admission gate, budget, telemetry + controller
        # history: one shared teardown (core/engine_base.py)
        self.deregister_tenant(tenant_id)
        self._prio_base[tenant_id] = 1.0
        self._dwrr_base[tenant_id] = 1.0
        self.st.queue_len[tenant_id] = 0
        self.st.prio[tenant_id] = 1.0
        self.st.total_occup[tenant_id] = 0.0   # a reused tenant id must not
        self.st.bvt[tenant_id] = 0.0           # inherit WLBVT service history
        self.dwrr.deficit[tenant_id] = 0.0
        if eq is not None:
            eq.push(Event(tenant_id, EventKind.EVICTED, self.step_count))
            return eq.drain()
        return []

    def attach_controller(self, controller) -> None:
        """Install a ``QoSController``; it runs every ``qos_interval``
        steps, adapting WLBVT/DWRR weights and the admission gate."""
        if self.tel is None or self.cfg.qos_interval <= 0:
            raise ValueError(
                "attach_controller requires EngineConfig.telemetry=True "
                "and qos_interval > 0 — the control loop would never run")
        self.controller = controller

    def submit(self, req: Request) -> Request:
        if req.tenant_id not in self.ectx:
            req.status = RequestStatus.REJECTED
            return req
        if self.tel is not None:
            self.tel.inc("arrivals", req.tenant_id)
            self.tel.inc("bytes_in", req.tenant_id, req.prompt_len)
        tr = self.trace
        uid = -1
        if tr is not None:
            uid = self._tr_uid
            self._tr_uid += 1
        if not self._admit[req.tenant_id]:
            # QoS controller backpressure (hysteresis on congestion)
            req.status = RequestStatus.REJECTED
            self._reject_count(req.tenant_id)
            if tr is not None:
                self._trace_reject(uid, req.tenant_id)
            self.eq[req.tenant_id].push(Event(
                req.tenant_id, EventKind.BACKPRESSURE, self.step_count))
            return req
        # Lifetime billing budget (R5): a tenant whose total token spend
        # exhausted its allowance gets no further admission.
        tlimit = self.ectx[req.tenant_id].slo.total_cycle_limit
        if self.budget.exhausted(req.tenant_id, tlimit):
            req.status = RequestStatus.REJECTED
            self._reject_count(req.tenant_id)
            if tr is not None:
                self._trace_reject(uid, req.tenant_id)
            self.eq[req.tenant_id].push(Event(
                req.tenant_id, EventKind.TOTAL_BUDGET_EXCEEDED,
                self.step_count,
                f"lifetime budget {tlimit} tokens exhausted"))
            return req
        if req.prompt_len + req.max_new_tokens > self.cfg.max_len:
            req.status = RequestStatus.REJECTED
            self._reject_count(req.tenant_id)
            if tr is not None:
                self._trace_reject(uid, req.tenant_id)
            self.eq[req.tenant_id].push(Event(
                req.tenant_id, EventKind.MEMORY_FAULT, self.step_count,
                "request exceeds slot KV capacity"))
            return req
        # Watchdog admission check (R5): a request whose prompt alone blows
        # the kernel cycle budget would be killed at its first decode token
        # — reject it up front instead of burning prefill work on it.
        limit = self.ectx[req.tenant_id].slo.kernel_cycle_limit
        if limit and req.prompt_len + 1 > limit:
            req.status = RequestStatus.REJECTED
            self._reject_count(req.tenant_id)
            if tr is not None:
                self._trace_reject(uid, req.tenant_id)
            self.eq[req.tenant_id].push(Event(
                req.tenant_id, EventKind.CYCLE_BUDGET_EXCEEDED,
                self.step_count,
                f"prompt {req.prompt_len} cannot fit cycle budget {limit}"))
            return req
        req.rid = self._next_rid
        self._next_rid += 1
        req.arrival_step = self.step_count
        if tr is not None:
            now = float(self.step_count)
            tr.span(TR.ST_ARRIVE, uid, req.tenant_id, now, now, TR.D_OK)
            tr.span_begin(TR.ST_FMQ, uid, req.tenant_id, now)
            self._tr_uid_by_rid[req.rid] = uid
        self.queues[req.tenant_id].append(req)
        self.st.queue_len[req.tenant_id] += 1
        return req

    def _reject_count(self, tenant_id: int) -> None:
        if self.tel is not None:
            self.tel.inc("rejected", tenant_id)

    def _trace_reject(self, uid: int, tenant_id: int) -> None:
        now = float(self.step_count)
        self.trace.span(TR.ST_ARRIVE, uid, tenant_id, now, now, TR.D_REJECT)
        TR.record_admission_reject(self.trace, now, tenant_id)

    def poll_events(self, tenant_id: int) -> List[Event]:
        return self.eqhub.poll(tenant_id)

    # ------------------------------------------------------------------
    # data plane step
    # ------------------------------------------------------------------
    def _select_round(self, k: int) -> List[int]:
        """The winners of one scheduling round: up to ``k`` tenant picks,
        KV-quota caps folded into eligibility vectorially (R1 + R3).
        ``st.queue_len``/``st.cur_occup`` are charged per pick."""
        caps = self.slots.quota_caps(self.cfg.max_tenants)
        tr = self.trace
        now = float(self.step_count)
        if self.cfg.scheduler == "rr":
            picks: List[int] = []
            for _ in range(k):
                i, ptr = W.select_rr(self.rr_ptr, self.st.queue_len,
                                     mask=self.st.cur_occup < caps)
                if i < 0:
                    break
                if tr is not None:
                    TR.record_rr_pick(
                        tr, now, TR.K_PU_RR, i,
                        np.where(self.st.cur_occup < caps,
                                 self.st.queue_len, 0),
                        self.st.bvt)
                self.rr_ptr = ptr
                self.st.queue_len[i] -= 1
                self.st.cur_occup[i] += 1
                picks.append(i)
            return picks
        if tr is None:
            return [int(t) for t in
                    W.select_k(self.st, self.cfg.max_slots, k, cap=caps)
                    if t >= 0]
        # decision provenance (DESIGN.md §10): stage picks + post-round
        # state; commit reconstructs the pre-round arrays — the
        # scheduler itself stays untouched
        picks = [int(t) for t in
                 W.select_k(self.st, self.cfg.max_slots, k, cap=caps)
                 if t >= 0]
        TR.record_wlbvt_round(tr, now, self.st, picks, self.cfg.max_slots,
                              TR.K_PU_WLBVT, cap=caps)
        return picks

    def _assign_slots(self) -> None:
        k = int(self.slots.free_slots().size)
        if k == 0:
            return
        picks = self._select_round(k)
        if not picks:
            return
        keep = np.ones(self.cfg.max_slots, bool)
        tr = self.trace
        for t in picks:
            req = self.queues[t].popleft()
            s = self.slots.take(t)
            req.slot = s
            req.status = RequestStatus.PREFILL
            req.start_step = self.step_count
            self.slot_req[s] = req
            self.lengths[s] = 0
            keep[s] = False
            if tr is not None:
                uid = self._tr_uid_by_rid.get(req.rid, -1)
                now = float(self.step_count)
                tr.span_end(TR.ST_FMQ, uid, now, TR.D_OK, pu=s)
                tr.span(TR.ST_GRANT, uid, t, now, now, TR.D_OK, pu=s)
        # invalidate stale cache rows for every slot assigned this step in
        # ONE batched call (R3 isolation, single XLA invocation)
        self.exe.reset(keep)

    def _finish(self, slot: int, status: RequestStatus,
                kill_kind: EventKind = EventKind.REQUEST_KILLED) -> None:
        req = self.slot_req[slot]
        req.status = status
        req.finish_step = self.step_count
        t = req.tenant_id
        tr = self.trace
        if tr is not None:
            uid = self._tr_uid_by_rid.pop(req.rid, -1)
            now = float(self.step_count)
            killed = status == RequestStatus.KILLED
            disp = TR.D_KILL if killed else TR.D_OK
            tr.span(TR.ST_PU, uid, t, float(req.start_step), now, disp,
                    pu=slot)
            tr.span(TR.ST_EQ, uid, t, now, now, disp, pu=slot)
        self.st.cur_occup[t] -= 1
        self.slots.release(slot)
        self.slot_req[slot] = None
        self.done.append(req)
        if self.tel is not None:
            killed = status == RequestStatus.KILLED
            self.tel.inc("killed" if killed else "completed", t)
            if not killed:
                self.tel.inc("bytes_out", t, len(req.generated))
            self.tel.lat(t, max(req.fct, 1))   # sojourn incl. queueing
        if status == RequestStatus.KILLED:
            self.eq[t].push(Event(t, kill_kind, self.step_count,
                                  f"rid={req.rid}"))

    def _prefill_phase(self) -> None:
        """Chunked prefill with DWRR tenant arbitration (R2): at most
        ``prefill_slots_per_step`` slots advance one fragment per step."""
        C = self.cfg.prefill_chunk
        tr = self.trace
        pending_slots: Dict[int, List[int]] = {}
        for s, r in enumerate(self.slot_req):
            if r is not None and r.status == RequestStatus.PREFILL:
                pending_slots.setdefault(r.tenant_id, []).append(s)
        if not pending_slots:
            return
        chosen: List[int] = []
        if self.cfg.arbiter == "fifo":
            # no-QoS baseline: oldest requests first regardless of tenant
            order = sorted(
                (s for ss in pending_slots.values() for s in ss),
                key=lambda s: self.slot_req[s].rid)
            chosen = order[: self.cfg.prefill_slots_per_step]
        else:
            T = self.cfg.max_tenants
            counts = np.zeros(T, np.int64)
            for i, ss in pending_slots.items():
                counts[i] = len(ss)
            head = np.full(T, float(C))
            d0 = self.dwrr.deficit.copy() if tr is not None else None
            c0 = counts.copy() if tr is not None else None
            picks = W.dwrr_select_k(self.dwrr, head, counts,
                                    quantum=float(C),
                                    k=self.cfg.prefill_slots_per_step)
            if tr is not None:
                TR.record_dwrr_round(
                    tr, float(self.step_count), TR.K_AXI_DWRR,
                    [int(i) for i in picks if i >= 0], d0, c0,
                    self.dwrr.weights)
            chosen = [pending_slots[int(i)].pop(0) for i in picks if i >= 0]

        if not chosen:
            return
        B = self.cfg.max_slots
        tokens = np.zeros((B, C), np.int32)
        valid_n = np.zeros(B, np.int32)
        for s in chosen:
            r = self.slot_req[s]
            n = min(C, r.prompt_len - r.prefill_done)
            tokens[s, :n] = r.prompt[r.prefill_done:r.prefill_done + n]
            valid_n[s] = n
        nxt = self.exe.prefill(tokens, self.lengths.copy(), valid_n)
        self.prefill_chunks += 1
        for s in chosen:
            r = self.slot_req[s]
            n = int(valid_n[s])
            r.prefill_done += n
            self.lengths[s] += n
            self._charge_tokens(r.tenant_id, n)
            r.chunk_steps.append(self.step_count)
            if tr is not None:
                # chunked prefill is the DMA-fragmentation analog: one
                # zero-width DMA marker per fragment (step clock has no
                # intra-step duration, so PU+FMQ still reconcile exactly)
                uid = self._tr_uid_by_rid.get(r.rid, -1)
                now = float(self.step_count)
                tr.span(TR.ST_DMA, uid, r.tenant_id, now, now, TR.D_OK,
                        pu=s)
            if r.prefill_done >= r.prompt_len:
                r.status = RequestStatus.DECODE
                r.generated.append(int(nxt[s]))
                self.last_tok[s] = nxt[s]
            if self._over_total_budget(r.tenant_id):
                self._finish(s, RequestStatus.KILLED,
                             kill_kind=EventKind.TOTAL_BUDGET_EXCEEDED)

    def _decode_phase(self) -> None:
        active = np.array([
            r is not None and r.status == RequestStatus.DECODE
            for r in self.slot_req])
        if not active.any():
            return
        nxt = self.exe.decode(self.last_tok.copy(), self.lengths.copy(),
                              active)
        self.decode_steps += 1
        for s in np.flatnonzero(active):
            r = self.slot_req[s]
            self.lengths[s] += 1
            r.generated.append(int(nxt[s]))
            self.last_tok[s] = nxt[s]
            self._charge_tokens(r.tenant_id, 1)
            limit = self.ectx[r.tenant_id].slo.kernel_cycle_limit
            if self._over_total_budget(r.tenant_id):
                self._finish(s, RequestStatus.KILLED,
                             kill_kind=EventKind.TOTAL_BUDGET_EXCEEDED)
            elif limit and r.total_tokens > limit:
                self._finish(s, RequestStatus.KILLED)
            elif len(r.generated) >= r.max_new_tokens:
                self._finish(s, RequestStatus.DONE)

    def _charge_tokens(self, tenant: int, n: int) -> None:
        self.budget.charge(tenant, n)
        if self.tel is not None:
            self.tel.inc("tokens", tenant, n)

    def _over_total_budget(self, tenant: int) -> bool:
        t = self.ectx.get(tenant)
        return t is not None and self.budget.over_total(
            tenant, t.slo.total_cycle_limit)

    def _kv_pressure(self) -> np.ndarray:
        caps = self.slots.quota_caps(self.cfg.max_tenants)
        held = np.bincount(self.slots.slot_tenant[self.slots.slot_tenant >= 0],
                           minlength=self.cfg.max_tenants)
        return held / np.maximum(caps, 1)

    def _commit_telemetry(self) -> None:
        """Per-step telemetry flush + gauge window (DESIGN.md §6): one
        counter/latency commit and one ring push — a single jitted call
        each on the jnp backend, so the data plane never syncs."""
        tel = self.tel
        gauges = np.zeros((len(GAUGES), self.cfg.max_tenants))
        gauges[G_IDX["occupancy"]] = self.st.cur_occup
        gauges[G_IDX["queue_len"]] = self.st.queue_len
        gauges[G_IDX["service_rate"]] = tel.staged("tokens")
        gauges[G_IDX["kv_pressure"]] = self._kv_pressure()
        tel.commit()
        tel.commit_window(gauges)
        obs_every = (self.cfg.observe_interval or self.cfg.qos_interval
                     or 16)
        if (self.step_count > 0 and self.step_count % obs_every == 0):
            self.observe_tick(
                t=float(self.step_count), prio=self.st.prio,
                total_occup=self.st.total_occup, bvt=self.st.bvt,
                kv_pressure=gauges[G_IDX["kv_pressure"]])
        if (self.controller is not None and self.cfg.qos_interval
                and self.step_count > 0
                and self.step_count % self.cfg.qos_interval == 0):
            self.qos_tick(
                prio=self.st.prio, total_occup=self.st.total_occup,
                bvt=self.st.bvt, kv_pressure=gauges[G_IDX["kv_pressure"]],
                knobs=((self.st.prio, self._prio_base),
                       (self.dwrr.weights, self._dwrr_base)),
                installed=self._installed,
                t=float(self.step_count))

    def step(self) -> None:
        # R5: control traffic first
        while self._control:
            self._control.popleft()()
        self._assign_slots()
        self._prefill_phase()
        self._decode_phase()
        # WLBVT accounting + fairness (per engine step = one "cycle")
        W.advance(self.st, 1.0)
        act = self.st.active & self._installed
        if act.sum() >= 2:
            self.fairness.update(
                self.st.cur_occup[act], 1.0,
                weights=self.st.prio[act])
        if self.tel is not None:
            self._commit_telemetry()
        if self.trace is not None:
            self.trace.maybe_commit()
        self.step_count += 1

    def run(self, steps: int) -> None:
        for _ in range(steps):
            self.step()

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            busy = any(r is not None for r in self.slot_req) or \
                any(len(q) for q in self.queues.values())
            if not busy:
                return
            self.step()
        raise RuntimeError("engine did not drain")  # pragma: no cover

    # ------------------------------------------------------------------
    def metrics(self) -> Dict[str, Any]:
        """Untyped engine counters.

        Deprecated as a public surface: external consumers should run
        through ``repro.api`` (``ServeRuntime``/``run_scenario``) and
        consume the schema-validated ``RunReport`` instead (DESIGN.md
        §7)."""
        per_tenant: Dict[int, Dict[str, float]] = {}
        for r in self.done:
            d = per_tenant.setdefault(r.tenant_id, {
                "done": 0, "killed": 0, "fct_sum": 0.0, "tokens": 0})
            if r.status == RequestStatus.DONE:
                d["done"] += 1
                d["fct_sum"] += r.fct
                d["tokens"] += r.total_tokens
            else:
                d["killed"] += 1
        for t, d in per_tenant.items():
            d["mean_fct"] = d["fct_sum"] / max(d["done"], 1)
        return {
            "steps": self.step_count,
            "jain_timeavg": self.fairness.value,
            "decode_steps": self.decode_steps,
            "prefill_chunks": self.prefill_chunks,
            "tenants": per_tenant,
        }

    def telemetry_report(self) -> Dict[str, Any]:
        """Per-tenant telemetry plane report (latency units = steps)."""
        if self.tel is None:
            return {"telemetry": "disabled"}
        self.tel.commit()
        names = {t: e.name for t, e in self.ectx.items()}
        return tenant_report(self.tel, names=names)
