"""Token sampling: greedy / temperature / top-k, batched and jittable."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sample(logits: jnp.ndarray, *, temperature: float = 0.0,
           top_k: int = 0, rng: Optional[jax.Array] = None) -> jnp.ndarray:
    """logits: (B, V) fp32 -> (B,) int32.

    temperature == 0 => greedy.  top_k > 0 restricts to the k best before
    the categorical draw.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / temperature
    if top_k > 0:
        kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    assert rng is not None, "temperature sampling needs an rng"
    return jax.random.categorical(rng, scaled, axis=-1).astype(jnp.int32)
