"""Slot-based KV cache manager with static per-tenant quotas (R3).

The paper allocates each tenant a *static* sNIC memory segment at ECTX
creation; here the fixed pool is ``max_slots × max_len`` cache tokens and a
tenant's segment caps how many concurrent batch slots it may hold
(``quota_tokens // max_len``).  No paging — an over-quota admission errors
out (AdmissionError), and slot writes are bounds-checked against the
owning tenant (the PMP analogue).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core.admission import AdmissionError, SegmentAllocator


@dataclasses.dataclass
class SlotManager:
    max_slots: int
    max_len: int
    overcommit: float = 1.0   # >1: bounded quota oversubscription; physical
    #                           exhaustion then backpressures at take()

    def __post_init__(self):
        self.alloc = SegmentAllocator(
            pool_size=int(self.max_slots * self.max_len * self.overcommit))
        self.slot_tenant = np.full(self.max_slots, -1, np.int64)
        self.quota_slots: Dict[int, int] = {}

    # -- admission (control plane) -----------------------------------------
    def admit(self, tenant: int, kv_quota_tokens: int = 0) -> int:
        """Reserve a static segment; returns the tenant's slot cap."""
        quota = kv_quota_tokens or self.max_len  # default: 1 slot worth
        self.alloc.allocate(tenant, quota)
        cap = max(1, quota // self.max_len)
        self.quota_slots[tenant] = cap
        return cap

    def evict(self, tenant: int) -> None:
        self.alloc.free(tenant)
        self.quota_slots.pop(tenant, None)
        self.slot_tenant[self.slot_tenant == tenant] = -1

    # -- slot data plane -----------------------------------------------------
    def free_slots(self) -> np.ndarray:
        return np.flatnonzero(self.slot_tenant < 0)

    def held(self, tenant: int) -> int:
        return int((self.slot_tenant == tenant).sum())

    def can_take(self, tenant: int) -> bool:
        return self.held(tenant) < self.quota_slots.get(tenant, 0)

    def quota_caps(self, num_tenants: int) -> np.ndarray:
        """Vectorized per-tenant slot caps (0 for unadmitted tenants) —
        folded into batched scheduler eligibility (R3)."""
        caps = np.zeros(num_tenants, np.int64)
        for t, c in self.quota_slots.items():
            if 0 <= t < num_tenants:
                caps[t] = c
        return caps

    def take(self, tenant: int) -> int:
        if not self.can_take(tenant):
            raise AdmissionError(f"tenant {tenant} over KV quota")
        free = self.free_slots()
        if free.size == 0:
            raise AdmissionError("no free slots")
        s = int(free[0])
        self.slot_tenant[s] = tenant
        return s

    def release(self, slot: int) -> None:
        self.slot_tenant[slot] = -1

    def check_access(self, tenant: int, slot: int) -> bool:
        """PMP-style bounds check: a tenant may only touch its own slots."""
        return 0 <= slot < self.max_slots and self.slot_tenant[slot] == tenant
