"""Jitted serving steps: batched chunked-prefill and decode.

``build_serve_fns(cfg, mesh, batch, max_len, ...)`` returns the data-plane
programs the engine (and the dry-run) calls:

  * ``prefill_chunk(params, cache, tokens(B,C), lengths(B,), valid_n(B,))``
      -> (next_token (B,), last_logits (B,V), cache)
    Ragged tails are exact: pad entries are written with position -1 and
    recurrent state is untouched past valid_n (see models' ``valid`` path).
  * ``decode(params, cache, tokens(B,), lengths(B,), active(B,))``
      -> (next_token (B,), cache)
  * ``reset_slots(cache, keep_mask(B,))`` — zero/invalidate freed slots'
    cache rows so re-assigned slots never attend to a previous tenant's KV
    (the paper's memory-isolation requirement R3 at the cache level).

Every function is jitted with donated cache and explicit shardings when a
mesh is supplied; ``decode`` is exactly what launch/dryrun.py lowers for
the decode_32k / long_500k cells.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import sharding as SH
from repro.models.registry import Model, build_model
from repro.serving.sampler import sample


@dataclasses.dataclass
class ServeFns:
    cfg: ModelConfig
    model: Model
    init_params: Callable[[jax.Array], Any]
    init_cache: Callable[[], Any]
    prefill_chunk: Callable[..., Tuple[jnp.ndarray, jnp.ndarray, Any]]
    decode: Callable[..., Tuple[jnp.ndarray, Any]]
    reset_slots: Callable[[Any, jnp.ndarray], Any]
    param_shardings: Any = None
    cache_shardings: Any = None


def _cache_batch_dim(path_s: str, ndim: int) -> int:
    """Locate the slot/batch dim of a cache leaf by its key name."""
    last = path_s.rsplit("/", 1)[-1]
    if last == "pos" or last == "h":
        return ndim - 2
    if last in ("ckv", "krope") or last.startswith("conv"):
        return ndim - 3
    if last == "state":
        return ndim - 4
    return ndim - 4          # k / v / xk / xv


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        else:  # pragma: no cover
            parts.append(str(k))
    return "/".join(parts)


def make_reset_slots(cfg: ModelConfig):
    """reset(cache, keep (B,) bool) -> cache with dropped slots invalidated."""

    def reset(cache, keep):
        def leaf(path, x):
            p = _path_str(path)
            bdim = max(_cache_batch_dim(p, x.ndim), 0)
            shape = [1] * x.ndim
            shape[bdim] = x.shape[bdim]
            k = keep.reshape(shape)
            if p.rsplit("/", 1)[-1] == "pos":
                return jnp.where(k, x, -1)
            last = p.rsplit("/", 1)[-1]
            if last in ("h", "state") or last.startswith("conv"):
                return jnp.where(k, x, 0)
            return x          # k/v/ckv payloads are masked by pos
        return jax.tree_util.tree_map_with_path(leaf, cache)

    return reset


def build_serve_fns(cfg: ModelConfig, mesh: Optional[Mesh] = None, *,
                    batch: int, max_len: int, prefill_chunk: int = 256,
                    moe_impl: str = "gshard", temperature: float = 0.0,
                    donate: bool = True, shard_cache_length: bool = False
                    ) -> ServeFns:
    model = build_model(cfg, moe_impl=moe_impl)
    if cfg.window_size:
        prefill_chunk = min(prefill_chunk, cfg.window_size)
    SH.set_activation_mesh(mesh)   # in-scan activation anchors

    # ---- shardings ---------------------------------------------------------
    param_sh = cache_sh = tok_sh = scalar_sh = None
    if mesh is not None:
        params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        pspecs = SH.param_pspecs(cfg, params_sds, mesh, "serve")
        param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                is_leaf=lambda x: isinstance(x, P))
        cache_sds = jax.eval_shape(
            functools.partial(model.init_cache, batch, max_len))
        cspecs = SH.cache_pspecs(cfg, cache_sds, mesh,
                                 shard_length=shard_cache_length)
        cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                                is_leaf=lambda x: isinstance(x, P))
        bspec = SH.batch_pspec(mesh, batch)
        tok_sh = NamedSharding(mesh, bspec)
        scalar_sh = NamedSharding(mesh, bspec)

    # ---- step bodies ---------------------------------------------------------
    def _prefill(params, cache, tokens, lengths, valid_n):
        B, C = tokens.shape
        valid = jnp.arange(C)[None, :] < valid_n[:, None]
        logits, cache = model.prefill(params, tokens, cache, lengths,
                                      valid=valid)
        last = jnp.take_along_axis(
            logits, jnp.maximum(valid_n - 1, 0)[:, None, None], axis=1
        )[:, 0]                                           # (B, V)
        nxt = sample(last, temperature=temperature)
        return nxt, last, cache

    def _decode(params, cache, tokens, lengths, active):
        logits, cache = model.decode_step(
            params, tokens[:, None], cache, lengths,
            valid=active[:, None])
        nxt = sample(logits[:, -1], temperature=temperature)
        return nxt, cache

    reset = make_reset_slots(cfg)

    # ---- jit ----------------------------------------------------------------
    if mesh is not None:
        prefill_fn = jax.jit(
            _prefill,
            in_shardings=(param_sh, cache_sh, tok_sh, scalar_sh, scalar_sh),
            out_shardings=(scalar_sh, None, cache_sh),
            donate_argnums=(1,) if donate else ())
        decode_fn = jax.jit(
            _decode,
            in_shardings=(param_sh, cache_sh, scalar_sh, scalar_sh,
                          scalar_sh),
            out_shardings=(scalar_sh, cache_sh),
            donate_argnums=(1,) if donate else ())
        reset_fn = jax.jit(reset, in_shardings=(cache_sh, scalar_sh),
                           out_shardings=cache_sh,
                           donate_argnums=(0,) if donate else ())
        init_params = jax.jit(model.init, out_shardings=param_sh)
        init_cache = jax.jit(
            functools.partial(model.init_cache, batch, max_len),
            out_shardings=cache_sh)
    else:
        prefill_fn = jax.jit(_prefill, donate_argnums=(1,) if donate else ())
        decode_fn = jax.jit(_decode, donate_argnums=(1,) if donate else ())
        reset_fn = jax.jit(reset, donate_argnums=(0,) if donate else ())
        init_params = jax.jit(model.init)
        init_cache = jax.jit(functools.partial(model.init_cache, batch,
                                               max_len))

    return ServeFns(cfg=cfg, model=model, init_params=init_params,
                    init_cache=init_cache, prefill_chunk=prefill_fn,
                    decode=decode_fn, reset_slots=reset_fn,
                    param_shardings=param_sh, cache_shardings=cache_sh)
