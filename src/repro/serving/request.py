"""Serving requests: the 'packets' of the TPU adaptation (DESIGN.md §2)."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import numpy as np


class RequestStatus(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"
    KILLED = "killed"        # watchdog budget exceeded (paper §5.3)
    REJECTED = "rejected"    # admission failure (R3)


@dataclasses.dataclass
class Request:
    tenant_id: int
    prompt: np.ndarray                  # (P,) int32
    max_new_tokens: int = 32
    rid: int = -1                       # assigned by the engine
    arrival_step: int = -1
    status: RequestStatus = RequestStatus.QUEUED

    # progress
    prefill_done: int = 0               # tokens of prompt already processed
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    start_step: int = -1
    finish_step: int = -1
    chunk_steps: List[int] = dataclasses.field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_tokens(self) -> int:
        return self.prompt_len + len(self.generated)

    @property
    def fct(self) -> Optional[int]:
        if self.finish_step < 0:
            return None
        return self.finish_step - self.arrival_step
