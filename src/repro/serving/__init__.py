"""Multi-tenant serving engine: OSMOSIS scheduling over continuous batching."""
from repro.serving.engine import Engine, EngineConfig
from repro.serving.request import Request, RequestStatus
from repro.serving.sampler import sample

__all__ = ["Engine", "EngineConfig", "Request", "RequestStatus", "sample"]
