"""Process-level XLA environment tuning (DESIGN.md §13.5).

XLA options only take effect if ``XLA_FLAGS`` is set before the first
``import jax`` initializes the backend, so this module must stay free of
jax (and repro-module) imports and be called at entry-point top, before
anything that transitively pulls jax in.
"""
from __future__ import annotations

import os


def tune_cpu_for_scan_sweeps() -> None:
    """Pin the XLA:CPU options that favour long scan-dominated sweeps.

    The CPU thunk runtime dispatches every fused kernel through a
    thread-pool; a device-path sweep step is ~50 tiny ops over [R, T]
    lanes, so per-thunk dispatch dominates and the pool buys nothing
    (measured ~3x wall-clock on the 256-replica benchmark sweep).  The
    legacy emitter runs the step body inline.  No-op when the user
    already pins ``xla_cpu_use_thunk_runtime`` themselves, and harmless
    on non-CPU backends (the flag only affects the CPU client).
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_cpu_use_thunk_runtime" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_cpu_use_thunk_runtime=false").strip()
