"""Data pipeline: deterministic, checkpointable, host-sharded.

Two sources behind one iterator protocol (``next() -> batch dict``,
``state() -> dict``, ``restore(state)``):

  * ``SyntheticLM`` — counter-based PRNG stream (stateless hash of
    (seed, step, host)); exact resume = restoring an integer.  Markov-chain
    token transitions so the loss has learnable structure.
  * ``MemmapCorpus`` — tokenized corpus in a flat .bin memmap; shuffled
    window sampling keyed by (seed, step) — same exact-resume property.

Per-host sharding: each host draws only its slice of the global batch
(``host_batch = global_batch // num_hosts``); restore works across a
*different* host count because the stream is keyed by the global step.
A background prefetch thread keeps one batch ahead.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# synthetic LM stream
# ---------------------------------------------------------------------------
class SyntheticLM:
    """Markov-chain token stream; batch ~ (host_batch, seq+1) -> tokens/labels."""

    def __init__(self, cfg: ModelConfig, seq_len: int, global_batch: int,
                 seed: int = 0, host_index: int = 0, num_hosts: int = 1):
        assert global_batch % num_hosts == 0
        self.cfg = cfg
        self.seq_len = seq_len
        self.host_batch = global_batch // num_hosts
        self.seed = seed
        self.host_index = host_index
        self.num_hosts = num_hosts
        self.step = 0
        V = cfg.vocab_size
        # fixed sparse transition structure (derived from seed only)
        rs = np.random.RandomState(seed)
        self._next_tok = rs.randint(0, V, size=(V, 4)).astype(np.int64)

    def _rng(self, step: int) -> np.random.RandomState:
        key = (self.seed * 1_000_003 + step) * 97 + self.host_index
        return np.random.RandomState(key % (2**31 - 1))

    def __next__(self) -> Dict[str, np.ndarray]:
        rs = self._rng(self.step)
        B, S, V = self.host_batch, self.seq_len, self.cfg.vocab_size
        toks = np.empty((B, S + 1), np.int64)
        toks[:, 0] = rs.randint(0, V, size=B)
        choices = rs.randint(0, 4, size=(B, S))
        noise = rs.rand(B, S) < 0.1
        rand_toks = rs.randint(0, V, size=(B, S))
        for t in range(S):
            nxt = self._next_tok[toks[:, t], choices[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_toks[:, t], nxt)
        self.step += 1
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def state(self) -> Dict:
        return {"kind": "synthetic", "step": self.step, "seed": self.seed}

    def restore(self, st: Dict) -> None:
        assert st["kind"] == "synthetic"
        self.step = int(st["step"])
        self.seed = int(st["seed"])


# ---------------------------------------------------------------------------
# memmap corpus
# ---------------------------------------------------------------------------
class MemmapCorpus:
    """Flat int32 token file; samples random windows keyed by (seed, step)."""

    def __init__(self, path: str, cfg: ModelConfig, seq_len: int,
                 global_batch: int, seed: int = 0, host_index: int = 0,
                 num_hosts: int = 1):
        assert global_batch % num_hosts == 0
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.cfg = cfg
        self.seq_len = seq_len
        self.host_batch = global_batch // num_hosts
        self.seed = seed
        self.host_index = host_index
        self.step = 0
        self.path = path
        if len(self.tokens) < seq_len + 2:
            raise ValueError("corpus shorter than one sequence")

    def __next__(self) -> Dict[str, np.ndarray]:
        key = (self.seed * 1_000_003 + self.step) * 97 + self.host_index
        rs = np.random.RandomState(key % (2**31 - 1))
        B, S = self.host_batch, self.seq_len
        starts = rs.randint(0, len(self.tokens) - S - 1, size=B)
        rows = np.stack([self.tokens[s:s + S + 1] for s in starts])
        self.step += 1
        return {"tokens": rows[:, :-1].astype(np.int32),
                "labels": rows[:, 1:].astype(np.int32)}

    def state(self) -> Dict:
        return {"kind": "memmap", "step": self.step, "seed": self.seed,
                "path": self.path}

    def restore(self, st: Dict) -> None:
        self.step = int(st["step"])
        self.seed = int(st["seed"])


# ---------------------------------------------------------------------------
# prefetcher
# ---------------------------------------------------------------------------
class Prefetcher:
    """One-batch-ahead background prefetch; state delegates to the source.

    Checkpoint correctness: ``state()`` reports the number of batches the
    *consumer* has taken (source step minus what's still buffered), so
    save+restore never drops or replays a batch.
    """

    def __init__(self, source, depth: int = 2):
        self.source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._taken = 0
        self._lock = threading.Lock()
        self._stop = False
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        while not self._stop:
            try:
                item = next(self.source)
            except StopIteration:  # pragma: no cover
                self._q.put(None)
                return
            self._q.put(item)

    def __next__(self):
        item = self._q.get()
        if item is None:  # pragma: no cover
            raise StopIteration
        with self._lock:
            self._taken += 1
        return item

    def state(self) -> Dict:
        st = self.source.state()
        st = dict(st)
        st["step"] = self._taken
        return st

    def close(self):
        self._stop = True

    def restore(self, st: Dict) -> None:  # pragma: no cover
        self.source.restore(st)
        with self._lock:
            self._taken = int(st["step"])


def make_pipeline(cfg: ModelConfig, seq_len: int, global_batch: int, *,
                  corpus_path: Optional[str] = None, seed: int = 0,
                  host_index: int = 0, num_hosts: int = 1,
                  prefetch: bool = False):
    if corpus_path:
        src = MemmapCorpus(corpus_path, cfg, seq_len, global_batch,
                           seed=seed, host_index=host_index,
                           num_hosts=num_hosts)
    else:
        src = SyntheticLM(cfg, seq_len, global_batch, seed=seed,
                          host_index=host_index, num_hosts=num_hosts)
    return Prefetcher(src) if prefetch else src
