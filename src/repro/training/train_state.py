"""TrainState pytree: params + optimizer slots + step + data-RNG."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray                    # int32 scalar
    err_feedback: Optional[Any] = None   # gradient-compression residual

    @classmethod
    def create(cls, params, optimizer, *, compression: bool = False):
        from repro.distributed import compression as C
        return cls(
            params=params,
            opt_state=optimizer.init(params),
            step=jnp.zeros((), jnp.int32),
            err_feedback=C.init_error(params) if compression else None,
        )


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt_state, s.step, s.err_feedback), None),
    lambda _, ch: TrainState(*ch),
)
