"""Train-step factory: loss, grad-accumulation, optimizer, sharding glue.

``build_trainer(cfg, mesh)`` returns a ``Trainer`` whose ``train_step`` is
a jitted (state, batch) -> (state, metrics) with:

  * cross-entropy loss over vocab-sharded fp32 logits (+ z-loss, + MoE aux),
  * gradient accumulation (lax.scan over microbatches; grads in fp32),
  * AdamW / Adafactor with cosine schedule and global-norm clipping,
  * ZeRO-3: params and optimizer slots sharded over data+model (rules in
    distributed/sharding.py); XLA inserts the gradient reduce-scatters.

The same factory produces the AOT-lowerable step used by launch/dryrun.py:
every argument has an explicit PartitionSpec so ``.lower().compile()``
works from ShapeDtypeStructs alone.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import sharding as SH
from repro.models.registry import Model, build_model
from repro.training import optimizer as OPT
from repro.training.train_state import TrainState

Z_LOSS = 1e-4
MOE_AUX = 1e-2


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------
def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """logits fp32 (B,S,V); labels int32 (B,S), -1 = masked.
    Returns (summed loss, token count)."""
    mask = (labels >= 0)
    lse = jax.nn.logsumexp(logits, axis=-1)                      # (B,S)
    lab = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = (lse - lab) + Z_LOSS * jnp.square(lse)
    nll = jnp.where(mask, nll, 0.0)
    return jnp.sum(nll), jnp.sum(mask)


def make_loss_fn(model: Model, cfg: ModelConfig, mesh: Optional[Mesh]):
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        if mesh is not None:
            logits = SH.constrain(logits, mesh,
                                  ("pod", "data"), None, "model")
        loss_sum, n_tok = cross_entropy(logits, batch["labels"])
        loss = loss_sum / jnp.maximum(n_tok, 1.0)
        if cfg.moe is not None:
            loss = loss + MOE_AUX * aux / max(cfg.num_layers, 1)
        return loss, {"ntok": n_tok}
    return loss_fn


# ---------------------------------------------------------------------------
# trainer
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Trainer:
    cfg: ModelConfig
    model: Model
    optimizer: OPT.Optimizer
    mesh: Optional[Mesh]
    train_step: Callable[[TrainState, Dict[str, jnp.ndarray]],
                         Tuple[TrainState, Dict[str, jnp.ndarray]]]
    init_state: Callable[[jax.Array], TrainState]
    state_pspecs: Any
    batch_pspecs: Any


def opt_state_pspecs(cfg: ModelConfig, params_sds: Any, pspecs: Any) -> Any:
    """PartitionSpecs for the optimizer slots, mirroring param specs."""
    if cfg.optimizer == "adamw":
        return {"m": pspecs, "v": pspecs, "step": P()}

    def one(sds, spec):
        t = tuple(spec)
        t = t + (None,) * (len(sds.shape) - len(t))
        if len(sds.shape) >= 2:
            return {"v_row": P(*t[:-1]), "v_col": P(*(t[:-2] + t[-1:]))}
        return {"v": P(*t)}

    slots = jax.tree.map(one, params_sds, pspecs,
                         is_leaf=lambda x: hasattr(x, "shape"))
    return {"slots": slots, "step": P()}


def build_trainer(cfg: ModelConfig, mesh: Optional[Mesh] = None, *,
                  total_steps: int = 10_000, warmup_steps: int = 100,
                  grad_accum: Optional[int] = None,
                  moe_impl: str = "gshard", donate: bool = True,
                  seq_parallel: bool = False) -> Trainer:
    model = build_model(cfg, moe_impl=moe_impl)
    opt = OPT.make_optimizer(cfg, total_steps, warmup_steps)
    accum = grad_accum if grad_accum is not None else cfg.grad_accum
    loss_fn = make_loss_fn(model, cfg, mesh)
    # in-scan activation anchors; seq_parallel shards the residual stream
    # over 'model' between blocks (memory term -42% on qwen3 train,
    # EXPERIMENTS.md §Perf iteration 7) at the cost of more collectives
    SH.set_activation_mesh(mesh, "model" if seq_parallel else None)

    # ---- sharding specs ----------------------------------------------------
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if mesh is not None:
        pspecs = SH.param_pspecs(cfg, params_sds, mesh, "train")
        ospecs = opt_state_pspecs(cfg, params_sds, pspecs)
        state_pspecs = TrainState(params=pspecs, opt_state=ospecs,
                                  step=P(), err_feedback=None)
        state_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), state_pspecs,
            is_leaf=lambda x: isinstance(x, P))
    else:
        pspecs = state_pspecs = state_shardings = None

    # ---- step --------------------------------------------------------------
    def _grads(params, batch):
        if accum <= 1:
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, grads

        def mb(leaf):  # (B, ...) -> (A, B/A, ...)
            B = leaf.shape[0]
            return leaf.reshape(accum, B // accum, *leaf.shape[1:])

        mbatch = jax.tree.map(mb, batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)

        def body(carry, xs):
            loss_acc, g_acc = carry
            (loss, aux), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, xs)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (loss_acc + loss, g_acc), None

        (loss_sum, gsum), _ = jax.lax.scan(body, (0.0, zero), mbatch)
        grads = jax.tree.map(lambda g: g / accum, gsum)
        return loss_sum / accum, grads

    def step_fn(state: TrainState, batch):
        loss, grads = _grads(state.params, batch)
        gnorm = OPT.global_norm(grads)
        updates, new_opt = opt.update(grads, state.opt_state, state.params)
        new_params = OPT.apply_updates(state.params, updates)
        new_state = TrainState(params=new_params, opt_state=new_opt,
                               step=state.step + 1,
                               err_feedback=state.err_feedback)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": new_state.step}
        return new_state, metrics

    def _init(rng):
        params = model.init(rng)
        return TrainState.create(params, opt)

    if mesh is not None:
        train_step = jax.jit(
            step_fn,
            in_shardings=(state_shardings, None),
            out_shardings=(state_shardings, None),
            donate_argnums=(0,) if donate else ())
        init_state = jax.jit(_init, out_shardings=state_shardings)
    else:
        train_step = jax.jit(step_fn, donate_argnums=(0,) if donate else ())
        init_state = jax.jit(_init)

    return Trainer(cfg=cfg, model=model, optimizer=opt, mesh=mesh,
                   train_step=train_step, init_state=init_state,
                   state_pspecs=state_pspecs,
                   batch_pspecs=None)
