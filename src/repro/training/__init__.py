"""Training substrate: optimizers, train step, checkpointing, data."""
from repro.training.optimizer import (adafactor, adamw, cosine_schedule,
                                      global_norm, make_optimizer)
from repro.training.train_state import TrainState

__all__ = ["adafactor", "adamw", "cosine_schedule", "global_norm",
           "make_optimizer", "TrainState"]
