"""From-scratch optimizers (no optax in the container).

Functional API mirroring optax:

    opt = make_optimizer(cfg, total_steps)
    state = opt.init(params)                 # sharded like params (ZeRO)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

AdamW keeps fp32 ``m``/``v`` sharded identically to the params (running the
init inside jit makes the zeros inherit the param sharding = ZeRO-3 state
partitioning for free).  Adafactor factors the second moment over the last
two dims (row/col accumulators), the HBM-budget choice for the 400B MoE.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (g, st, p) -> (u, st)


# ---------------------------------------------------------------------------
# schedules / utilities
# ---------------------------------------------------------------------------
def cosine_schedule(base_lr: float, total_steps: int,
                    warmup_steps: int = 100, min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw(lr_fn, *, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, max_grad_norm: float = 1.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, st, params):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        step = st["step"] + 1
        t = step.astype(jnp.float32)
        lr = lr_fn(step)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def one(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / bc1
            vh = v / bc2
            u = -lr * (mh / (jnp.sqrt(vh) + eps)
                       + weight_decay * p.astype(jnp.float32))
            return u, m, v

        flat_g, tree = jax.tree.flatten(grads)
        flat_m = jax.tree.leaves(st["m"])
        flat_v = jax.tree.leaves(st["v"])
        flat_p = jax.tree.leaves(params)
        out = [one(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = jax.tree.unflatten(tree, [o[0] for o in out])
        new_st = {"m": jax.tree.unflatten(tree, [o[1] for o in out]),
                  "v": jax.tree.unflatten(tree, [o[2] for o in out]),
                  "step": step}
        return updates, new_st

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; Shazeer & Stern 2018)
# ---------------------------------------------------------------------------
def adafactor(lr_fn, *, decay_pow: float = 0.8, clip_threshold: float = 1.0,
              eps: float = 1e-30, weight_decay: float = 0.0,
              max_grad_norm: float = 1.0) -> Optimizer:
    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {
                    "v_row": jnp.zeros(p.shape[:-1], jnp.float32),
                    "v_col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"slots": jax.tree.map(one, params,
                                      is_leaf=lambda x: hasattr(x, "shape")),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, st, params):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        step = st["step"] + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-decay_pow)
        lr = lr_fn(step)

        def one(g, slot, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(g.shape):
                v_row = beta2 * slot["v_row"] + (1 - beta2) * jnp.mean(g2, -1)
                v_col = beta2 * slot["v_col"] + (1 - beta2) * jnp.mean(g2, -2)
                r = v_row / jnp.maximum(
                    jnp.mean(v_row, axis=-1, keepdims=True), eps)
                vhat = r[..., None] * v_col[..., None, :]
                new_slot = {"v_row": v_row, "v_col": v_col}
            else:
                vhat = beta2 * slot["v"] + (1 - beta2) * g2
                new_slot = {"v": vhat}
            u = g * jax.lax.rsqrt(jnp.maximum(vhat, eps))
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            u = -lr * u
            if weight_decay:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return u, new_slot

        is_slot = lambda x: isinstance(x, dict) and ("v" in x or "v_row" in x)
        flat_g, tree = jax.tree.flatten(grads)
        flat_s = jax.tree.leaves(st["slots"], is_leaf=is_slot)
        flat_p = jax.tree.leaves(params)
        out = [one(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        updates = jax.tree.unflatten(tree, [o[0] for o in out])
        new_slots = jax.tree.unflatten(tree, [o[1] for o in out])
        return updates, {"slots": new_slots, "step": step}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
def make_optimizer(cfg: ModelConfig, total_steps: int = 10_000,
                   warmup_steps: int = 100) -> Optimizer:
    lr_fn = cosine_schedule(cfg.learning_rate, total_steps, warmup_steps)
    if cfg.optimizer == "adafactor":
        return adafactor(lr_fn)
    if cfg.optimizer == "adamw":
        return adamw(lr_fn)
    raise ValueError(f"unknown optimizer {cfg.optimizer!r}")
