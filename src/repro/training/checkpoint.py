"""Sharded checkpointing with atomic commit and elastic resharding.

Layout (one directory per step):

    ckpt_dir/
      step_000420/
        index.json            # tree structure, shapes, dtypes, shard files
        <leaf-id>.s<k>.npy    # one file per saved shard (global slice)
      LATEST                  # atomically renamed pointer file

Save: every process writes only its *addressable* shards (each annotated
with its global slice), then process 0 commits by renaming a tmp dir and
rewriting LATEST — a torn save is never visible.  An optional background
thread makes saves asynchronous (training continues while the previous
step serializes).

Restore: ``load(dir, target)`` assembles each device's required global
slice from whichever saved shard files overlap it — the saved mesh and the
restoring mesh are independent, so a checkpoint written on (data=16,
model=16) restores onto (data=4, model=2) or a single host (elastic
scaling / failure recovery).  Data-pipeline state rides in index.json.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# tree path <-> string ids
# ---------------------------------------------------------------------------
def _leaf_id(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):  # pragma: no cover
            parts.append(str(k.name))
        else:  # pragma: no cover
            parts.append(str(k))
    return ".".join(parts) or "root"


def _slices_to_json(idx: Tuple[slice, ...], shape) -> List[List[int]]:
    out = []
    for s, n in zip(idx, shape):
        start = 0 if s.start is None else int(s.start)
        stop = n if s.stop is None else int(s.stop)
        out.append([start, stop])
    return out


# ---------------------------------------------------------------------------
# save
# ---------------------------------------------------------------------------
def save(state: Any, ckpt_dir: str, step: int, *,
         extra: Optional[Dict] = None, keep: int = 3,
         process_index: Optional[int] = None) -> str:
    """Write a checkpoint for ``step``; returns the committed directory."""
    pid = jax.process_index() if process_index is None else process_index
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp{pid}"
    os.makedirs(tmp, exist_ok=True)

    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    index: Dict[str, Any] = {
        "step": step,
        "treedef": None,   # reconstructed from target on load
        "leaves": {},
        "extra": extra or {},
    }
    for path, leaf in flat:
        lid = _leaf_id(path)
        arr = leaf if isinstance(leaf, jax.Array) else jnp.asarray(leaf)
        entry = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                 "shards": []}
        shards = getattr(arr, "addressable_shards", None)
        if shards:
            seen = set()
            for k, sh in enumerate(shards):
                idx = sh.index if sh.index else (slice(None),) * arr.ndim
                idx_json = _slices_to_json(idx, arr.shape)
                key = tuple(map(tuple, idx_json))
                if key in seen:      # replicated shards: save once
                    continue
                seen.add(key)
                fname = f"{lid}.s{k}.npy"
                np.save(os.path.join(tmp, fname), np.asarray(sh.data))
                entry["shards"].append({"file": fname, "index": idx_json})
        else:  # plain numpy / scalar leaf
            fname = f"{lid}.s0.npy"
            np.save(os.path.join(tmp, fname), np.asarray(arr))
            entry["shards"].append({
                "file": fname,
                "index": _slices_to_json((slice(None),) * arr.ndim,
                                         arr.shape)})
        index["leaves"][lid] = entry

    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump(index, f)
    # commit (process 0 on multi-host; unconditional single-process)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _write_latest(ckpt_dir, step)
    _gc(ckpt_dir, keep)
    return final


def _write_latest(ckpt_dir: str, step: int) -> None:
    tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(ckpt_dir, "LATEST"))


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp0"))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


# ---------------------------------------------------------------------------
# async wrapper
# ---------------------------------------------------------------------------
class AsyncCheckpointer:
    """Fire-and-forget saves on a background thread (one in flight)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, state: Any, step: int, extra: Optional[Dict] = None):
        self.wait()
        # device->host copy happens here (synchronously, consistent snapshot)
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def work():
            try:
                save(host_state, self.ckpt_dir, step,
                     extra=extra, keep=self.keep)
            except BaseException as e:  # pragma: no cover
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            raise self.last_error


# ---------------------------------------------------------------------------
# load (with resharding)
# ---------------------------------------------------------------------------
def load(ckpt_dir: str, target: Any, step: Optional[int] = None,
         shardings: Optional[Any] = None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``target`` (tree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional tree of Shardings; default
    = each target leaf's own sharding (or unsharded host arrays).

    Returns (state, extra).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no LATEST in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "index.json")) as f:
        index = json.load(f)

    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                  else [getattr(l, "sharding", None) for _, l in flat])

    out_leaves = []
    cache: Dict[str, np.ndarray] = {}

    def read(fname: str) -> np.ndarray:
        if fname not in cache:
            cache[fname] = np.load(os.path.join(d, fname))
        return cache[fname]

    for (path, leaf), shd in zip(flat, shard_flat):
        lid = _leaf_id(path)
        if lid not in index["leaves"]:
            raise KeyError(f"checkpoint missing leaf {lid}")
        entry = index["leaves"][lid]
        shape = tuple(entry["shape"])
        dtype = np.dtype(entry["dtype"])
        if tuple(leaf.shape) != shape:
            raise ValueError(
                f"{lid}: target shape {leaf.shape} != saved {shape}")

        def assemble(global_idx: Tuple[slice, ...]) -> np.ndarray:
            want = [(0 if s.start is None else s.start,
                     shape[i] if s.stop is None else s.stop)
                    for i, s in enumerate(global_idx)]
            out = np.zeros([b - a for a, b in want], dtype)
            for sh in entry["shards"]:
                have = [tuple(x) for x in sh["index"]]
                inter = [(max(a, c), min(b, e))
                         for (a, b), (c, e) in zip(want, have)]
                if any(a >= b for a, b in inter):
                    continue
                src = tuple(slice(a - c, b - c)
                            for (a, b), (c, _) in zip(inter, have))
                dst = tuple(slice(a - w, b - w)
                            for (a, b), (w, _) in zip(inter, want))
                out[dst] = read(sh["file"])[src]
            return out

        if shd is not None and hasattr(shd, "device_set"):
            arr = jax.make_array_from_callback(shape, shd, assemble)
        else:
            arr = jnp.asarray(assemble((slice(None),) * len(shape)), dtype)
        out_leaves.append(arr)
        cache.clear()

    state = jax.tree_util.tree_unflatten(treedef, out_leaves)
    return state, index.get("extra", {})
