"""Pass 4 — frozen-spec + fixed-shape discipline (DESIGN.md §9.5).

Two related invariants:

  * **frozen-spec** — ``ScenarioSpec``/``TenantSpec`` (and the other
    ``api/spec.py`` frozen dataclasses) are immutable inputs: after
    construction nothing may assign to their attributes, ``setattr``
    them, or smuggle writes through ``object.__setattr__``.  Evolution
    goes through ``spec.replace(...)`` / ``dataclasses.replace``.
    Spec-typed names are recognized from parameter annotations,
    constructor calls, ``.replace()`` results, and the conventional
    ``spec`` parameter name.

  * **fixed-shape** — telemetry collector kernels (the ``xp``-generic
    functions in ``telemetry/metrics.py``) must allocate fixed shapes
    only: no ``nonzero``/``unique``-style data-dependent producers and
    no boolean-mask indexing, which would break the single-jit-per-step
    commit path.
"""
from __future__ import annotations

import ast
from typing import List, Set, Tuple

from repro.analysis.framework import (
    Module, Finding, RepoIndex, Rule, register_rule,
)

SPEC_CLASSES = {
    "ScenarioSpec", "TenantSpec", "WorkloadSpec", "ArrivalSpec",
    "ControllerSpec", "ServeSpec",
}
SPEC_PARAM_NAMES = {"spec"}
# the defining module may use object.__setattr__ in __post_init__
DEFINING_MODULES = ("src/repro/api/spec.py",)

DYNAMIC_SHAPE_ATTRS = {"nonzero", "flatnonzero", "unique", "argwhere"}


def _ann_name(ann) -> str:
    if isinstance(ann, ast.Name):
        return ann.id
    if isinstance(ann, ast.Attribute):
        return ann.attr
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        return ann.value.split(".")[-1]
    if isinstance(ann, ast.Subscript):     # Optional[TenantSpec]
        return _ann_name(ann.slice)
    return ""


def _spec_names_in(fn: ast.AST) -> Set[str]:
    """Names bound to spec instances inside one function scope."""
    names: Set[str] = set()
    args = fn.args
    for a in args.posonlyargs + args.args + args.kwonlyargs:
        if _ann_name(a.annotation) in SPEC_CLASSES or \
                a.arg in SPEC_PARAM_NAMES:
            names.add(a.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            fname = node.value.func
            called = (fname.id if isinstance(fname, ast.Name)
                      else fname.attr if isinstance(fname, ast.Attribute)
                      else "")
            is_ctor = called in SPEC_CLASSES
            is_replace = (called == "replace"
                          and isinstance(fname, ast.Attribute)
                          and isinstance(fname.value, ast.Name)
                          and fname.value.id in names)
            if is_ctor or is_replace:
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        names.add(t.id)
    return names


@register_rule
class FrozenSpecRule(Rule):
    name = "frozen-spec"
    description = ("ScenarioSpec/TenantSpec and friends are immutable "
                   "after construction — use spec.replace(...)")

    def __init__(self, scope: Tuple[str, ...] = ("src/*", "benchmarks/*",
                                                 "examples/*"),
                 defining: Tuple[str, ...] = DEFINING_MODULES):
        self.scope = scope
        self.defining = defining

    def run(self, index: RepoIndex) -> List[Finding]:
        findings: List[Finding] = []
        for mod in index.matching(list(self.scope)):
            if mod.path in self.defining:
                continue
            for node in ast.walk(mod.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    findings.extend(self._check_scope(mod, node))
        return findings

    def _check_scope(self, mod: Module, fn: ast.AST) -> List[Finding]:
        findings: List[Finding] = []
        specs = _spec_names_in(fn)
        if not specs:
            return findings

        def is_spec_attr(target: ast.AST) -> bool:
            return (isinstance(target, ast.Attribute)
                    and isinstance(target.value, ast.Name)
                    and target.value.id in specs)

        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if is_spec_attr(t):
                        findings.append(self.finding(
                            mod, t,
                            f"assignment to frozen spec attribute "
                            f"`{t.value.id}.{t.attr}`; build a new spec "
                            "with `.replace(...)`"))
            elif isinstance(node, ast.AugAssign) and is_spec_attr(node.target):
                t = node.target
                findings.append(self.finding(
                    mod, t,
                    f"in-place update of frozen spec attribute "
                    f"`{t.value.id}.{t.attr}`"))
            elif isinstance(node, ast.Call):
                f = node.func
                if (isinstance(f, ast.Name) and f.id == "setattr"
                        and node.args
                        and isinstance(node.args[0], ast.Name)
                        and node.args[0].id in specs):
                    findings.append(self.finding(
                        mod, node,
                        f"setattr on frozen spec `{node.args[0].id}`"))
                elif (isinstance(f, ast.Attribute)
                      and f.attr == "__setattr__" and node.args
                      and isinstance(node.args[0], ast.Name)
                      and node.args[0].id in specs):
                    findings.append(self.finding(
                        mod, node,
                        f"`object.__setattr__` bypasses the frozen spec "
                        f"contract on `{node.args[0].id}`"))
        return findings


@register_rule
class FixedShapeRule(Rule):
    name = "fixed-shape"
    description = ("telemetry collector kernels must allocate fixed "
                   "shapes: no data-dependent producers or boolean-mask "
                   "indexing")

    def __init__(self, scope: Tuple[str, ...] = ("src/repro/telemetry/*",)):
        self.scope = scope

    def run(self, index: RepoIndex) -> List[Finding]:
        findings: List[Finding] = []
        for mod in index.matching(list(self.scope)):
            for fn in ast.walk(mod.tree):
                if not isinstance(fn, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    continue
                args = fn.args
                names = {a.arg for a in (args.posonlyargs + args.args
                                         + args.kwonlyargs)}
                if "xp" not in names:
                    continue
                findings.extend(self._check_kernel(mod, fn))
        return findings

    def _check_kernel(self, mod: Module, fn: ast.AST) -> List[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                f = node.func
                attr = (f.attr if isinstance(f, ast.Attribute)
                        else f.id if isinstance(f, ast.Name) else None)
                if attr in DYNAMIC_SHAPE_ATTRS:
                    findings.append(self.finding(
                        mod, node,
                        f"`{attr}` allocates a data-dependent shape in a "
                        "telemetry collector kernel"))
                elif (attr == "where" and len(node.args) == 1
                      and not node.keywords):
                    findings.append(self.finding(
                        mod, node,
                        "one-argument `where` is data-dependent; use the "
                        "three-argument select form"))
            elif (isinstance(node, ast.Subscript)
                  and isinstance(node.slice, ast.Compare)):
                findings.append(self.finding(
                    mod, node,
                    "boolean-mask indexing yields a data-dependent shape "
                    "in a telemetry collector kernel"))
        return findings
