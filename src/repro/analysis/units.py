"""Pass 2 — time-unit flow checker (DESIGN.md §9.3).

Lightweight unit inference over the repo's four time domains — virtual
nanoseconds (sim), microseconds (scenario specs), PU cycles (PsPIN
hardware costs), and engine steps (serving) — without executing any
code.  Units are sourced from:

  * name suffixes: ``*_ns`` / ``*_us`` / ``*_cycles`` / ``*_steps``;
  * repo-known bare names (``now`` is virtual ns inside ``sim/``);
  * conversion idioms: ``x_us * 1e3`` -> ns, ``x_ns / 1e3`` -> us,
    ``hw.cycles_ns(c)`` -> ns, ``wire_ns_per_byte(...)`` -> ns/byte
    (so ``nbytes * ns_per_byte`` -> ns);

and flow forward through local assignments.  Findings fire on
cross-unit ``+``/``-``/comparisons, on assigning a value of one unit to
a name suffixed with another, on keyword arguments whose name declares
a different unit than the value carries, on non-cycles arguments to
``cycles_ns``, and on ``time_unit`` string literals outside the
``TIME_UNITS`` whitelist (read statically from ``api/report.py`` so the
checker and ``RunReport.validate`` share one source of truth).
"""
from __future__ import annotations

import ast
import fnmatch
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.framework import (
    Module, Finding, RepoIndex, Rule, const_value, is_const, register_rule,
)

TIME_UNIT_SUFFIXES = {"ns": "ns", "us": "us", "cycles": "cycles",
                      "steps": "steps"}
RATE = "ns_per_byte"          # ns/byte rate: multiplying by bytes yields ns
RATE_NAMES = {"ns_per_b", "ns_per_byte", "wire_ns_per_byte"}
# functions with a known result unit (matched on the terminal call name)
FUNC_UNITS = {"cycles_ns": "ns", "wire_ns_per_byte": RATE}
US_TO_NS = {1e3, 1000, 1000.0}
NS_TO_US = {1e-3, 0.001}
# bare names with a repo-defined unit, per module glob
KNOWN_NAME_UNITS: Tuple[Tuple[str, Dict[str, str]], ...] = (
    ("src/repro/sim/*", {"now": "ns"}),
)
DEFAULT_TIME_UNITS = ("ns", "steps")


def suffix_unit(name: str) -> Optional[str]:
    for suf, unit in TIME_UNIT_SUFFIXES.items():
        if name == suf or name.endswith("_" + suf):
            return unit
    if name in RATE_NAMES:
        return RATE
    return None


def _chain_str(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(parts[::-1])
    return None


def _terminal_name(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


class _FunctionChecker(ast.NodeVisitor):
    """Infers units through one function (or module) body in source
    order, emitting findings on cross-unit flow."""

    def __init__(self, rule: "TimeUnitFlowRule", module: Module,
                 known: Dict[str, str], time_units: Set[str]):
        self.rule = rule
        self.module = module
        self.known = known          # bare-name -> unit for this module
        self.time_units = time_units
        self.env: Dict[str, str] = {}
        self.findings: List[Finding] = []
        self._reported: set = set()

    # -- unit inference ------------------------------------------------------
    def unit_of(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, (ast.Name, ast.Attribute)):
            chain = _chain_str(node)
            if chain is not None and chain in self.env:
                return self.env[chain]
            term = _terminal_name(node)
            if term is None:
                return None
            u = suffix_unit(term)
            if u is not None:
                return u
            return self.known.get(term)
        if isinstance(node, ast.Call):
            term = _terminal_name(node.func)
            if term in FUNC_UNITS:
                return FUNC_UNITS[term]
            return None
        if isinstance(node, ast.BinOp):
            return self._binop_unit(node)
        if isinstance(node, ast.IfExp):
            a, b = self.unit_of(node.body), self.unit_of(node.orelse)
            if a == b:
                return a
            # `x if cond else 0`: the zero is unit-neutral
            if const_value(node.orelse) in (0, 0.0):
                return a
            if const_value(node.body) in (0, 0.0):
                return b
            return None
        if isinstance(node, ast.BoolOp):
            us = {self.unit_of(v) for v in node.values}
            return us.pop() if len(us) == 1 else None
        if isinstance(node, ast.UnaryOp):
            return self.unit_of(node.operand)
        if isinstance(node, ast.Subscript):
            return self.unit_of(node.value)   # t_ns[i] carries t_ns's unit
        return None

    def _binop_unit(self, node: ast.BinOp) -> Optional[str]:
        lu, ru = self.unit_of(node.left), self.unit_of(node.right)
        lc, rc = const_value(node.left), const_value(node.right)
        if isinstance(node.op, ast.Mult):
            if RATE in (lu, ru):
                other = ru if lu == RATE else lu
                return "ns" if other is None else None
            for u, c in ((lu, rc), (ru, lc)):
                if u == "us" and c in US_TO_NS:
                    return "ns"
                if u == "ns" and c in NS_TO_US:
                    return "us"
            if lu is not None and ru is None:
                return lu
            if ru is not None and lu is None:
                return ru
            return None
        if isinstance(node.op, ast.Div):
            if lu is not None and lu == ru:
                return None          # ratio of like units
            if lu == "ns" and rc in US_TO_NS:
                return "us"
            if lu is not None and ru is None and is_const(node.right):
                return lu            # plain scaling
            return None
        if isinstance(node.op, (ast.Add, ast.Sub)):
            if lu is not None and ru is not None and lu != ru:
                self._mix(node, lu, ru, "+" if isinstance(node.op, ast.Add)
                          else "-")
                return lu
            return lu if lu is not None else ru
        if isinstance(node.op, ast.Mod):
            return lu
        return None

    def _mix(self, node: ast.AST, a: str, b: str, op: str) -> None:
        if id(node) in self._reported:
            return
        self._reported.add(id(node))
        self.findings.append(self.rule.finding(
            self.module, node,
            f"`{op}` mixes time units: {a} and {b} (convert explicitly "
            "before combining)"))

    # -- visitors ------------------------------------------------------------
    def visit_BinOp(self, node: ast.BinOp) -> None:
        # evaluate every arithmetic node so cross-unit mixes are caught
        # wherever they appear (call arguments, returns, subscripts...)
        self.generic_visit(node)
        self.unit_of(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        vu = self.unit_of(node.value)
        for t in node.targets:
            self._bind_target(t, vu, node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self.generic_visit(node)
        if node.value is not None:
            self._bind_target(node.target, self.unit_of(node.value), node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self.generic_visit(node)
        tu = self.unit_of(node.target)
        vu = self.unit_of(node.value)
        if (isinstance(node.op, (ast.Add, ast.Sub)) and tu is not None
                and vu is not None and tu != vu):
            self._mix(node, tu, vu, "+=" if isinstance(node.op, ast.Add)
                      else "-=")

    def _bind_target(self, target: ast.AST, vu: Optional[str],
                     node: ast.AST) -> None:
        chain = _chain_str(target)
        if chain is None:
            return
        term = _terminal_name(target)
        declared = suffix_unit(term) if term else None
        if declared is not None and vu is not None and declared != vu:
            self.findings.append(self.rule.finding(
                self.module, node,
                f"assigns a {vu} value to `{term}` "
                f"(name declares {declared})"))
        self.env[chain] = declared if declared is not None else vu

    def visit_Call(self, node: ast.Call) -> None:
        self.generic_visit(node)
        term = _terminal_name(node.func)
        # unit-typed keyword arguments
        for kw in node.keywords:
            if kw.arg is None:
                continue
            # time_unit= literal whitelist ("time_unit" itself carries no
            # unit suffix, so this must come before the suffix bail-out)
            if kw.arg == "time_unit":
                v = const_value(kw.value)
                if isinstance(v, str) and v not in self.time_units:
                    self.findings.append(self.rule.finding(
                        self.module, node,
                        f"time_unit={v!r} is not one of "
                        f"{sorted(self.time_units)} (TIME_UNITS)"))
                continue
            declared = suffix_unit(kw.arg)
            if declared is None:
                continue
            vu = self.unit_of(kw.value)
            if vu is not None and vu != declared:
                self.findings.append(self.rule.finding(
                    self.module, node,
                    f"keyword `{kw.arg}=` declares {declared} but the "
                    f"value carries {vu}"))
        # cycles -> ns converter takes cycles
        if term == "cycles_ns" and node.args:
            au = self.unit_of(node.args[0])
            if au is not None and au != "cycles":
                self.findings.append(self.rule.finding(
                    self.module, node,
                    f"cycles_ns() applied to a {au} value"))

    def visit_Compare(self, node: ast.Compare) -> None:
        self.generic_visit(node)
        sides = [node.left] + node.comparators
        # time_unit == "literal" whitelist check
        terms = [_terminal_name(s) for s in sides]
        if "time_unit" in terms:
            for s in sides:
                lits = ([s] if isinstance(s, ast.Constant)
                        else list(s.elts) if isinstance(s, (ast.Tuple,
                                                            ast.List,
                                                            ast.Set))
                        else [])
                for lit in lits:
                    v = const_value(lit)
                    if isinstance(v, str) and v not in self.time_units:
                        self.findings.append(self.rule.finding(
                            self.module, lit,
                            f"time_unit compared against {v!r}, not one "
                            f"of {sorted(self.time_units)} (TIME_UNITS)"))
            return
        if any(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
               for op in node.ops):
            return
        units = [self.unit_of(s) for s in sides]
        seen = [u for u in units if u is not None]
        if len(set(seen)) > 1:
            a, b = sorted(set(seen))[:2]
            self._mix(node, a, b, "comparison")

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # nested defs get their own checker (fresh env)
        sub = _FunctionChecker(self.rule, self.module, self.known,
                               self.time_units)
        sub.seed_params(node)
        for stmt in node.body:
            sub.visit(stmt)
        self.findings.extend(sub.findings)

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def seed_params(self, fn: ast.AST) -> None:
        args = fn.args
        for a in args.posonlyargs + args.args + args.kwonlyargs:
            u = suffix_unit(a.arg)
            if u is not None:
                self.env[a.arg] = u


@register_rule
class TimeUnitFlowRule(Rule):
    name = "time-unit-flow"
    description = ("ns/us/cycles/steps values must not be combined "
                   "without explicit conversion; RunReport time_unit "
                   "literals must come from TIME_UNITS")

    def __init__(self, scope: Tuple[str, ...] = ("src/*", "benchmarks/*",
                                                 "examples/*")):
        self.scope = scope

    def run(self, index: RepoIndex) -> List[Finding]:
        time_units = self._time_units(index)
        findings: List[Finding] = []
        for mod in index.matching(list(self.scope)):
            known: Dict[str, str] = {}
            for pattern, names in KNOWN_NAME_UNITS:
                if fnmatch.fnmatch(mod.path, pattern):
                    known.update(names)
            checker = _FunctionChecker(self, mod, known, time_units)
            for stmt in mod.tree.body:
                checker.visit(stmt)
            findings.extend(checker.findings)
        return findings

    @staticmethod
    def _time_units(index: RepoIndex) -> Set[str]:
        """The TIME_UNITS whitelist, read statically from the module that
        defines it (api/report.py)."""
        for mod in index.modules:
            for stmt in mod.tree.body:
                if (isinstance(stmt, ast.Assign)
                        and any(isinstance(t, ast.Name)
                                and t.id == "TIME_UNITS"
                                for t in stmt.targets)
                        and isinstance(stmt.value, (ast.Tuple, ast.List,
                                                    ast.Set))):
                    vals = {const_value(e) for e in stmt.value.elts}
                    strs = {v for v in vals if isinstance(v, str)}
                    if strs:
                        return strs
        return set(DEFAULT_TIME_UNITS)
