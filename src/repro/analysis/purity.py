"""Pass 1 — jit-purity / backend-purity (DESIGN.md §9.2).

Scope: every function that is backend-generic (takes an ``xp`` parameter
— the numpy/jnp shared-subset idiom of ``core/sched_generic.py`` and
``telemetry/metrics.py``) plus every function reachable from a
``jax.jit`` entry point through the repo-local call graph (including
``jax.lax.scan``/``cond``/``while_loop`` body arguments and nested
closures).

Inside that scope the kernel contract is enforced:

  * no ``np.<ufunc>.at`` / other in-place numpy mutation APIs;
  * no subscript stores (``x[i] = v`` / ``x[i] += v``) — kernels return
    new arrays;
  * no bare ``np.*`` references (backend mixing) except the allowlisted
    host-constant idioms: dtype/constant attributes (``np.float32``,
    ``np.inf``...), ``xp is np`` backend tests, numpy guards (code under
    an ``xp is np`` branch), and calls whose arguments are all literals
    or ALL_CAPS module constants (``np.log(HIST_GROWTH)``);
  * no Python branches on traced values: ``if``/``while``/ternary tests
    may only compare with ``is``/``is not`` (the ``cap is None`` static
    pattern) or involve parameters annotated as Python scalars
    (``temperature: float``), which jit treats as trace-time constants;
  * no data-dependent shapes (``nonzero``/``flatnonzero``/``unique``/
    ``argwhere``/one-arg ``where``).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.framework import (
    Finding, Module, RepoIndex, Rule, import_map, is_const, jnp_aliases,
    numpy_aliases, register_rule,
)

# modules whose functions are subject to the kernel contract (roots may
# pull callees in from anywhere in the index)
DEFAULT_SCOPE = (
    "src/repro/core/*", "src/repro/telemetry/*", "src/repro/serving/*",
    "src/repro/sim/*", "src/repro/kernels/*",
)

# np attributes that are host constants / dtypes — fine under trace
NP_CONST_ATTRS = {
    "float16", "float32", "float64", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64", "bool_", "complex64",
    "complex128", "pi", "e", "inf", "nan", "newaxis", "ndarray", "dtype",
    "generic", "number", "integer", "floating", "finfo", "iinfo",
}

# numpy APIs that mutate an operand in place
NP_INPLACE_ATTRS = {"put", "place", "copyto", "putmask", "fill_diagonal"}

# callables whose function-typed arguments are traced (control-flow HOFs)
TRACED_HOF_ATTRS = {"scan", "cond", "while_loop", "fori_loop", "switch",
                    "map", "associative_scan", "checkpoint", "remat",
                    "vmap", "grad", "value_and_grad"}

DYNAMIC_SHAPE_ATTRS = {"nonzero", "flatnonzero", "unique", "argwhere"}

SCALAR_ANNOTATIONS = {"float", "int", "bool", "str"}

FuncKey = Tuple[str, str]  # (module path, function qualname)


def _name_of(node: ast.AST) -> Optional[str]:
    return node.id if isinstance(node, ast.Name) else None


def _attr_chain(node: ast.AST) -> Optional[List[str]]:
    """``a.b.c`` -> ["a", "b", "c"]; None for non-trivial bases."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


class _FuncTable:
    """Every function/lambda-free def in the index, keyed by
    (path, qualname), plus per-module import maps."""

    def __init__(self, index: RepoIndex):
        self.index = index
        self.defs: Dict[FuncKey, ast.AST] = {}
        self.imports: Dict[str, Dict[str, str]] = {}
        for mod in index.modules:
            self.imports[mod.path] = import_map(mod.tree)
            for node in ast.walk(mod.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self.defs[(mod.path, node.qual)] = node

    def resolve(self, mod: Module, scope_qual: str,
                node: ast.AST) -> Optional[FuncKey]:
        """Resolve a called expression to a function def in the index."""
        name = _name_of(node)
        if name is not None:
            # innermost enclosing scope first, then module level
            qual = scope_qual
            while True:
                cand = f"{qual}.{name}" if qual else name
                if (mod.path, cand) in self.defs:
                    return (mod.path, cand)
                if "." not in qual:
                    break
                qual = qual.rsplit(".", 1)[0]
            if (mod.path, name) in self.defs:
                return (mod.path, name)
            dotted = self.imports[mod.path].get(name)
            if dotted:
                return self._resolve_dotted(dotted)
            return None
        chain = _attr_chain(node)
        if chain and len(chain) >= 2:
            base = self.imports[mod.path].get(chain[0])
            if base:
                return self._resolve_dotted(".".join([base] + chain[1:]))
        return None

    def _resolve_dotted(self, dotted: str) -> Optional[FuncKey]:
        if "." not in dotted:
            return None
        mod_name, func = dotted.rsplit(".", 1)
        target = self.index.by_dotted(mod_name)
        if target is not None and (target.path, func) in self.defs:
            return (target.path, func)
        return None


def _called_funcs(fn: ast.AST, table: _FuncTable, mod: Module) -> Set[FuncKey]:
    """Repo-local callees of ``fn`` (direct calls + function-typed args of
    jax control-flow HOFs + nested defs, which are traced as closures)."""
    out: Set[FuncKey] = set()
    qual = fn.qual
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node is not fn:
                out.add((mod.path, node.qual))
            continue
        if not isinstance(node, ast.Call):
            continue
        key = table.resolve(mod, qual, node.func)
        if key is not None:
            out.add(key)
        chain = _attr_chain(node.func)
        if chain and chain[-1] in TRACED_HOF_ATTRS:
            for arg in node.args:
                akey = table.resolve(mod, qual, arg)
                if akey is not None:
                    out.add(akey)
    return out


def _jit_roots(mod: Module, table: _FuncTable) -> Set[FuncKey]:
    """Functions handed to ``jax.jit`` (call or decorator form) in a
    module: named references, lambdas' repo-local callees, and
    ``functools.partial(jax.jit, ...)`` decorations."""
    roots: Set[FuncKey] = set()

    def is_jit(node: ast.AST) -> bool:
        chain = _attr_chain(node)
        return bool(chain) and chain[-1] == "jit"

    def add_target(target: ast.AST, qual: str) -> None:
        if isinstance(target, ast.Lambda):
            for sub in ast.walk(target.body):
                if isinstance(sub, ast.Call):
                    key = table.resolve(mod, qual, sub.func)
                    if key is not None:
                        roots.add(key)
            return
        key = table.resolve(mod, qual, target)
        if key is not None:
            roots.add(key)

    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) and is_jit(node.func) and node.args:
            add_target(node.args[0], node.qual)
        elif isinstance(node, ast.Call) and node.args and is_jit(node.args[0]):
            # functools.partial(jax.jit, static_argnames=...) decorator
            chain = _attr_chain(node.func)
            if chain and chain[-1] == "partial":
                parent = node.parent
                if isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    roots.add((mod.path, parent.qual))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if is_jit(dec):
                    roots.add((mod.path, node.qual))
    return roots


def _param_info(fn: ast.AST) -> Tuple[Set[str], Set[str]]:
    """(all param names, static param names).  A parameter is static when
    annotated as a Python scalar (jit closes over it at trace time)."""
    args = fn.args
    every = [a for a in (args.posonlyargs + args.args + args.kwonlyargs)]
    if args.vararg:
        every.append(args.vararg)
    if args.kwarg:
        every.append(args.kwarg)
    names = {a.arg for a in every}
    static = {"xp"}
    for a in every:
        ann = a.annotation
        if isinstance(ann, ast.Name) and ann.id in SCALAR_ANNOTATIONS:
            static.add(a.arg)
        elif isinstance(ann, ast.Constant) and ann.value in SCALAR_ANNOTATIONS:
            static.add(a.arg)
        elif (isinstance(ann, ast.Subscript)
              and isinstance(ann.slice, ast.Name)
              and ann.slice.id in SCALAR_ANNOTATIONS):
            static.add(a.arg)  # Optional[float] etc.
    return names, static


STATIC_ATTRS = {"shape", "ndim", "size", "dtype"}  # trace-time metadata


def _traced_ref(node: ast.Name) -> bool:
    """False when the name is only read through trace-time metadata
    (``x.shape``, ``x.ndim``, ``len(x)``) — those comparisons are static."""
    cur: ast.AST = node
    par = getattr(cur, "parent", None)
    while isinstance(par, (ast.Attribute, ast.Subscript, ast.Call)):
        if isinstance(par, ast.Attribute) and par.attr in STATIC_ATTRS:
            return False
        if (isinstance(par, ast.Call) and isinstance(par.func, ast.Name)
                and par.func.id == "len" and cur in par.args):
            return False
        cur, par = par, getattr(par, "parent", None)
    return True


def _is_static_test(test: ast.AST, params: Set[str],
                    static: Set[str]) -> bool:
    """True when a branch condition is trace-time static: only ``is`` /
    ``is not`` comparisons, shape/metadata comparisons, or no reference
    to a non-static parameter."""
    traced = params - static
    for node in ast.walk(test):
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                continue
            for sub in [node.left] + node.comparators:
                for n in ast.walk(sub):
                    if (isinstance(n, ast.Name) and n.id in traced
                            and _traced_ref(n)):
                        return False
        elif isinstance(node, ast.Name) and node.id in traced:
            par = node.parent
            if node is test or isinstance(par, (ast.BoolOp, ast.UnaryOp)):
                return False
    return True


@register_rule
class JitPurityRule(Rule):
    name = "jit-purity"
    description = ("backend-generic/jit-traced kernels must stay pure: "
                   "no numpy mixing, in-place stores, traced-value "
                   "branches, or data-dependent shapes")

    def __init__(self, scope: Tuple[str, ...] = DEFAULT_SCOPE):
        self.scope = scope

    # -- scope ---------------------------------------------------------------
    def _in_scope_funcs(self, index: RepoIndex,
                        table: _FuncTable) -> Set[FuncKey]:
        scoped = {m.path for m in index.matching(list(self.scope))}
        in_scope: Set[FuncKey] = set()
        work: List[FuncKey] = []
        for (path, qual), fn in table.defs.items():
            names, _ = _param_info(fn)
            if "xp" in names and path in scoped:
                in_scope.add((path, qual))
        for mod in index.modules:
            if mod.path in scoped:
                in_scope |= _jit_roots(mod, table)
        work = list(in_scope)
        while work:
            key = work.pop()
            fn = table.defs.get(key)
            if fn is None:
                continue
            mod = index.get(key[0])
            for callee in _called_funcs(fn, table, mod):
                if callee not in in_scope:
                    in_scope.add(callee)
                    work.append(callee)
        return in_scope

    # -- checks --------------------------------------------------------------
    def run(self, index: RepoIndex) -> List[Finding]:
        table = _FuncTable(index)
        findings: List[Finding] = []
        for key in sorted(self._in_scope_funcs(index, table)):
            fn = table.defs.get(key)
            mod = index.get(key[0])
            if fn is None or mod is None:
                continue
            findings.extend(self._check_function(mod, fn))
        return findings

    def _check_function(self, mod: Module, fn: ast.AST) -> List[Finding]:
        findings: List[Finding] = []
        np_names = numpy_aliases(mod.tree)
        jnp_names = jnp_aliases(mod.tree)
        params, static = _param_info(fn)
        guarded = _np_guarded_nodes(fn, np_names)
        consts = _module_constants(mod.tree)

        def body_nodes(node: ast.AST):
            """Walk, skipping nested defs (they are separate units)."""
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                yield child
                yield from body_nodes(child)

        nodes = [n for stmt in fn.body for n in [stmt] + list(body_nodes(stmt))]
        # skip default-argument expressions (evaluated at def time, host)
        defaults = set()
        for d in fn.args.defaults + [d for d in fn.args.kw_defaults if d]:
            defaults.add(d)
            defaults.update(ast.walk(d))

        for node in nodes:
            if node in defaults:
                continue
            # np.<ufunc>.at(...) — in-place scatter
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "at"):
                chain = _attr_chain(node.func)
                if chain and chain[0] in np_names:
                    findings.append(self.finding(
                        mod, node,
                        f"in-place `{'.'.join(chain)}` update inside a "
                        "backend-generic/jit-traced kernel"))
                    continue
            # subscript stores
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if isinstance(t, ast.Subscript):
                        findings.append(self.finding(
                            mod, t,
                            "subscript store mutates an array in place; "
                            "kernels must return new arrays "
                            "(use `xp.where` / one-hot adds)"))
            # bare numpy references (backend mixing)
            if (isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Name)
                    and node.value.id in np_names):
                if self._np_use_allowed(node, guarded, consts):
                    continue
                findings.append(self.finding(
                    mod, node,
                    f"`{node.value.id}.{node.attr}` inside a backend-"
                    "generic/jit-traced kernel mixes numpy into the "
                    "traced path (use `xp`)"))
            # Python branches on traced values
            if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                if not _is_static_test(node.test, params, static):
                    kind = ("while" if isinstance(node, ast.While) else "if")
                    findings.append(self.finding(
                        mod, node,
                        f"Python `{kind}` on a traced value; use "
                        "`xp.where` (only `is None` / annotated-scalar "
                        "config branches are static under jit)"))
            # data-dependent shapes
            if isinstance(node, ast.Call):
                chain = _attr_chain(node.func)
                attr = chain[-1] if chain else None
                base_ok = (chain and (chain[0] in np_names
                                      or chain[0] in jnp_names
                                      or chain[0] == "xp"))
                if attr in DYNAMIC_SHAPE_ATTRS and chain is not None:
                    findings.append(self.finding(
                        mod, node,
                        f"`{attr}` produces a data-dependent shape; "
                        "jit-traced kernels must stay fixed-shape"))
                elif (attr == "where" and base_ok and len(node.args) == 1
                      and not node.keywords):
                    findings.append(self.finding(
                        mod, node,
                        "one-argument `where` returns data-dependent "
                        "indices; use the three-argument select form"))
        return findings

    @staticmethod
    def _np_use_allowed(node: ast.Attribute, guarded: Set[ast.AST],
                        consts: Set[str]) -> bool:
        if node.attr in NP_CONST_ATTRS:
            return True
        if node in guarded:
            return True
        par = node.parent
        # `xp is np` backend tests reference the alias itself — but only
        # via a bare Name, never an attribute, so nothing to allow here.
        # np.f(<literals / ALL_CAPS consts>): host-constant math
        if isinstance(par, ast.Call) and par.func is node:
            if node.attr in NP_INPLACE_ATTRS:
                return False
            args = list(par.args) + [k.value for k in par.keywords]
            if args and all(
                    is_const(a)
                    or (isinstance(a, ast.Name)
                        and (a.id.isupper() or a.id in consts))
                    for a in args):
                return True
        return False


def _module_constants(tree: ast.Module) -> Set[str]:
    """Module-level ALL_CAPS constant names."""
    out: Set[str] = set()
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                if isinstance(t, ast.Name) and t.id.isupper():
                    out.add(t.id)
        elif isinstance(stmt, ast.AnnAssign):
            if isinstance(stmt.target, ast.Name) and stmt.target.id.isupper():
                out.add(stmt.target.id)
    return out


def _np_guarded_nodes(fn: ast.AST, np_names: Set[str]) -> Set[ast.AST]:
    """Nodes inside an ``xp is np`` guard (either branch of an If/IfExp
    whose test is an xp-identity check) — numpy use there is the
    sanctioned eager fast path."""
    out: Set[ast.AST] = set()

    def is_xp_np_test(test: ast.AST) -> bool:
        for node in ast.walk(test):
            if (isinstance(node, ast.Compare)
                    and all(isinstance(op, (ast.Is, ast.IsNot))
                            for op in node.ops)):
                names = {n.id for n in ast.walk(node)
                         if isinstance(n, ast.Name)}
                if "xp" in names and names & np_names:
                    return True
        return False

    for node in ast.walk(fn):
        if isinstance(node, (ast.If, ast.IfExp)) and is_xp_np_test(node.test):
            branches = (node.body + node.orelse
                        if isinstance(node, ast.If)
                        else [node.body, node.orelse])
            for b in branches:
                out.add(b)
                out.update(ast.walk(b))
    return out
