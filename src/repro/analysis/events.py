"""Pass 3 — EQ-event exhaustiveness (DESIGN.md §9.4).

The event queue (paper §5.2) is the tenants' only notification channel,
so an ``EventKind`` that is emitted but never consumed — or consumed
but impossible to emit — is a silent contract break.  This pass keeps
the enum, the emit sites, and the consumption story in lockstep:

  * every declared ``EventKind`` member must have an entry in the
    ``EVENT_DISPOSITIONS`` registry next to the enum, naming where the
    event is consumed (report/telemetry/control handling) — adding a
    kind without deciding its consumer is an error;
  * every kind listed in ``EVENT_DISPOSITIONS`` must still exist on the
    enum (no stale registry rows);
  * every kind that is emitted somewhere in ``src/`` must appear in a
    consume context (a comparison / membership test / dispatch-dict key)
    or carry a registry entry;
  * a declared kind that is never emitted anywhere is reported
    (warning) — consumers waiting on it can never fire.

Emit contexts are occurrences of ``EventKind.X`` inside call arguments,
returns, or dict *values* (the fastpath's small-int code tables);
consume contexts are comparisons, ``in`` tests, and dict *keys*.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.framework import (
    Module, Finding, RepoIndex, Rule, register_rule,
)

ENUM_NAME = "EventKind"
REGISTRY_NAME = "EVENT_DISPOSITIONS"


def _find_enum(index: RepoIndex) -> Optional[Tuple[Module, ast.ClassDef]]:
    for mod in index.modules:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef) and node.name == ENUM_NAME:
                return mod, node
    return None


def _enum_members(cls: ast.ClassDef) -> Dict[str, ast.AST]:
    out: Dict[str, ast.AST] = {}
    for stmt in cls.body:
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                if isinstance(t, ast.Name) and not t.id.startswith("_"):
                    out[t.id] = stmt
    return out


def _find_registry(mod: Module) -> Optional[Tuple[ast.Assign, ast.Dict]]:
    for stmt in mod.tree.body:
        if (isinstance(stmt, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == REGISTRY_NAME
                        for t in stmt.targets)
                and isinstance(stmt.value, ast.Dict)):
            return stmt, stmt.value
    return None


def _kind_refs(mod: Module) -> List[ast.Attribute]:
    """All ``EventKind.X`` attribute references in a module."""
    out = []
    for node in ast.walk(mod.tree):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == ENUM_NAME):
            out.append(node)
    return out


def _classify(ref: ast.Attribute) -> str:
    """'consume' | 'emit' | 'neutral' based on the syntactic context."""
    node: ast.AST = ref
    parent = getattr(node, "parent", None)
    while parent is not None:
        if isinstance(parent, ast.Compare):
            return "consume"
        if isinstance(parent, (ast.Tuple, ast.List, ast.Set)):
            gp = getattr(parent, "parent", None)
            if isinstance(gp, ast.Compare) and parent in gp.comparators:
                return "consume"   # `kind in (A, B)`
            node, parent = parent, gp
            continue
        if isinstance(parent, ast.Dict):
            if node in parent.keys:
                return "consume"   # dispatch table key
            if node in parent.values:
                return "emit"      # code -> kind decode table
            return "neutral"
        if isinstance(parent, ast.Subscript) and node is parent.slice:
            return "consume"       # table[EventKind.X]
        if isinstance(parent, ast.Call):
            return "emit"          # Event(..., kind), push_raw(kind), ...
        if isinstance(parent, (ast.Return, ast.IfExp)):
            return "emit"          # kill_kind()-style producers
        if isinstance(parent, ast.arguments):
            return "emit"          # default value flowing into an emit
        if isinstance(parent, (ast.Assign, ast.keyword)):
            node, parent = parent, getattr(parent, "parent", None)
            continue
        if isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef, ast.Module)):
            return "neutral"
        node, parent = parent, getattr(parent, "parent", None)
    return "neutral"


@register_rule
class EventExhaustivenessRule(Rule):
    name = "eq-event-exhaustiveness"
    description = ("every EventKind must be registered in "
                   "EVENT_DISPOSITIONS and every emitted kind must have "
                   "a consumer; unreachable kinds are reported")

    def __init__(self, scope: Tuple[str, ...] = ("src/*",)):
        self.scope = scope

    def run(self, index: RepoIndex) -> List[Finding]:
        found = _find_enum(index)
        if found is None:
            return []
        enum_mod, enum_cls = found
        members = _enum_members(enum_cls)
        findings: List[Finding] = []

        registry = _find_registry(enum_mod)
        reg_keys: Dict[str, ast.AST] = {}
        if registry is None:
            findings.append(self.finding(
                enum_mod, enum_cls,
                f"no {REGISTRY_NAME} registry next to {ENUM_NAME}: each "
                "kind must name where it is consumed"))
        else:
            stmt, dct = registry
            for k, v in zip(dct.keys, dct.values):
                if (isinstance(k, ast.Attribute)
                        and isinstance(k.value, ast.Name)
                        and k.value.id == ENUM_NAME):
                    reg_keys[k.attr] = k
                    if not (isinstance(v, ast.Constant)
                            and isinstance(v.value, str) and v.value.strip()):
                        findings.append(self.finding(
                            enum_mod, k,
                            f"{REGISTRY_NAME}[{ENUM_NAME}.{k.attr}] must "
                            "be a non-empty string naming the consumer"))
            for name, key_node in reg_keys.items():
                if name not in members:
                    findings.append(self.finding(
                        enum_mod, key_node,
                        f"{REGISTRY_NAME} lists {ENUM_NAME}.{name}, which "
                        "is not a declared member (stale registry row)"))

        emitted: Set[str] = set()
        consumed: Set[str] = set()
        for mod in index.matching(list(self.scope)):
            if mod.path == enum_mod.path:
                continue
            for ref in _kind_refs(mod):
                ctx = _classify(ref)
                if ctx == "emit":
                    emitted.add(ref.attr)
                elif ctx == "consume":
                    consumed.add(ref.attr)

        for name, node in members.items():
            if registry is not None and name not in reg_keys:
                findings.append(self.finding(
                    enum_mod, node,
                    f"{ENUM_NAME}.{name} has no {REGISTRY_NAME} entry: "
                    "declare where this event is consumed"))
            if name not in emitted:
                findings.append(self.finding(
                    enum_mod, node,
                    f"{ENUM_NAME}.{name} is declared but never emitted; "
                    "consumers waiting on it can never fire",
                    severity="warning"))
        for name in sorted(emitted):
            if name in members and name not in consumed \
                    and name not in reg_keys:
                findings.append(self.finding(
                    enum_mod, members[name],
                    f"{ENUM_NAME}.{name} is emitted but never consumed "
                    f"and has no {REGISTRY_NAME} entry"))
        return findings
