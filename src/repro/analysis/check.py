"""CLI driver: ``python -m repro.analysis.check``.

Runs every registered rule over the repo, diffs the findings against
the checked-in baseline (``analysis_baseline.json``), and exits
non-zero when anything un-baselined (or a stale baseline entry) is
present — the CI gate.

Options::

    paths...          roots to scan (default: src benchmarks examples tests)
    --root DIR        repo root (default: auto-detect from cwd upward)
    --baseline FILE   baseline path (default: <root>/analysis_baseline.json)
    --json            machine-readable report on stdout
    --fix-baseline    pin current findings into the baseline and prune
                      stale entries (new pins get a TODO justification
                      that must be edited before review)
    --rule NAME       run only the named rule (repeatable)
    --list-rules      print registered rules and exit
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Optional, Sequence

from repro.analysis import (
    Baseline, RULE_REGISTRY, RepoIndex, run_rules,
)
from repro.analysis.framework import DEFAULT_PATHS

BASELINE_NAME = "analysis_baseline.json"


def find_root(start: Optional[str] = None) -> str:
    """Walk upward from ``start`` (default cwd) to the first directory
    holding pyproject.toml or .git."""
    cur = os.path.abspath(start or os.getcwd())
    while True:
        if (os.path.exists(os.path.join(cur, "pyproject.toml"))
                or os.path.exists(os.path.join(cur, ".git"))):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return os.path.abspath(start or os.getcwd())
        cur = parent


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="repo-aware static checker suite (DESIGN.md §9)")
    p.add_argument("paths", nargs="*", default=None,
                   help=f"roots to scan (default: {' '.join(DEFAULT_PATHS)})")
    p.add_argument("--root", default=None, help="repo root")
    p.add_argument("--baseline", default=None,
                   help=f"baseline file (default: <root>/{BASELINE_NAME})")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="machine-readable output")
    p.add_argument("--fix-baseline", action="store_true",
                   help="absorb current findings into the baseline")
    p.add_argument("--rule", action="append", default=None,
                   help="run only this rule (repeatable)")
    p.add_argument("--list-rules", action="store_true")
    return p


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for name, cls in sorted(RULE_REGISTRY.items()):
            print(f"{name}: {cls.description}")
        return 0

    root = args.root or find_root()
    baseline_path = args.baseline or os.path.join(root, BASELINE_NAME)
    rules = None
    if args.rule:
        unknown = [r for r in args.rule if r not in RULE_REGISTRY]
        if unknown:
            print(f"unknown rule(s): {', '.join(unknown)}", file=sys.stderr)
            return 2
        rules = [RULE_REGISTRY[r]() for r in args.rule]

    index = RepoIndex.load(root, paths=args.paths or None)
    findings = run_rules(index, rules)
    baseline = Baseline.load(baseline_path)
    if args.fix_baseline:
        baseline.absorb(findings)
        baseline.save(baseline_path)
        print(f"baseline updated: {len(baseline.entries)} entries "
              f"-> {os.path.relpath(baseline_path, root)}")
        return 0
    new, stale = baseline.diff(findings)

    if args.as_json:
        print(json.dumps({
            "root": root,
            "modules_scanned": len(index.modules),
            "findings": [f.to_dict() for f in findings],
            "new": [f.to_dict() for f in new],
            "baselined": len(findings) - len(new),
            "stale_baseline": stale,
            "ok": not new and not stale,
        }, indent=2))
    else:
        for f in findings:
            tag = "" if f.key in baseline.entries else " (new)"
            print(f.format() + tag)
        for k in stale:
            print(f"stale baseline entry (finding no longer raised): {k}")
        n_err = sum(1 for f in new if f.severity == "error")
        n_warn = len(new) - n_err
        print(f"{len(index.modules)} modules scanned: "
              f"{len(findings)} finding(s), {len(new)} new "
              f"({n_err} error / {n_warn} warning), "
              f"{len(findings) - len(new)} baselined, "
              f"{len(stale)} stale baseline entr(y/ies)")
        if new or stale:
            print("un-baselined findings or stale entries present; "
                  "fix them or run with --fix-baseline and justify.")
    return 1 if (new or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
