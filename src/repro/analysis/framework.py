"""Rule engine for the repo-aware static checkers (DESIGN.md §9).

Small, dependency-free core: a ``RepoIndex`` of parsed modules, a
``Rule`` base class + registry, ``Finding`` records with stable baseline
keys, and ``Baseline`` load/save/diff.  Rules are pure functions of the
index — they never import the code under analysis, so the suite runs in
any environment (CI included) without jax or the repo's runtime deps.

Baseline keys deliberately exclude line numbers (``rule::path::symbol::
message``) so unrelated edits that shift code do not invalidate pinned
findings; moving or renaming the offending symbol does, which is the
point — a grandfathered finding must be re-justified when its code is
touched.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import json
import os
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Type

SEVERITIES = ("error", "warning")

# directories never scanned (fixtures contain deliberate violations)
DEFAULT_EXCLUDES = (
    "tests/data/*", "*/.git/*", "*/__pycache__/*", "build/*", "dist/*",
)
DEFAULT_PATHS = ("src", "benchmarks", "examples", "tests")


# ---------------------------------------------------------------------------
# findings
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    path: str            # repo-relative, posix separators
    line: int
    col: int
    rule: str
    severity: str        # "error" | "warning"
    message: str
    symbol: str = ""     # enclosing function/class qualname ("" = module)

    @property
    def key(self) -> str:
        """Stable baseline key — no line/col, so edits elsewhere in the
        file do not churn the baseline."""
        return f"{self.rule}::{self.path}::{self.symbol}::{self.message}"

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["key"] = self.key
        return d

    def format(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule} {self.severity}: {self.message}{sym}")


# ---------------------------------------------------------------------------
# parsed-module index
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Module:
    path: str            # repo-relative posix path
    tree: ast.Module
    source: str

    @property
    def dotted(self) -> str:
        """Best-effort dotted module name (``src/repro/a/b.py`` ->
        ``repro.a.b``) used for import resolution."""
        p = self.path
        if p.endswith(".py"):
            p = p[:-3]
        parts = [q for q in p.split("/") if q]
        if parts and parts[0] == "src":
            parts = parts[1:]
        if parts and parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)


def attach_parents(tree: ast.AST) -> None:
    """Annotate every node with ``.parent`` (None at the root)."""
    tree.parent = None  # type: ignore[attr-defined]
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child.parent = node  # type: ignore[attr-defined]


def attach_qualnames(tree: ast.AST) -> None:
    """Annotate every node with ``.qual``: the enclosing def/class
    qualname (the node's own name for def/class nodes themselves)."""

    def visit(node: ast.AST, stack: Tuple[str, ...]) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            stack = stack + (node.name,)
        node.qual = ".".join(stack)  # type: ignore[attr-defined]
        for child in ast.iter_child_nodes(node):
            visit(child, stack)

    visit(tree, ())


class RepoIndex:
    """All parsed Python modules under a repo root."""

    def __init__(self, root: str, modules: Sequence[Module]):
        self.root = root
        self.modules: List[Module] = list(modules)
        self._by_path = {m.path: m for m in self.modules}
        self._by_dotted = {m.dotted: m for m in self.modules}

    @classmethod
    def load(cls, root: str, paths: Optional[Sequence[str]] = None,
             excludes: Sequence[str] = DEFAULT_EXCLUDES) -> "RepoIndex":
        root = os.path.abspath(root)
        modules: List[Module] = []
        roots = [os.path.join(root, p) for p in (paths or DEFAULT_PATHS)]
        roots = [r for r in roots if os.path.exists(r)]
        for r in roots:
            if os.path.isfile(r):
                files: Iterable[str] = [r]
            else:
                files = sorted(
                    os.path.join(dp, f)
                    for dp, _, fs in os.walk(r)
                    for f in fs if f.endswith(".py"))
            for f in files:
                rel = os.path.relpath(f, root).replace(os.sep, "/")
                if any(fnmatch.fnmatch(rel, pat) for pat in excludes):
                    continue
                mod = cls.parse_file(f, rel)
                if mod is not None:
                    modules.append(mod)
        return cls(root, modules)

    @staticmethod
    def parse_file(abspath: str, rel: str) -> Optional[Module]:
        try:
            with open(abspath, "r", encoding="utf-8") as fh:
                source = fh.read()
            tree = ast.parse(source, filename=rel)
        except (OSError, SyntaxError):
            return None
        attach_parents(tree)
        attach_qualnames(tree)
        return Module(path=rel, tree=tree, source=source)

    def get(self, path: str) -> Optional[Module]:
        return self._by_path.get(path)

    def by_dotted(self, dotted: str) -> Optional[Module]:
        return self._by_dotted.get(dotted)

    def matching(self, patterns: Sequence[str]) -> List[Module]:
        return [m for m in self.modules
                if any(fnmatch.fnmatch(m.path, p) for p in patterns)]


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------
class Rule:
    """One analysis pass.  Subclasses set ``name``/``description`` and
    implement ``run(index) -> [Finding]``."""

    name: str = "abstract"
    description: str = ""

    def run(self, index: RepoIndex) -> List[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, module: Module, node: ast.AST, message: str,
                severity: str = "error") -> Finding:
        assert severity in SEVERITIES
        return Finding(path=module.path,
                       line=getattr(node, "lineno", 0),
                       col=getattr(node, "col_offset", 0),
                       rule=self.name, severity=severity, message=message,
                       symbol=getattr(node, "qual", ""))


RULE_REGISTRY: Dict[str, Type[Rule]] = {}


def register_rule(cls: Type[Rule]) -> Type[Rule]:
    RULE_REGISTRY[cls.name] = cls
    return cls


def run_rules(index: RepoIndex,
              rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Run rule instances (default: one of each registered rule) over the
    index; findings come back sorted by location."""
    if rules is None:
        rules = [cls() for _, cls in sorted(RULE_REGISTRY.items())]
    findings: List[Finding] = []
    for rule in rules:
        findings.extend(rule.run(index))
    return sorted(findings)


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------
class Baseline:
    """Grandfathered findings: ``key -> justification``.

    Every entry must carry a human-written justification; ``--fix-baseline``
    inserts ``TODO: justify`` placeholders which the repo-wide test treats
    as findings of their own.
    """

    VERSION = 1

    def __init__(self, entries: Optional[Dict[str, str]] = None):
        self.entries: Dict[str, str] = dict(entries or {})

    @classmethod
    def load(cls, path: str) -> "Baseline":
        if not os.path.exists(path):
            return cls()
        with open(path, "r", encoding="utf-8") as fh:
            data = json.load(fh)
        entries = {e["key"]: e.get("justification", "")
                   for e in data.get("entries", [])}
        return cls(entries)

    def save(self, path: str) -> None:
        data = {
            "version": self.VERSION,
            "entries": [{"key": k, "justification": v}
                        for k, v in sorted(self.entries.items())],
        }
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(data, fh, indent=2, sort_keys=False)
            fh.write("\n")

    def diff(self, findings: Sequence[Finding]
             ) -> Tuple[List[Finding], List[str]]:
        """Split findings into (new, stale-baseline-keys)."""
        seen = {f.key for f in findings}
        new = [f for f in findings if f.key not in self.entries]
        stale = sorted(k for k in self.entries if k not in seen)
        return new, stale

    def absorb(self, findings: Sequence[Finding]) -> None:
        """--fix-baseline: pin current findings, drop stale entries."""
        seen = {f.key for f in findings}
        self.entries = {k: v for k, v in self.entries.items() if k in seen}
        for f in findings:
            self.entries.setdefault(f.key, "TODO: justify")


# ---------------------------------------------------------------------------
# shared AST helpers used by several rules
# ---------------------------------------------------------------------------
def numpy_aliases(tree: ast.Module) -> set:
    """Names the module binds to the ``numpy`` top-level module."""
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "numpy":
                    out.add(a.asname or "numpy")
    return out


def jnp_aliases(tree: ast.Module) -> set:
    """Names the module binds to ``jax.numpy``."""
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "jax.numpy" and a.asname:
                    out.add(a.asname)
        elif isinstance(node, ast.ImportFrom):
            if node.module == "jax" and node.level == 0:
                for a in node.names:
                    if a.name == "numpy":
                        out.add(a.asname or "numpy")
    return out


def import_map(tree: ast.Module) -> Dict[str, str]:
    """Local name -> dotted target for module/function imports.

    ``from repro.core import sched_generic as G`` maps ``G`` to
    ``repro.core.sched_generic``; ``from repro.x import f`` maps ``f`` to
    ``repro.x.f``; ``import repro.x as rx`` maps ``rx`` to ``repro.x``.
    """
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def const_value(node: ast.AST):
    """The literal value of a Constant (or unary-minus Constant), else
    a sentinel."""
    if isinstance(node, ast.Constant):
        return node.value
    if (isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub)
            and isinstance(node.operand, ast.Constant)):
        v = node.operand.value
        if isinstance(v, (int, float)):
            return -v
    return _NO_CONST


_NO_CONST = object()


def is_const(node: ast.AST) -> bool:
    return const_value(node) is not _NO_CONST
