"""Pass 6 — exported-metric name checker (DESIGN.md §11.2).

Lints every literal ``MetricSpec(...)`` declaration (the exporter's
``METRICS`` registry in ``telemetry/export.py`` — and anything else
that mints one) without importing anything:

  * names must be snake_case (``[a-z][a-z0-9_]*``);
  * the last name component must equal the declared ``unit``, and the
    unit must come from the whitelist — the report schema's
    ``TIME_UNITS`` (read statically from ``api/report.py``, the same
    single source of truth the time-unit-flow pass uses) plus the
    exporter's dimensionless suffixes (read statically from
    ``DIMENSIONLESS_SUFFIXES`` where it is defined);
  * ``kind`` must be ``counter`` or ``gauge``, and counters must end
    ``_total`` (the OpenMetrics convention);
  * no two specs may declare the same name + label set — duplicate
    sample shapes silently shadow each other at scrape time.

Dynamic declarations (non-literal name/kind/unit) are themselves
findings: the registry exists so the exported surface is statically
known.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.framework import (
    Module, Finding, RepoIndex, Rule, const_value, register_rule,
)
from repro.analysis.units import TimeUnitFlowRule

SNAKE_CASE = re.compile(r"^[a-z][a-z0-9_]*$")
METRIC_KINDS = ("counter", "gauge")
# fallbacks when the defining modules are outside the scanned index
# (fixture runs) — mirror export.py / api/report.py
DEFAULT_DIMENSIONLESS = ("total", "ratio", "count")
DEFAULT_LABELS = ("tenant", "backend")


def _str_tuple_assign(index: RepoIndex, name: str) -> Optional[Tuple[str, ...]]:
    """Statically read a module-level ``NAME = ("a", "b", ...)`` string
    tuple from wherever the index defines it."""
    for mod in index.modules:
        for stmt in mod.tree.body:
            if (isinstance(stmt, ast.Assign)
                    and any(isinstance(t, ast.Name) and t.id == name
                            for t in stmt.targets)
                    and isinstance(stmt.value, (ast.Tuple, ast.List))):
                vals = tuple(const_value(e) for e in stmt.value.elts)
                if all(isinstance(v, str) for v in vals):
                    return vals
    return None


def _call_arg(node: ast.Call, pos: int, kw: str) -> Optional[ast.AST]:
    if len(node.args) > pos:
        return node.args[pos]
    for k in node.keywords:
        if k.arg == kw:
            return k.value
    return None


@register_rule
class MetricNamesRule(Rule):
    name = "metric-names"
    description = ("exported MetricSpec names must be snake_case, end in "
                   "their declared unit (TIME_UNITS + dimensionless "
                   "whitelist), counters must end _total, and no "
                   "name+labelset may repeat")

    def __init__(self, scope: Tuple[str, ...] = ("src/*", "benchmarks/*",
                                                 "examples/*")):
        self.scope = scope

    def run(self, index: RepoIndex) -> List[Finding]:
        time_units = TimeUnitFlowRule._time_units(index)
        dimless = (_str_tuple_assign(index, "DIMENSIONLESS_SUFFIXES")
                   or DEFAULT_DIMENSIONLESS)
        allowed: Set[str] = set(time_units) | set(dimless)
        findings: List[Finding] = []
        seen: Dict[Tuple[str, Tuple[str, ...]], Tuple[Module, ast.AST]] = {}
        for mod in index.matching(list(self.scope)):
            for node in ast.walk(mod.tree):
                if not (isinstance(node, ast.Call)
                        and self._is_metric_spec(node.func)):
                    continue
                findings.extend(
                    self._check_spec(mod, node, allowed, seen))
        return findings

    @staticmethod
    def _is_metric_spec(func: ast.AST) -> bool:
        if isinstance(func, ast.Name):
            return func.id == "MetricSpec"
        if isinstance(func, ast.Attribute):
            return func.attr == "MetricSpec"
        return False

    def _check_spec(self, mod: Module, node: ast.Call, allowed: Set[str],
                    seen: dict) -> List[Finding]:
        out: List[Finding] = []
        name = const_value(_call_arg(node, 0, "name"))
        kind = const_value(_call_arg(node, 1, "kind"))
        unit = const_value(_call_arg(node, 2, "unit"))
        if not isinstance(name, str) or not isinstance(kind, str) \
                or not isinstance(unit, str):
            out.append(self.finding(
                mod, node,
                "MetricSpec name/kind/unit must be string literals — the "
                "exported surface is statically declared"))
            return out
        if not SNAKE_CASE.match(name):
            out.append(self.finding(
                mod, node, f"metric name {name!r} is not snake_case"))
        if unit not in allowed:
            out.append(self.finding(
                mod, node,
                f"metric {name!r} declares unit {unit!r}, not one of "
                f"{sorted(allowed)} (TIME_UNITS + dimensionless suffixes)"))
        if not name.endswith("_" + unit):
            out.append(self.finding(
                mod, node,
                f"metric name {name!r} does not end in its declared "
                f"unit suffix `_{unit}`"))
        if kind not in METRIC_KINDS:
            out.append(self.finding(
                mod, node,
                f"metric {name!r} kind {kind!r} is not one of "
                f"{METRIC_KINDS}"))
        elif kind == "counter" and not name.endswith("_total"):
            out.append(self.finding(
                mod, node,
                f"counter {name!r} must end `_total` (OpenMetrics)"))
        labels = self._labels(node)
        key = (name, labels)
        if key in seen:
            prev_mod, prev_node = seen[key]
            out.append(self.finding(
                mod, node,
                f"duplicate metric {name!r} with labels {list(labels)} "
                f"(first declared at {prev_mod.path}:"
                f"{getattr(prev_node, 'lineno', 0)})"))
        else:
            seen[key] = (mod, node)
        return out

    @staticmethod
    def _labels(node: ast.Call) -> Tuple[str, ...]:
        arg = _call_arg(node, 4, "labels")
        if arg is None:
            return tuple(sorted(DEFAULT_LABELS))
        if isinstance(arg, (ast.Tuple, ast.List)):
            vals = tuple(const_value(e) for e in arg.elts)
            if all(isinstance(v, str) for v in vals):
                return tuple(sorted(vals))
        # a named constant (LABELS_TENANT / LABELS_GLOBAL) — treat the
        # name itself as the labelset identity
        if isinstance(arg, (ast.Name, ast.Attribute)):
            return (getattr(arg, "id", None) or getattr(arg, "attr", "?"),)
        return ("?",)
