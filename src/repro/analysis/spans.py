"""Pass 5 — trace span-balance (DESIGN.md §10).

The flight recorder's paired span API (``span_begin`` / ``span_end`` /
``span_abandon``) keeps a per-``(stage, uid)`` open table; a stage that
is opened on some code path but never closed *or* abandoned leaks into
``flush_open`` and shows up as a permanently-OPEN span in every trace —
the observability analogue of an emitted-but-never-consumed EQ event.
This pass keeps the open/close story balanced per module:

  * every trace stage passed to ``span_begin`` must also appear in a
    ``span_end`` or ``span_abandon`` call in the same module — opening
    a stage whose close lives in another module hides the pairing from
    review and from this checker (error);
  * ``span_abandon`` must carry a *terminal* disposition (``D_DROP`` /
    ``D_REJECT`` / ``D_KILL``) — abandoning a span as OK/OPEN
    mislabels a terminated packet as healthy (error);
  * a ``span_end`` / ``span_abandon`` for a stage that is never opened
    in the module is reported (warning) — it raises ``KeyError`` at
    runtime if no other path opened the pair;
  * stage arguments must be ``ST_*`` constants (or recognizable
    aliases), not bare numbers — magic stage codes defeat the pairing
    analysis (error).

``span``/``span_packet`` record complete rows and need no balancing;
the recorder module itself (which defines the API) is skipped.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis.framework import (
    Finding, Module, RepoIndex, Rule, register_rule,
)

TERMINAL_DISPS = ("D_DROP", "D_REJECT", "D_KILL")
RECORDER_MODULE = "repro.telemetry.trace"


def _stage_name(node: ast.AST) -> Optional[str]:
    """``TR.ST_FMQ`` / ``trace.ST_FMQ`` / bare ``ST_FMQ`` -> 'ST_FMQ'."""
    if isinstance(node, ast.Attribute) and node.attr.startswith("ST_"):
        return node.attr
    if isinstance(node, ast.Name) and node.id.startswith("ST_"):
        return node.id
    return None


def _disp_name(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and node.attr.startswith("D_"):
        return node.attr
    if isinstance(node, ast.Name) and node.id.startswith("D_"):
        return node.id
    return None


def _span_calls(mod: Module) -> List[Tuple[str, ast.Call]]:
    """(method, call) for every ``*.span_begin/span_end/span_abandon``."""
    out = []
    for node in ast.walk(mod.tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("span_begin", "span_end",
                                       "span_abandon")):
            out.append((node.func.attr, node))
    return out


def _arg(call: ast.Call, pos: int, kw: str) -> Optional[ast.AST]:
    if len(call.args) > pos:
        return call.args[pos]
    for k in call.keywords:
        if k.arg == kw:
            return k.value
    return None


@register_rule
class SpanBalanceRule(Rule):
    name = "span-balance"
    description = ("every span_begin stage must be span_end/abandoned in "
                   "the same module, and span_abandon dispositions must "
                   "be terminal (DROP/REJECT/KILL)")

    def __init__(self, scope: Tuple[str, ...] = ("src/*",)):
        self.scope = scope

    def run(self, index: RepoIndex) -> List[Finding]:
        findings: List[Finding] = []
        for mod in index.matching(list(self.scope)):
            if mod.dotted == RECORDER_MODULE:
                continue
            calls = _span_calls(mod)
            if not calls:
                continue
            begins: Dict[str, ast.Call] = {}
            closes: Dict[str, ast.Call] = {}
            for method, call in calls:
                stage_node = _arg(call, 0, "stage")
                stage = (_stage_name(stage_node)
                         if stage_node is not None else None)
                if stage is None:
                    findings.append(self.finding(
                        mod, call,
                        f"{method} stage argument must be an ST_* "
                        "constant, not a computed or numeric value"))
                    continue
                if method == "span_begin":
                    begins.setdefault(stage, call)
                else:
                    closes.setdefault(stage, call)
                if method == "span_abandon":
                    disp_node = _arg(call, 3, "disp")
                    disp = (_disp_name(disp_node)
                            if disp_node is not None else None)
                    if disp is None or disp not in TERMINAL_DISPS:
                        findings.append(self.finding(
                            mod, call,
                            f"span_abandon({stage}) disposition must be "
                            f"one of {'/'.join(TERMINAL_DISPS)}, got "
                            f"{disp or 'a non-D_* expression'}: an "
                            "abandoned span is a terminated packet"))
            for stage, call in begins.items():
                if stage not in closes:
                    findings.append(self.finding(
                        mod, call,
                        f"span_begin({stage}) has no span_end/"
                        f"span_abandon for {stage} in this module: the "
                        "span leaks to flush_open as permanently OPEN"))
            for stage, call in closes.items():
                if stage not in begins:
                    findings.append(self.finding(
                        mod, call,
                        f"span_end/span_abandon({stage}) without a "
                        f"span_begin({stage}) in this module raises "
                        "KeyError unless another path opened the pair",
                        severity="warning"))
        return findings
