"""Repo-aware static analysis suite (DESIGN.md §9).

AST-level checkers for the invariants the dynamic test suite can only
sample: backend purity of jit-traced kernels, time-unit flow across the
ns/us/cycles/steps domains, EventKind emit/consume exhaustiveness, and
frozen-spec / fixed-shape discipline.  Run via::

    python -m repro.analysis.check [--json] [--baseline FILE] [--fix-baseline]

The framework (rule registry, repo index, baseline handling) lives in
``framework``; each pass is one module registering one or more rules.
Importing this package pulls in every built-in rule.
"""
from repro.analysis.framework import (  # noqa: F401
    Baseline, Finding, Module, RepoIndex, Rule, RULE_REGISTRY,
    register_rule, run_rules,
)
from repro.analysis import (purity, units, events, frozen, spans,  # noqa: F401
                            metrics_names)

__all__ = [
    "Baseline", "Finding", "Module", "RepoIndex", "Rule", "RULE_REGISTRY",
    "register_rule", "run_rules",
]
