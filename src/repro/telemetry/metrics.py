"""Backend-generic per-tenant metric collectors (DESIGN.md §6).

Fixed-shape, array-native state — the telemetry analogue of
``core/sched_generic.py``: every kernel here is written once against the
array-API subset numpy and ``jax.numpy`` share, is purely functional
(returns new arrays, never mutates), and is branch-free in traced values,
so the serving data plane commits samples under ``jax.jit`` with zero
host sync while the cycle simulator commits eagerly on numpy fp64.

Three collector families, all ``[T]``-leading so one state serves every
tenant at once:

  * counters        — ``counts [T, C]``, one named column per event kind;
  * latency histograms — ``hist [T, B]`` log-bucketed (HDR-style): bucket
    ``i`` covers ``[LO·G^i, LO·G^(i+1))``, so 32 base-2 buckets span
    1 ns .. ~4 s (or 1 .. 2^32 engine steps) at fixed memory;
  * windowed gauges — ``ring [G, T, W]`` circular buffers of per-window
    samples (occupancy, queue depth, service rate, KV pressure) with a
    single shared write pointer.

``TelemetryState`` is a plain dict of arrays (a jit-able pytree); the
``Telemetry`` wrapper below stages scalar events cheaply on the host and
flushes them through the pure kernels once per step/window — the same
staging API backs the simulator (numpy backend) and the serving engine
(numpy or jitted jnp backend).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

# counter columns (fixed order — indices are part of the state layout)
COUNTERS: Tuple[str, ...] = (
    "arrivals", "drops", "ecn_marks", "completed", "killed", "rejected",
    "bytes_in", "bytes_out", "tokens",
)
C_IDX: Dict[str, int] = {n: i for i, n in enumerate(COUNTERS)}

# ring-buffered gauges (one per-window sample each)
GAUGES: Tuple[str, ...] = ("occupancy", "queue_len", "service_rate",
                           "kv_pressure")
G_IDX: Dict[str, int] = {n: i for i, n in enumerate(GAUGES)}

HIST_BUCKETS = 32    # [T, 32] log2 buckets: 1 .. 2^32 latency units
HIST_LO = 1.0        # lower edge of bucket 0 (ns on the sim, steps serving)
HIST_GROWTH = 2.0
RING_WINDOW = 64     # windows retained per gauge
BUCKET_EPS = 1e-6    # pre-floor epsilon: fp32 (jit) and fp64 (sim) agree
#                      at exact-boundary values (CEIL_EPS idiom, DESIGN §3.2)


# ---------------------------------------------------------------------------
# pure kernels (numpy + jnp)
# ---------------------------------------------------------------------------
def create_state(num_tenants: int, *, n_buckets: int = HIST_BUCKETS,
                 window: int = RING_WINDOW, xp=np, dtype=None) -> dict:
    """Fresh all-zero telemetry state for ``num_tenants`` tenants.

    Counters and histogram bins are integers — monotone accumulators in
    fp32 (the jit dtype) would silently saturate at 2^24 (+1 becomes a
    no-op), blinding interval-differenced signals on long runs.  Gauges
    stay float (``dtype`` overrides the ring dtype only).
    """
    dt = dtype or (np.float64 if xp is np else xp.float32)
    ct = np.int64 if xp is np else xp.int32
    T = num_tenants
    return {
        "counts": xp.zeros((T, len(COUNTERS)), ct),
        "hist": xp.zeros((T, n_buckets), ct),
        "ring": xp.zeros((len(GAUGES), T, window), dt),
        "ptr": xp.zeros((), xp.int32),
    }


def bucket_index(values, n_buckets: int, xp):
    """Log-bucket index of each value: ``clip(floor(log_G(v/LO)), 0, B-1)``."""
    v = xp.maximum(xp.asarray(values, xp.float32 if xp is not np
                              else np.float64), HIST_LO)
    idx = xp.floor(xp.log(v / HIST_LO) / np.log(HIST_GROWTH) + BUCKET_EPS)
    return xp.clip(idx, 0, n_buckets - 1).astype(xp.int32)


def bucket_value(idx, xp=np):
    """Representative latency of a bucket (geometric mid of its edges)."""
    return HIST_LO * HIST_GROWTH ** (xp.asarray(idx, float) + 0.5)


def hist_add(hist, values, mask, xp):
    """Scatter one latency sample per masked tenant into ``hist [T, B]``.

    One-hot add keeps the op fixed-shape and scatter-free, so it lowers
    to a plain compare+add under jit (no host sync, no dynamic shapes).
    """
    B = hist.shape[1]
    idx = bucket_index(values, B, xp)
    onehot = (xp.arange(B)[None, :] == idx[:, None]) & \
        xp.asarray(mask, bool)[:, None]
    return hist + onehot.astype(hist.dtype)


def hist_quantile(hist, q: float, xp=np):
    """Per-tenant quantile estimate from the log histogram.

    Returns the representative value of the first bucket whose CDF
    reaches ``q`` (``[T]`` float; 0 where a tenant has no samples).
    """
    total = xp.sum(hist, axis=1)
    cdf = xp.cumsum(hist, axis=1)
    target = xp.maximum(q * total, 1e-12)
    first = xp.argmax(cdf >= target[:, None], axis=1)
    return xp.where(total > 0, bucket_value(first, xp), 0.0)


def ring_push(ring, ptr, samples, xp):
    """Append one ``[G, T]`` sample column to ``ring [G, T, W]``.

    Returns ``(ring, ptr+1)``; the write slot is ``ptr % W`` so the ring
    holds the last W windows once warm.
    """
    W = ring.shape[-1]
    hot = xp.arange(W) == ptr % W
    ring = xp.where(hot[None, None, :],
                    xp.asarray(samples, ring.dtype)[..., None], ring)
    return ring, ptr + 1


def ring_mean(ring, ptr, xp=np):
    """Mean of the valid portion of each gauge ring -> ``[G, T]``."""
    W = ring.shape[-1]
    n = xp.clip(ptr, 1, W)
    valid = (xp.arange(W) < ptr)[None, None, :]
    return xp.sum(xp.where(valid, ring, 0.0), axis=-1) / n


def record_step(state: dict, counts_inc, lat_values, lat_mask, xp) -> dict:
    """Commit one flush of staged samples: counter increments ``[T, C]``
    plus at most one latency sample per tenant (``lat_values/lat_mask``,
    both ``[T]``).  Pure; jit this with ``xp=jnp`` for the data plane."""
    return dict(state,
                counts=state["counts"] + xp.asarray(counts_inc,
                                                    state["counts"].dtype),
                hist=hist_add(state["hist"], lat_values, lat_mask, xp))


def record_window(state: dict, gauges, xp) -> dict:
    """Commit one ``[G, T]`` gauge sample column into the rings.  Pure."""
    ring, ptr = ring_push(state["ring"], state["ptr"], gauges, xp)
    return dict(state, ring=ring, ptr=ptr)


# ---------------------------------------------------------------------------
# staging wrapper (both execution surfaces)
# ---------------------------------------------------------------------------
class Telemetry:
    """Per-tenant metric plane: cheap host-side staging + array commits.

    ``inc``/``lat`` stage scalar events in O(1) numpy writes; ``commit``
    flushes them through the pure kernels above on the configured
    backend.  With ``backend="jnp"`` the state lives on device and every
    commit is a single jitted call (no host sync); signal readers pull
    the arrays back explicitly via ``snapshot()``.
    """

    def __init__(self, num_tenants: int, *, n_buckets: int = HIST_BUCKETS,
                 window: int = RING_WINDOW, backend: str = "numpy"):
        self.T = num_tenants
        self.backend = backend
        if backend == "jnp":
            import jax
            import jax.numpy as jnp
            self.xp = jnp
            self._jit_step = jax.jit(
                lambda st, ci, lv, lm: record_step(st, ci, lv, lm, jnp))
            self._jit_window = jax.jit(
                lambda st, g: record_window(st, g, jnp))
        else:
            self.xp = np
            self._jit_step = self._jit_window = None
        self.state = create_state(num_tenants, n_buckets=n_buckets,
                                  window=window, xp=self.xp)
        self._staged_counts = np.zeros((num_tenants, len(COUNTERS)))
        self._staged_lat: List[Tuple[int, float]] = []

    # -- staging (host, O(1) per event) ------------------------------------
    def inc(self, name: str, tenant: int, amount: float = 1.0) -> None:
        self._staged_counts[tenant, C_IDX[name]] += amount

    def inc_column(self, name: str, totals) -> None:
        """Stage pre-aggregated per-tenant totals (``[T]``) in one add —
        equal to per-event ``inc`` calls for the integer-valued totals
        this plane records (integer float sums are exact)."""
        self._staged_counts[:, C_IDX[name]] += totals

    def lat(self, tenant: int, value: float) -> None:
        self._staged_lat.append((tenant, value))

    def staged(self, name: str) -> np.ndarray:
        """Not-yet-committed counter increments for ``name`` (``[T]``)."""
        return self._staged_counts[:, C_IDX[name]].copy()

    # -- commits ------------------------------------------------------------
    def _flush_rounds(self):
        """Group staged latencies into rounds of <= 1 sample per tenant."""
        rounds: List[Tuple[np.ndarray, np.ndarray]] = []
        vals = np.zeros(self.T)
        mask = np.zeros(self.T, bool)
        for t, v in self._staged_lat:
            if mask[t]:
                rounds.append((vals, mask))
                vals, mask = np.zeros(self.T), np.zeros(self.T, bool)
            vals[t] = v
            mask[t] = True
        if mask.any():
            rounds.append((vals, mask))
        self._staged_lat.clear()
        return rounds

    def commit(self) -> None:
        """Flush staged counters + latencies (call once per step/window).

        The numpy backend takes an in-place fast path (one vectorized
        ``np.add.at`` through the same ``bucket_index`` kernel — result
        identical to the one-hot ``record_step`` path the jnp backend
        jits; the parity tests pin both levels)."""
        if self._jit_step is None:
            if self._staged_counts.any():
                self.state["counts"] += self._staged_counts.astype(
                    self.state["counts"].dtype)
                self._staged_counts[:] = 0.0
            if self._staged_lat:
                ts = np.array([t for t, _ in self._staged_lat], np.int64)
                vs = np.array([v for _, v in self._staged_lat])
                idx = bucket_index(vs, self.state["hist"].shape[1], np)
                np.add.at(self.state["hist"], (ts, idx), 1)
                self._staged_lat.clear()
            return
        rounds = self._flush_rounds()
        counts = self._staged_counts
        if not rounds and not counts.any():
            return
        if not rounds:
            rounds = [(np.zeros(self.T), np.zeros(self.T, bool))]
        for i, (vals, mask) in enumerate(rounds):
            ci = counts if i == 0 else np.zeros_like(counts)
            self.state = self._jit_step(self.state, ci, vals, mask)
        self._staged_counts[:] = 0.0

    def commit_window(self, gauges) -> None:
        """Push one ``[G, T]`` gauge sample (occupancy, queue, rate, KV)."""
        if self._jit_window is not None:
            self.state = self._jit_window(self.state,
                                          np.asarray(gauges, float))
        else:
            ring, ptr = self.state["ring"], self.state["ptr"]
            ring[:, :, int(ptr) % ring.shape[-1]] = gauges
            ptr += 1          # 0-d array: in-place increment

    def reset_tenant(self, tenant: int) -> None:
        """Zero one tenant's committed and staged metrics (ECTX teardown
        — a reused tenant id must not inherit telemetry history)."""
        self._staged_counts[tenant] = 0.0
        self._staged_lat = [(t, v) for t, v in self._staged_lat
                            if t != tenant]
        if self.xp is np:
            self.state["counts"][tenant] = 0
            self.state["hist"][tenant] = 0
            self.state["ring"][:, tenant, :] = 0.0
        else:
            self.state = dict(
                self.state,
                counts=self.state["counts"].at[tenant].set(0),
                hist=self.state["hist"].at[tenant].set(0),
                ring=self.state["ring"].at[:, tenant, :].set(0.0))

    # -- reads (host) --------------------------------------------------------
    def snapshot(self) -> dict:
        """Committed state as host numpy copies (the only sync point) —
        a snapshot stays frozen while in-place numpy commits continue."""
        return {k: np.array(v) for k, v in self.state.items()}

    def counter(self, name: str, snap: Optional[dict] = None) -> np.ndarray:
        s = snap or self.snapshot()
        return s["counts"][:, C_IDX[name]]
