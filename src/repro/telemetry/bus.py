"""Streaming metrics bus (DESIGN.md §11): live per-interval observability.

The telemetry plane (§6) and the trace plane (§10) only materialize
*after* a run completes; nothing can observe, alert on, or attribute
SLO violations while the controller is acting.  The bus closes that
gap: every engine — the event-loop sim, the batched sim and the serving
engine — publishes one ``BusFrame`` per observation interval through a
shared ``EngineBase`` hook (``observe_tick``), in virtual-ns or step
time order, and consumers attach without perturbing the jit-safe
commit path (frames are built from the same host-side
``Telemetry.snapshot`` sync point the QoS controller already uses).

Two consumption surfaces:

  * ``subscribe()``   — a bounded **drop-oldest** queue
    (``Subscription``): a slow consumer loses the *oldest* frames, the
    producer never blocks, and the drop count is explicit.
  * ``add_sink()``    — a synchronous tap (``on_frame``/``close``):
    streaming exporters and the live dashboard run inline at publish
    time; the run's wall clock pays exactly what the sink costs.

With nothing attached the engines' per-interval cost is one attribute
check (see ``benchmarks/export_overhead.py`` for the gate).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

import numpy as np

from repro.telemetry.signals import SignalFrame

DEFAULT_QUEUE_DEPTH = 256


@dataclasses.dataclass(frozen=True)
class BusFrame:
    """One observation interval, as published on the bus.

    ``t`` is the interval's *end* in the backend's declared time unit
    (virtual ns on the simulators, engine steps on the serving engine);
    ``seq`` is the 0-based interval index.  ``signals`` is the
    interval-differenced ``SignalFrame``; ``counts`` the cumulative
    committed counter matrix ``[T, C]`` and ``interval_counts`` its
    difference against the previous frame.  ``weights``/``admit`` are
    the live scheduler arrays (post any controller actuation of the
    *previous* interval).  ``alerts`` carries the SLO burn-rate alerts
    raised in this interval (``slo_audit.SLOAlert``), empty when no
    audit is attached.  ``nic`` distinguishes publishers sharing one
    bus in a fleet run (``"nic<k>"``; empty on single-engine runs).
    """
    t: float
    seq: int
    time_unit: str
    backend: str
    signals: SignalFrame
    counts: np.ndarray
    interval_counts: np.ndarray
    weights: np.ndarray
    admit: np.ndarray
    alerts: Tuple = ()
    nic: str = ""


class Subscription:
    """Bounded drop-oldest frame queue handed out by ``subscribe``."""

    def __init__(self, maxlen: int = DEFAULT_QUEUE_DEPTH, name: str = ""):
        if maxlen <= 0:
            raise ValueError(f"subscription depth must be > 0, got {maxlen}")
        self.name = name
        self._q: Deque[BusFrame] = deque(maxlen=maxlen)
        self.dropped = 0          # frames evicted before being drained
        self.delivered = 0        # frames ever enqueued
        self.closed = False

    def _offer(self, frame: BusFrame) -> None:
        if self.closed:
            return
        if len(self._q) == self._q.maxlen:
            self.dropped += 1
        self._q.append(frame)
        self.delivered += 1

    def drain(self) -> List[BusFrame]:
        out = list(self._q)
        self._q.clear()
        return out

    def latest(self) -> Optional[BusFrame]:
        """Most recent frame, discarding anything older."""
        if not self._q:
            return None
        frame = self._q[-1]
        self._q.clear()
        return frame

    def close(self) -> None:
        self.closed = True
        self._q.clear()

    def __len__(self) -> int:
        return len(self._q)


class MetricsBus:
    """Fan-out hub between the engines' observe hook and consumers."""

    def __init__(self) -> None:
        self._subs: List[Subscription] = []
        self._sinks: List = []
        self.published = 0
        self.closed = False

    # -- consumer surface ---------------------------------------------------
    def subscribe(self, maxlen: int = DEFAULT_QUEUE_DEPTH,
                  name: str = "") -> Subscription:
        sub = Subscription(maxlen=maxlen, name=name)
        self._subs.append(sub)
        return sub

    def add_sink(self, sink):
        """Register a synchronous tap: ``sink.on_frame(frame)`` runs at
        every publish; ``sink.close()`` (if present) runs at bus close.
        Returns the sink for chaining."""
        self._sinks.append(sink)
        return sink

    # -- producer surface ---------------------------------------------------
    def publish(self, frame: BusFrame) -> None:
        if self.closed:
            raise RuntimeError("publish on a closed MetricsBus")
        self.published += 1
        for sub in self._subs:
            sub._offer(frame)
        for sink in self._sinks:
            sink.on_frame(frame)

    def close(self) -> None:
        """Flush + close every sink (exporters write their files here);
        subscriptions keep their queued frames for a final drain."""
        if self.closed:
            return
        self.closed = True
        for sink in self._sinks:
            close = getattr(sink, "close", None)
            if close is not None:
                close()

    @property
    def dropped(self) -> int:
        return sum(s.dropped for s in self._subs)
