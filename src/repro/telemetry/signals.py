"""Derived per-tenant congestion / SLO signals (DESIGN.md §6).

The control plane reads the committed telemetry state plus the live
scheduler arrays and folds them into the signal vector the QoS
controller acts on.  Everything here is host-side numpy — signals are
consumed at control-interval granularity, so a single device->host pull
per interval (``Telemetry.snapshot``) is the only sync the jnp backend
pays.

Signals (all ``[T]`` unless noted):

  * ``p50`` / ``p99``     — kernel/request sojourn latency from the log
                            histogram (queueing included);
  * ``ecn_rate``          — ECN-marked fraction of arrivals;
  * ``drop_rate``         — dropped fraction of arrivals;
  * ``service_debt``      — WLBVT debt: mean active priority-normalized
                            throughput minus own (positive = underserved);
  * ``kv_pressure``       — current occupancy / quota cap (serving R3) or
                            FIFO depth / capacity (sim);
  * ``occupancy_mean``    — windowed mean PU/slot occupancy (gauge ring);
  * ``queue_mean``        — windowed mean backlog;
  * ``jain_weighted``     — scalar: weighted Jain index over windowed
                            occupancy (folds core/accounting in).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.accounting import weighted_jain
from repro.telemetry import metrics as M


@dataclasses.dataclass
class SignalFrame:
    """One control-interval reading of the telemetry plane.

    Zero-completion semantics (pinned): an interval in which a tenant
    records no latency samples reads ``p50 == p99 == 0.0`` — never a
    stale carry-forward of the previous interval and never NaN.  Any
    real sample lands in a log2 bucket whose value is >= 1, so 0.0
    uniquely encodes "no data"; ``lat_samples`` carries the per-tenant
    interval sample count so consumers (the SLO burn-rate audit) can
    tell an idle interval from a fast one and must not count it as a
    latency violation.
    """
    p50: np.ndarray
    p99: np.ndarray
    ecn_rate: np.ndarray
    drop_rate: np.ndarray
    service_debt: np.ndarray
    kv_pressure: np.ndarray
    occupancy_mean: np.ndarray
    queue_mean: np.ndarray
    jain_weighted: float
    lat_samples: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))

    def as_dict(self) -> dict:
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}


def wlbvt_service_debt(total_occup, bvt, prio) -> np.ndarray:
    """Per-tenant WLBVT debt: how far below the mean *active* tenant's
    priority-normalized service rate this tenant is running.  Positive =
    underserved (owed service), negative = overserved."""
    total_occup = np.asarray(total_occup, float)
    bvt = np.asarray(bvt, float)
    prio = np.asarray(prio, float)
    norm = total_occup / np.maximum(bvt, 1.0) / np.maximum(prio, 1e-9)
    active = bvt > 0
    if not active.any():
        return np.zeros_like(norm)
    return np.where(active, norm[active].mean() - norm, 0.0)


def compute_signals(tel, *, prio, total_occup, bvt,
                    kv_pressure: Optional[np.ndarray] = None,
                    baseline: Optional[dict] = None,
                    snap: Optional[dict] = None) -> SignalFrame:
    """Fold the committed telemetry state + live WLBVT arrays into one
    ``SignalFrame``.  ``tel`` is a ``Telemetry`` wrapper (any backend).

    With ``baseline`` (a previous ``tel.snapshot()``), counters and the
    latency histogram are differenced against it so the latency/rate
    signals cover only the interval since — the responsive form a closed
    loop needs; without it they are run-lifetime cumulative.  Pass a
    pre-taken ``snap`` to reuse it (the control loop hands the same
    snapshot on as the next interval's baseline, so one device->host
    pull per interval is the only sync the jnp backend pays).
    """
    if snap is None:
        snap = tel.snapshot()
    counts, hist = snap["counts"], snap["hist"]
    if baseline is not None:
        counts = counts - baseline["counts"]
        hist = hist - baseline["hist"]
    arrivals = np.maximum(counts[:, M.C_IDX["arrivals"]], 1.0)
    gmean = M.ring_mean(snap["ring"], int(snap["ptr"]), np)
    occ_mean = gmean[M.G_IDX["occupancy"]]
    prio = np.asarray(prio, float)
    active = occ_mean > 0
    jain = (weighted_jain(occ_mean[active], prio[active])
            if active.sum() >= 2 else 1.0)
    return SignalFrame(
        p50=M.hist_quantile(hist, 0.50, np),
        p99=M.hist_quantile(hist, 0.99, np),
        ecn_rate=counts[:, M.C_IDX["ecn_marks"]] / arrivals,
        drop_rate=counts[:, M.C_IDX["drops"]] / arrivals,
        service_debt=wlbvt_service_debt(total_occup, bvt, prio),
        kv_pressure=(np.zeros(tel.T) if kv_pressure is None
                     else np.asarray(kv_pressure, float)),
        occupancy_mean=occ_mean,
        queue_mean=gmean[M.G_IDX["queue_len"]],
        jain_weighted=float(jain),
        lat_samples=hist.sum(axis=1).astype(float),
    )
