"""OpenMetrics + JSONL exporters over the metrics bus (DESIGN.md §11.2).

Every exported metric is declared once in ``METRICS`` as a literal
``MetricSpec`` so the static checker (``repro.analysis.metrics_names``)
can lint the whole surface without running anything: names are
snake_case, every name ends in its declared unit suffix, the unit
comes from the whitelist derived from the report schema's
``TIME_UNITS`` single source of truth (plus the dimensionless
suffixes), and no name+labelset is declared twice.  Counters follow the
OpenMetrics convention (family ``osmosis_arrivals`` -> sample
``osmosis_arrivals_total``); time-valued gauges exist once per declared
time unit and the exporter picks the variant matching the run's
backend, so a metric name never carries an ambiguous unit.

Two sinks, both attachable to a ``MetricsBus``:

  * ``JsonlExporter``     — streaming: one JSON object per ``BusFrame``
    written at publish time.
  * ``OpenMetricsWriter`` — scrape-style: tracks the latest frame and
    renders one Prometheus/OpenMetrics text exposition at close.

``python -m repro.telemetry.export --schema FILE [--golden GOLDEN]``
prints (or diffs) the schema of an exposition file — metric names,
types and label *keys* only, never values — which CI pins against
``tests/data/openmetrics_schema.golden``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.api.report import TIME_UNITS
from repro.telemetry.metrics import C_IDX

# unit-suffix whitelist: the declared report time units + the
# dimensionless suffixes the exporter uses
DIMENSIONLESS_SUFFIXES = ("total", "ratio", "count")
UNIT_SUFFIXES = TIME_UNITS + DIMENSIONLESS_SUFFIXES

# the ``nic`` label distinguishes publishers sharing one bus in a
# fleet run; single-engine runs export it empty (per the Prometheus
# convention an empty label is equivalent to the label being absent)
LABELS_TENANT = ("tenant", "backend", "nic")
LABELS_GLOBAL = ("backend", "nic")
LABELS_FLEET = ("backend", "nic")   # fabric rows: nic = switch port


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One exported metric family (a literal row in ``METRICS``)."""
    name: str                          # full sample name incl. unit suffix
    kind: str                          # "counter" | "gauge"
    unit: str                          # last name component; whitelisted
    help: str
    labels: Tuple[str, ...] = LABELS_TENANT

    @property
    def family(self) -> str:
        """OpenMetrics family name (counters drop the _total suffix)."""
        if self.kind == "counter" and self.name.endswith("_total"):
            return self.name[:-len("_total")]
        return self.name


METRICS = (
    # cumulative counters (from the committed counter matrix)
    MetricSpec("osmosis_arrivals_total", "counter", "total",
               "work items arrived (packets / requests)"),
    MetricSpec("osmosis_completed_total", "counter", "total",
               "work items completed"),
    MetricSpec("osmosis_drops_total", "counter", "total",
               "FMQ overflow drops"),
    MetricSpec("osmosis_rejected_total", "counter", "total",
               "admission-gate rejections (controller backpressure)"),
    MetricSpec("osmosis_killed_total", "counter", "total",
               "watchdog / budget kills"),
    MetricSpec("osmosis_ecn_marks_total", "counter", "total",
               "ECN-marked arrivals"),
    MetricSpec("osmosis_bytes_in_total", "counter", "total",
               "ingress bytes"),
    MetricSpec("osmosis_bytes_out_total", "counter", "total",
               "egress bytes"),
    MetricSpec("osmosis_tokens_total", "counter", "total",
               "generated tokens (serving backend)"),
    MetricSpec("osmosis_slo_alerts_total", "counter", "total",
               "burn-rate SLO alerts raised"),
    # per-interval gauges (from the interval-differenced SignalFrame);
    # time-valued gauges exist once per declared time unit
    MetricSpec("osmosis_p50_sojourn_ns", "gauge", "ns",
               "interval p50 sojourn latency (sim backend)"),
    MetricSpec("osmosis_p50_sojourn_steps", "gauge", "steps",
               "interval p50 sojourn latency (serving backend)"),
    MetricSpec("osmosis_p99_sojourn_ns", "gauge", "ns",
               "interval p99 sojourn latency (sim backend)"),
    MetricSpec("osmosis_p99_sojourn_steps", "gauge", "steps",
               "interval p99 sojourn latency (serving backend)"),
    MetricSpec("osmosis_lat_samples_count", "gauge", "count",
               "interval sojourn samples (0 = idle interval)"),
    MetricSpec("osmosis_ecn_rate_ratio", "gauge", "ratio",
               "interval ECN-marked fraction of arrivals"),
    MetricSpec("osmosis_drop_rate_ratio", "gauge", "ratio",
               "interval dropped fraction of arrivals"),
    MetricSpec("osmosis_service_debt_ratio", "gauge", "ratio",
               "WLBVT service debt (positive = underserved)"),
    MetricSpec("osmosis_kv_pressure_ratio", "gauge", "ratio",
               "KV quota / FIFO pressure"),
    MetricSpec("osmosis_occupancy_count", "gauge", "count",
               "windowed mean PU/slot occupancy"),
    MetricSpec("osmosis_queue_depth_count", "gauge", "count",
               "windowed mean backlog"),
    MetricSpec("osmosis_sched_weight_ratio", "gauge", "ratio",
               "live scheduler weight (base x AIMD boost)"),
    MetricSpec("osmosis_admit_ratio", "gauge", "ratio",
               "admission gate (1 = admitted, 0 = paused)"),
    # engine-global gauges
    MetricSpec("osmosis_jain_weighted_ratio", "gauge", "ratio",
               "weighted Jain fairness over windowed occupancy",
               labels=LABELS_GLOBAL),
    # fleet fabric rows (fleet/engine.fleet_metric_rows feeds these via
    # OpenMetricsWriter.extra_rows; nic = switch output port)
    MetricSpec("osmosis_switch_voq_depth_count", "gauge", "count",
               "peak VOQ depth feeding this output port",
               labels=LABELS_FLEET),
    MetricSpec("osmosis_link_utilization_ratio", "gauge", "ratio",
               "output link serialization busy fraction",
               labels=LABELS_FLEET),
    MetricSpec("osmosis_migrations_total", "counter", "total",
               "live migrations landed on this NIC",
               labels=LABELS_FLEET),
)

SPECS_BY_NAME = {m.name: m for m in METRICS}

# counter sample name -> committed counter column
COUNTER_SOURCES = {
    "osmosis_arrivals_total": "arrivals",
    "osmosis_completed_total": "completed",
    "osmosis_drops_total": "drops",
    "osmosis_rejected_total": "rejected",
    "osmosis_killed_total": "killed",
    "osmosis_ecn_marks_total": "ecn_marks",
    "osmosis_bytes_in_total": "bytes_in",
    "osmosis_bytes_out_total": "bytes_out",
    "osmosis_tokens_total": "tokens",
}

# signal attribute -> unitless gauge sample name
SIGNAL_SOURCES = {
    "lat_samples": "osmosis_lat_samples_count",
    "ecn_rate": "osmosis_ecn_rate_ratio",
    "drop_rate": "osmosis_drop_rate_ratio",
    "service_debt": "osmosis_service_debt_ratio",
    "kv_pressure": "osmosis_kv_pressure_ratio",
    "occupancy_mean": "osmosis_occupancy_count",
    "queue_mean": "osmosis_queue_depth_count",
}


def time_metric(base: str, time_unit: str) -> str:
    """The time-suffixed variant of a declared metric family, e.g.
    ``time_metric("osmosis_p99_sojourn", "ns")``.  Raises on a name
    that is not in the registry (typos can't mint metrics)."""
    name = f"{base}_{time_unit}"
    if name not in SPECS_BY_NAME:
        raise KeyError(f"{name} is not a declared metric")
    return name


def _active_tenants(frame) -> List[int]:
    """Tenants with any committed activity, in id order."""
    return [int(i) for i in
            np.nonzero(frame.counts.sum(axis=1) > 0)[0]]


def _tenant_label(names: Optional[Dict[int, str]], t: int) -> str:
    return names[t] if names and t in names else f"tenant{t}"


def frame_values(frame, names: Optional[Dict[int, str]] = None,
                 alert_totals: Optional[Dict[int, int]] = None) -> list:
    """Flatten one ``BusFrame`` into ``(metric_name, labels, value)``
    rows — the single mapping both exporters (and the dashboard's JSON
    mode) share, so they can never disagree on names."""
    rows = []
    sig = frame.signals
    tenants = _active_tenants(frame)
    p50_name = time_metric("osmosis_p50_sojourn", frame.time_unit)
    p99_name = time_metric("osmosis_p99_sojourn", frame.time_unit)
    for t in tenants:
        labels = {"tenant": _tenant_label(names, t),
                  "backend": frame.backend, "nic": frame.nic}
        for mname, col in COUNTER_SOURCES.items():
            rows.append((mname, labels, float(frame.counts[t, C_IDX[col]])))
        rows.append(("osmosis_slo_alerts_total", labels,
                     float((alert_totals or {}).get(t, 0))))
        rows.append((p50_name, labels, float(sig.p50[t])))
        rows.append((p99_name, labels, float(sig.p99[t])))
        for attr, mname in SIGNAL_SOURCES.items():
            rows.append((mname, labels, float(getattr(sig, attr)[t])))
        rows.append(("osmosis_sched_weight_ratio", labels,
                     float(frame.weights[t])))
        rows.append(("osmosis_admit_ratio", labels,
                     float(frame.admit[t])))
    rows.append(("osmosis_jain_weighted_ratio",
                 {"backend": frame.backend, "nic": frame.nic},
                 float(sig.jain_weighted)))
    return rows


def _fmt_labels(labels: Dict[str, str]) -> str:
    # empty value == label absent (Prometheus data-model convention);
    # single-engine runs publish nic="" and render without the label
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()) if v)
    return "{" + inner + "}"


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------
class JsonlExporter:
    """Streaming JSONL sink: one line per published frame."""

    def __init__(self, path: str, *, names: Optional[Dict[int, str]] = None):
        self.path = path
        self.names = names
        self._f = open(path, "w")
        # alert totals accumulate per publisher: on a shared fleet bus
        # one NIC's alerts must not leak into another NIC's rows
        self._alert_totals: Dict[str, Dict[int, int]] = {}
        self.lines = 0

    def on_frame(self, frame) -> None:
        totals = self._alert_totals.setdefault(frame.nic, {})
        for a in frame.alerts:
            totals[a.tenant] = totals.get(a.tenant, 0) + 1
        metrics: Dict[str, Dict[str, float]] = {}
        for mname, labels, value in frame_values(
                frame, self.names, totals):
            metrics.setdefault(mname, {})[
                labels.get("tenant", "_global")] = value
        rec = {
            "t": frame.t, "seq": frame.seq, "backend": frame.backend,
            "nic": frame.nic, "time_unit": frame.time_unit,
            "metrics": metrics,
            "alerts": [{"tenant": _tenant_label(self.names, a.tenant),
                        "window": a.window,
                        "burn_rate": a.burn_rate, "p99": a.p99,
                        "target": a.target} for a in frame.alerts],
        }
        self._f.write(json.dumps(rec, sort_keys=True) + "\n")
        self.lines += 1

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class OpenMetricsWriter:
    """Scrape-style sink: renders the latest frame *per publisher* as
    one Prometheus/OpenMetrics text exposition at close (or on demand
    via ``render``).  On a single-engine bus that is exactly the old
    one-frame behavior; on a shared fleet bus each ``(backend, nic)``
    source contributes its own latest frame, and the fleet engine can
    append fabric-level rows through ``extra_rows``."""

    def __init__(self, path: str = "",
                 *, names: Optional[Dict[int, str]] = None):
        self.path = path
        self.names = names
        self._last: Dict[Tuple[str, str], object] = {}   # (backend, nic)
        self._alert_totals: Dict[str, Dict[int, int]] = {}
        self.frames = 0
        # explicit (name, labels, value) rows merged into the render —
        # fleet fabric gauges that no BusFrame carries
        self.extra_rows: List[tuple] = []

    def on_frame(self, frame) -> None:
        totals = self._alert_totals.setdefault(frame.nic, {})
        for a in frame.alerts:
            totals[a.tenant] = totals.get(a.tenant, 0) + 1
        self._last[(frame.backend, frame.nic)] = frame
        self.frames += 1

    def render(self) -> str:
        if not self._last and not self.extra_rows:
            return "# EOF\n"
        by_metric: Dict[str, list] = {}
        for key in sorted(self._last):
            frame = self._last[key]
            for mname, labels, value in frame_values(
                    frame, self.names,
                    self._alert_totals.get(frame.nic, {})):
                by_metric.setdefault(mname, []).append((labels, value))
        for mname, labels, value in self.extra_rows:
            by_metric.setdefault(mname, []).append((dict(labels), value))
        lines: List[str] = []
        for spec in METRICS:               # declared order = stable output
            samples = by_metric.get(spec.name)
            if not samples:
                continue
            lines.append(f"# TYPE {spec.family} {spec.kind}")
            if spec.unit not in DIMENSIONLESS_SUFFIXES:
                lines.append(f"# UNIT {spec.family} {spec.unit}")
            lines.append(f"# HELP {spec.family} {spec.help}")
            for labels, value in samples:
                lines.append(f"{spec.name}{_fmt_labels(labels)} {value:g}")
        lines.append("# EOF")
        return "\n".join(lines) + "\n"

    def close(self) -> None:
        if self.path:
            with open(self.path, "w") as f:
                f.write(self.render())


def attach_exporters(bus, out_prefix: str,
                     *, names: Optional[Dict[int, str]] = None) -> tuple:
    """Attach both exporters to ``bus``; files land at
    ``<out_prefix>.om.txt`` (OpenMetrics) and ``<out_prefix>.jsonl``."""
    om = bus.add_sink(OpenMetricsWriter(out_prefix + ".om.txt",
                                        names=names))
    jl = bus.add_sink(JsonlExporter(out_prefix + ".jsonl", names=names))
    return om, jl


# ---------------------------------------------------------------------------
# schema extraction (CI golden diff: names + label keys, never values)
# ---------------------------------------------------------------------------
def schema_lines(text: str) -> List[str]:
    """The structural schema of an exposition: ``# TYPE``/``# UNIT``
    lines verbatim plus ``name{label,keys}`` per distinct sample shape,
    sorted and deduplicated."""
    out = set()
    for line in text.splitlines():
        line = line.strip()
        if not line or line == "# EOF" or line.startswith("# HELP"):
            continue
        if line.startswith("# TYPE") or line.startswith("# UNIT"):
            out.add(line)
            continue
        if line.startswith("#"):
            continue
        sample = line.split(" ")[0]
        if "{" in sample:
            name, rest = sample.split("{", 1)
            keys = sorted(kv.split("=")[0]
                          for kv in rest.rstrip("}").split(",") if kv)
            out.add(f"{name}{{{','.join(keys)}}}")
        else:
            out.add(sample)
    return sorted(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="OpenMetrics exposition schema tool")
    ap.add_argument("--schema", required=True,
                    help="exposition file to extract the schema of")
    ap.add_argument("--golden", default="",
                    help="diff the schema against this golden file; "
                         "nonzero exit on mismatch")
    args = ap.parse_args(argv)
    with open(args.schema) as f:
        got = schema_lines(f.read())
    if not args.golden:
        for line in got:
            print(line)
        return 0
    with open(args.golden) as f:
        want = [ln for ln in (x.strip() for x in f) if ln]
    missing = sorted(set(want) - set(got))
    extra = sorted(set(got) - set(want))
    for m in missing:
        print(f"MISSING {m}")
    for e in extra:
        print(f"EXTRA   {e}")
    if missing or extra:
        print(f"schema mismatch: {len(missing)} missing, "
              f"{len(extra)} extra (golden {args.golden})")
        return 1
    print(f"schema ok: {len(got)} entries match {args.golden}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
