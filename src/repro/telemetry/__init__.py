"""Per-tenant telemetry plane + closed-loop QoS control (DESIGN.md §6).

``metrics``    — fixed-shape array-native collectors (counters, log
                 histograms, gauge rings); one backend-generic kernel set
                 for numpy (sim, eager) and jnp-under-jit (serving).
``signals``    — derived congestion/SLO signals read by the control plane.
``controller`` — AIMD weight adaptation + hysteretic admission gate.
``report``     — per-tenant JSON/console reports.
``bus``        — streaming metrics bus (bounded drop-oldest fan-out).
``export``     — OpenMetrics / JSONL exporters over the bus.
``slo_audit``  — per-tenant error budgets + burn-rate SLO alerts.
"""
from repro.telemetry.metrics import (COUNTERS, GAUGES, C_IDX, G_IDX,
                                     HIST_BUCKETS, RING_WINDOW, Telemetry,
                                     bucket_index, bucket_value, create_state,
                                     hist_add, hist_quantile, record_step,
                                     record_window, ring_mean, ring_push)
from repro.telemetry.signals import (SignalFrame, compute_signals,
                                     wlbvt_service_debt)
from repro.telemetry.controller import (ControlAction, QoSConfig,
                                        QoSController, apply_to_scheduler)
from repro.telemetry.report import dump_json, format_console, tenant_report
from repro.telemetry.trace import (DECISION_KINDS, DISPOSITIONS, REASONS,
                                   STAGES, TraceRecorder, ring_scatter,
                                   record_slo_alert, record_qos_intervention)
from repro.telemetry.traceview import (console_waterfall, to_perfetto,
                                       write_perfetto)
from repro.telemetry.bus import BusFrame, MetricsBus, Subscription
from repro.telemetry.export import (METRICS, MetricSpec, JsonlExporter,
                                    OpenMetricsWriter, attach_exporters,
                                    schema_lines)
from repro.telemetry.slo_audit import (SLOAlert, SLOAudit, SLOAuditConfig)

__all__ = [
    "COUNTERS", "GAUGES", "C_IDX", "G_IDX", "HIST_BUCKETS", "RING_WINDOW",
    "Telemetry", "bucket_index", "bucket_value", "create_state", "hist_add",
    "hist_quantile", "record_step", "record_window", "ring_mean", "ring_push",
    "SignalFrame", "compute_signals", "wlbvt_service_debt",
    "ControlAction", "QoSConfig", "QoSController", "apply_to_scheduler",
    "dump_json", "format_console", "tenant_report",
    "DECISION_KINDS", "DISPOSITIONS", "REASONS", "STAGES",
    "TraceRecorder", "ring_scatter",
    "console_waterfall", "to_perfetto", "write_perfetto",
    "record_slo_alert", "record_qos_intervention",
    "BusFrame", "MetricsBus", "Subscription",
    "METRICS", "MetricSpec", "JsonlExporter", "OpenMetricsWriter",
    "attach_exporters", "schema_lines",
    "SLOAlert", "SLOAudit", "SLOAuditConfig",
]
