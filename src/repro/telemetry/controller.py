"""Closed-loop QoS controller (DESIGN.md §6).

Consumes ``SignalFrame``s at a fixed control interval and steers two
actuators both execution surfaces expose:

  * **scheduler weights** (WLBVT ``prio`` + DWRR weights) — AIMD: a
    tenant whose interval p99 sojourn latency violates its SLO target
    gains weight additively; a tenant meeting its target decays
    multiplicatively back toward its base (admission-time) weight, so
    transient boosts are returned once congestion passes and tenants
    without targets keep their static share;
  * **admission backpressure** — hysteresis on congestion pressure
    (max of ECN-mark rate, drop rate, KV/queue pressure): a tenant is
    paused above ``pause_hi`` and resumed only below ``resume_lo``, so
    the gate does not chatter around a single threshold.

The controller is surface-agnostic: the simulator applies actions at
window boundaries in virtual time, the serving engine every
``qos_interval`` steps.  It never touches engine state itself — it
returns a ``ControlAction`` the caller applies.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.telemetry.signals import SignalFrame


@dataclasses.dataclass(frozen=True)
class QoSConfig:
    ai: float = 0.5          # additive weight increase per violating interval
    md: float = 0.7          # multiplicative decay toward base when meeting
    w_min_scale: float = 0.25    # weight floor/ceiling, relative to base
    w_max_scale: float = 16.0
    pause_hi: float = 0.85   # pressure above which admission is paused
    resume_lo: float = 0.5   # pressure below which it resumes
    headroom: float = 0.8    # target fraction: act before the SLO is blown


@dataclasses.dataclass
class ControlAction:
    weights: np.ndarray      # (T,) controller weights (base * boost)
    boost: np.ndarray        # (T,) multiplicative factor vs controller base
    admit: np.ndarray        # (T,) bool: False = backpressure this tenant
    violating: np.ndarray    # (T,) bool: interval p99 above target


class QoSController:
    """Per-tenant AIMD weight adaptation + hysteretic admission gate."""

    def __init__(self, base_weights, p99_targets=None,
                 cfg: QoSConfig = QoSConfig()):
        self.cfg = cfg
        self.base = np.asarray(base_weights, float).copy()
        T = self.base.shape[0]
        t = (np.zeros(T) if p99_targets is None
             else np.asarray(p99_targets, float))
        self.targets = t            # 0 = no latency SLO for that tenant
        self.weights = self.base.copy()
        self.paused = np.zeros(T, bool)
        self.history: List[ControlAction] = []

    def reset_tenant(self, tenant: int, base_weight: float = None) -> None:
        """Forget a tenant's AIMD boost and pause state (ECTX teardown —
        a reused tenant id must not inherit control history)."""
        if base_weight is not None:
            self.base[tenant] = base_weight
        self.weights[tenant] = self.base[tenant]
        self.paused[tenant] = False

    def update(self, sig: SignalFrame) -> ControlAction:
        cfg = self.cfg
        has_slo = self.targets > 0
        viol = has_slo & (sig.p99 > cfg.headroom * self.targets)
        # AIMD on scheduler weights
        boosted = self.weights + cfg.ai * self.base
        decayed = cfg.md * self.weights + (1.0 - cfg.md) * self.base
        w = np.where(viol, boosted, decayed)
        self.weights = np.clip(w, cfg.w_min_scale * self.base,
                               cfg.w_max_scale * self.base)
        # hysteresis on admission: pressure is the worst congestion signal
        pressure = np.maximum.reduce([sig.ecn_rate, sig.drop_rate,
                                      sig.kv_pressure])
        self.paused = np.where(self.paused,
                               pressure > cfg.resume_lo,   # stay paused?
                               pressure > cfg.pause_hi)    # newly pause?
        action = ControlAction(weights=self.weights.copy(),
                               boost=self.weights
                               / np.maximum(self.base, 1e-12),
                               admit=~self.paused, violating=viol)
        self.history.append(action)
        return action


def apply_to_scheduler(action: ControlAction, *targets,
                       installed: Optional[np.ndarray] = None) -> None:
    """Actuate the action onto live scheduler arrays in place.

    Each target is a ``(live_array, base_array)`` pair — WLBVT ``prio``
    and any DWRR weight arrays, each with its *own* SLO-configured base
    (priority vs dma_priority vs egress_priority differ per knob).  The
    controller contributes only the multiplicative ``boost``:
    ``live = base * boost``, so configured QoS provisioning is scaled,
    never clobbered.  ``installed`` restricts writes so un-admitted FMQ
    rows keep their defaults.
    """
    b = action.boost
    sel = (np.ones(len(b), bool) if installed is None
           else np.asarray(installed, bool))
    for live, base in targets:
        n = min(len(b), len(live))
        s = sel[:n]
        live[:n][s] = np.asarray(base)[:n][s] * b[:n][s]
