"""Per-tenant telemetry reports: JSON-able dicts + console rendering."""
from __future__ import annotations

import json
from typing import Dict, Optional

import numpy as np

from repro.telemetry import metrics as M
from repro.telemetry.signals import SignalFrame


def tenant_report(tel, *, names: Optional[Dict[int, str]] = None,
                  signals: Optional[SignalFrame] = None,
                  only_active: bool = True) -> dict:
    """Fold a ``Telemetry`` plane (and optionally a ``SignalFrame``) into
    a JSON-able per-tenant report."""
    snap = tel.snapshot()
    counts, hist = snap["counts"], snap["hist"]
    p50 = M.hist_quantile(hist, 0.50, np)
    p99 = M.hist_quantile(hist, 0.99, np)
    seen = counts.sum(axis=1) + hist.sum(axis=1)
    tenants = {}
    for t in range(tel.T):
        if only_active and seen[t] == 0:
            continue
        row = {n: float(counts[t, i]) for n, i in M.C_IDX.items()}
        row["p50_latency"] = float(p50[t])
        row["p99_latency"] = float(p99[t])
        row["latency_samples"] = float(hist[t].sum())
        if names and t in names:
            row["name"] = names[t]
        if signals is not None:
            row["service_debt"] = float(signals.service_debt[t])
            row["ecn_rate"] = float(signals.ecn_rate[t])
            row["kv_pressure"] = float(signals.kv_pressure[t])
        tenants[t] = row
    out = {"num_tenants": tel.T, "backend": tel.backend, "tenants": tenants}
    if signals is not None:
        out["jain_weighted"] = signals.jain_weighted
    return out


def format_console(report: dict) -> str:
    cols = ["arrivals", "completed", "killed", "drops", "ecn_marks",
            "p50_latency", "p99_latency"]
    lines = [" tenant  " + "  ".join(f"{c:>12}" for c in cols)]
    for t, row in sorted(report["tenants"].items()):
        label = row.get("name", f"tenant{t}")[:8]
        vals = "  ".join(f"{row[c]:>12.6g}" for c in cols)
        lines.append(f" {label:<8}" + vals)
    if "jain_weighted" in report:
        lines.append(f" weighted Jain fairness: "
                     f"{report['jain_weighted']:.4f}")
    return "\n".join(lines)


def dump_json(report: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
