"""Per-tenant telemetry reports: JSON-able dicts + console rendering."""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

import numpy as np

from repro.telemetry import metrics as M
from repro.telemetry.signals import SignalFrame


def tenant_report(tel, *, names: Optional[Dict[int, str]] = None,
                  signals: Optional[SignalFrame] = None,
                  only_active: bool = True) -> dict:
    """Fold a ``Telemetry`` plane (and optionally a ``SignalFrame``) into
    a JSON-able per-tenant report."""
    snap = tel.snapshot()
    counts, hist = snap["counts"], snap["hist"]
    p50 = M.hist_quantile(hist, 0.50, np)
    p99 = M.hist_quantile(hist, 0.99, np)
    seen = counts.sum(axis=1) + hist.sum(axis=1)
    tenants = {}
    for t in range(tel.T):
        if only_active and seen[t] == 0:
            continue
        row = {n: float(counts[t, i]) for n, i in M.C_IDX.items()}
        row["p50_latency"] = float(p50[t])
        row["p99_latency"] = float(p99[t])
        row["latency_samples"] = float(hist[t].sum())
        if names and t in names:
            row["name"] = names[t]
        if signals is not None:
            row["service_debt"] = float(signals.service_debt[t])
            row["ecn_rate"] = float(signals.ecn_rate[t])
            row["kv_pressure"] = float(signals.kv_pressure[t])
        tenants[t] = row
    out = {"num_tenants": tel.T, "backend": tel.backend, "tenants": tenants}
    if signals is not None:
        out["jain_weighted"] = signals.jain_weighted
    return out


# columns holding times in the report's declared latency unit
TIME_COLS = ("p50_latency", "p99_latency")


def _latency_unit(report: dict, time_unit: Optional[str]) -> str:
    # lazy import: api.report pulls telemetry for trace summaries
    from repro.api.report import TIME_UNITS
    unit = time_unit or report.get("latency_unit") or TIME_UNITS[0]
    if unit not in TIME_UNITS:
        raise ValueError(f"latency unit {unit!r} is not one of the "
                         f"declared TIME_UNITS {TIME_UNITS}")
    return unit


def format_console(report: dict, *,
                   time_unit: Optional[str] = None) -> str:
    """Console table; time columns carry the declared unit
    (``api.report.TIME_UNITS``) in their header, never bare numbers."""
    unit = _latency_unit(report, time_unit)
    cols = ["arrivals", "completed", "killed", "drops", "ecn_marks",
            "p50_latency", "p99_latency"]
    heads = [f"{c[:3]}({unit})" if c in TIME_COLS else c for c in cols]
    lines = [" tenant  " + "  ".join(f"{h:>12}" for h in heads)]
    for t, row in sorted(report["tenants"].items()):
        label = row.get("name", f"tenant{t}")[:8]
        vals = "  ".join(f"{row[c]:>12.6g}" for c in cols)
        lines.append(f" {label:<8}" + vals)
    if "jain_weighted" in report:
        lines.append(f" weighted Jain fairness: "
                     f"{report['jain_weighted']:.4f}")
    return "\n".join(lines)


def dump_json(report: dict, path: str, *,
              overwrite: bool = False) -> None:
    """Write the report as JSON; refuses to clobber an existing file
    unless ``overwrite=True``."""
    if not overwrite and os.path.exists(path):
        raise FileExistsError(
            f"{path} exists; pass overwrite=True to replace it")
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
