"""Per-tenant SLO error-budget accounting + burn-rate alerts (§11.3).

SRE-style multi-window burn-rate alerting over the per-interval
``SignalFrame`` stream: each observation interval is classified good or
bad per tenant, and an ``SLOAlert`` fires when the bad fraction over a
short ("fast") or long ("slow") trailing window burns the error budget
(``1 - objective``) faster than its threshold.  Two windows give the
standard trade-off — the fast window catches an acute violation within
a couple of intervals (before the AIMD controller's first actuation,
whose interval is several observation windows long), the slow window
catches sustained low-grade burn without paging on blips.

An interval is **bad** for a tenant when either
  * latency: the interval recorded sojourn samples and its p99 exceeds
    the tenant's target (``TenantSpec.p99_target`` scaled to the
    backend's time unit — the same targets the QoS controller acts
    on); or
  * goodput: the tenant had arrivals but zero completions (starved
    under demand — the goodput face of its ``SLOPolicy`` share).

An idle interval (no samples, no arrivals) is **good**: the pinned
``SignalFrame`` zero-completion semantics read p99 == 0.0 with
``lat_samples == 0`` there, so burn windows never double-count idleness
as violation (see ``tests/test_observability.py``).

The audit is pure host-side arithmetic over bit-identical committed
telemetry, so the event-loop and batched sim datapaths raise identical
alerts at identical virtual times.  ``EngineBase.observe_tick`` drives
it, pushes each alert as an ``EventKind.SLO_ALERT`` EQ event, and
annotates the trace plane; ``note_intervention`` is called from the
controller tick so the summary can attribute alert -> AIMD/admission
intervention lead times.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.telemetry.signals import SignalFrame

MAX_ALERTS = 256               # bounded alert log in the summary
MAX_INTERVENTIONS = 256
MAX_VIOLATION_WINDOWS = 64     # merged bad-interval spans kept per tenant

FAST = "fast"
SLOW = "slow"

# intervention kinds (note_intervention / summary attribution)
IV_AIMD_WEIGHT = "aimd_weight"
IV_ADMISSION = "admission"


@dataclasses.dataclass(frozen=True)
class SLOAuditConfig:
    """Burn-rate policy knobs.

    ``objective`` is the availability objective over observation
    intervals (0.9 = at most 10% of intervals may be bad); the error
    budget is ``1 - objective``.  A window alerts when
    ``bad_fraction / budget >= *_burn`` once it has seen ``*_windows``
    intervals.  Defaults: with budget 0.1, the fast window needs both
    of its 2 intervals bad (burn 10 >= 5), the slow window needs 2 of
    8 (burn 2.5 >= 2).
    """
    objective: float = 0.9
    fast_windows: int = 2
    slow_windows: int = 8
    fast_burn: float = 5.0
    slow_burn: float = 2.0

    def __post_init__(self):
        if not 0.0 < self.objective < 1.0:
            raise ValueError(f"objective must be in (0, 1), got "
                             f"{self.objective}")
        if self.fast_windows <= 0 or self.slow_windows < self.fast_windows:
            raise ValueError("need 0 < fast_windows <= slow_windows, got "
                             f"{self.fast_windows}/{self.slow_windows}")

    @property
    def budget(self) -> float:
        return 1.0 - self.objective


@dataclasses.dataclass(frozen=True)
class SLOAlert:
    """One burn-rate alert (rising edge of a window crossing)."""
    t: float                   # interval end, backend time unit
    tenant: int
    window: str                # FAST | SLOW
    burn_rate: float
    p99: float                 # interval p99 that tripped it
    target: float


class SLOAudit:
    """Streaming per-tenant error-budget accountant."""

    def __init__(self, p99_targets, *, config: Optional[SLOAuditConfig] = None,
                 time_unit: str = "ns"):
        self.cfg = config or SLOAuditConfig()
        self.targets = np.asarray(p99_targets, float)
        self.time_unit = time_unit
        T = len(self.targets)
        self.T = T
        self.intervals = 0
        # trailing bad-interval window, per tenant (slow window length
        # bounds it; the fast window reads its tail)
        self._bad: List[Deque[bool]] = [
            deque(maxlen=self.cfg.slow_windows) for _ in range(T)]
        self._alert_on = {FAST: np.zeros(T, bool),
                          SLOW: np.zeros(T, bool)}
        self._observed = np.zeros(T, np.int64)    # intervals with activity
        self._violating = np.zeros(T, np.int64)
        self.alerts: List[SLOAlert] = []
        self.alerts_total = 0
        self._first_alert_t: Dict[int, float] = {}
        self._first_intervention_t: Dict[int, float] = {}
        self.interventions: List[dict] = []
        self.interventions_total = 0
        self._last_boost: Optional[np.ndarray] = None
        self._last_admit: Optional[np.ndarray] = None
        # merged [first_bad_t, last_bad_t] spans, per tenant
        self._vwindows: Dict[int, List[List[float]]] = {}
        self._open_window: Dict[int, bool] = {}

    # -- per-interval classification ---------------------------------------
    def observe(self, *, t: float, sig: SignalFrame,
                interval_counts: np.ndarray) -> Tuple[SLOAlert, ...]:
        """Classify one observation interval; returns newly-raised
        alerts (rising edges only) in tenant order."""
        from repro.telemetry.metrics import C_IDX
        self.intervals += 1
        arrivals = interval_counts[:, C_IDX["arrivals"]]
        completed = interval_counts[:, C_IDX["completed"]]
        samples = sig.lat_samples
        has_target = self.targets > 0
        bad_lat = has_target & (samples > 0) & (sig.p99 > self.targets)
        starved = has_target & (arrivals > 0) & (completed == 0) \
            & (samples == 0)
        bad = bad_lat | starved
        active = (samples > 0) | (arrivals > 0)
        self._observed += (has_target & active).astype(np.int64)
        self._violating += bad.astype(np.int64)
        out: List[SLOAlert] = []
        budget = self.cfg.budget
        for i in np.nonzero(has_target)[0]:
            i = int(i)
            hist = self._bad[i]
            hist.append(bool(bad[i]))
            self._note_violation_span(i, t, bool(bad[i]))
            for window, length, thresh in (
                    (FAST, self.cfg.fast_windows, self.cfg.fast_burn),
                    (SLOW, self.cfg.slow_windows, self.cfg.slow_burn)):
                if len(hist) < length:
                    continue
                tail = list(hist)[-length:]
                burn = (sum(tail) / length) / budget
                on = self._alert_on[window]
                if burn >= thresh and not on[i]:
                    on[i] = True
                    alert = SLOAlert(
                        t=float(t), tenant=i, window=window,
                        burn_rate=float(burn), p99=float(sig.p99[i]),
                        target=float(self.targets[i]))
                    out.append(alert)
                    self.alerts_total += 1
                    if len(self.alerts) < MAX_ALERTS:
                        self.alerts.append(alert)
                    self._first_alert_t.setdefault(i, float(t))
                elif burn < thresh and on[i]:
                    on[i] = False
        return tuple(out)

    def _note_violation_span(self, tenant: int, t: float, bad: bool) -> None:
        if bad:
            wins = self._vwindows.setdefault(tenant, [])
            if self._open_window.get(tenant):
                if wins:
                    wins[-1][1] = float(t)
            elif len(wins) < MAX_VIOLATION_WINDOWS:
                wins.append([float(t), float(t)])
            self._open_window[tenant] = True
        else:
            self._open_window[tenant] = False

    # -- controller coupling ------------------------------------------------
    def note_intervention(self, t: float, action,
                          installed=None) -> List[dict]:
        """Record the QoS controller's actuation for this tick.  A
        tenant counts as *intervened* when its AIMD boost changed or
        its admission gate flipped relative to the previous tick.
        Returns the new intervention rows (for trace annotation)."""
        boost = np.asarray(action.boost, float)
        admit = np.asarray(action.admit, bool)
        mask = np.ones(len(boost), bool) if installed is None \
            else np.asarray(installed, bool)
        # neutral pre-controller state: unit boost, everyone admitted —
        # so a first tick that moves a knob already counts
        if self._last_boost is None:
            self._last_boost = np.ones_like(boost)
        if self._last_admit is None:
            self._last_admit = np.ones(len(admit), bool)
        new: List[dict] = []
        changed = mask & (boost != self._last_boost)
        for i in np.nonzero(changed)[0]:
            new.append({"t": float(t), "tenant": int(i),
                        "kind": IV_AIMD_WEIGHT,
                        "value": float(boost[i])})
        flipped = mask & (admit != self._last_admit)
        for i in np.nonzero(flipped)[0]:
            new.append({"t": float(t), "tenant": int(i),
                        "kind": IV_ADMISSION,
                        "value": float(admit[i])})
        self._last_boost = boost.copy()
        self._last_admit = admit.copy()
        for iv in new:
            self.interventions_total += 1
            if len(self.interventions) < MAX_INTERVENTIONS:
                self.interventions.append(iv)
            self._first_intervention_t.setdefault(iv["tenant"], iv["t"])
        return new

    # -- report -------------------------------------------------------------
    def summary(self) -> dict:
        """The ``RunReport.extras['slo_audit']`` block (JSON-able)."""
        tenants = {}
        for i in np.nonzero(self.targets > 0)[0]:
            i = int(i)
            observed = int(self._observed[i])
            viol = int(self._violating[i])
            first_alert = self._first_alert_t.get(i)
            first_iv = self._first_intervention_t.get(i)
            lead = (first_iv - first_alert
                    if first_alert is not None and first_iv is not None
                    else None)
            tenants[i] = {
                "target_p99": float(self.targets[i]),
                "observed_intervals": observed,
                "violating_intervals": viol,
                "compliance_pct": round(
                    100.0 * (1.0 - viol / observed) if observed else 100.0,
                    4),
                "budget_burn": round(
                    (viol / self.intervals) / self.cfg.budget
                    if self.intervals else 0.0, 4),
                "alerts": int(sum(a.tenant == i for a in self.alerts)),
                "first_alert_t": first_alert,
                "first_intervention_t": first_iv,
                "alert_lead": lead,
                "violation_windows": self._vwindows.get(i, []),
            }
        return {
            "objective": self.cfg.objective,
            "budget": self.cfg.budget,
            "fast_windows": self.cfg.fast_windows,
            "slow_windows": self.cfg.slow_windows,
            "fast_burn": self.cfg.fast_burn,
            "slow_burn": self.cfg.slow_burn,
            "intervals": self.intervals,
            "interval_unit": self.time_unit,
            "alerts_total": self.alerts_total,
            "interventions_total": self.interventions_total,
            "tenants": tenants,
            "alerts": [dataclasses.asdict(a) for a in self.alerts],
            "interventions": list(self.interventions),
        }


# summary keys RunReport.validate() checks (single source of truth)
SUMMARY_KEYS = ("objective", "budget", "fast_windows", "slow_windows",
                "fast_burn", "slow_burn", "intervals", "interval_unit",
                "alerts_total", "interventions_total", "tenants", "alerts",
                "interventions")
TENANT_SUMMARY_KEYS = ("target_p99", "observed_intervals",
                       "violating_intervals", "compliance_pct",
                       "budget_burn", "alerts", "first_alert_t",
                       "first_intervention_t", "alert_lead",
                       "violation_windows")
