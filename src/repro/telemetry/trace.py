"""Trace plane: packet-lifecycle flight recorder + decision provenance.

DESIGN.md §10.  Two bounded, preallocated structure-of-arrays ring
buffers shared by every datapath through ``EngineBase``:

  * **span ring** — one row per packet lifecycle stage
    (ARRIVE → FMQ_ENQ → SCHED_GRANT → PU_EXEC → DMA → EQ_COMPLETE),
    each carrying tenant, PU slot, disposition and virtual-time
    begin/end.  Rows are written *complete* (at stage close), so ring
    eviction under flood can never strand half a span.
  * **decision ring** — one row per scheduler grant (WLBVT / RR /
    DWRR) or admission reject, carrying the eligible set, a per-tenant
    BVT-or-deficit snapshot, the winner and a reason code.

Recording sites stage O(1) work per event into flat typed buffers
(``array.array`` / ``bytearray`` — no per-event tuples or numpy calls)
and ``commit()`` expands + scatters them into the rings vectorized —
the same staging→commit rhythm as the telemetry plane: per window on
the sim datapaths, per step on the serving engine.  Each staging
method also notes the ring-row offset its rows will occupy, so commit
reassembles the exact staging order with index arithmetic alone
(no per-entry type dispatch).  The scatter itself is the pure
fixed-shape kernel :func:`ring_scatter` (functional ``.at[].set`` on
jnp, in-place on numpy).

The two staging records that carry the hot paths:

  * :meth:`TraceRecorder.span_packet` — one flat-buffer append
    covering a granted packet's whole lifecycle; commit expands it to
    the FMQ / GRANT / PU (/ DMA) / EQ rows.
  * :func:`record_wlbvt_round` — one append per scheduling round: the
    post-round scheduler arrays as a raw-bytes snapshot plus the pick
    list.  Commit reconstructs the pre-round state (picks are the
    exact charge the scheduler applied), replays per-pick eligibility
    with one batched computation over all rounds, and derives the
    reason codes.

Provenance is recorded by *replay*: the scheduler's own decision code
is never touched (bit-identity with tracing off is a hard contract).
The replay recomputes eligibility from snapshots with the same
formulas (``sched_generic``) the scheduler used.
"""
from __future__ import annotations

from array import array
from typing import Dict, List, Tuple

import numpy as np

from repro.core import sched_generic as G

# --------------------------------------------------------------------------
# encodings
# --------------------------------------------------------------------------

# lifecycle stages (span ring ``stage`` column)
ST_ARRIVE = 0   # instant; disposition records the admission outcome
ST_FMQ = 1      # span [arrival, grant]: FMQ residency
ST_GRANT = 2    # instant at the WLBVT/RR grant; carries the PU slot
ST_PU = 3       # span [grant, t_comp]: PU execution (incl. DMA setup)
ST_DMA = 4      # span [t_comp, io_done]: AXI/egress DMA drain
ST_EQ = 5       # instant at EQ completion/kill
ST_SWITCH = 6   # span [fabric inject, delivery]: VOQ wait + crossbar
#                 serialization + propagation (fleet plane)
STAGES = ("ARRIVE", "FMQ_ENQ", "SCHED_GRANT", "PU_EXEC", "DMA",
          "EQ_COMPLETE", "SWITCH")

# span dispositions (``disp`` column)
D_OPEN = 0      # flushed while still open (end of run)
D_OK = 1
D_MARK = 2      # admitted but ECN-marked (ARRIVE rows only)
D_DROP = 3      # FMQ overflow drop
D_REJECT = 4    # admission-gate / backpressure reject
D_KILL = 5      # watchdog or total-budget kill
DISPOSITIONS = ("OPEN", "OK", "ECN_MARK", "DROP", "REJECT", "KILL")
TERMINAL_DISPOSITIONS = (D_DROP, D_REJECT, D_KILL)

# maps the batched arrival classifier's ``kind`` codes (0 ok / 1 mark /
# 2 drop) onto ARRIVE dispositions
DISP_FROM_KIND = np.array([D_OK, D_MARK, D_DROP], np.int8)

# decision kinds (decision ring ``kind`` column)
K_PU_WLBVT = 0
K_PU_RR = 1
K_AXI_DWRR = 2
K_EGRESS_DWRR = 3
K_ADMISSION = 4
K_SLO_ALERT = 5       # burn-rate SLO alert (telemetry/slo_audit.py)
K_QOS_INTERVENE = 6   # controller actuation: AIMD weight / admission flip
K_FLEET_MIGRATE = 7   # global QoS live migration (fleet/engine.py)
DECISION_KINDS = ("PU_WLBVT", "PU_RR", "AXI_DWRR", "EGRESS_DWRR",
                  "ADMISSION", "SLO_ALERT", "QOS_INTERVENE",
                  "FLEET_MIGRATE")

# reason codes (decision ring ``reason`` column)
R_PRIORITY = 0        # winner was the highest-priority/-weight eligible
R_DEBT = 1            # a lower-priority tenant won on lagging BVT/deficit
R_FORCED_SINGLE = 2   # exactly one eligible tenant — no real choice
R_ADMISSION_REJECT = 3
R_BURN_FAST = 4       # fast-window burn crossing (SLO_ALERT rows)
R_BURN_SLOW = 5       # slow-window burn crossing (SLO_ALERT rows)
R_AIMD_WEIGHT = 6     # QOS_INTERVENE: boost changed for the winner tenant
R_ADMISSION_GATE = 7  # QOS_INTERVENE: admission gate flipped
R_MIGRATION = 8       # FLEET_MIGRATE: SLO violation on an overloaded NIC
REASONS = ("PRIORITY", "DEBT", "FORCED_SINGLE", "ADMISSION_REJECT",
           "BURN_FAST", "BURN_SLOW", "AIMD_WEIGHT", "ADMISSION_GATE",
           "MIGRATION")

SPAN_RING_DEPTH = 65536
DECISION_RING_DEPTH = 8192

_SPAN_DTYPES = (
    ("uid", np.int64), ("tenant", np.int16), ("stage", np.int8),
    ("pu", np.int16), ("disp", np.int8), ("t0", np.float64),
    ("t1", np.float64),
)


# --------------------------------------------------------------------------
# pure ring kernel
# --------------------------------------------------------------------------

def ring_scatter(ring, count, vals, xp):
    """Write ``vals`` (m <= capacity rows) into ``ring`` at positions
    ``(count + arange(m)) % capacity``.

    Fixed-shape for a fixed ``m``: index arithmetic only, no
    data-dependent producers — in-place on numpy, functional
    ``.at[].set`` on jnp so the serving commit stays jit-safe.
    """
    cap = ring.shape[0]
    m = vals.shape[0]
    idx = (count + xp.arange(m)) % cap
    if xp is np:
        ring[idx] = vals
        return ring
    return ring.at[idx].set(vals)


# --------------------------------------------------------------------------
# recorder
# --------------------------------------------------------------------------

class TraceRecorder:
    """Bounded SoA flight recorder for spans + scheduler decisions."""

    def __init__(self, num_tenants: int, *, num_pus: int = 0,
                 depth: int = SPAN_RING_DEPTH,
                 decision_depth: int = DECISION_RING_DEPTH,
                 xp=np):
        self.T = int(num_tenants)
        self.P = int(num_pus)
        self.depth = int(depth)
        self.decision_depth = int(decision_depth)
        self.xp = xp
        d = self.depth
        self.spans: Dict[str, np.ndarray] = {
            name: (xp.full(d, -1, dt) if name in ("uid", "pu")
                   else xp.zeros(d, dt))
            for name, dt in _SPAN_DTYPES
        }
        dd = self.decision_depth
        self.decisions: Dict[str, np.ndarray] = {
            "time": xp.zeros(dd, np.float64),
            "kind": xp.zeros(dd, np.int8),
            "winner": xp.full(dd, -1, np.int32),
            "reason": xp.zeros(dd, np.int8),
            "n_elig": xp.zeros(dd, np.int32),
            "metric": xp.zeros(dd, np.float64),
            "snapshot": xp.zeros((dd, self.T), np.float32),
            "elig": xp.zeros((dd, self.T), bool),
        }
        self.span_count = 0      # rows ever written (monotone; evicted
        self.decision_count = 0  # rows are still counted)
        self._open: Dict[Tuple[int, int], Tuple[int, float]] = {}
        self._reset_span_stage()
        self._reset_decision_stage()
        # staged-row watermark for maybe_commit(): large enough to
        # amortize the fixed numpy cost of a batched expansion over
        # tens of thousands of rows, small enough to bound staging
        # memory (a staged row is a few dozen bytes)
        self._commit_every = max(1024, min(self.depth, 32768))

    def _reset_span_stage(self) -> None:
        # Flat staging buffers; fresh objects (not in-place clears) so
        # numpy views taken by commit never pin a buffer we resize.
        # Each kind also stages the ring-row offset of its rows
        # (``*_pos``); ``_srows`` is the running staged-row total.
        self._sp_plain = array("d")      # 7 values per plain row
        self._sp_plain_pos = array("q")
        self._sp_pk = array("d")         # 8 values per packet record
        self._sp_pk_pos = array("q")
        self._sp_blocks: List[Dict[str, np.ndarray]] = []
        self._sp_blk_pos = array("q")
        self._srows = 0

    def _reset_decision_stage(self) -> None:
        # one (now, kind, num_pus, n_picks) quad per WLBVT round — a
        # single flat f64 buffer so staging is one C-level extend
        self._wl_meta = array("d")
        self._wl_pos = array("q")
        self._wl_picks = array("q")      # ... flattened picks
        self._wl_snap = bytearray()      # ... concatenated raw arrays
        self._wl_caps: List[Tuple[int, bytes]] = []
        self._rr_rows: List[tuple] = []
        self._rr_pos = array("q")
        self._dw_rows: List[tuple] = []
        self._dw_pos = array("q")
        self._d_plain: List[tuple] = []
        self._d_plain_pos = array("q")
        self._drows = 0

    # -- span recording ----------------------------------------------------

    def span(self, stage: int, uid: int, tenant: int, t0: float,
             t1: float, disp: int = D_OK, pu: int = -1) -> None:
        """Record one complete lifecycle row."""
        self._sp_plain.extend((uid, tenant, stage, pu, disp, t0, t1))
        self._sp_plain_pos.append(self._srows)
        self._srows += 1

    def span_begin(self, stage: int, uid: int, tenant: int,
                   t0: float) -> None:
        """Open a span; it enters the ring only when closed (or
        abandoned / flushed) so eviction never splits a pair."""
        self._open[(stage, uid)] = (tenant, t0)

    def span_end(self, stage: int, uid: int, t1: float,
                 disp: int = D_OK, pu: int = -1) -> None:
        tenant, t0 = self._open.pop((stage, uid))
        self.span(stage, uid, tenant, t0, t1, disp, pu)

    def span_abandon(self, stage: int, uid: int, t1: float,
                     disp: int) -> None:
        """Close an open span on a terminal path (DROP/REJECT/KILL)."""
        tenant, t0 = self._open.pop((stage, uid))
        self.span(stage, uid, tenant, t0, t1, disp)

    def flush_open(self, t: float) -> None:
        """Write every still-open span with disposition OPEN (end of
        run: packets still queued when the horizon hit)."""
        ordered = sorted(self._open.items(),
                         key=lambda kv: (kv[1][1], kv[0][1], kv[0][0]))
        for (stage, uid), (tenant, t0) in ordered:
            self.span(stage, uid, tenant, t0, t, D_OPEN)
        self._open.clear()

    def span_packet(self, uid: int, tenant: int, pu: int, disp: int,
                    adisp: int, t_arr: float, t_grant: float,
                    t_comp: float, t_done: float) -> None:
        """One append covering a granted packet's whole lifecycle.

        Commit expands it to the ARRIVE instant (disposition
        ``adisp``: the admission outcome, OK or ECN_MARK), FMQ
        [arr, grant], GRANT instant, PU [grant, comp], DMA
        [comp, done] (only when ``t_done > t_comp``) and EQ instant
        rows, in that order.  Packets that never reach a PU (drops /
        rejects / still queued at flush) record their rows through
        :meth:`span` instead.  This is the simulators' hot completion
        path — keep it one flat append.
        """
        self._sp_pk.extend((uid, tenant, pu, disp, adisp, t_arr,
                            t_grant, t_comp, t_done))
        self._sp_pk_pos.append(self._srows)
        self._srows += 6 if t_done > t_comp else 5

    def span_block(self, stage: int, uids, tenants, t0s, t1s, disps,
                   pus=None) -> None:
        """Vectorized row block (batched-arrival fast paths)."""
        uids = np.asarray(uids, np.int64)
        m = len(uids)
        cols = {
            "uid": uids,
            "tenant": np.asarray(tenants, np.int16),
            "stage": np.full(m, stage, np.int8),
            "pu": (np.full(m, -1, np.int16) if pus is None
                   else np.asarray(pus, np.int16)),
            "disp": (np.full(m, disps, np.int8) if np.isscalar(disps)
                     else np.asarray(disps, np.int8)),
            "t0": np.asarray(t0s, np.float64),
            "t1": np.asarray(t1s, np.float64),
        }
        self._sp_blocks.append(cols)
        self._sp_blk_pos.append(self._srows)
        self._srows += m

    # -- decision recording ------------------------------------------------

    def decision(self, time: float, kind: int, winner: int, reason: int,
                 n_elig: int, metric: float = 0.0, snapshot=None,
                 elig=None) -> None:
        snap = (np.zeros(self.T, np.float32) if snapshot is None
                else np.array(snapshot, np.float32))
        el = (np.zeros(self.T, bool) if elig is None
              else np.array(elig, bool))
        self._d_plain.append((float(time), int(kind), int(winner),
                              int(reason), int(n_elig), float(metric),
                              snap, el))
        self._d_plain_pos.append(self._drows)
        self._drows += 1

    # -- commit / readout --------------------------------------------------

    def maybe_commit(self) -> None:
        """Commit only once enough rows are staged to amortize the
        batched expansion — the engines call this per telemetry window
        / step; nothing reads the rings mid-run (``rows()`` and friends
        force a commit), so the cadence is purely a cost knob."""
        if self._srows + self._drows >= self._commit_every:
            self.commit()

    def commit(self) -> None:
        """Scatter staged rows into the rings.

        Each staging kind is expanded with one batched numpy
        computation, merged into staging order via the offsets noted
        at stage time, and scattered with one :func:`ring_scatter` per
        column — commit cost is O(columns) per kind, not O(events).
        """
        if self._srows:
            self._scatter_spans(self._merge_spans())
            self._reset_span_stage()
        if self._drows:
            self._scatter_decisions(self._merge_decisions())
            self._reset_decision_stage()

    @staticmethod
    def _seg_dest(offs: np.ndarray, cnt: np.ndarray) -> np.ndarray:
        """Destination indices for entries whose rows start at ``offs``
        and run for ``cnt`` rows each (segmented arange)."""
        tot = int(cnt.sum())
        return (np.repeat(offs, cnt) + np.arange(tot)
                - np.repeat(np.cumsum(cnt) - cnt, cnt))

    def _merge_spans(self) -> Dict[str, np.ndarray]:
        """Expand the span staging buffers — plain rows, packet records
        and column blocks interleave freely — into one column set in
        staging order (one batched expansion per staging kind, then a
        single ring scatter)."""
        out = {name: np.empty(self._srows, dt)
               for name, dt in _SPAN_DTYPES}
        if self._sp_plain_pos:
            a = np.frombuffer(self._sp_plain, np.float64).reshape(-1, 7)
            pos = np.frombuffer(self._sp_plain_pos, np.int64)
            for j, (name, dt) in enumerate(_SPAN_DTYPES):
                out[name][pos] = a[:, j].astype(dt, copy=False)
        if self._sp_pk_pos:
            pk = np.frombuffer(self._sp_pk, np.float64).reshape(-1, 9)
            cnt = np.where(pk[:, 8] > pk[:, 7], 6, 5)
            dest = self._seg_dest(
                np.frombuffer(self._sp_pk_pos, np.int64), cnt)
            for k, v in self._expand_pkts(pk).items():
                out[k][dest] = v
        if self._sp_blocks:
            blocks = self._sp_blocks
            cnt = np.asarray([len(b["uid"]) for b in blocks], np.int64)
            dest = self._seg_dest(
                np.frombuffer(self._sp_blk_pos, np.int64), cnt)
            for k in out:
                out[k][dest] = np.concatenate([b[k] for b in blocks])
        return out

    @staticmethod
    def _expand_pkts(a: np.ndarray) -> Dict[str, np.ndarray]:
        """Expand ``span_packet`` records — already stacked as a
        float64 ``(n, 9)`` array — into per-stage rows, grouped per
        packet so ring eviction keeps lifecycles contiguous."""
        n = len(a)
        uid = a[:, 0].astype(np.int64)
        ten = a[:, 1].astype(np.int16)
        pu = a[:, 2].astype(np.int16)
        disp = a[:, 3].astype(np.int8)
        adisp = a[:, 4].astype(np.int8)
        ta, tg, tc, td = a[:, 5], a[:, 6], a[:, 7], a[:, 8]
        K = 6
        stages = np.array([ST_ARRIVE, ST_FMQ, ST_GRANT, ST_PU, ST_DMA,
                           ST_EQ], np.int8)
        t0s = np.stack([ta, ta, tg, tg, tc, td], 1)
        t1s = np.stack([ta, tg, tg, tc, td, td], 1)
        dmat = np.empty((n, K), np.int8)
        dmat[:, 0] = adisp
        dmat[:, 1] = D_OK
        dmat[:, 2] = D_OK
        dmat[:, 3] = disp
        dmat[:, 4] = D_OK
        dmat[:, 5] = disp
        pmat = np.empty((n, K), np.int16)
        pmat[:, 0] = -1              # ARRIVE predates the grant
        pmat[:, 1:] = pu[:, None]
        keep = np.ones((n, K), bool)
        keep[:, 4] = td > tc  # zero-width DMA (kills): no row
        flat = keep.ravel()
        return {
            "uid": np.repeat(uid, K)[flat],
            "tenant": np.repeat(ten, K)[flat],
            "stage": np.tile(stages, n)[flat],
            "pu": pmat.ravel()[flat],
            "disp": dmat.ravel()[flat],
            "t0": t0s.ravel()[flat],
            "t1": t1s.ravel()[flat],
        }

    def _merge_decisions(self) -> Dict[str, np.ndarray]:
        """Expand the decision staging buffers — WLBVT rounds, RR
        picks, DWRR grants and plain rows interleave freely — into one
        column set in staging order."""
        total = self._drows
        out = {
            "time": np.empty(total, np.float64),
            "kind": np.empty(total, np.int8),
            "winner": np.empty(total, np.int32),
            "reason": np.empty(total, np.int8),
            "n_elig": np.empty(total, np.int32),
            "metric": np.empty(total, np.float64),
            "snapshot": np.empty((total, self.T), np.float32),
            "elig": np.empty((total, self.T), bool),
        }
        if self._wl_meta:
            meta = np.frombuffer(self._wl_meta,
                                 np.float64).reshape(-1, 4)
            dest = self._seg_dest(
                np.frombuffer(self._wl_pos, np.int64),
                meta[:, 3].astype(np.int64))
            for k, v in self._expand_wlbvt().items():
                out[k][dest] = v
        for rows, pos, expand in (
                (self._rr_rows, self._rr_pos, self._expand_rr),
                (self._dw_rows, self._dw_pos, self._expand_dwrr),
                (self._d_plain, self._d_plain_pos,
                 self._drows_to_cols)):
            if rows:
                dest = np.frombuffer(pos, np.int64)
                for k, v in expand(rows).items():
                    out[k][dest] = v
        return out

    @staticmethod
    def _drows_to_cols(buf: List[tuple]) -> Dict[str, np.ndarray]:
        time, kind, winner, reason, n_elig, metric, snap, el = zip(*buf)
        return {
            "time": np.asarray(time, np.float64),
            "kind": np.asarray(kind, np.int8),
            "winner": np.asarray(winner, np.int32),
            "reason": np.asarray(reason, np.int8),
            "n_elig": np.asarray(n_elig, np.int32),
            "metric": np.asarray(metric, np.float64),
            "snapshot": np.stack(snap),
            "elig": np.stack(el),
        }

    def _expand_wlbvt(self) -> Dict[str, np.ndarray]:
        """Replay the staged WLBVT rounds from post-round snapshots.

        Each round stages its picks plus the *post*-round scheduler
        arrays as raw bytes.  ``select_k`` charges exactly one
        ``queue_len -= 1`` / ``cur_occup += 1`` per pick, so the
        pre-round and per-pick states are reconstructed from a
        segmented exclusive cumsum of one-hot picks; eligibility,
        reason codes and metrics are then derived for every pick of
        every round in one batched computation (``total_occup``/
        ``bvt``/``prio`` do not change within a round).
        """
        T = self.T
        meta = np.frombuffer(self._wl_meta, np.float64).reshape(-1, 4)
        times, npus = meta[:, 0], meta[:, 2]
        kinds = meta[:, 1].astype(np.int8)
        lens = meta[:, 3].astype(np.int64)
        R = len(lens)
        ints = np.frombuffer(self._wl_snap, np.int64).reshape(R, 5, T)
        flts = np.frombuffer(self._wl_snap,
                             np.float64).reshape(R, 5, T)
        ql_post, co_post = ints[:, 0], ints[:, 1]
        bvt, occ, prio = flts[:, 2], flts[:, 3], flts[:, 4]
        picks = np.frombuffer(self._wl_picks, np.int64)
        N = len(picks)
        rid = np.repeat(np.arange(R), lens)
        starts = np.concatenate(([0], np.cumsum(lens)[:-1]))
        onehot = np.zeros((N, T), np.int64)
        onehot[np.arange(N), picks] = 1
        S = np.cumsum(onehot, axis=0)
        E = S - onehot                        # global exclusive cumsum
        C = E - E[starts][rid]                # charges earlier this round
        tot = S[starts + lens - 1] - E[starts]  # total charge per round
        QL = ql_post[rid] + tot[rid] - C      # state the pick saw
        CO = co_post[rid] - tot[rid] + C
        PR = prio[rid]
        # rowwise G.pu_limit
        psum = np.sum(np.where(QL > 0, PR, 0.0), axis=1, keepdims=True)
        lim = np.ceil(npus[rid][:, None] * PR / np.maximum(psum, 1e-9)
                      - G.CEIL_EPS)
        limit = np.where(psum > 0, lim, npus[rid][:, None])
        elig = (QL > 0) & (CO < limit)
        if self._wl_caps:
            caps = np.full((R, T), np.inf)
            for r, b in self._wl_caps:
                caps[r] = np.frombuffer(b, np.float64)
            elig &= CO < caps[rid]
        ne = elig.sum(axis=1)
        pw = PR[np.arange(N), picks]
        pmax = np.where(elig, PR, -np.inf).max(axis=1)
        reason = np.where(ne <= 1, R_FORCED_SINGLE,
                          np.where(pw >= pmax, R_PRIORITY,
                                   R_DEBT)).astype(np.int8)
        met = (G.tput(occ, bvt, np) / prio)[rid, picks]
        return {
            "time": times[rid],
            "kind": kinds[rid],
            "winner": picks.astype(np.int32),
            "reason": reason,
            "n_elig": ne.astype(np.int32),
            "metric": met,
            "snapshot": bvt[rid].astype(np.float32),
            "elig": elig,
        }

    def _expand_rr(self, entries: List[tuple]) -> Dict[str, np.ndarray]:
        T = self.T
        R = len(entries)
        ql = np.frombuffer(b"".join(e[3] for e in entries),
                           np.int64).reshape(R, T)
        snap = np.frombuffer(b"".join(e[4] for e in entries),
                             np.float64).reshape(R, T)
        pend = ql > 0
        ne = pend.sum(axis=1)
        return {
            "time": np.asarray([e[0] for e in entries], np.float64),
            "kind": np.asarray([e[1] for e in entries], np.int8),
            "winner": np.asarray([e[2] for e in entries], np.int32),
            "reason": np.where(ne <= 1, R_FORCED_SINGLE,
                               R_PRIORITY).astype(np.int8),
            "n_elig": ne.astype(np.int32),
            "metric": np.zeros(R, np.float64),
            "snapshot": snap.astype(np.float32),
            "elig": pend,
        }

    def _expand_dwrr(self, entries: List[tuple]) -> Dict[str, np.ndarray]:
        T = self.T
        R = len(entries)
        win = np.asarray([e[2] for e in entries], np.int64)
        defc = np.frombuffer(b"".join(e[3] for e in entries),
                             np.float64).reshape(R, T)
        w = np.frombuffer(b"".join(e[4] for e in entries),
                          np.float64).reshape(R, T)
        pend = np.frombuffer(b"".join(e[5] for e in entries),
                             np.bool_).reshape(R, T)
        ne = pend.sum(axis=1)
        ww = w[np.arange(R), win]
        wmax = np.where(pend, w, -np.inf).max(axis=1)
        reason = np.where(ne <= 1, R_FORCED_SINGLE,
                          np.where(ww >= wmax, R_PRIORITY,
                                   R_DEBT)).astype(np.int8)
        return {
            "time": np.asarray([e[0] for e in entries], np.float64),
            "kind": np.asarray([e[1] for e in entries], np.int8),
            "winner": win.astype(np.int32),
            "reason": reason,
            "n_elig": ne.astype(np.int32),
            "metric": defc[np.arange(R), win],
            "snapshot": defc.astype(np.float32),
            "elig": pend,
        }

    def _scatter_spans(self, cols: Dict[str, np.ndarray]) -> None:
        m = len(cols["uid"])
        if m == 0:
            return
        cap = self.depth
        start = self.span_count
        if m > cap:  # keep only the newest ``cap`` rows of the chunk
            start += m - cap
            cols = {k: v[m - cap:] for k, v in cols.items()}
        for k, ring in self.spans.items():
            ring_scatter(ring, start, cols[k], self.xp)
        self.span_count += m

    def _scatter_decisions(self, cols: Dict[str, np.ndarray]) -> None:
        m = len(cols["time"])
        if m == 0:
            return
        cap = self.decision_depth
        start = self.decision_count
        if m > cap:
            start += m - cap
            cols = {k: v[m - cap:] for k, v in cols.items()}
        for k, ring in self.decisions.items():
            ring_scatter(ring, start, cols[k], self.xp)
        self.decision_count += m

    def _order(self, count: int, cap: int) -> np.ndarray:
        if count <= cap:
            return np.arange(count)
        cut = count % cap
        return np.concatenate([np.arange(cut, cap), np.arange(cut)])

    def rows(self) -> Dict[str, np.ndarray]:
        """Retained span rows in write (chronological) order."""
        self.commit()
        order = self._order(self.span_count, self.depth)
        return {k: v[order] for k, v in self.spans.items()}

    def tail(self, n: int) -> Dict[str, np.ndarray]:
        """The newest ``n`` retained span rows (write order)."""
        r = self.rows()
        m = len(r["uid"])
        k = max(0, min(int(n), m))
        return {c: v[m - k:] for c, v in r.items()}

    def decision_rows(self) -> Dict[str, np.ndarray]:
        """Retained decision rows in write order."""
        self.commit()
        order = self._order(self.decision_count, self.decision_depth)
        return {k: v[order] for k, v in self.decisions.items()}

    # -- summaries ---------------------------------------------------------
    # keys of the trace_summary() extras block — RunReport.validate()
    # checks the exported schema against this tuple
    TRACE_SUMMARY_KEYS = (
        "spans_recorded", "spans_retained", "span_depth",
        "decisions_recorded", "decisions_retained", "decision_depth",
        "open_spans", "stage_time_share", "decision_reasons",
        "decision_kinds")

    def trace_summary(self) -> dict:
        """RunReport ``extras`` block: volumes, per-tenant stage time
        shares, decision reason/kind histograms."""
        r = self.rows()
        d = self.decision_rows()
        dur = r["t1"] - r["t0"]
        shares: Dict[int, Dict[str, float]] = {}
        for t in np.unique(r["tenant"]).tolist():
            mt = r["tenant"] == t
            tot = float(dur[mt].sum())
            row = {}
            for s in (ST_FMQ, ST_PU, ST_DMA):
                v = float(dur[mt & (r["stage"] == s)].sum())
                row[STAGES[s]] = round(v / tot, 6) if tot > 0 else 0.0
            shares[int(t)] = row
        reasons = {}
        for i, name in enumerate(REASONS):
            c = int(np.count_nonzero(d["reason"] == i))
            if c:
                reasons[name] = c
        kinds = {}
        for i, name in enumerate(DECISION_KINDS):
            c = int(np.count_nonzero(d["kind"] == i))
            if c:
                kinds[name] = c
        return {
            "spans_recorded": int(self.span_count),
            "spans_retained": int(len(r["uid"])),
            "span_depth": self.depth,
            "decisions_recorded": int(self.decision_count),
            "decisions_retained": int(len(d["time"])),
            "decision_depth": self.decision_depth,
            "open_spans": len(self._open),
            "stage_time_share": shares,
            "decision_reasons": reasons,
            "decision_kinds": kinds,
        }


# --------------------------------------------------------------------------
# provenance replay helpers (never touch live scheduler state)
# --------------------------------------------------------------------------

def record_wlbvt_round(tr: TraceRecorder, now: float, st, picks,
                       num_pus: int, kind: int, cap=None) -> None:
    """Stage one WLBVT round's provenance from *post*-round state.

    Called after ``select_k`` with the live (already-charged)
    ``WLBVTState`` — no copies.  The picks are exactly the charge the
    scheduler applied, so :meth:`TraceRecorder._expand_wlbvt`
    reconstructs the pre-round and per-pick states at commit time.
    ``st``'s dtypes are the WLBVTState contract (queue_len/cur_occup
    int64, bvt/total_occup/prio float64) — the byte snapshot relies
    on it.
    """
    n = len(picks)
    if not n:
        return
    tr._wl_meta.extend((now, kind, num_pus, n))
    tr._wl_picks.extend(picks)
    snap = tr._wl_snap
    snap += st.queue_len.tobytes()
    snap += st.cur_occup.tobytes()
    snap += st.bvt.tobytes()
    snap += st.total_occup.tobytes()
    snap += st.prio.tobytes()
    if cap is not None:
        tr._wl_caps.append((len(tr._wl_meta) // 4 - 1,
                            np.asarray(cap, np.float64).tobytes()))
    tr._wl_pos.append(tr._drows)
    tr._drows += n


def record_rr_pick(tr: TraceRecorder, now: float, kind: int, winner: int,
                   queue_len, snapshot) -> None:
    """One round-robin grant: called before the caller charges
    ``queue_len`` so the eligible set is the pre-grant one."""
    tr._rr_rows.append((
        float(now), int(kind), int(winner),
        np.ascontiguousarray(queue_len, np.int64).tobytes(),
        np.ascontiguousarray(snapshot, np.float64).tobytes()))
    tr._rr_pos.append(tr._drows)
    tr._drows += 1


def record_dwrr_grant(tr: TraceRecorder, now: float, kind: int,
                      winner: int, deficit, pending, weights) -> None:
    """One DWRR grant; ``deficit`` is the pre-grant deficit snapshot
    (the scheduler mutates it in place, so the caller copies it)."""
    tr._dw_rows.append((
        float(now), int(kind), int(winner),
        np.ascontiguousarray(deficit, np.float64).tobytes(),
        np.ascontiguousarray(weights, np.float64).tobytes(),
        np.ascontiguousarray(pending, bool).tobytes()))
    tr._dw_pos.append(tr._drows)
    tr._drows += 1


def record_dwrr_round(tr: TraceRecorder, now: float, kind: int, picks,
                      deficit, counts, weights) -> None:
    """Replay a multi-grant DWRR round (serving prefill arbitration).

    ``deficit``/``counts`` are pre-round copies; the pending set is
    replayed per pick, the deficit snapshot is round-granularity.
    """
    counts = np.asarray(counts).copy()
    for i in picks:
        if i < 0:
            break
        record_dwrr_grant(tr, now, kind, int(i), deficit, counts > 0,
                          weights)
        counts[i] -= 1


def record_admission_reject(tr: TraceRecorder, now: float,
                            tenant: int) -> None:
    tr.decision(now, K_ADMISSION, int(tenant), R_ADMISSION_REJECT, 0,
                0.0)


def record_slo_alert(tr: TraceRecorder, now: float, tenant: int,
                     window: str, burn_rate: float) -> None:
    """SLO burn-rate alert row: Perfetto renders it on the Scheduler
    track next to the QOS_INTERVENE rows it precedes, making the
    alert -> intervention causality visible; ``metric`` carries the
    burn rate."""
    tr.decision(now, K_SLO_ALERT, int(tenant),
                R_BURN_FAST if window == "fast" else R_BURN_SLOW, 0,
                float(burn_rate))


def record_qos_intervention(tr: TraceRecorder, now: float, tenant: int,
                            kind: str, value: float) -> None:
    """Controller actuation row (``kind``: the slo_audit intervention
    kinds — aimd_weight / admission); ``metric`` carries the new boost
    or gate value."""
    tr.decision(now, K_QOS_INTERVENE, int(tenant),
                R_AIMD_WEIGHT if kind == "aimd_weight" else R_ADMISSION_GATE,
                0, float(value))
