"""Trace readouts: Perfetto/Chrome ``trace_event`` export + console
waterfall (DESIGN.md §10).

Perfetto mapping:

  * pid 1 ``PU slots`` — one thread per PU; PU_EXEC spans as complete
    ("X") duration events named ``t<tenant>/pkt<uid>``.
  * pid 2 ``Tenants`` — one thread per tenant; ARRIVE / EQ_COMPLETE as
    instant ("i") events (drops, kills, rejects and ECN marks are
    process-scoped so they read as flow markers), FMQ and DMA residency
    as async ("b"/"e") spans keyed by packet uid.
  * pid 3 ``Scheduler`` — one thread per decision kind; every grant is
    an instant event carrying winner / reason / eligible-count args.

Timestamps are emitted in microseconds as the trace_event spec
requires: virtual-ns are scaled by 1e-3, serving steps map to 1 step =
1 µs for display.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

import numpy as np

from repro.telemetry.trace import (
    DECISION_KINDS, DISPOSITIONS, D_DROP, D_KILL, D_MARK, D_OK, D_OPEN,
    D_REJECT, REASONS, ST_ARRIVE, ST_DMA, ST_EQ, ST_FMQ, ST_GRANT,
    ST_PU, STAGES, TraceRecorder,
)

PID_PU = 1
PID_TENANTS = 2
PID_SCHED = 3

_ARRIVE_NAMES = {D_OK: "arrive", D_MARK: "ecn_mark", D_DROP: "drop",
                 D_REJECT: "reject"}


def _scale(time_unit: str) -> float:
    # trace_event ts/dur are microseconds; 1 serving step displays as 1us
    return 1e-3 if time_unit == "ns" else 1.0


def to_perfetto(trace: TraceRecorder, *, time_unit: str = "ns",
                last: Optional[int] = None,
                tenant_names: Optional[Dict[int, str]] = None) -> dict:
    """Render the retained rings as a Chrome/Perfetto trace dict."""
    r = trace.tail(last) if last else trace.rows()
    d = trace.decision_rows()
    k = _scale(time_unit)
    names = tenant_names or {}
    ev: List[dict] = []

    def meta(pid, name):
        ev.append({"ph": "M", "pid": pid, "tid": 0,
                   "name": "process_name", "args": {"name": name}})

    def thread(pid, tid, name):
        ev.append({"ph": "M", "pid": pid, "tid": int(tid),
                   "name": "thread_name", "args": {"name": name}})

    meta(PID_PU, "PU slots")
    for p in range(max(trace.P, 1)):
        thread(PID_PU, p, f"PU {p}")
    meta(PID_TENANTS, "Tenants")
    tenants = sorted(set(np.asarray(r["tenant"]).tolist())
                     | set(int(t) for t in names))
    for t in tenants:
        thread(PID_TENANTS, t, names.get(t, f"tenant {t}"))
    meta(PID_SCHED, "Scheduler")
    kinds_present = sorted(set(np.asarray(d["kind"]).tolist()))
    for kd in kinds_present:
        thread(PID_SCHED, kd, DECISION_KINDS[kd])

    n = len(r["uid"])
    for i in range(n):
        uid = int(r["uid"][i])
        t = int(r["tenant"][i])
        stage = int(r["stage"][i])
        disp = int(r["disp"][i])
        pu = int(r["pu"][i])
        t0 = float(r["t0"][i]) * k
        t1 = float(r["t1"][i]) * k
        args = {"uid": uid, "tenant": t, "disp": DISPOSITIONS[disp]}
        if stage == ST_ARRIVE:
            ev.append({"ph": "i", "pid": PID_TENANTS, "tid": t,
                       "ts": t0, "s": "t" if disp == D_OK else "p",
                       "name": _ARRIVE_NAMES.get(disp, "arrive"),
                       "cat": "arrive", "args": args})
        elif stage == ST_GRANT:
            ev.append({"ph": "i", "pid": PID_TENANTS, "tid": t,
                       "ts": t0, "s": "t", "name": "grant",
                       "cat": "sched", "args": dict(args, pu=pu)})
        elif stage == ST_PU:
            ev.append({"ph": "X", "pid": PID_PU, "tid": max(pu, 0),
                       "ts": t0, "dur": t1 - t0,
                       "name": f"t{t}/pkt{uid}", "cat": "pu",
                       "args": args})
            if disp == D_KILL:
                ev.append({"ph": "i", "pid": PID_TENANTS, "tid": t,
                           "ts": t1, "s": "p", "name": "kill",
                           "cat": "pu", "args": args})
        elif stage == ST_EQ:
            ev.append({"ph": "i", "pid": PID_TENANTS, "tid": t,
                       "ts": t0, "s": "t" if disp == D_OK else "p",
                       "name": ("eq_complete" if disp == D_OK
                                else "eq_kill"),
                       "cat": "eq", "args": args})
        else:  # FMQ / DMA residency as async spans keyed by uid
            cat = "fmq" if stage == ST_FMQ else "dma"
            name = STAGES[stage]
            if disp == D_OPEN:
                args["open"] = True
            base = {"pid": PID_TENANTS, "tid": t, "cat": cat,
                    "id": uid, "name": name}
            ev.append(dict(base, ph="b", ts=t0, args=args))
            ev.append(dict(base, ph="e", ts=t1, args={}))

    for i in range(len(d["time"])):
        kd = int(d["kind"][i])
        ev.append({
            "ph": "i", "pid": PID_SCHED, "tid": kd,
            "ts": float(d["time"][i]) * k, "s": "t",
            "name": REASONS[int(d["reason"][i])], "cat": "decision",
            "args": {"winner": int(d["winner"][i]),
                     "n_elig": int(d["n_elig"][i]),
                     "metric": float(d["metric"][i])},
        })

    return {"traceEvents": ev, "displayTimeUnit": "ms",
            "otherData": {"time_unit": time_unit,
                          "spans_recorded": int(trace.span_count),
                          "decisions_recorded": int(trace.decision_count)}}


def write_perfetto(trace: TraceRecorder, path: str, **kw) -> dict:
    doc = to_perfetto(trace, **kw)
    with open(path, "w") as fh:
        json.dump(doc, fh)
    return doc


def console_waterfall(trace: TraceRecorder, *, top_k: int = 10,
                      time_unit: str = "ns") -> str:
    """Top-k slowest packets with a per-stage breakdown."""
    r = trace.rows()
    per: Dict[int, dict] = {}
    n = len(r["uid"])
    for i in range(n):
        uid = int(r["uid"][i])
        stage = int(r["stage"][i])
        rec = per.setdefault(uid, {"tenant": int(r["tenant"][i]),
                                   "stages": {}, "disp": D_OK,
                                   "arrive": None})
        dur = float(r["t1"][i]) - float(r["t0"][i])
        if stage in (ST_FMQ, ST_PU, ST_DMA):
            rec["stages"][stage] = rec["stages"].get(stage, 0.0) + dur
        if stage == ST_ARRIVE:
            rec["arrive"] = float(r["t0"][i])
        if stage in (ST_EQ, ST_PU) and int(r["disp"][i]) != D_OK:
            rec["disp"] = int(r["disp"][i])
    ranked = sorted(per.items(),
                    key=lambda kv: -sum(kv[1]["stages"].values()))
    lines = [f"top {min(top_k, len(ranked))} slowest packets "
             f"({time_unit}):",
             f"{'uid':>8} {'tenant':>6} {'total':>12} {'fmq_wait':>12} "
             f"{'pu_exec':>12} {'dma':>12}  disp"]
    for uid, rec in ranked[:top_k]:
        s = rec["stages"]
        total = sum(s.values())
        lines.append(
            f"{uid:>8} {rec['tenant']:>6} {total:>12.1f} "
            f"{s.get(ST_FMQ, 0.0):>12.1f} {s.get(ST_PU, 0.0):>12.1f} "
            f"{s.get(ST_DMA, 0.0):>12.1f}  "
            f"{DISPOSITIONS[rec['disp']]}")
    return "\n".join(lines)
