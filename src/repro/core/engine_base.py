"""Shared engine-core layer (DESIGN.md §8): one tenant/budget/EQ/telemetry
plumbing stack for every execution engine.

Before this layer existed, ``sim/engine.py`` (cycle simulator) and
``serving/engine.py`` (TPU serving engine) each re-implemented the same
OSMOSIS control-plane machinery — ECTX bookkeeping, SLO budget charging
(``CYCLE_BUDGET_EXCEEDED`` / ``TOTAL_BUDGET_EXCEEDED``), EQ delivery,
telemetry staging/commit, and the closed-loop QoS controller tick — so
every control-plane change had to be patched into both engines in
parallel.  ``EngineBase`` and its three components hold that logic
exactly once:

  * ``BudgetLedger``    — per-tenant lifetime spend (PU cycles on the
    simulator, tokens on the serving engine) plus the watchdog clamp
    semantics of §5.2/§5.3: a kernel is truncated at its per-kernel
    cycle budget, and at the tenant's remaining *total* allowance (the
    permanent form of the same mechanism).
  * ``EQHub``           — per-ECTX event-queue delivery in both layouts
    the engines use: one shared chronological queue (the simulator's
    ``SimResult.events``) or one ``EventQueue`` per tenant (the serving
    engine's ``poll_events`` surface, with retire-on-destroy).
  * ``EngineBase``      — ECTX registry (dense tenant table + installed
    mask), the telemetry plane (staging wrapper + window commits), the
    admission gate, and the QoS controller tick (signal read → AIMD
    update → weight actuation → admit mask), shared verbatim by both
    engines and by the batched simulator fast path (``sim/fastpath.py``).

Backends remain free in *when* they invoke these mechanisms (the
simulator at virtual-time window boundaries, the serving engine once per
step); the mechanisms themselves are no longer duplicated.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.events import Event, EventKind, EventQueue
from repro.core.slo import ECTX


class BudgetLedger:
    """Per-tenant lifetime spend + the paper's watchdog clamp semantics.

    The unit is backend-defined (PU cycles on the simulator, tokens on
    the serving engine); the logic is shared.
    """

    def __init__(self, num_tenants: int):
        self.spent = np.zeros(num_tenants)

    # -- simulator surface: clamp a kernel's cost before execution ---------
    @staticmethod
    def clamp_kernel(cost: float, limit: float) -> tuple:
        """Per-kernel watchdog (§5.3): returns ``(cost, killed)`` with the
        cost truncated at ``limit`` (0 = unlimited)."""
        if limit and cost > limit:
            return float(limit), True
        return cost, False

    def clamp_total(self, tenant: int, cost: float, limit: float) -> tuple:
        """Lifetime-budget watchdog (billing, §5.2): truncate ``cost`` at
        the tenant's remaining total allowance and charge the ledger.
        Returns ``(cost, budget_killed)`` — exhaustion is permanent."""
        budget_killed = False
        if limit:
            remaining = float(limit) - self.spent[tenant]
            if cost > remaining:
                budget_killed = True
                cost = max(0.0, remaining)
        self.spent[tenant] += cost
        return cost, budget_killed

    # -- serving surface: incremental charging --------------------------------
    def charge(self, tenant: int, amount: float) -> None:
        self.spent[tenant] += amount

    def over_total(self, tenant: int, limit: float) -> bool:
        """Post-charge lifetime check (the serving engine charges per
        generated token, then kills)."""
        return bool(limit and self.spent[tenant] > limit)

    def exhausted(self, tenant: int, limit: float) -> bool:
        """Admission-time lifetime check (>=: a tenant that spent exactly
        its allowance gets no further admission)."""
        return bool(limit and self.spent[tenant] >= limit)

    def reset(self, tenant: int) -> None:
        """Budget is per tenant *identity*: a reused id starts fresh."""
        self.spent[tenant] = 0.0

    @staticmethod
    def kill_kind(budget_killed: bool) -> EventKind:
        return (EventKind.TOTAL_BUDGET_EXCEEDED if budget_killed
                else EventKind.CYCLE_BUDGET_EXCEEDED)


class EQHub:
    """Per-ECTX event-queue delivery (paper §5.2, R5) in both layouts.

    ``shared=True``  — one chronological queue for the whole engine (the
    simulator: events are produced in virtual-time order and drained
    into ``SimResult.events``).
    ``shared=False`` — one ``EventQueue`` per tenant with install/retire
    lifecycle (the serving engine's ``poll_events`` surface).
    """

    def __init__(self, *, shared: bool, capacity: int = 4096):
        self.shared = shared
        self.capacity = capacity
        self._q: Optional[EventQueue] = (EventQueue(capacity) if shared
                                         else None)
        self.queues: Dict[int, EventQueue] = {}

    def install(self, tenant: int) -> None:
        if not self.shared:
            self.queues[tenant] = EventQueue(self.capacity)

    def retire(self, tenant: int) -> Optional[EventQueue]:
        """Remove a tenant's queue (last chance to observe its events)."""
        return self.queues.pop(tenant, None)

    def __contains__(self, tenant: int) -> bool:
        return self.shared or tenant in self.queues

    def push(self, ev: Event) -> None:
        q = self._q if self.shared else self.queues.get(ev.tenant)
        if q is not None:
            q.push(ev)

    def poll(self, tenant: int) -> List[Event]:
        if self.shared:
            raise RuntimeError("shared EQHub drains globally, not per "
                               "tenant")
        return self.queues[tenant].drain()

    def drain_all(self) -> List[Event]:
        if not self.shared:
            raise RuntimeError("per-tenant EQHub is polled per tenant")
        return self._q.drain()

    def snapshot(self, tenant: int) -> List[Event]:
        q = self._q if self.shared else self.queues.get(tenant)
        return q.snapshot() if q is not None else []


class EngineBase:
    """Backend-agnostic tenant machinery shared by every engine.

    ``OBS_BACKEND`` labels the frames this engine publishes on the
    metrics bus ("sim" | "serve" — the serving engine overrides it).

    Owns the ECTX registry (dense table + installed mask), the budget
    ledger, the EQ hub, the telemetry plane, the admission gate, and the
    QoS controller tick.  Subclasses (``sim.engine.Simulator``,
    ``sim.fastpath.BatchedSimulator``, ``serving.engine.Engine``) keep
    only their execution semantics: *when* these mechanisms fire and
    what the data plane in between looks like.
    """

    OBS_BACKEND = "sim"

    def __init__(self, max_tenants: int, *, shared_eq: bool,
                 eq_capacity: int = 4096, telemetry: bool = True,
                 telemetry_backend: str = "numpy", trace: bool = False,
                 trace_depth: int = 65536,
                 trace_decision_depth: int = 8192, trace_pus: int = 0):
        from repro.telemetry import Telemetry
        T = max_tenants
        self.max_tenants = T
        self.ectxs: Dict[int, ECTX] = {}
        self._installed = np.zeros(T, bool)
        self.budget = BudgetLedger(T)
        self.eqhub = EQHub(shared=shared_eq, capacity=eq_capacity)
        self.tel = (Telemetry(T, backend=telemetry_backend)
                    if telemetry else None)
        if trace:
            from repro.telemetry.trace import TraceRecorder
            self.trace: Optional["TraceRecorder"] = TraceRecorder(
                T, num_pus=trace_pus, depth=trace_depth,
                decision_depth=trace_decision_depth)
        else:
            self.trace = None
        self.controller = None
        self._ctrl_baseline = None
        self._admit = np.ones(T, bool)       # controller backpressure gate
        # streaming observability plane (DESIGN.md §11): a MetricsBus
        # and/or SLO burn-rate audit attached via attach_bus /
        # attach_slo_audit; observe_tick publishes one frame per
        # backend observation interval against its own baseline (the
        # controller's interval differencing is untouched)
        self.bus = None
        self.slo_audit = None
        self._obs_baseline = None
        self._obs_seq = 0
        self.obs_nic = ""   # fleet runs tag shared-bus frames "nic<k>"

    # -- trace plane ---------------------------------------------------------
    def trace_flush(self, t: float) -> None:
        """Flush in-flight trace state at end of run: write every
        still-open span with disposition OPEN and commit.  Engines
        whose hot paths skip the open-span dict (the simulators record
        whole lifecycles at completion) override this to walk their
        queues and in-flight slots instead."""
        if self.trace is None:
            return
        self.trace.flush_open(t)
        self.trace.commit()

    # -- ECTX registry -------------------------------------------------------
    def register_tenant(self, e: ECTX, *, fmq_index: Optional[int] = None,
                        announce: bool = False, now: float = 0.0) -> ECTX:
        """Install one ECTX: dense-table row, EQ install, optional
        ``ADMITTED`` event.  The caller seeds its scheduler arrays."""
        tid = e.tenant_id
        if fmq_index is not None:
            e.fmq_index = fmq_index
        self.ectxs[tid] = e
        self._installed[tid] = True
        self.eqhub.install(tid)
        if announce:
            self.eqhub.push(Event(tid, EventKind.ADMITTED, now))
        return e

    def deregister_tenant(self, tenant: int) -> Optional[EventQueue]:
        """Uninstall one ECTX: registry row, installed bit, admission
        gate, budget, telemetry + controller history (a reused tenant id
        must not inherit any of them).  Returns the retired EventQueue
        (per-tenant layout) so the caller can flush final events."""
        self.ectxs.pop(tenant, None)
        self._installed[tenant] = False
        self._admit[tenant] = True
        self.budget.reset(tenant)
        if self.controller is not None:
            self.controller.reset_tenant(tenant, base_weight=1.0)
        if self.tel is not None:
            self.tel.reset_tenant(tenant)
            if self._ctrl_baseline is not None:
                self._ctrl_baseline["counts"][tenant] = 0
                self._ctrl_baseline["hist"][tenant] = 0
            if self._obs_baseline is not None:
                self._obs_baseline["counts"][tenant] = 0
                self._obs_baseline["hist"][tenant] = 0
        return self.eqhub.retire(tenant)

    @property
    def installed(self) -> np.ndarray:
        return self._installed

    def admitted(self, tenant: int) -> bool:
        """Controller backpressure gate (False = source-throttled)."""
        return bool(self._admit[tenant])

    # -- QoS control loop ----------------------------------------------------
    def qos_tick(self, *, prio, total_occup, bvt, kv_pressure,
                 knobs, installed: Optional[np.ndarray] = None,
                 t: float = 0.0) -> None:
        """One closed-loop controller interval (DESIGN.md §6): read the
        committed telemetry into a ``SignalFrame``, run the AIMD update,
        actuate the scheduler-weight ``knobs`` (``(live, base)`` pairs),
        and refresh the admission gate.  Call only when a controller is
        attached and the backend's interval elapsed.  ``t`` is the
        interval end in the backend's time unit; an attached SLO audit
        uses it to attribute alerts to the interventions this tick
        applies (which the trace plane also records)."""
        from repro.telemetry import apply_to_scheduler, compute_signals
        snap = self.tel.snapshot()
        sig = compute_signals(
            self.tel, prio=prio, total_occup=total_occup, bvt=bvt,
            kv_pressure=kv_pressure, baseline=self._ctrl_baseline,
            snap=snap)
        self._ctrl_baseline = snap
        act = self.controller.update(sig)
        if self.slo_audit is not None:
            new_ivs = self.slo_audit.note_intervention(t, act, installed)
            if self.trace is not None and new_ivs:
                from repro.telemetry.trace import record_qos_intervention
                for iv in new_ivs:
                    record_qos_intervention(self.trace, t, iv["tenant"],
                                            iv["kind"], iv["value"])
        apply_to_scheduler(act, *knobs, installed=installed)
        self._admit = act.admit

    # -- streaming observability (DESIGN.md §11) -----------------------------
    def attach_bus(self, bus) -> None:
        """Attach a ``telemetry.bus.MetricsBus``; ``observe_tick``
        publishes one ``BusFrame`` per observation interval."""
        self.bus = bus

    def attach_slo_audit(self, audit) -> None:
        """Attach a ``telemetry.slo_audit.SLOAudit``; ``observe_tick``
        feeds it and pushes its alerts as ``SLO_ALERT`` EQ events."""
        self.slo_audit = audit

    def observe_tick(self, *, t: float, prio, total_occup, bvt,
                     kv_pressure) -> None:
        """One observation interval: difference the committed telemetry
        against the observer baseline, run the SLO audit (alerts land
        in the EQ stream and, when tracing, the decision ring), and
        publish a ``BusFrame``.  No-op (one attribute check) with
        nothing attached; reads only host-side committed state, so the
        jit-safe commit path is untouched.  Backends call this *before*
        any same-boundary ``qos_tick`` so an alert raised at the
        boundary precedes the controller's intervention."""
        if self.bus is None and self.slo_audit is None:
            return
        from repro.telemetry import compute_signals
        snap = self.tel.snapshot()
        sig = compute_signals(
            self.tel, prio=prio, total_occup=total_occup, bvt=bvt,
            kv_pressure=kv_pressure, baseline=self._obs_baseline,
            snap=snap)
        counts = snap["counts"]
        interval_counts = (counts - self._obs_baseline["counts"]
                           if self._obs_baseline is not None
                           else counts.copy())
        self._obs_baseline = snap
        alerts = ()
        if self.slo_audit is not None:
            alerts = self.slo_audit.observe(
                t=t, sig=sig, interval_counts=interval_counts)
            for a in alerts:
                self.eqhub.push(Event(
                    a.tenant, EventKind.SLO_ALERT, t,
                    detail=f"{a.window} burn={a.burn_rate:.3g} "
                           f"p99={a.p99:.6g} target={a.target:.6g}"))
            if self.trace is not None and alerts:
                from repro.telemetry.trace import record_slo_alert
                for a in alerts:
                    record_slo_alert(self.trace, t, a.tenant, a.window,
                                     a.burn_rate)
        if self.bus is not None:
            from repro.api.report import TIME_UNITS
            from repro.telemetry.bus import BusFrame
            sim_unit, step_unit = TIME_UNITS
            self.bus.publish(BusFrame(
                t=float(t), seq=self._obs_seq,
                time_unit=(step_unit if self.OBS_BACKEND == "serve"
                           else sim_unit),
                backend=self.OBS_BACKEND,
                signals=sig, counts=counts,
                interval_counts=interval_counts,
                weights=np.array(prio, float),
                admit=self._admit.copy(), alerts=alerts,
                nic=self.obs_nic))
        self._obs_seq += 1
