"""OSMOSIS core: schedulers, FMQs, SLO, fragmentation, accounting."""
from repro.core.accounting import (FCTTracker, TimeAveragedJain,
                                   jain_fairness, weighted_jain)
from repro.core.admission import AdmissionError, SegmentAllocator
from repro.core.engine_base import BudgetLedger, EngineBase, EQHub
from repro.core.events import Event, EventKind, EventQueue
from repro.core.fmq import FMQ, PacketDescriptor, PushResult
from repro.core.fragmentation import (Fragment, FragmentationPolicy,
                                      fragment_tokens, fragment_transfer)
from repro.core.matching import MatchingEngine, MatchRule
from repro.core.slo import ECTX, SLOPolicy
from repro.core import sched_generic, wlbvt

__all__ = [
    "FCTTracker", "TimeAveragedJain", "jain_fairness", "weighted_jain",
    "AdmissionError", "SegmentAllocator", "BudgetLedger", "EngineBase",
    "EQHub", "Event", "EventKind", "EventQueue",
    "FMQ", "PacketDescriptor", "PushResult", "Fragment",
    "FragmentationPolicy",
    "fragment_tokens", "fragment_transfer", "MatchingEngine", "MatchRule",
    "ECTX", "SLOPolicy", "sched_generic", "wlbvt",
]
