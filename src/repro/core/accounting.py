"""Fairness and completion-time accounting (paper §7.2 metrics)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np


def jain_fairness(x) -> float:
    """Jain's index [36]: (Σx)² / (n·Σx²); 1 = perfectly fair, 1/n = one
    tenant starves the rest."""
    x = np.asarray(x, np.float64)
    x = x[np.isfinite(x)]
    if x.size == 0 or np.all(x == 0):
        return 1.0
    return float(x.sum() ** 2 / (x.size * np.square(x).sum()))


def weighted_jain(x, weights) -> float:
    """Priority-adjusted fairness: normalize service by weight first."""
    x = np.asarray(x, np.float64)
    w = np.asarray(weights, np.float64)
    return jain_fairness(x / np.maximum(w, 1e-12))


@dataclasses.dataclass
class TimeAveragedJain:
    """Time-averaged fairness over a run (paper Figs. 12-13 bottom panes)."""
    acc: float = 0.0
    t: float = 0.0

    def update(self, shares, dt: float, weights=None) -> None:
        j = (weighted_jain(shares, weights) if weights is not None
             else jain_fairness(shares))
        self.acc += j * dt
        self.t += dt

    @property
    def value(self) -> float:
        return self.acc / self.t if self.t > 0 else 1.0


@dataclasses.dataclass
class FCTTracker:
    """Flow completion times + per-kernel completion distribution."""
    start: Dict[int, float] = dataclasses.field(default_factory=dict)
    fct: Dict[int, float] = dataclasses.field(default_factory=dict)
    kernel_times: Dict[int, List[float]] = dataclasses.field(
        default_factory=dict)

    def flow_started(self, tenant: int, now: float) -> None:
        self.start.setdefault(tenant, now)

    def flow_finished(self, tenant: int, now: float) -> None:
        if tenant in self.start:
            self.fct[tenant] = now - self.start[tenant]

    def kernel_done(self, tenant: int, elapsed: float) -> None:
        self.kernel_times.setdefault(tenant, []).append(elapsed)

    def percentile(self, tenant: int, q: float) -> float:
        ts = self.kernel_times.get(tenant, [])
        return float(np.percentile(ts, q)) if ts else 0.0
