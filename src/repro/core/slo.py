"""SLO policies and tenant execution contexts (ECTX) — paper §5.2.

The SLO knobs mirror Table 3: per-resource priorities, a kernel cycle
budget (watchdog), and a static memory allocation size.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    priority: float = 1.0            # PU (compute) priority weight
    dma_priority: float = 1.0        # DMA WRR weight
    egress_priority: float = 1.0     # egress WRR weight
    kernel_cycle_limit: int = 0      # 0 = unlimited (watchdog, paper §5.3)
    total_cycle_limit: int = 0       # per-tenant lifetime budget (billing)
    memory_bytes: int = 1 << 20      # static sNIC memory segment
    # TPU serving adaptation:
    kv_quota_tokens: int = 0         # static KV segment (0 = engine default)
    max_chunk_tokens: int = 0        # fragmentation grain override

    def __post_init__(self):
        for knob in ("priority", "dma_priority", "egress_priority"):
            if getattr(self, knob) <= 0:
                raise ValueError(f"{knob} must be positive, got "
                                 f"{getattr(self, knob)}")
        for limit in ("kernel_cycle_limit", "total_cycle_limit",
                      "memory_bytes", "kv_quota_tokens",
                      "max_chunk_tokens"):
            if getattr(self, limit) < 0:
                raise ValueError(f"{limit} must be >= 0 (0 = unlimited/"
                                 f"default), got {getattr(self, limit)}")


@dataclasses.dataclass
class ECTX:
    """Flow execution context (paper §5.1 step 1-2).

    Encapsulates everything the control plane installs on the device:
    matching rule, kernel (cost model or serving request handler), SLO
    policy, and the statically allocated memory segment.
    """
    tenant_id: int
    name: str
    slo: SLOPolicy
    kernel: Optional[object] = None      # sim: WorkloadModel; serving: arch id
    match_rule: Optional[object] = None  # matching.MatchRule
    memory_segment: Optional[tuple] = None  # (offset, size) once admitted
    fmq_index: int = -1                  # assigned at admission

    @property
    def admitted(self) -> bool:
        return self.fmq_index >= 0
