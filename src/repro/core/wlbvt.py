"""WLBVT / RR / WRR schedulers — paper Listing 1 and §5.3.

One backend-generic implementation (``core/sched_generic.py``) adapted to
two surfaces (DESIGN.md §3):

  * ``WLBVTState``/``DWRRState`` + ``select``/``select_k``/``advance``/
    ``pu_limit``/``dwrr_select`` on numpy arrays — stateful wrappers used
    by the cycle-accurate PsPIN simulator (event-driven, so per-cycle
    ``update_tput`` is folded into ``advance(dt)``).
  * ``*_jnp`` mirrors — jitted, functional, used inside the TPU serving
    engine's scheduling step.  ``tests/test_sched_core.py`` asserts
    numpy↔jnp parity on randomized states.

``select_k(st, num_pus, k)`` is the batch API: the k winners of one
scheduling round in a single call (a ``lax.scan`` under jit — one XLA
invocation instead of k dispatches), replacing the per-tenant Python
loops the serving engine and simulator used to carry.

Interpretation note (DESIGN.md §3.2): Listing 1's
``pu_limit = ceil(len(FMQs) * prio / prio_sum)`` reads as the *PU count*
times the normalized priority — with ``len(FMQs)`` the paper's 128-FMQ
constant the limit would never bind at 32 PUs, contradicting §5.3's
"weighted PU occupation's upper limit guarantees fair QoS".  We use
``ceil(num_pus * prio / prio_sum_active)``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import sched_generic as G
from repro.core.sched_generic import BIG, CEIL_EPS, GRANT_EPS  # noqa: F401

try:  # jnp mirror (optional import so the simulator stays jax-free)
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None

_JIT_CACHE: dict = {}


# ---------------------------------------------------------------------------
# numpy surface (simulator control plane)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class WLBVTState:
    prio: np.ndarray            # (T,) float64, >0
    total_occup: np.ndarray     # (T,) float64 — cumulative PU-cycles
    bvt: np.ndarray             # (T,) float64 — active cycles
    cur_occup: np.ndarray       # (T,) int64 — PUs currently held
    queue_len: np.ndarray       # (T,) int64 — packets waiting

    @classmethod
    def create(cls, priorities) -> "WLBVTState":
        p = np.asarray(priorities, np.float64)
        T = p.shape[0]
        return cls(prio=p.copy(),
                   total_occup=np.zeros(T), bvt=np.zeros(T),
                   cur_occup=np.zeros(T, np.int64),
                   queue_len=np.zeros(T, np.int64))

    @property
    def active(self) -> np.ndarray:
        return (self.queue_len > 0) | (self.cur_occup > 0)

    def tput(self) -> np.ndarray:
        return G.tput(self.total_occup, self.bvt, np)


def advance(st: WLBVTState, dt: float) -> None:
    """Fold `dt` cycles of update_tput (paper lines 8-13) in one step."""
    st.total_occup, st.bvt = G.advance(
        st.queue_len, st.cur_occup, st.total_occup, st.bvt, float(dt), np)


def pu_limit(st: WLBVTState, num_pus: int) -> np.ndarray:
    return G.pu_limit(st.prio, st.queue_len, num_pus, np).astype(np.int64)


def select(st: WLBVTState, num_pus: int, cap=None) -> int:
    """Paper lines 15-24: non-empty FMQ under its weighted PU cap with the
    lowest priority-normalized throughput.  Returns -1 if none eligible.
    ``cap`` optionally folds an extra occupancy ceiling (e.g. KV-quota
    slot caps) into eligibility."""
    return int(G.select(st.prio, st.queue_len, st.cur_occup,
                        st.total_occup, st.bvt, num_pus, np, cap=cap))


def select_k(st: WLBVTState, num_pus: int, k: int, cap=None) -> np.ndarray:
    """Batch API: the k winners of one scheduling round.

    Equivalent to k sequential ``select`` calls with the winner's queue
    popped and occupancy charged between picks — ``st.queue_len`` and
    ``st.cur_occup`` are updated in place accordingly (the caller then
    dequeues the actual work items in pick order).  Returns a (k,) int64
    array, -1-padded once nothing is eligible.
    """
    picks = np.full(k, -1, np.int64)
    # Round invariants, hoisted: total_occup/bvt (hence the metric) never
    # change between picks, and pu_limit only changes when a pick drains
    # a queue to zero (the non-empty prio_sum shrinks — work conservation).
    # Between drains each pick only flips its own winner's eligibility, so
    # the masked metric is maintained incrementally: picks are O(argmin),
    # not O(full eligibility rebuild) — decisions stay identical to the
    # sequential scalar loop because every updated entry takes exactly the
    # value a full rebuild would give it.
    metric = G.tput(st.total_occup, st.bvt, np) / st.prio

    def rebuild():
        limit = G.pu_limit(st.prio, st.queue_len, num_pus, np)
        eligible = (st.queue_len > 0) & (st.cur_occup < limit)
        if cap is not None:
            eligible = eligible & (st.cur_occup < cap)
        return limit, np.where(eligible, metric, G.BIG)

    limit, masked = rebuild()
    for j in range(k):
        i = int(np.argmin(masked))
        if masked[i] >= G.BIG:      # nothing eligible
            break
        picks[j] = i
        st.queue_len[i] -= 1
        st.cur_occup[i] += 1
        if st.queue_len[i] == 0:    # non-empty set shrank: limits change
            limit, masked = rebuild()
        else:
            ok = st.cur_occup[i] < limit[i] and (
                cap is None or st.cur_occup[i] < cap[i])
            masked[i] = metric[i] if ok else G.BIG
    return picks


def select_rr(rr_ptr: int, queue_len: np.ndarray, mask=None) -> tuple:
    """Round-robin baseline (paper Fig. 4/9).  Returns (idx, new_ptr)."""
    idx, ptr = G.select_rr(rr_ptr, queue_len, np, mask=mask)
    return int(idx), int(ptr)


# ---------------------------------------------------------------------------
# jnp surface (serving engine — jittable)
# ---------------------------------------------------------------------------
def init_state_jnp(priorities):
    p = jnp.asarray(priorities, jnp.float32)
    T = p.shape[0]
    return {
        "prio": p,
        "total_occup": jnp.zeros((T,), jnp.float32),
        "bvt": jnp.zeros((T,), jnp.float32),
        "cur_occup": jnp.zeros((T,), jnp.int32),
        "queue_len": jnp.zeros((T,), jnp.int32),
    }


def advance_jnp(st: dict, dt) -> dict:
    total_occup, bvt = G.advance(
        st["queue_len"], st["cur_occup"], st["total_occup"], st["bvt"],
        jnp.asarray(dt, jnp.float32), jnp)
    return dict(st, total_occup=total_occup, bvt=bvt)


def pu_limit_jnp(st: dict, num_pus: int):
    return G.pu_limit(st["prio"], st["queue_len"], num_pus,
                      jnp).astype(jnp.int32)


def select_jnp(st: dict, num_pus: int):
    """Returns idx (int32, -1 if none eligible)."""
    return G.select(st["prio"], st["queue_len"], st["cur_occup"],
                    st["total_occup"], st["bvt"], num_pus,
                    jnp).astype(jnp.int32)


def _select_k_fn(num_pus: int, k: int, has_cap: bool):
    key = ("select_k", num_pus, k, has_cap)
    if key not in _JIT_CACHE:
        def run(prio, queue_len, cur_occup, total_occup, bvt, cap):
            def body(carry, _):
                ql, co = carry
                idx, ql, co = G.select_round(
                    prio, ql, co, total_occup, bvt, num_pus, jnp,
                    cap=cap if has_cap else None)
                return (ql, co), idx.astype(jnp.int32)
            (ql, co), picks = jax.lax.scan(
                body, (queue_len, cur_occup), None, length=k)
            return picks, ql, co
        _JIT_CACHE[key] = jax.jit(run)
    return _JIT_CACHE[key]


def select_k_jnp(st: dict, num_pus: int, k: int, cap=None):
    """Jitted batch select: one XLA call for the whole round.

    Returns ``(picks, new_state)`` — picks is a (k,) int32 array,
    -1-padded; the new state carries the drained queue lengths and
    charged occupancies.
    """
    has_cap = cap is not None
    fn = _select_k_fn(int(num_pus), int(k), has_cap)
    dummy = st["cur_occup"] if not has_cap else jnp.asarray(cap)
    picks, ql, co = fn(st["prio"], st["queue_len"], st["cur_occup"],
                       st["total_occup"], st["bvt"], dummy)
    return picks, dict(st, queue_len=ql, cur_occup=co)


# ---------------------------------------------------------------------------
# Deficit Weighted Round Robin (IO arbitration — paper §5.1 step 5, §6.2)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DWRRState:
    weights: np.ndarray        # (Q,) float
    deficit: np.ndarray        # (Q,) float — bytes of credit
    ptr: int = 0

    @classmethod
    def create(cls, weights) -> "DWRRState":
        w = np.asarray(weights, np.float64)
        return cls(weights=w, deficit=np.zeros_like(w))


def dwrr_select(st: DWRRState, head_size: np.ndarray, pending: np.ndarray,
                quantum: float) -> int:
    """Pick the next queue whose head fragment fits its deficit.

    head_size: (Q,) bytes; pending: (Q,) bool.  Returns queue idx (its
    deficit is charged) or -1 if nothing pending.  See
    ``sched_generic.dwrr_select`` for the O(1) top-up semantics.
    """
    idx, deficit, ptr = G.dwrr_select(
        st.weights, st.deficit, st.ptr, np.asarray(head_size, np.float64),
        np.asarray(pending, bool), float(quantum), np)
    st.deficit = deficit
    st.ptr = int(ptr)
    return int(idx)


def dwrr_select_k(st: DWRRState, head_size: np.ndarray, counts: np.ndarray,
                  quantum: float, k: int) -> np.ndarray:
    """Batch DWRR: up to k grants of one arbitration round.

    ``counts`` (int array) holds the number of queued fragments per
    queue and is decremented in place as grants are issued; the deficit
    state advances exactly as k sequential ``dwrr_select`` calls would.
    Returns a (k,) int64 array of queue indices, -1-padded.
    """
    picks = np.full(k, -1, np.int64)
    for j in range(k):
        i = dwrr_select(st, head_size, counts > 0, quantum)
        if i < 0:
            break
        counts[i] -= 1
        picks[j] = i
    return picks


def dwrr_state_jnp(weights) -> dict:
    w = jnp.asarray(weights, jnp.float32)
    return {"weights": w, "deficit": jnp.zeros_like(w),
            "ptr": jnp.asarray(0, jnp.int32)}


def dwrr_select_jnp(st: dict, head_size, pending, quantum):
    """Jitted DWRR grant.  Returns ``(idx, new_state)``."""
    key = ("dwrr",)
    if key not in _JIT_CACHE:
        def run(weights, deficit, ptr, head, pending, quantum):
            return G.dwrr_select(weights, deficit, ptr, head, pending,
                                 quantum, jnp)
        _JIT_CACHE[key] = jax.jit(run)
    idx, deficit, ptr = _JIT_CACHE[key](
        st["weights"], st["deficit"], st["ptr"],
        jnp.asarray(head_size, jnp.float32), jnp.asarray(pending, bool),
        jnp.asarray(quantum, jnp.float32))
    return idx.astype(jnp.int32), dict(st, deficit=deficit, ptr=ptr)
