"""WLBVT / RR / WRR schedulers — paper Listing 1 and §5.3.

Two numerically identical implementations of the Weight-Limited Borrowed
Virtual Time policy:

  * ``WLBVTState`` + ``select``/``advance`` on numpy arrays — used by the
    cycle-accurate PsPIN simulator (event-driven, so per-cycle
    ``update_tput`` is folded into ``advance(dt)``).
  * ``select_jnp``/``advance_jnp`` — jittable, used inside the TPU serving
    engine's scheduling step.  ``tests/test_wlbvt.py`` asserts equivalence.

Interpretation note (documented in DESIGN.md): Listing 1's
``pu_limit = ceil(len(FMQs) * prio / prio_sum)`` reads as the *PU count*
times the normalized priority — with ``len(FMQs)`` the paper's 128-FMQ
constant the limit would never bind at 32 PUs, contradicting §5.3's
"weighted PU occupation's upper limit guarantees fair QoS".  We use
``ceil(num_pus * prio / prio_sum_active)``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

try:  # jnp mirror (optional import so the simulator stays jax-free)
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None

BIG = 1e30


# ---------------------------------------------------------------------------
# numpy implementation (simulator)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class WLBVTState:
    prio: np.ndarray            # (T,) float64, >0
    total_occup: np.ndarray     # (T,) float64 — cumulative PU-cycles
    bvt: np.ndarray             # (T,) float64 — active cycles
    cur_occup: np.ndarray       # (T,) int64 — PUs currently held
    queue_len: np.ndarray       # (T,) int64 — packets waiting

    @classmethod
    def create(cls, priorities) -> "WLBVTState":
        p = np.asarray(priorities, np.float64)
        T = p.shape[0]
        return cls(prio=p.copy(),
                   total_occup=np.zeros(T), bvt=np.zeros(T),
                   cur_occup=np.zeros(T, np.int64),
                   queue_len=np.zeros(T, np.int64))

    @property
    def active(self) -> np.ndarray:
        return (self.queue_len > 0) | (self.cur_occup > 0)

    def tput(self) -> np.ndarray:
        return self.total_occup / np.maximum(self.bvt, 1.0)


def advance(st: WLBVTState, dt: float) -> None:
    """Fold `dt` cycles of update_tput (paper lines 8-13) in one step."""
    act = st.active
    st.total_occup[act] += st.cur_occup[act] * dt
    st.bvt[act] += dt


def pu_limit(st: WLBVTState, num_pus: int) -> np.ndarray:
    # Listing 1 line 4-5: prio_sum over *non-empty* FMQs — queues that
    # drained release their share immediately (work conservation).
    # The 1e-6 pre-ceil epsilon makes the hardware-width (fp32) and
    # reference (fp64) implementations agree at exact-integer boundaries.
    nonempty = st.queue_len > 0
    psum = float(st.prio[nonempty].sum())
    if psum <= 0:
        return np.full(st.prio.shape, num_pus, np.int64)
    return np.ceil(num_pus * st.prio / psum - 1e-6).astype(np.int64)


def select(st: WLBVTState, num_pus: int) -> int:
    """Paper lines 15-24: non-empty FMQ under its weighted PU cap with the
    lowest priority-normalized throughput.  Returns -1 if none eligible."""
    limit = pu_limit(st, num_pus)
    eligible = (st.queue_len > 0) & (st.cur_occup < limit)
    if not eligible.any():
        return -1
    metric = np.where(eligible, st.tput() / st.prio, BIG)
    return int(np.argmin(metric))


def select_rr(rr_ptr: int, queue_len: np.ndarray) -> tuple:
    """Plain round-robin baseline (paper Fig. 4/9).  Returns (idx, new_ptr)."""
    T = queue_len.shape[0]
    for k in range(T):
        i = (rr_ptr + k) % T
        if queue_len[i] > 0:
            return i, (i + 1) % T
    return -1, rr_ptr


# ---------------------------------------------------------------------------
# jnp mirror (serving engine — jittable)
# ---------------------------------------------------------------------------
def init_state_jnp(priorities):
    p = jnp.asarray(priorities, jnp.float32)
    T = p.shape[0]
    return {
        "prio": p,
        "total_occup": jnp.zeros((T,), jnp.float32),
        "bvt": jnp.zeros((T,), jnp.float32),
        "cur_occup": jnp.zeros((T,), jnp.int32),
        "queue_len": jnp.zeros((T,), jnp.int32),
    }


def advance_jnp(st: dict, dt) -> dict:
    act = (st["queue_len"] > 0) | (st["cur_occup"] > 0)
    dt = jnp.asarray(dt, jnp.float32)
    return dict(
        st,
        total_occup=st["total_occup"]
        + jnp.where(act, st["cur_occup"].astype(jnp.float32) * dt, 0.0),
        bvt=st["bvt"] + jnp.where(act, dt, 0.0),
    )


def pu_limit_jnp(st: dict, num_pus: int):
    nonempty = st["queue_len"] > 0
    psum = jnp.sum(jnp.where(nonempty, st["prio"], 0.0))
    return jnp.where(
        psum > 0,
        jnp.ceil(num_pus * st["prio"] / jnp.maximum(psum, 1e-9) - 1e-6),
        float(num_pus)).astype(jnp.int32)


def select_jnp(st: dict, num_pus: int):
    """Returns idx (int32, -1 if none eligible)."""
    limit = pu_limit_jnp(st, num_pus)
    tput = st["total_occup"] / jnp.maximum(st["bvt"], 1.0)
    eligible = (st["queue_len"] > 0) & (st["cur_occup"] < limit)
    metric = jnp.where(eligible, tput / st["prio"], BIG)
    idx = jnp.argmin(metric)
    return jnp.where(jnp.any(eligible), idx, -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Deficit Weighted Round Robin (IO arbitration — paper §5.1 step 5, §6.2)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DWRRState:
    weights: np.ndarray        # (Q,) float
    deficit: np.ndarray        # (Q,) float — bytes of credit
    ptr: int = 0

    @classmethod
    def create(cls, weights) -> "DWRRState":
        w = np.asarray(weights, np.float64)
        return cls(weights=w, deficit=np.zeros_like(w))


def dwrr_select(st: DWRRState, head_size: np.ndarray, pending: np.ndarray,
                quantum: float) -> int:
    """Pick the next queue whose head fragment fits its deficit.

    head_size: (Q,) bytes; pending: (Q,) bool.  Returns queue idx (its
    deficit is charged) or -1 if nothing pending.  Deficit top-up jumps
    directly to the first round at which *some* pending queue becomes
    eligible (O(1) virtual-time advance — equivalent to iterating rounds,
    robust to heads many quanta large), then grants in round-robin order
    from the saved pointer.  Idle queues cannot hoard more than one
    head+quantum of credit.
    """
    Q = st.weights.shape[0]
    if not pending.any():
        return -1

    def grant() -> int:
        for k in range(Q):
            i = (st.ptr + k) % Q
            if pending[i] and st.deficit[i] >= head_size[i] - 1e-9:
                st.deficit[i] -= head_size[i]
                st.ptr = (i + 1) % Q
                return i
        return -1

    got = grant()                     # spend credit from earlier rounds
    if got >= 0:
        return got
    inc = quantum * st.weights
    need = np.where(pending, head_size - st.deficit, np.inf)
    rounds = int(np.ceil(np.maximum(need, 0.0)[pending]
                         / inc[pending]).min())
    st.deficit[pending] += max(rounds, 1) * inc[pending]
    # idle credit cap: at most one head + one round of quantum
    np.minimum(st.deficit, head_size + inc, out=st.deficit)
    return grant()
