"""Event queues (EQ) — paper §5.2: per-ECTX host notification channel.

EQ traffic shares the DMA path but at the *highest* IO priority (R5);
in the serving engine, control events are drained before data-path
scheduling each step.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Deque, List, Optional


class EventKind(enum.Enum):
    KERNEL_ERROR = "kernel_error"
    CYCLE_BUDGET_EXCEEDED = "cycle_budget_exceeded"
    TOTAL_BUDGET_EXCEEDED = "total_budget_exceeded"
    MEMORY_FAULT = "memory_fault"
    QUEUE_OVERFLOW = "queue_overflow"
    ECN_MARK = "ecn_mark"
    BACKPRESSURE = "backpressure"
    REQUEST_KILLED = "request_killed"
    ADMITTED = "admitted"
    EVICTED = "evicted"
    SLO_ALERT = "slo_alert"
    MIGRATE_START = "migrate_start"
    MIGRATE_DONE = "migrate_done"
    SWITCH_DROP = "switch_drop"


# Where each kind is consumed once it leaves the EQ.  Every member MUST
# have a row here — ``repro.analysis`` (eq-event-exhaustiveness) fails
# the CI gate otherwise — so adding a kind forces a decision about who
# reacts to it.  All kinds additionally reach tenants via
# ``Runtime.poll_events`` and the bounded ``RunReport.events`` block.
EVENT_DISPOSITIONS = {
    EventKind.KERNEL_ERROR:
        "reserved (paper §5.2 fault channel); no kernel-fault model "
        "emits it yet — pinned in analysis_baseline.json",
    EventKind.CYCLE_BUDGET_EXCEEDED:
        "telemetry: `killed` counter; report: per-tenant killed count "
        "(watchdog clamp, engine_base.BudgetLedger.kill_kind)",
    EventKind.TOTAL_BUDGET_EXCEEDED:
        "telemetry: `killed` counter; billing exhaustion is permanent "
        "(BudgetLedger.over_total gates later admissions)",
    EventKind.MEMORY_FAULT:
        "telemetry: `killed` counter; serving KV-quota violation path "
        "(serving/engine._kill_request callers)",
    EventKind.QUEUE_OVERFLOW:
        "telemetry: `drops` counter -> signals.drop_rate -> QoS "
        "controller admission pressure",
    EventKind.ECN_MARK:
        "telemetry: `ecn_marks` counter -> signals.ecn_rate -> QoS "
        "controller admission pressure",
    EventKind.BACKPRESSURE:
        "tenant-facing pause notification (controller hysteresis gate); "
        "drained via poll_events before the next submit",
    EventKind.REQUEST_KILLED:
        "telemetry: `killed` counter; serving kill/evict default kind",
    EventKind.ADMITTED:
        "tenant-facing ECTX-creation ack (engine_base.register_tenant)",
    EventKind.EVICTED:
        "tenant-facing ECTX teardown notice; controller.reset_tenant "
        "clears AIMD state on the same boundary",
    EventKind.SLO_ALERT:
        "burn-rate SLO alert (telemetry/slo_audit.py): consumed by the "
        "metrics bus / dashboard, the trace plane (alert->intervention "
        "causality) and RunReport.extras['slo_audit']",
    EventKind.MIGRATE_START:
        "fleet plane (fleet/engine.py): global QoS began live-migrating "
        "the tenant — source FMQ drained, queue state in flight; paired "
        "with MIGRATE_DONE in RunReport.extras['fleet']['migrations']",
    EventKind.MIGRATE_DONE:
        "fleet plane (fleet/engine.py): drained queue replayed through "
        "the fabric onto the destination NIC; tenant re-homed in "
        "extras['fleet']['placement_final']",
    EventKind.SWITCH_DROP:
        "fabric VOQ overflow (fleet/switch.py): counted per tenant in "
        "extras['fleet']['switch'] and the switch conservation law "
        "(injected == delivered + dropped + inflight)",
}


@dataclasses.dataclass(frozen=True)
class Event:
    tenant: int
    kind: EventKind
    time: float
    detail: str = ""


class EventQueue:
    def __init__(self, capacity: int = 4096) -> None:
        self._q: Deque[Event] = deque(maxlen=capacity)
        self.dropped = 0

    def push(self, ev: Event) -> None:
        if len(self._q) == self._q.maxlen:
            self.dropped += 1
        self._q.append(ev)

    def poll(self) -> Optional[Event]:
        return self._q.popleft() if self._q else None

    def drain(self) -> List[Event]:
        out = list(self._q)
        self._q.clear()
        return out

    def snapshot(self) -> List[Event]:
        """Non-destructive view of the queued events (reports use this
        so ``poll``/``drain`` still deliver them to the tenant)."""
        return list(self._q)

    def __len__(self) -> int:
        return len(self._q)
