"""Flow Management Queues — paper §5.3 hardware flow abstraction.

An FMQ is a FIFO of packet descriptors plus scheduling state (the BVT
counters live in the shared WLBVT arrays, indexed by ``index``) plus the
pointers into the ECTX.  The 64-bit BVT counter / 16-bit priority register
widths from §6.2 are modeled by the array dtypes in wlbvt.py.

Overflow follows the paper's ECN mark-before-drop discipline: once the
FIFO depth crosses ``ecn_threshold`` the packet is still accepted but
ECN-marked (``PushResult.MARKED``, counted in ``ecn_marks``) so the
telemetry/control plane sees congestion *before* losses start; only a
full FIFO drops (``PushResult.DROPPED``).
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Deque, Optional

from repro.core.slo import ECTX


class PushResult(enum.IntEnum):
    """Truthiness = "was the packet accepted" (MARKED packets are)."""
    DROPPED = 0
    OK = 1
    MARKED = 2


@dataclasses.dataclass
class PacketDescriptor:
    tenant: int
    size_bytes: int           # payload + header
    arrival: float            # cycles
    transfer_id: int = -1
    ecn: bool = False         # set when the FMQ marked this packet
    meta: Optional[dict] = None


@dataclasses.dataclass
class FMQ:
    index: int
    ectx: ECTX
    capacity: int = 1024      # descriptor FIFO depth
    ecn_threshold: int = 0    # mark depth; 0 = 3/4 of capacity
    fifo: Deque[PacketDescriptor] = dataclasses.field(default_factory=deque)
    drops: int = 0
    ecn_marks: int = 0
    enqueued: int = 0
    completed: int = 0

    def __post_init__(self):
        if self.ecn_threshold <= 0:
            self.ecn_threshold = max(1, (3 * self.capacity) // 4)

    def push(self, pkt: PacketDescriptor) -> PushResult:
        """DROPPED => FIFO overflow; MARKED => accepted but ECN-marked
        (depth at/above the mark-before-drop threshold)."""
        if len(self.fifo) >= self.capacity:
            self.drops += 1
            return PushResult.DROPPED
        self.fifo.append(pkt)
        self.enqueued += 1
        if len(self.fifo) >= self.ecn_threshold:
            self.ecn_marks += 1
            pkt.ecn = True
            return PushResult.MARKED
        return PushResult.OK

    def pop(self) -> Optional[PacketDescriptor]:
        return self.fifo.popleft() if self.fifo else None

    def __len__(self) -> int:
        return len(self.fifo)
