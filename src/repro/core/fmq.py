"""Flow Management Queues — paper §5.3 hardware flow abstraction.

An FMQ is a FIFO of packet descriptors plus scheduling state (the BVT
counters live in the shared WLBVT arrays, indexed by ``index``) plus the
pointers into the ECTX.  The 64-bit BVT counter / 16-bit priority register
widths from §6.2 are modeled by the array dtypes in wlbvt.py.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Optional

from repro.core.slo import ECTX


@dataclasses.dataclass
class PacketDescriptor:
    tenant: int
    size_bytes: int           # payload + header
    arrival: float            # cycles
    transfer_id: int = -1
    meta: Optional[dict] = None


@dataclasses.dataclass
class FMQ:
    index: int
    ectx: ECTX
    capacity: int = 1024      # descriptor FIFO depth
    fifo: Deque[PacketDescriptor] = dataclasses.field(default_factory=deque)
    drops: int = 0
    enqueued: int = 0
    completed: int = 0

    def push(self, pkt: PacketDescriptor) -> bool:
        """False => FIFO overflow (paper: ECN-mark / drop)."""
        if len(self.fifo) >= self.capacity:
            self.drops += 1
            return False
        self.fifo.append(pkt)
        self.enqueued += 1
        return True

    def pop(self) -> Optional[PacketDescriptor]:
        return self.fifo.popleft() if self.fifo else None

    def __len__(self) -> int:
        return len(self.fifo)
