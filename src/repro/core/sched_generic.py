"""Backend-generic WLBVT / DWRR scheduling kernels (DESIGN.md §3).

Single source of truth for the paper's two arbitration policies: every
function here is written once against the array-API subset that numpy and
``jax.numpy`` share, is purely functional (returns new arrays, never
mutates), and is branch-free in traced values — so the same code path
runs eagerly on fp64 numpy arrays inside the cycle-accurate simulator's
control plane and compiles under ``jax.jit`` on fp32 arrays inside the
serving engine's data plane.  ``core/wlbvt.py`` wraps these kernels in
the stateful numpy API (``WLBVTState``/``DWRRState``) and the jitted jnp
API; both are thin adapters, not re-implementations.

The only Python-level branches are on *static* configuration (``cap is
None``/``mask is None``), which jit treats as trace-time constants.
"""
from __future__ import annotations

BIG = 1e30        # ineligible-metric sentinel (select)
CEIL_EPS = 1e-6   # pre-ceil epsilon: fp32 (hw-width) and fp64 (reference)
#                   pu_limit agree at exact-integer boundaries
GRANT_EPS = 1e-9  # DWRR deficit comparison slack


# ---------------------------------------------------------------------------
# WLBVT (PU scheduling — paper Listing 1, §5.3)
# ---------------------------------------------------------------------------
def tput(total_occup, bvt, xp):
    """Priority-unnormalized service rate (paper line 12)."""
    return total_occup / xp.maximum(bvt, 1.0)


def advance(queue_len, cur_occup, total_occup, bvt, dt, xp):
    """Fold ``dt`` cycles of update_tput (paper lines 8-13) in one step.

    Returns the new ``(total_occup, bvt)``; inactive tenants' virtual
    time stays frozen so an idle tenant does not bank credit.
    """
    act = (queue_len > 0) | (cur_occup > 0)
    total_occup = total_occup + xp.where(act, cur_occup * dt, 0.0)
    bvt = bvt + xp.where(act, dt, 0.0)
    return total_occup, bvt


def pu_limit(prio, queue_len, num_pus, xp):
    """Weighted per-tenant PU cap as a float array of integral values.

    Listing 1 lines 4-5: prio_sum over *non-empty* FMQs — queues that
    drained release their share immediately (work conservation).  See
    DESIGN.md §3.2 for the ``num_pus``-vs-``len(FMQs)`` interpretation
    note and the CEIL_EPS rationale.
    """
    nonempty = queue_len > 0
    psum = xp.sum(xp.where(nonempty, prio, 0.0))
    lim = xp.ceil(num_pus * prio / xp.maximum(psum, 1e-9) - CEIL_EPS)
    return xp.where(psum > 0, lim, float(num_pus))


def select(prio, queue_len, cur_occup, total_occup, bvt, num_pus, xp,
           cap=None):
    """One WLBVT decision (paper lines 15-24): the non-empty FMQ under its
    weighted PU cap with the lowest priority-normalized throughput.

    ``cap`` (optional int array) is an extra per-tenant occupancy ceiling
    folded into eligibility — the serving engine passes its static
    KV-quota slot caps here (R3).  Returns -1 if nothing is eligible.
    """
    limit = pu_limit(prio, queue_len, num_pus, xp)
    eligible = (queue_len > 0) & (cur_occup < limit)
    if cap is not None:
        eligible = eligible & (cur_occup < cap)
    metric = xp.where(eligible, tput(total_occup, bvt, xp) / prio, BIG)
    idx = xp.argmin(metric)
    return xp.where(xp.any(eligible), idx, -1)


def select_round(prio, queue_len, cur_occup, total_occup, bvt, num_pus, xp,
                 cap=None):
    """One pick of a multi-winner round: returns ``(idx, queue_len,
    cur_occup)`` with the winner's queue drained by one and its occupancy
    charged — exactly the state transition the sequential scalar loop
    performed between two ``select`` calls.  ``select_k`` drivers iterate
    this kernel (a Python loop on numpy, ``lax.scan`` under jit)."""
    idx = select(prio, queue_len, cur_occup, total_occup, bvt, num_pus, xp,
                 cap=cap)
    won = idx >= 0
    iv = xp.where(won, idx, 0)
    hot = (xp.arange(queue_len.shape[0]) == iv) & won
    queue_len = queue_len - hot.astype(queue_len.dtype)
    cur_occup = cur_occup + hot.astype(cur_occup.dtype)
    return idx, queue_len, cur_occup


def select_rr(ptr, queue_len, xp, mask=None):
    """Vectorized round-robin baseline (paper Fig. 4/9): first non-empty
    queue at or after ``ptr``.  Returns ``(idx, new_ptr)``; the pointer
    is unchanged when nothing is pending."""
    T = queue_len.shape[0]
    ok = queue_len > 0
    if mask is not None:
        ok = ok & mask
    order = (xp.arange(T) - ptr) % T
    i = xp.argmin(xp.where(ok, order, T))
    found = xp.any(ok)
    idx = xp.where(found, i, -1)
    new_ptr = xp.where(found, (i + 1) % T, ptr)
    return idx, new_ptr


# ---------------------------------------------------------------------------
# DWRR (IO arbitration — paper §5.1 step 5, §6.2)
# ---------------------------------------------------------------------------
def dwrr_grant(deficit, ptr, head, pending, xp):
    """Spend phase: first pending queue (in RR order from ``ptr``) whose
    deficit covers its head fragment.  Returns ``(idx, deficit, ptr)``;
    idx -1 and unchanged state when no queue can be granted."""
    Q = deficit.shape[0]
    ok = pending & (deficit >= head - GRANT_EPS)
    order = (xp.arange(Q) - ptr) % Q
    i = xp.argmin(xp.where(ok, order, Q))
    found = xp.any(ok)
    charge = xp.where((xp.arange(Q) == i) & found, head, 0.0)
    idx = xp.where(found, i, -1)
    new_ptr = xp.where(found, (i + 1) % Q, ptr)
    return idx, deficit - charge, new_ptr


def dwrr_select(weights, deficit, ptr, head, pending, quantum, xp):
    """One DWRR grant with O(1) virtual-time top-up.

    Spend existing credit first; if no pending queue is covered, jump
    directly to the first round at which *some* pending queue becomes
    eligible (equivalent to iterating rounds, robust to heads many quanta
    large) and grant from the saved pointer.  Idle queues cannot hoard
    more than one head+quantum of credit.  Returns ``(idx, deficit,
    ptr)``; idx -1 and unchanged state when nothing is pending.
    """
    any_p = xp.any(pending)
    i1, d1, p1 = dwrr_grant(deficit, ptr, head, pending, xp)
    f1 = i1 >= 0
    inc = quantum * weights
    need = xp.maximum(xp.where(pending, head - deficit, 0.0), 0.0)
    rounds_each = xp.where(pending,
                           xp.ceil(need / xp.maximum(inc, 1e-30)), BIG)
    rounds = xp.maximum(xp.min(rounds_each), 1.0)
    topped = xp.minimum(deficit + xp.where(pending, rounds * inc, 0.0),
                        head + inc)  # idle-credit cap, applied to all queues
    i2, d2, p2 = dwrr_grant(topped, ptr, head, pending, xp)
    idx = xp.where(any_p, xp.where(f1, i1, i2), -1)
    new_deficit = xp.where(any_p, xp.where(f1, d1, d2), deficit)
    new_ptr = xp.where(any_p, xp.where(f1, p1, p2), ptr)
    return idx, new_deficit, new_ptr


# ---------------------------------------------------------------------------
# Lane-batched WLBVT (device datapath — DESIGN.md §13)
# ---------------------------------------------------------------------------
def pu_limit_lanes(prio, queue_len, num_pus, xp):
    """`pu_limit` reduced over the trailing tenant axis: every leading
    axis is an independent replica lane, so one call computes the caps
    for a whole ``[R, T]`` sweep batch.  Formula is token-for-token the
    scalar kernel's — the device datapath's parity guarantee rests on
    the two never diverging."""
    nonempty = queue_len > 0
    psum = xp.sum(xp.where(nonempty, prio, 0.0), axis=-1, keepdims=True)
    lim = xp.ceil(num_pus * prio / xp.maximum(psum, 1e-9) - CEIL_EPS)
    return xp.where(psum > 0, lim, float(num_pus))


def select_lanes(prio, queue_len, cur_occup, total_occup, bvt, num_pus, xp,
                 metric=None):
    """`select` over ``[..., T]`` lanes: one WLBVT decision per leading
    index, -1 where nothing is eligible.  ``metric`` lets round drivers
    hoist the throughput term (constant within a dispatch round — picks
    change only eligibility, never total_occup/bvt/prio)."""
    limit = pu_limit_lanes(prio, queue_len, num_pus, xp)
    eligible = (queue_len > 0) & (cur_occup < limit)
    if metric is None:
        metric = tput(total_occup, bvt, xp) / prio
    masked = xp.where(eligible, metric, BIG)
    idx = xp.argmin(masked, axis=-1)
    any_e = xp.any(eligible, axis=-1)
    return xp.where(any_e, idx, -1)
