"""Matching engine — paper §5.1 step 3: map inbound packets to FMQs by
UDP 3-tuple / TCP 5-tuple; in the serving adaptation, by tenant id."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MatchRule:
    """Wildcard fields are None."""
    src_ip: Optional[int] = None
    dst_ip: Optional[int] = None
    src_port: Optional[int] = None
    dst_port: Optional[int] = None
    proto: str = "udp"

    def matches(self, pkt: dict) -> bool:
        for f in ("src_ip", "dst_ip", "src_port", "dst_port"):
            want = getattr(self, f)
            if want is not None and pkt.get(f) != want:
                return False
        return pkt.get("proto", "udp") == self.proto


class MatchingEngine:
    """Exact-match table with rule priority = installation order."""

    def __init__(self) -> None:
        self._rules: Dict[int, Tuple[MatchRule, int]] = {}
        self._next = 0

    def install(self, rule: MatchRule, fmq_index: int) -> int:
        rid = self._next
        self._next += 1
        self._rules[rid] = (rule, fmq_index)
        return rid

    def remove(self, rule_id: int) -> None:
        self._rules.pop(rule_id, None)

    def match(self, pkt: dict) -> int:
        """Returns FMQ index or -1 (-> conventional NIC path, paper Fig. 2)."""
        for rid in sorted(self._rules):
            rule, fmq = self._rules[rid]
            if rule.matches(pkt):
                return fmq
        return -1
