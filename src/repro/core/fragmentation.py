"""IO transfer fragmentation — paper §5.1 step 5 / §6.2 "Enhanced DMA engine".

Large DMA/egress transfers are split into fragments so small transfers are
never HoL-blocked for more than one fragment's service time.  Two modes:

  * ``software`` — fragmentation in the kernel call: each fragment pays a
    per-fragment issue overhead on the PU (control traffic), which is the
    congestor-throughput cost visible in paper Fig. 10.
  * ``hardware`` — the DMA engine keeps per-transfer state and interleaves
    bursts; per-fragment overhead is a bus-arbitration constant.

The same policy fragments serving-engine prefills (chunked prefill): a 32k
prefill becomes ceil(32k/F) chunks, each a run-to-completion step.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List


@dataclasses.dataclass(frozen=True)
class FragmentationPolicy:
    mode: str = "hardware"            # "off" | "software" | "hardware"
    fragment_bytes: int = 512
    sw_overhead_cycles: int = 95      # per-fragment PU issue cost
    hw_overhead_cycles: int = 2       # per-fragment burst re-arb cost

    @property
    def per_fragment_overhead(self) -> int:
        if self.mode == "software":
            return self.sw_overhead_cycles
        if self.mode == "hardware":
            return self.hw_overhead_cycles
        return 0


@dataclasses.dataclass
class Fragment:
    tenant: int
    transfer_id: int
    seq: int
    nbytes: int
    last: bool


def fragment_transfer(policy: FragmentationPolicy, tenant: int,
                      transfer_id: int, nbytes: int) -> List[Fragment]:
    if policy.mode == "off" or nbytes <= policy.fragment_bytes:
        return [Fragment(tenant, transfer_id, 0, nbytes, True)]
    out, off, seq = [], 0, 0
    F = policy.fragment_bytes
    while off < nbytes:
        n = min(F, nbytes - off)
        out.append(Fragment(tenant, transfer_id, seq, n, off + n >= nbytes))
        off += n
        seq += 1
    return out


def fragment_tokens(total_tokens: int, chunk: int) -> Iterator[tuple]:
    """(offset, length) chunks for a prefill of `total_tokens` tokens."""
    off = 0
    while off < total_tokens:
        n = min(chunk, total_tokens - off)
        yield off, n
        off += n
