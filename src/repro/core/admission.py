"""Static memory admission control — paper §5.2 "sNIC memory segments" +
R3: lightweight allocation, no paging; over-quota ECTX creation errors out.

Used for sNIC L2 segments in the simulator and KV-cache quotas in the
serving engine (both are fixed pools carved into per-tenant segments)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


class AdmissionError(Exception):
    pass


@dataclasses.dataclass
class SegmentAllocator:
    """First-fit static segment allocator over a fixed pool."""
    pool_size: int
    _segments: Dict[int, Tuple[int, int]] = dataclasses.field(
        default_factory=dict)  # tenant -> (offset, size)

    def allocate(self, tenant: int, size: int) -> Tuple[int, int]:
        if size <= 0:
            raise AdmissionError(f"invalid segment size {size}")
        if tenant in self._segments:
            raise AdmissionError(f"tenant {tenant} already has a segment")
        taken = sorted(self._segments.values())
        off = 0
        for s_off, s_size in taken:
            if off + size <= s_off:
                break
            off = max(off, s_off + s_size)
        if off + size > self.pool_size:
            raise AdmissionError(
                f"pool exhausted: need {size} at {off}, pool {self.pool_size}")
        self._segments[tenant] = (off, size)
        return off, size

    def free(self, tenant: int) -> None:
        self._segments.pop(tenant, None)

    def segment(self, tenant: int) -> Optional[Tuple[int, int]]:
        return self._segments.get(tenant)

    def check_access(self, tenant: int, offset: int, nbytes: int) -> bool:
        """PMP-style bounds check (paper §6.1 memory isolation)."""
        seg = self._segments.get(tenant)
        if seg is None:
            return False
        s_off, s_size = seg
        return s_off <= offset and offset + nbytes <= s_off + s_size

    @property
    def used(self) -> int:
        return sum(s for _, s in self._segments.values())
