"""Public jit'd wrappers around the Pallas TPU kernels.

Layout adapters fold model-layout tensors ((B,S,H,D) etc.) into the
kernel-native folded layouts, dispatch to pl.pallas_call, and restore the
model layout.  ``interpret=True`` (automatic on CPU via ``on_cpu()``) runs
the kernel bodies in the Pallas interpreter — the correctness path used by
tests/test_kernels.py against the pure-jnp oracles in ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_folded
from repro.kernels.flash_attention import flash_attention_folded
from repro.kernels.rglru_scan import rglru_scan as _rglru_scan
from repro.kernels.ssd_scan import ssd_scan_folded


@functools.lru_cache(None)
def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def flash_attention(q, k, v, *, scale: float, causal: bool = True,
                    window: int = 0, cap: float = 0.0, bq: int = 128,
                    bk: int = 128, interpret: bool = False) -> jnp.ndarray:
    """q: (B,S,Hq,D); k/v: (B,T,Hkv,D) -> (B,S,Hq,D).  GQA folded: query
    heads of one KV head become extra query rows (position-major)."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = (q.reshape(B, S, Hkv, G, D)
          .transpose(0, 2, 1, 3, 4)          # (B,Hkv,S,G,D)
          .reshape(B * Hkv, S * G, D))
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, T, D)
    out = flash_attention_folded(qf, kf, vf, groups=G, scale=scale,
                                 causal=causal, window=window, cap=cap,
                                 bq=bq, bk=bk, interpret=interpret)
    return (out.reshape(B, Hkv, S, G, D)
            .transpose(0, 2, 1, 3, 4)
            .reshape(B, S, Hq, D))


def decode_attention(q, k, v, lengths, *, scale: float, window: int = 0,
                     cap: float = 0.0, bk: int = 512,
                     interpret: bool = False) -> jnp.ndarray:
    """q: (B,1,Hq,D); k/v: (B,T,Hkv,D); lengths: (B,) -> (B,1,Hq,D)."""
    B, _, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.reshape(B, Hkv, G, D).reshape(B * Hkv, G, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, T, D)
    lens = jnp.repeat(lengths.astype(jnp.int32), Hkv)
    out = decode_attention_folded(qf, kf, vf, lens, scale=scale,
                                  window=window, cap=cap, bk=bk,
                                  interpret=interpret)
    return out.reshape(B, Hkv, G, D).reshape(B, 1, Hq, D)


# ---------------------------------------------------------------------------
# ssd / rglru
# ---------------------------------------------------------------------------
def ssd_scan(x, dt, A_log, B_mat, C_mat, *, chunk: int = 128,
             interpret: bool = False):
    """Model layout: x (B,S,H,P); dt (B,S,H); A_log (H,); B/C (B,S,G,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N)) — matches ssd_scan_ref."""
    Bb, S, H, Pd = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    rep = H // G
    A = -jnp.exp(A_log.astype(jnp.float32))                    # (H,)
    xf = x.transpose(0, 2, 1, 3).reshape(Bb * H, S, Pd)
    dtf = dt.transpose(0, 2, 1).reshape(Bb * H, S).astype(jnp.float32)
    Bf = (jnp.repeat(B_mat, rep, axis=2).transpose(0, 2, 1, 3)
          .reshape(Bb * H, S, N).astype(jnp.float32))
    Cf = (jnp.repeat(C_mat, rep, axis=2).transpose(0, 2, 1, 3)
          .reshape(Bb * H, S, N).astype(jnp.float32))
    Af = jnp.tile(A, Bb)
    y, last = ssd_scan_folded(xf, dtf, Af, Bf, Cf, chunk=chunk,
                              interpret=interpret)
    y = y.reshape(Bb, H, S, Pd).transpose(0, 2, 1, 3)
    return y.astype(x.dtype), last.reshape(Bb, H, Pd, N)


def rglru_scan(a, b, h0=None, *, interpret: bool = False):
    """a, b: (B,S,W) fp32 -> (h (B,S,W), h_last (B,W))."""
    return _rglru_scan(a.astype(jnp.float32), b.astype(jnp.float32), h0,
                       interpret=interpret)
