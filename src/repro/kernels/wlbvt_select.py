"""WLBVT eligibility+select round as a Pallas TPU kernel (DESIGN.md §13.3).

One *dispatch round* of the simulator grants up to ``free_pus`` PU slots:
each pick recomputes eligibility (queue non-empty, occupancy under the
weighted ``pu_limit`` cap) and takes the eligible tenant with the lowest
priority-normalized throughput.  Within a round the throughput metric is
constant — picks move packets from queue to PU, touching only
``queue_len``/``cur_occup`` — so the metric is hoisted and each iteration
is a masked min over the ``[R, T]`` tenant lanes of a replica sweep.

Three value-identical implementations, selected ``attn_impl``-style:

* ``jnp``     — ``lax.while_loop`` with whole-batch early exit: a lane
  that returns -1 can never pick again this round (its state did not
  change), so once every lane stalls the remaining iterations are
  provably all -1 and are skipped.  Default on CPU; used by the device
  datapath inside its ``lax.scan`` step.
* ``jnp_ref`` — dense ``lax.scan`` over all ``max_picks`` iterations.
  The documented reference the Pallas kernel must match bit-exactly.
* ``pallas``  — TPU kernel: 8-row grid blocks over replicas, tenant
  lanes padded to the 128-wide VPU register; ``fori_loop`` over picks
  with the first-argmin computed by the min-index trick (min over lane
  iota where the metric equals its row min — identical tie-break to
  ``argmin``).  f32 lanes; on CPU it runs in the Pallas interpreter.

All three share the formulas in ``core/sched_generic`` (``pu_limit`` /
``select`` are the single source of truth); the equality is pinned by
tests/test_devicepath.py.

Contract: ``prio/total_occup/bvt`` float ``[R, T]``, ``queue_len``/
``cur_occup`` int32 ``[R, T]``, ``free_k`` int32 ``[R]`` (PUs grantable
per replica).  Returns ``(picks [R, max_picks] int32 (-1 = no grant,
trailing -1 padded), queue_len', cur_occup')``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core import sched_generic as G

_BR = 8       # replica rows per grid block (fp32 sublane tile)
_LANES = 128  # tenant lanes per block (VPU register width)


def _one_pick(k, prio, queue_len, cur_occup, total_occup, bvt, metric,
              free_k, num_pus: int):
    """One masked pick across all replica lanes; -1 where nothing is
    eligible or the replica's grantable-PU budget ``free_k`` is spent."""
    idx = G.select_lanes(prio, queue_len, cur_occup, total_occup, bvt,
                         num_pus, jnp, metric=metric)
    can = (idx >= 0) & (k < free_k)
    iv = jnp.where(can, idx, 0)
    lane = lax.broadcasted_iota(jnp.int32, queue_len.shape,
                                queue_len.ndim - 1)
    hot = (lane == iv[..., None]) & can[..., None]
    queue_len = queue_len - hot.astype(queue_len.dtype)
    cur_occup = cur_occup + hot.astype(cur_occup.dtype)
    pick = jnp.where(can, idx, -1).astype(jnp.int32)
    return pick, queue_len, cur_occup


def _rounds_jnp(prio, queue_len, cur_occup, total_occup, bvt, free_k, *,
                num_pus: int, max_picks: int):
    """Early-exit round driver (value-identical to the dense reference)."""
    metric = G.tput(total_occup, bvt, jnp) / prio
    R = queue_len.shape[0]
    if max_picks == 1:     # single-grant fast path: no loop machinery
        pick, ql, co = _one_pick(jnp.int32(0), prio, queue_len, cur_occup,
                                 total_occup, bvt, metric, free_k, num_pus)
        return pick[:, None], ql, co
    picks0 = jnp.full((R, max_picks), -1, jnp.int32)

    def cond(st):
        k, _ql, _co, _picks, alive = st
        return (k < max_picks) & alive

    def body(st):
        k, ql, co, picks, _alive = st
        pick, ql, co = _one_pick(k, prio, ql, co, total_occup, bvt,
                                 metric, free_k, num_pus)
        picks = picks.at[:, k].set(pick)
        return k + 1, ql, co, picks, jnp.any(pick >= 0)

    st = (jnp.int32(0), queue_len, cur_occup, picks0, jnp.asarray(True))
    _, ql, co, picks, _ = lax.while_loop(cond, body, st)
    return picks, ql, co


def wlbvt_select_rounds_ref(prio, queue_len, cur_occup, total_occup, bvt,
                            free_k, *, num_pus: int, max_picks: int):
    """Dense ``lax.scan`` reference — the Pallas kernel's bit-exact
    oracle (tests/test_devicepath.py)."""
    metric = G.tput(total_occup, bvt, jnp) / prio

    def step(carry, k):
        ql, co = carry
        pick, ql, co = _one_pick(k, prio, ql, co, total_occup, bvt,
                                 metric, free_k, num_pus)
        return (ql, co), pick

    ks = jnp.arange(max_picks, dtype=jnp.int32)
    (ql, co), picks = lax.scan(step, (queue_len, cur_occup), ks)
    return jnp.moveaxis(picks, 0, -1), ql, co


# ---------------------------------------------------------------------------
# pallas
# ---------------------------------------------------------------------------
def _select_kernel(prio_ref, ql_ref, co_ref, to_ref, bvt_ref, free_ref,
                   picks_ref, qlo_ref, coo_ref, *, num_pus: int,
                   max_picks: int):
    prio = prio_ref[...]                       # (BR, LANES) float
    to = to_ref[...]
    bvt = bvt_ref[...]
    fk = free_ref[...][:, :1]                  # (BR, 1) int32
    lane = lax.broadcasted_iota(jnp.int32, prio.shape, 1)
    # hoisted: constant within a round (picks touch only ql/co)
    metric0 = (to / jnp.maximum(bvt, 1.0)) / prio

    def body(k, st):
        ql, co, picks = st
        nonempty = ql > 0
        psum = jnp.sum(jnp.where(nonempty, prio, 0.0), axis=1,
                       keepdims=True)
        lim = jnp.ceil(num_pus * prio / jnp.maximum(psum, 1e-9)
                       - G.CEIL_EPS)
        lim = jnp.where(psum > 0, lim, float(num_pus))
        elig = nonempty & (co.astype(prio.dtype) < lim)
        masked = jnp.where(elig, metric0, G.BIG)
        m = jnp.min(masked, axis=1, keepdims=True)
        # first-argmin: min lane index among the row minima
        idx = jnp.min(jnp.where(masked == m, lane, _LANES), axis=1,
                      keepdims=True)
        can = jnp.any(elig, axis=1, keepdims=True) & (k < fk)
        hot = (lane == idx) & can
        ql = ql - hot.astype(ql.dtype)
        co = co + hot.astype(co.dtype)
        picks = jnp.where(lane == k, jnp.where(can, idx, -1), picks)
        return ql, co, picks

    picks0 = jnp.full(prio.shape, -1, jnp.int32)
    ql, co, picks = lax.fori_loop(
        0, max_picks, body, (ql_ref[...], co_ref[...], picks0))
    picks_ref[...] = picks
    qlo_ref[...] = ql
    coo_ref[...] = co


def _rounds_pallas(prio, queue_len, cur_occup, total_occup, bvt, free_k, *,
                   num_pus: int, max_picks: int, interpret: bool = False):
    R, T = prio.shape
    if T > _LANES or max_picks > _LANES:
        raise ValueError(
            f"pallas wlbvt_select supports T<= {_LANES} tenants and "
            f"max_picks <= {_LANES} (got T={T}, max_picks={max_picks})")
    pad_r = (-R) % _BR
    pad_t = _LANES - T
    Rp = R + pad_r
    # pad lanes are inert: queue_len 0 => never nonempty, never eligible
    prio_p = jnp.pad(prio, ((0, pad_r), (0, pad_t)), constant_values=1.0)
    ql_p = jnp.pad(queue_len, ((0, pad_r), (0, pad_t)))
    co_p = jnp.pad(cur_occup, ((0, pad_r), (0, pad_t)))
    to_p = jnp.pad(total_occup, ((0, pad_r), (0, pad_t)))
    bvt_p = jnp.pad(bvt, ((0, pad_r), (0, pad_t)))
    free_p = jnp.broadcast_to(
        jnp.pad(free_k.astype(jnp.int32), (0, pad_r))[:, None],
        (Rp, _LANES))
    kernel = functools.partial(_select_kernel, num_pus=num_pus,
                               max_picks=max_picks)
    spec = pl.BlockSpec((_BR, _LANES), lambda i: (i, 0))
    picks, ql, co = pl.pallas_call(
        kernel,
        grid=(Rp // _BR,),
        in_specs=[spec] * 6,
        out_specs=[spec] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((Rp, _LANES), jnp.int32),
            jax.ShapeDtypeStruct((Rp, _LANES), queue_len.dtype),
            jax.ShapeDtypeStruct((Rp, _LANES), cur_occup.dtype),
        ],
        interpret=interpret,
    )(prio_p, ql_p, co_p, to_p, bvt_p, free_p)
    return picks[:R, :max_picks], ql[:R, :T], co[:R, :T]


def wlbvt_select_rounds(prio, queue_len, cur_occup, total_occup, bvt,
                        free_k, *, num_pus: int, max_picks: int,
                        impl: str = "", interpret: bool = False):
    """Backend switch (``attn_impl`` idiom): '' picks pallas on TPU and
    the early-exit jnp path elsewhere."""
    if not impl:
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "jnp":
        return _rounds_jnp(prio, queue_len, cur_occup, total_occup, bvt,
                           free_k, num_pus=num_pus, max_picks=max_picks)
    if impl == "jnp_ref":
        return wlbvt_select_rounds_ref(prio, queue_len, cur_occup,
                                       total_occup, bvt, free_k,
                                       num_pus=num_pus,
                                       max_picks=max_picks)
    if impl == "pallas":
        return _rounds_pallas(prio, queue_len, cur_occup, total_occup, bvt,
                              free_k, num_pus=num_pus, max_picks=max_picks,
                              interpret=interpret
                              or jax.default_backend() == "cpu")
    raise ValueError(f"unknown wlbvt_select impl {impl!r} "
                     "(expected jnp | jnp_ref | pallas)")
