"""FlashAttention forward Pallas TPU kernel.

TPU adaptation of the memory-hierarchy insight: stream K/V blocks from
HBM through VMEM while the (bq, d) query block and the (bq, d) fp32
accumulator stay resident in VMEM; the online-softmax running max/sum
avoids materializing the S×T score matrix.  The innermost grid axis (KV
blocks) is sequential on TPU, so the accumulator lives in VMEM scratch
across iterations.  Supports GQA (query-head folding), causal masking,
sliding window, and gemma2 logit soft-capping.  Fully-masked KV blocks
are skipped via @pl.when (no MXU work for the upper triangle).

Block shapes default to (128, 128): MXU-aligned (multiples of 128) and
VMEM-sized — q/k/v/acc blocks at d=256 occupy ~0.5 MiB of the ~128 MiB
VMEM budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
                  scale: float, causal: bool, window: int, cap: float,
                  bq: int, bk: int, seq_q: int, seq_k: int, groups: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q_start = iq * bq
    k_start = ik * bk
    # block-level reachability: skip fully-masked KV blocks (positions are
    # row // groups in the GQA-folded layout)
    q_lo = q_start // groups
    q_hi = (q_start + bq - 1) // groups
    reachable = True
    if causal:
        reachable = k_start <= q_hi
    if window:
        in_window = q_lo - (k_start + bk - 1) < window
        reachable = jnp.logical_and(reachable, in_window) \
            if causal else in_window

    @pl.when(reachable if not isinstance(reachable, bool) else True)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if cap:
            s = cap * jnp.tanh(s / cap)
        qrow = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        qpos = qrow // groups          # folded rows are position-major
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = (kpos < seq_k)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new) * mask
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_s[...] = m_new
        acc[...] = acc[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v).astype(jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("groups", "scale", "causal", "window", "cap", "bq",
                     "bk", "interpret"))
def flash_attention_folded(q, k, v, *, groups: int = 1, scale: float,
                           causal: bool = True,
                           window: int = 0, cap: float = 0.0, bq: int = 128,
                           bk: int = 128, interpret: bool = False):
    """q: (BHkv, S*G, D) with G query rows per position (GQA-folded,
    position-major: row = s*G + g, so the causal mask uses row // G);
    k/v: (BHkv, T, D).  Returns (BHkv, S*G, D)."""
    BH, SG, D = q.shape
    T = k.shape[1]
    seq_q = SG // groups
    bq_ = min(bq, SG)
    bk_ = min(bk, T)
    nq = -(-SG // bq_)
    nk = -(-T // bk_)
    pad_q = nq * bq_ - SG
    pad_k = nk * bk_ - T
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window, cap=cap,
        bq=bq_, bk=bk_, seq_q=SG, seq_k=T, groups=groups)
    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq_, D), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk_, D), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, bk_, D), lambda bh, iq, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, D), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nq * bq_, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, D), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :SG]


flash_attention_folded.groups = 1  # set by ops.flash_attention per call
