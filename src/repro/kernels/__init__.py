"""Pallas TPU kernels for the compute hot-spots (ops.py = jit'd wrappers,
ref.py = pure-jnp oracles; every kernel validated in interpret mode)."""
