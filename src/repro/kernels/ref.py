"""Pure-jnp oracles for every Pallas kernel (sequential/naive forms —
the strongest possible references; kernels must match these allclose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, scale: float, causal: bool = True,
                        window: int = 0, cap: float = 0.0) -> jnp.ndarray:
    """q: (B,S,Hq,D), k/v: (B,T,Hkv,D) -> (B,S,Hq,D).  Full-matrix softmax."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = (q.astype(jnp.float32) * scale).reshape(B, S, Hkv, G, D)
    s = jnp.einsum("bshgd,bthd->bshgt", qf, k.astype(jnp.float32))
    if cap:
        s = cap * jnp.tanh(s / cap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bshgt,bthd->bshgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, Hq, D).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths, *, scale: float, window: int = 0,
                         cap: float = 0.0) -> jnp.ndarray:
    """q: (B,1,Hq,D); k/v: (B,T,Hkv,D); lengths: (B,) valid cache entries.
    Query position = lengths (appended token)."""
    B, _, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bthd->bhgt", qf, k.astype(jnp.float32))
    if cap:
        s = cap * jnp.tanh(s / cap)
    kpos = jnp.arange(T)[None, :]
    mask = kpos < lengths[:, None]
    if window:
        mask &= lengths[:, None] - kpos <= window
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = p * mask[:, None, None, :]
    o = jnp.einsum("bhgt,bthd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, 1, Hq, D).astype(q.dtype)


def ssd_scan_ref(x, dt, A_log, B_mat, C_mat,
                 init_state=None):
    """Sequential SSD recurrence (mamba2 §sec 3): the oracle.

    x: (B,S,H,P); dt: (B,S,H); A_log: (H,); B_mat/C_mat: (B,S,G,N).
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t x_t;  y_t = C_t . h_t.
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bb, S, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    rep = H // G
    A = -jnp.exp(A_log.astype(jnp.float32))
    Bf = jnp.repeat(B_mat.astype(jnp.float32), rep, axis=2)
    Cf = jnp.repeat(C_mat.astype(jnp.float32), rep, axis=2)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, t):
        xt, dtt, Bt, Ct = t
        decay = jnp.exp(dtt * A[None, :])[..., None, None]      # (B,H,1,1)
        h = h * decay + jnp.einsum("bhn,bh,bhp->bhpn", Bt, dtt, xt)
        y = jnp.einsum("bhn,bhpn->bhp", Ct, h)
        return h, y

    h0 = (jnp.zeros((Bb, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    ts = (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
          Bf.swapaxes(0, 1), Cf.swapaxes(0, 1))
    h, ys = jax.lax.scan(step, h0, ts)
    return ys.swapaxes(0, 1).astype(x.dtype), h


def rglru_scan_ref(a, b, h0=None):
    """Sequential linear recurrence h_t = a_t h_{t-1} + b_t.
    a, b: (B,S,W) fp32.  Returns (h (B,S,W), h_last (B,W))."""
    B, S, W = a.shape

    def step(h, t):
        at, bt = t
        h = at * h + bt
        return h, h

    h0 = jnp.zeros((B, W), jnp.float32) if h0 is None else h0
    h, ys = jax.lax.scan(step, h0, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), h
