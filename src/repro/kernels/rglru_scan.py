"""RG-LRU linear-recurrence Pallas TPU kernel: h_t = a_t * h_{t-1} + b_t.

The recurrence is diagonal (per-channel), so channels map onto VPU lanes:
the grid tiles (channels / bw) x (sequence / bs) with the sequence axis
innermost (sequential); the (1, bw) carry lives in VMEM scratch.  Inside a
block a fori_loop steps bs rows — each step is one (bw,)-wide VPU fma —
while the next (bs, bw) tile streams from HBM.  This is the TPU analogue
of the fused CUDA linear-scan: the carry never leaves registers/VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, hlast_ref, carry, *,
                  bs: int):
    isq = pl.program_id(2)
    nsq = pl.num_programs(2)

    @pl.when(isq == 0)
    def _init():
        carry[...] = h0_ref[0][None, :]

    a = a_ref[0]                              # (bs, bw) fp32
    b = b_ref[0]

    def step(i, h):
        h = a[i][None, :] * h + b[i][None, :]
        y_ref[0, i, :] = h[0]
        return h

    carry[...] = jax.lax.fori_loop(0, bs, step, carry[...])

    @pl.when(isq == nsq - 1)
    def _final():
        hlast_ref[0] = carry[0]


@functools.partial(jax.jit, static_argnames=("bs", "bw", "interpret"))
def rglru_scan(a, b, h0=None, *, bs: int = 256, bw: int = 128,
               interpret: bool = False):
    """a, b: (B, S, W) fp32; h0: (B, W) initial state.
    Returns (h (B,S,W), h_last (B,W)) — matches ref.rglru_scan_ref."""
    B, S, W = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)
    bs_ = min(bs, S)
    bw_ = min(bw, W)
    pad_s = (-S) % bs_
    pad_w = (-W) % bw_
    if pad_s or pad_w:
        # a=1, b=0 padding is the identity recurrence (inert)
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_w)),
                    constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, pad_w)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_w)))
    Sp, Wp = S + pad_s, W + pad_w

    kernel = functools.partial(_rglru_kernel, bs=bs_)
    h, hlast = pl.pallas_call(
        kernel,
        grid=(B, Wp // bw_, Sp // bs_),        # seq axis innermost/sequential
        in_specs=[
            pl.BlockSpec((1, bs_, bw_), lambda ib, iw, isq: (ib, isq, iw)),
            pl.BlockSpec((1, bs_, bw_), lambda ib, iw, isq: (ib, isq, iw)),
            pl.BlockSpec((1, bw_), lambda ib, iw, isq: (ib, iw)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs_, bw_), lambda ib, iw, isq: (ib, isq, iw)),
            pl.BlockSpec((1, bw_), lambda ib, iw, isq: (ib, iw)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sp, Wp), jnp.float32),
            jax.ShapeDtypeStruct((B, Wp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bw_), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return h[:, :S, :W], hlast[:, :W]
