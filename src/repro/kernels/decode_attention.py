"""Flash-decode Pallas TPU kernel: one query token against a long KV cache.

TPU adaptation: the KV cache streams HBM->VMEM in (bk, d) blocks along the
sequential innermost grid axis while the single query row and the fp32
online-softmax accumulator stay VMEM-resident.  GQA query heads for the
same KV head are folded into the row dimension of the query block, so the
MXU sees a (G, d) x (d, bk) matmul instead of G rank-1 products.  Cache
validity comes from per-row ``lengths`` (kpos < length) — the ring-buffer
semantics of the serving engine — plus an optional sliding window.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
                   scale: float, window: int, cap: float, bk: int,
                   seq_k: int):
    ik = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    length = len_ref[pl.program_id(0)]                   # this row's fill
    k_start = ik * bk

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (G, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, bk)
        if cap:
            s = cap * jnp.tanh(s / cap)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (kpos < length) & (kpos < seq_k)
        if window:
            mask &= (length - kpos) <= window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new) * mask
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_s[...] = m_new
        acc[...] = acc[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v).astype(jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)).astype(
            o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "window", "cap", "bk", "interpret"))
def decode_attention_folded(q, k, v, lengths, *, scale: float,
                            window: int = 0, cap: float = 0.0,
                            bk: int = 512, interpret: bool = False):
    """q: (BHkv, G, D) folded GQA query rows; k/v: (BHkv, T, D);
    lengths: (BHkv,) int32 valid cache entries per row.
    Returns (BHkv, G, D)."""
    BH, G, D = q.shape
    T = k.shape[1]
    bk_ = min(bk, T)
    nk = -(-T // bk_)
    pad_k = nk * bk_ - T
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               cap=cap, bk=bk_, seq_k=T)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, nk),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda bh, ik, lens: (bh, 0, 0)),
            pl.BlockSpec((1, bk_, D), lambda bh, ik, lens: (bh, ik, 0)),
            pl.BlockSpec((1, bk_, D), lambda bh, ik, lens: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda bh, ik, lens: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )

    def _index_lengths(bh, ik, lens):  # pragma: no cover (spec helper)
        return lens

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, G, D), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k, v)
    return out
