"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

TPU adaptation of the SSD block decomposition (arXiv:2405.21060 §6): the
sequence is tiled into (Q, ·) chunks; each grid step computes the
intra-chunk quadratic term on the MXU ((Q,N)x(N,Q) then (Q,Q)x(Q,P)) and
carries the (P,N) inter-chunk state in VMEM scratch across the sequential
chunk axis — the recurrence never round-trips to HBM.  Grid =
(B*H, n_chunks); chunk axis innermost (sequential on TPU).

All recurrence math runs in fp32 on the VPU/MXU; inputs may be bf16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, last_ref,
                state, *, Q: int, P: int, N: int):
    ic = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ic == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, 1)
    a = a_ref[0, 0]                           # scalar A (this head)
    Bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)         # (Q, N)

    dA = dt[:, 0] * a                                          # (Q,)
    csum = jnp.cumsum(dA)                                      # (Q,)
    # L[i,j] = exp(sum_{k=j+1..i} dA_k) for j<=i  (segment sums);
    # mask BEFORE exp: above-diagonal segment sums are positive (dA<0)
    # and would overflow for long chunks.
    seg = csum[:, None] - csum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    Lmat = jnp.exp(jnp.where(jj <= ii, seg, -jnp.inf))         # (Q, Q)

    # intra-chunk: (C L) (dt * B)^T x
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ()))) * Lmat
    xw = x * dt                                                # (Q, P)
    y_intra = jax.lax.dot(scores.astype(xw.dtype), xw)         # (Q, P)

    # inter-chunk: y += (C decay_in) . state
    decay_in = jnp.exp(csum)[:, None]                          # (Q, 1)
    y_inter = jax.lax.dot((Cm * decay_in).astype(jnp.float32),
                          state[...].swapaxes(0, 1))           # (Q, P)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: state = state * exp(sum dA) + (B*decay_out*dt)^T x
    total = jnp.exp(csum[-1])
    decay_out = jnp.exp(csum[-1] - csum)[:, None]              # (Q, 1)
    contrib = jax.lax.dot_general(
        x, Bm * (decay_out * dt), (((0,), (0,)), ((), ())))    # (P, N)
    state[...] = state[...] * total + contrib

    @pl.when(ic == nc - 1)
    def _final():
        last_ref[0] = state[...].astype(last_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_folded(x, dt, A, B_mat, C_mat, *, chunk: int = 128,
                    interpret: bool = False):
    """x: (BH, S, P); dt: (BH, S); A: (BH,); B/C: (BH, S, N)
    (heads pre-folded into the batch dim, groups pre-broadcast).
    Returns (y (BH, S, P), final_state (BH, P, N))."""
    BH, S, Pd = x.shape
    N = B_mat.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:  # dt=0 padding is inert (unit decay, zero contribution)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
        B_mat = jnp.pad(B_mat, ((0, 0), (0, pad), (0, 0)))
        C_mat = jnp.pad(C_mat, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    kernel = functools.partial(_ssd_kernel, Q=Q, P=Pd, N=N)
    y, last = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, Q, Pd), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, Q, 1), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, 1), lambda bh, ic: (bh, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, ic: (bh, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, Pd), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, Pd, N), lambda bh, ic: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sp, Pd), x.dtype),
            jax.ShapeDtypeStruct((BH, Pd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Pd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt[..., None], A[:, None], B_mat, C_mat)
    return y[:, :S], last
