"""Mixture-of-Experts FFN with two dispatch implementations.

``gshard`` — capacity-based one-hot dispatch/combine einsums.  Shards
cleanly under automatic SPMD (experts over the ``model`` axis => all-to-all)
but its dispatch matmuls are O(T²) HLO FLOPs — this is the paper-faithful
*baseline* for the MoE roofline cells.

``ragged`` — sort tokens by expert, grouped matmul via ``jax.lax.ragged_dot``.
O(T·k·d·f) FLOPs; the beyond-paper optimized path (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

MOE_IMPL = ("gshard", "ragged")


def init_moe(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    pd = L.pdtype_of(cfg)
    d, f, E = cfg.d_model, m.expert_d_ff, m.num_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * 0.02).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, f), jnp.float32) * scale).astype(pd),
        "w_up": (jax.random.normal(ks[2], (E, d, f), jnp.float32) * scale).astype(pd),
        "w_down": (jax.random.normal(ks[3], (E, f, d), jnp.float32) / jnp.sqrt(f)).astype(pd),
    }
    if m.num_shared_experts:
        p["shared"] = L.init_mlp(ks[4], d, m.num_shared_experts * f, pd)
    return p


def router_topk(params, x2d: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x2d: (T, d) -> (weights (T,k), experts (T,k) int32, aux_loss scalar)."""
    m = cfg.moe
    logits = x2d.astype(jnp.float32) @ params["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, m.top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balancing auxiliary loss
    T = x2d.shape[0]
    me = jnp.mean(probs, axis=0)                                  # (E,)
    ce = jnp.sum(jax.nn.one_hot(idx[:, 0], m.num_experts), axis=0) / T
    aux = m.num_experts * jnp.sum(me * ce)
    return w, idx, aux


def _expert_ffn(w_gate, w_up, w_down, h, act: str):
    """h: (g, E, C, d) grouped tokens vs stacked expert weights (E, d, f)."""
    g = L.act_fn(act)(jnp.einsum("gecd,edf->gecf", h, w_gate))
    u = jnp.einsum("gecd,edf->gecf", h, w_up)
    return jnp.einsum("gecf,efd->gecd", g * u, w_down)


def apply_moe_gshard(params, x: jnp.ndarray, cfg: ModelConfig,
                     capacity_factor: float = 0.0,
                     group_size: int = 2048):
    """Grouped capacity-based dispatch (GShard).  x: (B,S,d) -> (B,S,d).

    Tokens are dispatched within fixed-size *groups* (the GShard
    formulation): the position-in-expert cumsum and the capacity C are
    per-group, so the (t,e,c) dispatch/combine tensors stay
    O(group x E x C) instead of O(T x E x C) — with global capacity the
    dispatch matmuls cost ~E.C/(k.3.f) = 300x the expert FFN at 1M-token
    prefills (EXPERIMENTS.md §Perf, MoE hillclimb).  Groups ride the DP
    axes (g over 'data', experts over 'model' => all-to-all dispatch).
    """
    m = cfg.moe
    B, S, d = x.shape
    dt = x.dtype
    T = B * S
    x2d = x.reshape(T, d)
    w, idx, aux = router_topk(params, x2d, cfg)
    cf = capacity_factor or m.capacity_factor

    Gsz = min(group_size, T)
    nG = -(-T // Gsz)
    pad = nG * Gsz - T
    if pad:  # padded tokens: keep=False via zero weights / expert -1
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
        w = jnp.pad(w, ((0, pad), (0, 0)))
        idx = jnp.pad(idx, ((0, pad), (0, 0)), constant_values=-1)
    C = max(1, int(Gsz * m.top_k * cf / m.num_experts))

    xg = x2d.reshape(nG, Gsz, d)
    idxg = idx.reshape(nG, Gsz, m.top_k)
    wg = w.reshape(nG, Gsz, m.top_k)
    from repro.distributed.sharding import constrain_acts
    xg = constrain_acts(xg)

    # position of each (token, choice) inside its expert queue, per group
    onehot = jax.nn.one_hot(idxg, m.num_experts, dtype=jnp.int32)  # (g,t,k,E)
    flat = onehot.reshape(nG, Gsz * m.top_k, m.num_experts)
    pos = jnp.cumsum(flat, axis=1) * flat - 1                      # (g,tk,E)
    pos_in_e = jnp.max(pos.reshape(nG, Gsz, m.top_k, m.num_experts),
                       axis=-1)                                    # (g,t,k)
    keep = (pos_in_e < C) & (idxg >= 0)
    wk = wg * keep

    e_oh = jax.nn.one_hot(idxg, m.num_experts, dtype=dt)           # (g,t,k,E)
    c_oh = jax.nn.one_hot(jnp.clip(pos_in_e, 0, C - 1), C, dtype=dt)
    dispatch = jnp.einsum("gtke,gtkc->gtec",
                          e_oh * keep[..., None].astype(dt), c_oh)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", e_oh, c_oh,
                         wk.astype(dt))

    h = jnp.einsum("gtec,gtd->gecd", dispatch, xg)                 # (g,E,C,d)
    out_e = _expert_ffn(params["w_gate"].astype(dt),
                        params["w_up"].astype(dt),
                        params["w_down"].astype(dt), h, cfg.mlp_act)
    y = jnp.einsum("gtec,gecd->gtd", combine, out_e)
    y = y.reshape(nG * Gsz, d)[:T].reshape(B, S, d)
    if m.num_shared_experts:
        y = y + L.apply_mlp(params["shared"], x, cfg.mlp_act)
    return y, aux


def apply_moe_ragged(params, x: jnp.ndarray, cfg: ModelConfig):
    """Sort + ragged_dot grouped matmul (optimized).  x: (B,S,d)."""
    m = cfg.moe
    B, S, d = x.shape
    dt = x.dtype
    T = B * S
    x2d = x.reshape(T, d)
    w, idx, aux = router_topk(params, x2d, cfg)

    flat_e = idx.reshape(-1)                                        # (T*k,)
    order = jnp.argsort(flat_e)
    tok = jnp.repeat(jnp.arange(T), m.top_k)[order]                 # source row
    xs = x2d[tok]                                                   # (T*k, d)
    group_sizes = jnp.bincount(flat_e, length=m.num_experts).astype(jnp.int32)

    g = L.act_fn(cfg.mlp_act)(
        jax.lax.ragged_dot(xs, params["w_gate"].astype(dt), group_sizes))
    u = jax.lax.ragged_dot(xs, params["w_up"].astype(dt), group_sizes)
    o = jax.lax.ragged_dot(g * u, params["w_down"].astype(dt), group_sizes)

    wsorted = w.reshape(-1)[order].astype(dt)                       # (T*k,)
    y = jnp.zeros((T, d), dt).at[tok].add(o * wsorted[:, None])
    y = y.reshape(B, S, d)
    if m.num_shared_experts:
        y = y + L.apply_mlp(params["shared"], x, cfg.mlp_act)
    return y, aux


def apply_moe(params, x, cfg: ModelConfig, impl: str = "gshard"):
    if impl == "ragged":
        return apply_moe_ragged(params, x, cfg)
    return apply_moe_gshard(params, x, cfg)
