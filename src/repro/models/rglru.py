"""RG-LRU recurrent block (Griffin / RecurrentGemma). [arXiv:2402.19427]

Training/prefill parallelizes the linear recurrence with
``jax.lax.associative_scan``; decode is the O(1) update.  Gates are
diagonal (per-channel) rather than block-diagonal — documented deviation
(DESIGN.md §5); a Pallas linear-scan kernel lives in kernels/rglru_scan.py.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

_C = 8.0  # Griffin's fixed gate temperature


def init_rglru(key, cfg: ModelConfig) -> dict:
    w = cfg.lru_width or cfg.d_model
    pd = L.pdtype_of(cfg)
    ks = jax.random.split(key, 3)
    return {
        "w_x": L.dense_init(ks[0], cfg.d_model, w, pd),
        "w_gate": L.dense_init(ks[1], cfg.d_model, w, pd),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv1d_width, w), jnp.float32)
                   * (1.0 / jnp.sqrt(cfg.conv1d_width))).astype(pd),
        "conv_b": jnp.zeros((w,), pd),
        "lambda_": jnp.full((w,), 2.0, jnp.float32),   # softplus^-1-ish init
        "a_gate_w": jnp.ones((w,), jnp.float32),
        "a_gate_b": jnp.zeros((w,), jnp.float32),
        "i_gate_w": jnp.ones((w,), jnp.float32),
        "i_gate_b": jnp.zeros((w,), jnp.float32),
        "w_out": L.dense_init(jax.random.fold_in(key, 7), w, cfg.d_model, pd),
    }


def _conv(x, w, b, state, valid_n=None):
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)
    y = sum(xx[:, i:i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
            for i in range(W))
    if valid_n is None:
        new_state = xx[:, -(W - 1):, :]
    else:  # ragged chunk: state ends at the last *valid* token
        idx = valid_n[:, None] + jnp.arange(W - 1)[None, :]
        new_state = jnp.take_along_axis(xx, idx[..., None], axis=1)
    return y + b.astype(x.dtype), new_state


def _gates(params, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * params["a_gate_w"] + params["a_gate_b"])
    i = jax.nn.sigmoid(uf * params["i_gate_w"] + params["i_gate_b"])
    log_a = -_C * jax.nn.softplus(params["lambda_"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, b


def init_rglru_cache(cfg: ModelConfig, batch: int) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), L.dtype_of(cfg)),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def rglru_block(params, x, cfg: ModelConfig,
                cache: Optional[dict] = None,
                valid: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: (B,S,d) -> (B,S,d).  ``valid`` (B,S): pad tokens get a=1, b=0
    (identity recurrence) so ragged chunk tails are exactly inert."""
    dt = x.dtype
    gate = jax.nn.gelu(x @ params["w_gate"].astype(dt), approximate=True)
    u = x @ params["w_x"].astype(dt)
    conv_state = cache["conv"] if cache is not None else None
    vn = valid.sum(-1).astype(jnp.int32) if valid is not None else None
    u, new_conv = _conv(u, params["conv_w"], params["conv_b"], conv_state,
                        valid_n=vn)
    a, b = _gates(params, u)                     # (B,S,w) fp32
    if valid is not None:
        v = valid[..., None]
        a = jnp.where(v, a, 1.0)
        b = jnp.where(v, b, 0.0)

    if cache is not None and x.shape[1] == 1:
        h = a[:, 0] * cache["h"] + b[:, 0]
        hs = h[:, None]
        new_cache = {"conv": new_conv, "h": h}
    else:
        def combine(l, r):
            return (r[0] * l[0], r[0] * l[1] + r[1])
        A, Bc = jax.lax.associative_scan(combine, (a, b), axis=1)
        hs = Bc
        if cache is not None:
            hs = hs + A * cache["h"][:, None, :]
            new_cache = {"conv": new_conv, "h": hs[:, -1]}
        else:
            new_cache = None
    out = (gate * hs.astype(dt)) @ params["w_out"].astype(dt)
    return out, new_cache
