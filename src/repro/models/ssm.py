"""Mamba-2 SSD (state-space duality) block. [arXiv:2405.21060]

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic term
+ inter-chunk linear recurrence, lax.scan over chunks) — the TPU-native
adaptation of the paper's block decomposition; a Pallas kernel version
lives in kernels/ssd_scan.py.  Decode is the O(1) recurrent update.

Projections are kept *separate* (w_z / w_x / w_B / w_C / w_dt + per-stream
depthwise convs) rather than one fused in_proj: head-aligned output dims
(d_in, nheads) then shard cleanly over the ``model`` mesh axis (Mamba TP),
while the small shared B/C streams stay replicated.  Same parameter count
as the fused form.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    gn = s.n_groups * s.state_dim
    return s, d_in, nheads, gn


def init_ssd(key, cfg: ModelConfig) -> dict:
    s, d_in, nheads, gn = _dims(cfg)
    pd = L.pdtype_of(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 9)
    return {
        "w_z": L.dense_init(ks[0], d, d_in, pd),
        "w_x": L.dense_init(ks[1], d, d_in, pd),
        "w_B": L.dense_init(ks[2], d, gn, pd),
        "w_C": L.dense_init(ks[3], d, gn, pd),
        "w_dt": L.dense_init(ks[4], d, nheads, pd),
        "conv_x_w": (jax.random.normal(ks[5], (s.conv_dim, d_in), jnp.float32)
                     * (1.0 / jnp.sqrt(s.conv_dim))).astype(pd),
        "conv_x_b": jnp.zeros((d_in,), pd),
        "conv_B_w": (jax.random.normal(ks[6], (s.conv_dim, gn), jnp.float32)
                     * (1.0 / jnp.sqrt(s.conv_dim))).astype(pd),
        "conv_B_b": jnp.zeros((gn,), pd),
        "conv_C_w": (jax.random.normal(ks[7], (s.conv_dim, gn), jnp.float32)
                     * (1.0 / jnp.sqrt(s.conv_dim))).astype(pd),
        "conv_C_b": jnp.zeros((gn,), pd),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "gate_norm": jnp.zeros((d_in,), pd),
        "out_proj": L.dense_init(ks[8], d_in, d, pd),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None, act: bool = True,
                 valid_n: Optional[jnp.ndarray] = None):
    """x: (B,S,C); w: (W,C) depthwise.  Returns (y, new_state (B,W-1,C)).

    ``valid_n`` (B,): only the first valid_n tokens of each row are real
    (ragged chunked prefill) — the carried state then ends at the last
    *valid* token instead of the last position.
    """
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)              # (B, S+W-1, C)
    y = sum(xx[:, i:i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
            for i in range(W))
    if W > 1:
        if valid_n is None:
            new_state = xx[:, -(W - 1):, :]
        else:
            idx = valid_n[:, None] + jnp.arange(W - 1)[None, :]   # (B,W-1)
            new_state = jnp.take_along_axis(xx, idx[..., None], axis=1)
    else:  # pragma: no cover
        new_state = state
    y = y + b.astype(x.dtype)
    return (jax.nn.silu(y) if act else y), new_state


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., Q) -> (..., Q, Q) cumulative segment sums, -inf above diag."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, A, B_mat, C_mat, chunk: int,
                init_state: Optional[jnp.ndarray] = None):
    """Chunked SSD scan.

    x: (B,S,H,P); dt: (B,S,H); A: (H,); B_mat/C_mat: (B,S,G,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N)).  fp32 internally.
    """
    Bb, S_in, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    Q = min(chunk, S_in)
    pad = (-S_in) % Q
    if pad:  # zero dt => zero decay/contribution: padding is inert
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_mat = jnp.pad(B_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_mat = jnp.pad(C_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S = S_in + pad
    nc = S // Q
    rep = H // G

    xf = x.astype(jnp.float32).reshape(Bb, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bb, nc, Q, H)
    Bf = jnp.repeat(B_mat.astype(jnp.float32), rep, axis=2).reshape(Bb, nc, Q, H, N)
    Cf = jnp.repeat(C_mat.astype(jnp.float32), rep, axis=2).reshape(Bb, nc, Q, H, N)
    dA = dtf * (-jnp.exp(A.astype(jnp.float32)))[None, None, None, :]  # (B,nc,Q,H)

    def body(state, xs):
        xc, dtc, Bc, Cc, dAc = xs          # (B,Q,H,P) (B,Q,H) (B,Q,H,N) ...
        dAc_h = dAc.swapaxes(1, 2)          # (B,H,Q)
        Lmat = jnp.exp(_segsum(dAc_h))      # (B,H,Q,Q)
        # intra-chunk (quadratic within the chunk)
        scores = jnp.einsum("bqhn,bkhn->bhqk", Cc, Bc) * Lmat
        y_intra = jnp.einsum("bhqk,bkh,bkhp->bqhp", scores, dtc, xc)
        # contribution of the carried state
        decay_in = jnp.exp(jnp.cumsum(dAc_h, axis=-1))       # (B,H,Q)
        y_inter = jnp.einsum("bqhn,bhpn,bhq->bqhp", Cc, state, decay_in)
        # new carried state
        decay_out = jnp.exp(jnp.cumsum(dAc_h[..., ::-1], axis=-1)[..., ::-1]
                            - dAc_h)                          # exp(sum_{j>i} dA)
        new_state = state * jnp.exp(jnp.sum(dAc_h, axis=-1))[..., None, None] \
            + jnp.einsum("bqhn,bhq,bqh,bqhp->bhpn", Bc, decay_out, dtc, xc)
        return new_state, y_intra + y_inter

    state0 = (jnp.zeros((Bb, H, P, N), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))
    xs = (xf.swapaxes(0, 1), dtf.swapaxes(0, 1), Bf.swapaxes(0, 1),
          Cf.swapaxes(0, 1), dA.swapaxes(0, 1))
    final_state, ys = jax.lax.scan(body, state0, xs)
    y = ys.swapaxes(0, 1).reshape(Bb, S, H, P)[:, :S_in]
    return y.astype(x.dtype), final_state


def ssd_decode_step(x, dt, A, B_mat, C_mat, state):
    """One-token recurrent update.  x: (B,1,H,P); state: (B,H,P,N)."""
    xf = x.astype(jnp.float32)[:, 0]                     # (B,H,P)
    dtf = dt.astype(jnp.float32)[:, 0]                   # (B,H)
    rep = xf.shape[1] // B_mat.shape[2]
    Bf = jnp.repeat(B_mat.astype(jnp.float32), rep, axis=2)[:, 0]  # (B,H,N)
    Cf = jnp.repeat(C_mat.astype(jnp.float32), rep, axis=2)[:, 0]
    dA = jnp.exp(dtf * (-jnp.exp(A.astype(jnp.float32)))[None, :])  # (B,H)
    new_state = state * dA[..., None, None] + jnp.einsum(
        "bhn,bh,bhp->bhpn", Bf, dtf, xf)
    y = jnp.einsum("bhn,bhpn->bhp", Cf, new_state)
    return y[:, None].astype(x.dtype), new_state


def init_ssd_cache(cfg: ModelConfig, batch: int) -> dict:
    s, d_in, nheads, gn = _dims(cfg)
    dt = L.dtype_of(cfg)
    return {
        "conv_x": jnp.zeros((batch, s.conv_dim - 1, d_in), dt),
        "conv_B": jnp.zeros((batch, s.conv_dim - 1, gn), dt),
        "conv_C": jnp.zeros((batch, s.conv_dim - 1, gn), dt),
        "state": jnp.zeros((batch, nheads, s.head_dim, s.state_dim),
                           jnp.float32),
    }


def ssd_block(params, x, cfg: ModelConfig,
              cache: Optional[dict] = None,
              valid: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: (B,S,d) -> (B,S,d).  cache None => training/prefill-from-zero.

    ``valid`` (B,S) bool: padding tokens (ragged chunk tails) get dt=0 —
    zero state contribution *and* unit decay, so they are exactly inert."""
    s, d_in, nheads, gn = _dims(cfg)
    B, S, d = x.shape
    dt_ = x.dtype
    z = x @ params["w_z"].astype(dt_)
    xs_ = x @ params["w_x"].astype(dt_)
    B_in = x @ params["w_B"].astype(dt_)
    C_in = x @ params["w_C"].astype(dt_)
    dt_raw = x @ params["w_dt"].astype(dt_)

    cx = cache["conv_x"] if cache is not None else None
    cB = cache["conv_B"] if cache is not None else None
    cC = cache["conv_C"] if cache is not None else None
    vn = valid.sum(-1).astype(jnp.int32) if valid is not None else None
    xs_, new_cx = _causal_conv(xs_, params["conv_x_w"], params["conv_x_b"],
                               cx, valid_n=vn)
    B_in, new_cB = _causal_conv(B_in, params["conv_B_w"], params["conv_B_b"],
                                cB, valid_n=vn)
    C_in, new_cC = _causal_conv(C_in, params["conv_C_w"], params["conv_C_b"],
                                cC, valid_n=vn)

    xs = xs_.reshape(B, S, nheads, s.head_dim)
    B_mat = B_in.reshape(B, S, s.n_groups, s.state_dim)
    C_mat = C_in.reshape(B, S, s.n_groups, s.state_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    if valid is not None:
        dt = dt * valid[..., None].astype(jnp.float32)

    if cache is None:
        y, _ = ssd_chunked(xs, dt, params["A_log"], B_mat, C_mat, s.chunk_size)
        new_cache = None
    elif S == 1:
        y, new_state = ssd_decode_step(xs, dt, params["A_log"], B_mat, C_mat,
                                       cache["state"])
        new_cache = {"conv_x": new_cx, "conv_B": new_cB, "conv_C": new_cC,
                     "state": new_state}
    else:  # chunked prefill continuing from a carried state
        y, new_state = ssd_chunked(xs, dt, params["A_log"], B_mat, C_mat,
                                   s.chunk_size, init_state=cache["state"])
        new_cache = {"conv_x": new_cx, "conv_B": new_cB, "conv_C": new_cC,
                     "state": new_state}

    y = y + xs * params["D"][None, None, :, None].astype(dt_)
    y = y.reshape(B, S, d_in)
    y = L.rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    return y @ params["out_proj"].astype(dt_), new_cache
