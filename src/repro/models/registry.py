"""Unified model API over decoder-only and encoder-decoder stacks.

``build_model(cfg)`` returns a ``Model`` with:
  init(rng)                                  -> params
  forward(params, batch)                     -> (logits, aux)          # train
  init_cache(batch, max_len)                 -> cache
  prefill(params, tokens/..., cache, lengths)-> (logits, cache)
  decode_step(params, tokens, cache, lengths)-> (logits, cache)

``batch`` is a dict; see ``input_names(cfg, kind)`` for the contract used by
input_specs()/the data pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[Any], dict]
    forward: Callable[..., Tuple[jnp.ndarray, jnp.ndarray]]
    init_cache: Callable[..., dict]
    prefill: Callable[..., Tuple[jnp.ndarray, dict]]
    decode_step: Callable[..., Tuple[jnp.ndarray, dict]]


def input_names(cfg: ModelConfig, kind: str) -> Tuple[str, ...]:
    if cfg.is_encoder_decoder:
        if kind == "train":
            return ("frames", "tokens", "labels")
        return ("tokens",)
    if cfg.frontend_stub:  # vlm
        if kind == "train":
            return ("tokens", "vis_embeds", "vis_mask", "labels")
        return ("tokens",)
    if kind == "train":
        return ("tokens", "labels")
    return ("tokens",)


def build_model(cfg: ModelConfig, moe_impl: str = "ragged") -> Model:
    if cfg.is_encoder_decoder:
        return _build_encdec(cfg)
    return _build_decoder_only(cfg, moe_impl)


# ---------------------------------------------------------------------------
def _build_decoder_only(cfg: ModelConfig, moe_impl: str) -> Model:
    def init(rng):
        return transformer.init_model(rng, cfg)

    def forward(params, batch):
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = batch.get("positions")
        if positions is None:
            positions = transformer.make_positions(cfg, B, S)
        logits, aux, _ = transformer.forward(
            params, cfg, tokens, positions,
            seg=batch.get("segment_ids"),
            vis_embeds=batch.get("vis_embeds"),
            vis_mask=batch.get("vis_mask"),
            moe_impl=moe_impl)
        return logits, aux

    def init_cache(batch, max_len):
        return transformer.init_cache(cfg, batch, max_len)

    def prefill(params, tokens, cache, lengths, valid=None, **kw):
        """``valid`` (B,S) bool: ragged chunk tails / inactive decode slots.
        Pad entries are written with position -1 (never attended, ring-
        overwritten later); recurrent blocks treat them as exactly inert."""
        B, S = tokens.shape
        positions = transformer.make_positions(cfg, B, S, start=lengths)
        if valid is not None:
            vmask = valid if positions.ndim == 2 else valid[None]
            positions = jnp.where(vmask, positions, -1)
        logits, _, cache = transformer.forward(
            params, cfg, tokens, positions, cache=cache, lengths=lengths,
            vis_embeds=kw.get("vis_embeds"), vis_mask=kw.get("vis_mask"),
            moe_impl=moe_impl, valid=valid)
        return logits, cache

    def decode_step(params, tokens, cache, lengths, valid=None):
        return prefill(params, tokens, cache, lengths, valid=valid)

    return Model(cfg, init, forward, init_cache, prefill, decode_step)


# ---------------------------------------------------------------------------
def _build_encdec(cfg: ModelConfig) -> Model:
    def init(rng):
        return encdec.init_model(rng, cfg)

    def forward(params, batch):
        frames, tokens = batch["frames"], batch["tokens"]
        B, S = tokens.shape
        enc_out = encdec.encode(params, cfg, frames)
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        logits, aux, _ = encdec.decode(params, cfg, tokens, positions,
                                       enc_out=enc_out)
        return logits, aux

    def init_cache(batch, max_len):
        return encdec.init_cache(cfg, batch, max_len)

    def prefill(params, tokens, cache, lengths, frames=None, valid=None,
                **kw):
        """First call may carry encoder frames to fill the cross KV."""
        if frames is not None:
            enc_out = encdec.encode(params, cfg, frames)
            xk, xv = encdec.prepare_cross(params, cfg, enc_out)
            cache = {"self": cache["self"], "xk": xk, "xv": xv}
        B, S = tokens.shape
        positions = (jnp.arange(S, dtype=jnp.int32)[None]
                     + lengths[:, None]).astype(jnp.int32)
        if valid is not None:
            positions = jnp.where(valid, positions, -1)
        logits, _, cache = encdec.decode(params, cfg, tokens, positions,
                                         cache=cache, lengths=lengths)
        return logits, cache

    def decode_step(params, tokens, cache, lengths, valid=None):
        return prefill(params, tokens, cache, lengths, valid=valid)

    return Model(cfg, init, forward, init_cache, prefill, decode_step)
