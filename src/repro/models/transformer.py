"""Decoder-only LM assembly: block dispatch + scan-over-layers + caches.

Layers are grouped into a repeating *group* of length
``lcm(len(block_pattern), moe_period)``; full groups are stacked and scanned
(one compiled body regardless of depth), leading ``first_dense_layers`` and
any trailing partial group are applied unscanned.  Each layer kind
(attention global/local, SSD, RG-LRU) carries its own cache pytree.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (GLOBAL_ATTN, LOCAL_ATTN, RGLRU, SSD,
                                ModelConfig)
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S


# ---------------------------------------------------------------------------
# layer layout
# ---------------------------------------------------------------------------
def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def layer_layout(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    """(front, group_len, n_groups, tail) layer partition."""
    front = cfg.moe.first_dense_layers if cfg.moe else 0
    p = len(cfg.block_pattern)
    if cfg.moe:
        p = _lcm(p, cfg.moe.layer_period)
    rest = cfg.num_layers - front
    n_groups = rest // p if cfg.scan_layers else 0
    tail = rest - n_groups * p
    return front, p, n_groups, tail


def _layer_sig(cfg: ModelConfig, i: int) -> Tuple[str, bool]:
    pattern = cfg.pattern_for_layers()
    moe_mask = cfg.moe_layer_mask()
    return pattern[i], moe_mask[i]


# ---------------------------------------------------------------------------
# single-layer init / apply
# ---------------------------------------------------------------------------
def init_layer(key, cfg: ModelConfig, kind: str, is_moe: bool) -> dict:
    pd = L.pdtype_of(cfg)
    ks = jax.random.split(key, 3)
    p: Dict[str, Any] = {"norm1": jnp.zeros((cfg.d_model,), pd)}
    if kind in (GLOBAL_ATTN, LOCAL_ATTN):
        p["mixer"] = A.init_attention(ks[0], cfg)
    elif kind == SSD:
        p["mixer"] = S.init_ssd(ks[0], cfg)
        if cfg.use_post_norms:
            p["post_norm1"] = jnp.zeros((cfg.d_model,), pd)
        return p  # SSD block has no separate MLP
    elif kind == RGLRU:
        p["mixer"] = R.init_rglru(ks[0], cfg)
    else:
        raise ValueError(kind)
    p["norm2"] = jnp.zeros((cfg.d_model,), pd)
    if is_moe:
        p["moe"] = M.init_moe(ks[1], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, pd)
    if cfg.use_post_norms:
        p["post_norm1"] = jnp.zeros((cfg.d_model,), pd)
        p["post_norm2"] = jnp.zeros((cfg.d_model,), pd)
    return p


def apply_layer(lp: dict, x: jnp.ndarray, cfg: ModelConfig, kind: str,
                is_moe: bool, positions, seg, cache, offsets,
                moe_impl: str, valid=None
                ) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    from repro.distributed.sharding import constrain_acts
    x = constrain_acts(x)      # re-anchor batch sharding inside scan bodies
    h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
    if kind in (GLOBAL_ATTN, LOCAL_ATTN):
        mix, new_cache = A.attention_layer(lp["mixer"], h, positions, cfg,
                                           kind, cache, offsets, seg)
    elif kind == SSD:
        mix, new_cache = S.ssd_block(lp["mixer"], h, cfg, cache, valid)
    elif kind == RGLRU:
        mix, new_cache = R.rglru_block(lp["mixer"], h, cfg, cache, valid)
    else:
        raise ValueError(kind)
    if cfg.use_post_norms:
        mix = L.rms_norm(mix, lp["post_norm1"], cfg.norm_eps)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if kind == SSD:
        return x, new_cache, aux
    h2 = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
    if is_moe:
        y, aux = M.apply_moe(lp["moe"], h2, cfg, moe_impl)
    else:
        y = L.apply_mlp(lp["mlp"], h2, cfg.mlp_act)
    if cfg.use_post_norms:
        y = L.rms_norm(y, lp["post_norm2"], cfg.norm_eps)
    return x + y, new_cache, aux


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------
def init_model(key, cfg: ModelConfig) -> dict:
    pd = L.pdtype_of(cfg)
    front, p, n_groups, tail = layer_layout(cfg)
    keys = jax.random.split(key, cfg.num_layers + 2)
    params: Dict[str, Any] = {
        "embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model, pd),
        "final_norm": jnp.zeros((cfg.d_model,), pd),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[1], cfg.d_model,
                                         cfg.vocab_size, pd)

    def make(i):
        kind, is_moe = _layer_sig(cfg, i)
        return init_layer(keys[2 + i], cfg, kind, is_moe)

    params["front"] = [make(i) for i in range(front)]
    groups = []
    for g in range(n_groups):
        groups.append(tuple(make(front + g * p + j) for j in range(p)))
    if groups:
        params["groups"] = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
    params["tail"] = [make(front + n_groups * p + j) for j in range(tail)]
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def _layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    if kind in (GLOBAL_ATTN, LOCAL_ATTN):
        return A.init_kv_cache(cfg, kind, batch, max_len)
    if kind == SSD:
        return S.init_ssd_cache(cfg, batch)
    if kind == RGLRU:
        return R.init_rglru_cache(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    front, p, n_groups, tail = layer_layout(cfg)
    cache: Dict[str, Any] = {
        "front": [
            _layer_cache(cfg, _layer_sig(cfg, i)[0], batch, max_len)
            for i in range(front)],
        "tail": [
            _layer_cache(cfg, _layer_sig(cfg, front + n_groups * p + j)[0],
                         batch, max_len)
            for j in range(tail)],
    }
    if n_groups:
        one = tuple(_layer_cache(cfg, _layer_sig(cfg, front + j)[0],
                                 batch, max_len) for j in range(p))
        cache["groups"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape).copy(),
            one)
    return cache


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)  # "full"


def forward(params: dict, cfg: ModelConfig, tokens: Optional[jnp.ndarray],
            positions: jnp.ndarray, *,
            seg: Optional[jnp.ndarray] = None,
            cache: Optional[dict] = None,
            lengths: Optional[jnp.ndarray] = None,
            vis_embeds: Optional[jnp.ndarray] = None,
            vis_mask: Optional[jnp.ndarray] = None,
            moe_impl: str = "gshard",
            inputs_embeds: Optional[jnp.ndarray] = None,
            valid: Optional[jnp.ndarray] = None,
            ) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[dict]]:
    """Returns (logits fp32, moe_aux, new_cache).

    Train/prefill-from-zero: cache=None.  Serving: cache + lengths (B,) =
    current fill; positions must be absolute.  VLM stub: vis_embeds/vis_mask
    splice precomputed patch embeddings into the token stream.
    """
    front, p, n_groups, tail = layer_layout(cfg)
    if inputs_embeds is not None:
        x = inputs_embeds.astype(L.dtype_of(cfg))
        if cfg.scale_embeddings:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    else:
        x = L.embed_lookup(params["embed"], tokens, cfg)
    from repro.distributed.sharding import constrain_acts
    x = constrain_acts(x)
    if vis_embeds is not None:
        x = jnp.where(vis_mask[..., None], vis_embeds.astype(x.dtype), x)

    offsets = lengths if lengths is not None else None
    aux_total = jnp.zeros((), jnp.float32)

    def run(i, lp, xc, c):
        kind, is_moe = _layer_sig(cfg, i)
        return apply_layer(lp, xc, cfg, kind, is_moe, positions, seg, c,
                           offsets, moe_impl, valid)

    new_front = []
    for i, lp in enumerate(params["front"]):
        c = cache["front"][i] if cache is not None else None
        x, nc, aux = run(i, lp, x, c)
        aux_total = aux_total + aux
        new_front.append(nc)

    new_groups = None
    if n_groups:
        sigs = [_layer_sig(cfg, front + j) for j in range(p)]

        def group_fn(xa, gp, gc):
            xc, aux_c = xa
            new_cs = []
            for j in range(p):
                kind, is_moe = sigs[j]
                c = gc[j] if gc is not None else None
                xc, nc, aux = apply_layer(gp[j], xc, cfg, kind, is_moe,
                                          positions, seg, c, offsets,
                                          moe_impl, valid)
                aux_c = aux_c + aux
                new_cs.append(nc)
            return (xc, aux_c), tuple(new_cs)

        group_fn = _remat(group_fn, cfg)

        def scan_body(carry, xs):
            gp, gc = xs
            (xc, aux_c), new_cs = group_fn(carry, gp, gc)
            return (xc, aux_c), new_cs

        gc_xs = cache["groups"] if cache is not None else None
        if gc_xs is None:
            (x, aux_total), new_groups = jax.lax.scan(
                lambda ca, gp: scan_body(ca, (gp, None)),
                (x, aux_total), params["groups"])
            new_groups = None
        else:
            (x, aux_total), new_groups = jax.lax.scan(
                scan_body, (x, aux_total), (params["groups"], gc_xs))

    new_tail = []
    for j, lp in enumerate(params["tail"]):
        i = front + n_groups * p + j
        c = cache["tail"][j] if cache is not None else None
        x, nc, aux = run(i, lp, x, c)
        aux_total = aux_total + aux
        new_tail.append(nc)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(x, params["embed"], params.get("lm_head"), cfg)
    new_cache = None
    if cache is not None:
        new_cache = {"front": new_front, "groups": new_groups,
                     "tail": new_tail}
    return logits, aux_total, new_cache


def make_positions(cfg: ModelConfig, batch: int, seq: int,
                   start: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """(B,S) positions, or (3,B,S) identical streams for M-RoPE text."""
    base = jnp.arange(seq, dtype=jnp.int32)[None, :]
    pos = jnp.broadcast_to(base, (batch, seq))
    if start is not None:
        pos = pos + start[:, None]
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos
