"""Encoder-decoder backbone (whisper-large-v3). Frontend is a stub: the
encoder consumes precomputed frame embeddings (B, T_enc, d_model).
Sinusoidal absolute positions (parameter-free; DESIGN.md §5 deviation)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import GLOBAL_ATTN, ModelConfig
from repro.models import attention as A
from repro.models import layers as L


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_enc_layer(key, cfg: ModelConfig) -> dict:
    pd = L.pdtype_of(cfg)
    ks = jax.random.split(key, 2)
    return {
        "norm1": jnp.zeros((cfg.d_model,), pd),
        "mixer": A.init_attention(ks[0], cfg),
        "norm2": jnp.zeros((cfg.d_model,), pd),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, pd),
    }


def _init_dec_layer(key, cfg: ModelConfig) -> dict:
    pd = L.pdtype_of(cfg)
    ks = jax.random.split(key, 3)
    p = _init_enc_layer(ks[0], cfg)
    p["norm_x"] = jnp.zeros((cfg.d_model,), pd)
    p["cross"] = A.init_cross_attention(ks[1], cfg)
    return p


def init_model(key, cfg: ModelConfig) -> dict:
    pd = L.pdtype_of(cfg)
    k_emb, k_enc, k_dec = jax.random.split(key, 3)
    enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.num_layers)
    params: Dict[str, Any] = {
        "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model, pd),
        "enc_layers": jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[_init_enc_layer(k, cfg) for k in enc_keys]),
        "enc_norm": jnp.zeros((cfg.d_model,), pd),
        "dec_layers": jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[_init_dec_layer(k, cfg) for k in dec_keys]),
        "final_norm": jnp.zeros((cfg.d_model,), pd),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(jax.random.fold_in(key, 3),
                                         cfg.d_model, cfg.vocab_size, pd)
    return params


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------
def encode(params: dict, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, T_enc, d_model) stub embeddings -> encoder output."""
    B, T, _ = frames.shape
    x = frames.astype(L.dtype_of(cfg))
    x = x + L.sinusoidal_positions(T, cfg.d_model).astype(x.dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def body(xc, lp):
        from repro.distributed.sharding import constrain_acts
        xc = constrain_acts(xc)     # in-scan batch anchor (DESIGN.md §3)
        h = L.rms_norm(xc, lp["norm1"], cfg.norm_eps)
        mix, _ = A.attention_layer(lp["mixer"], h, pos, cfg, GLOBAL_ATTN,
                                   causal=False)
        xc = xc + mix
        h2 = L.rms_norm(xc, lp["norm2"], cfg.norm_eps)
        return xc + L.apply_mlp(lp["mlp"], h2, cfg.mlp_act), None

    body = jax.checkpoint(body) if cfg.remat != "none" else body
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------
def _dec_layer(lp, x, cfg, positions, enc_kv, cache, offsets, causal=True):
    from repro.distributed.sharding import constrain_acts
    x = constrain_acts(x)           # in-scan batch anchor (DESIGN.md §3)
    h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
    mix, new_cache = A.attention_layer(lp["mixer"], h, positions, cfg,
                                       GLOBAL_ATTN, cache, offsets)
    x = x + mix
    hx = L.rms_norm(x, lp["norm_x"], cfg.norm_eps)
    x = x + A.cross_attention_layer(lp["cross"], hx, enc_kv, cfg)
    h2 = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
    return x + L.apply_mlp(lp["mlp"], h2, cfg.mlp_act), new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: Optional[int] = None) -> dict:
    """Self-attn KV per decoder layer + precomputed cross K/V slots."""
    enc_len = enc_len or cfg.num_audio_frames
    dt = L.dtype_of(cfg)
    one = A.init_kv_cache(cfg, GLOBAL_ATTN, batch, max_len)
    nl = cfg.num_layers
    return {
        "self": jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (nl,) + x.shape).copy(), one),
        "xk": jnp.zeros((nl, batch, enc_len, cfg.num_heads, cfg.head_dim), dt),
        "xv": jnp.zeros((nl, batch, enc_len, cfg.num_heads, cfg.head_dim), dt),
    }


def prepare_cross(params: dict, cfg: ModelConfig, enc_out: jnp.ndarray):
    """Precompute per-layer cross K/V from encoder output (prefill time)."""
    def body(_, lp):
        k, v = A.encode_cross_kv(lp["cross"], enc_out, cfg)
        return None, (k, v)
    _, (xk, xv) = jax.lax.scan(body, None, params["dec_layers"])
    return xk, xv


def decode(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
           positions: jnp.ndarray, *,
           enc_out: Optional[jnp.ndarray] = None,
           cache: Optional[dict] = None,
           lengths: Optional[jnp.ndarray] = None,
           ) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[dict]]:
    """Decoder forward.  Train: enc_out given, cache None.  Serve: cache
    holds self KV + precomputed cross KV."""
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens, cfg)
    # additive sinusoidal positions gathered at absolute offsets
    pos2d = positions if positions.ndim == 2 else positions[0]
    pe_table = L.sinusoidal_positions(1 << 16, cfg.d_model)
    x = x + jnp.take(pe_table, jnp.clip(pos2d, 0, (1 << 16) - 1),
                     axis=0).astype(x.dtype)

    if cache is None:
        xk, xv = prepare_cross(params, cfg, enc_out)
        def body(xa, xs):
            lp, k, v = xs
            xc, _ = _dec_layer(lp, xa, cfg, pos2d, (k, v), None, None)
            return xc, None
        body = jax.checkpoint(body) if cfg.remat != "none" else body
        x, _ = jax.lax.scan(body, x, (params["dec_layers"], xk, xv))
        new_cache = None
    else:
        def body(xa, xs):
            lp, c, k, v = xs
            xc, nc = _dec_layer(lp, xa, cfg, pos2d, (k, v), c, lengths)
            return xc, nc
        x, new_self = jax.lax.scan(
            body, x, (params["dec_layers"], cache["self"],
                      cache["xk"], cache["xv"]))
        new_cache = {"self": new_self, "xk": cache["xk"], "xv": cache["xv"]}

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(x, params["embed"], params.get("lm_head"), cfg)
    return logits, jnp.zeros((), jnp.float32), new_cache
