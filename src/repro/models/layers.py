"""Shared model building blocks (functional, no framework deps).

Params are nested dicts of jnp arrays; every module is an (init, apply)
pair.  Norms/softmax/router run in fp32; matmuls in ``cfg.dtype``.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + scale) parameterization: init at zeros == identity
    return (normed * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {name}")


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def apply_mlp(params: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    dt = x.dtype
    gate = act_fn(act)(x @ params["w_gate"].astype(dt))
    up = x @ params["w_up"].astype(dt)
    return (gate * up) @ params["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float,
                mrope_sections: Tuple[int, ...] = ()) -> jnp.ndarray:
    """positions: (B, S) or (3, B, S) for M-RoPE -> angles (B, S, half)."""
    inv = rope_freqs(head_dim, theta)                       # (half,)
    if positions.ndim == 3:                                 # M-RoPE (t, h, w)
        if not mrope_sections:
            positions = positions[0]
        else:
            half = head_dim // 2
            sec_id = jnp.repeat(
                jnp.arange(len(mrope_sections)),
                jnp.array(mrope_sections),
                total_repeat_length=half)                   # (half,)
            # pick, per freq index, the position stream of its section
            pos = positions.astype(jnp.float32)             # (3, B, S)
            pos_sel = jnp.take(pos, sec_id, axis=0)         # (half, B, S)
            return jnp.einsum("hbs,h->bsh", pos_sel, inv)
    return positions.astype(jnp.float32)[..., None] * inv   # (B, S, half)


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, D); angles: (B, S, D/2) — NeoX rotate-half convention."""
    half = x.shape[-1] // 2
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., :half], xf[..., half:]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jnp.ndarray:
    """Parameter-free absolute positions (whisper backbone)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, dim / d_model)
    pe = jnp.zeros((seq_len, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


# ---------------------------------------------------------------------------
# embedding / logits
# ---------------------------------------------------------------------------
def embed_lookup(table: jnp.ndarray, tokens: jnp.ndarray, cfg: ModelConfig,
                 scale: Optional[float] = None) -> jnp.ndarray:
    x = jnp.take(table, tokens, axis=0).astype(dtype_of(cfg))
    if cfg.scale_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def lm_logits(x: jnp.ndarray, embed_table: jnp.ndarray,
              head: Optional[jnp.ndarray], cfg: ModelConfig) -> jnp.ndarray:
    table = embed_table if head is None else head
    logits = x @ (table.T if head is None else head).astype(x.dtype)
    logits = logits.astype(jnp.float32)
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits
