"""Attention: GQA/MQA/MHA + DeepSeek MLA, training and decode paths.

Three implementations selected by ``cfg.attn_impl``:
  * ``chunked`` — flash-style lax.scan over KV blocks in pure jnp.  O(S·D)
    memory; this is the AOT dry-run path (memory analysis stays honest).
  * ``pallas``  — Pallas TPU kernels (kernels/flash_attention.py,
    kernels/decode_attention.py); validated in interpret mode on CPU.
  * ``naive``   — full S×T score matrix; tiny-shape oracle only.

Local-attention layers use a ring-buffer KV cache of ``window`` entries with
stored absolute positions, so gemma2/recurrentgemma long-context decode
memory is O(window), not O(context).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, LOCAL_ATTN
from repro.models import layers as L

NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig) -> dict:
    pd = L.pdtype_of(cfg)
    d = cfg.d_model
    if cfg.mla is not None:
        m = cfg.mla
        qd = cfg.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
        ks = jax.random.split(key, 5)
        return {
            "wq": L.dense_init(ks[0], d, qd, pd),
            "w_dkv": L.dense_init(ks[1], d, m.kv_lora_rank + m.qk_rope_head_dim, pd),
            "kv_norm": jnp.zeros((m.kv_lora_rank,), pd),
            "w_uk": L.dense_init(ks[2], m.kv_lora_rank,
                                 cfg.num_heads * m.qk_nope_head_dim, pd),
            "w_uv": L.dense_init(ks[3], m.kv_lora_rank,
                                 cfg.num_heads * m.v_head_dim, pd),
            "wo": L.dense_init(ks[4], cfg.num_heads * m.v_head_dim, d, pd),
        }
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], d, cfg.q_dim, pd),
        "wk": L.dense_init(ks[1], d, cfg.kv_dim, pd),
        "wv": L.dense_init(ks[2], d, cfg.kv_dim, pd),
        "wo": L.dense_init(ks[3], cfg.q_dim, d, pd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), pd)
        p["bk"] = jnp.zeros((cfg.kv_dim,), pd)
        p["bv"] = jnp.zeros((cfg.kv_dim,), pd)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.head_dim,), pd)
        p["k_norm"] = jnp.zeros((cfg.head_dim,), pd)
    return p


def init_cross_attention(key, cfg: ModelConfig) -> dict:
    """Whisper decoder cross-attention (always dense MHA, no rope)."""
    pd = L.pdtype_of(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(ks[0], d, cfg.q_dim, pd),
        "wk": L.dense_init(ks[1], d, cfg.q_dim, pd),
        "wv": L.dense_init(ks[2], d, cfg.q_dim, pd),
        "wo": L.dense_init(ks[3], cfg.q_dim, d, pd),
    }


# ---------------------------------------------------------------------------
# core chunked flash-style attention (pure jnp, scan over KV blocks)
# ---------------------------------------------------------------------------
def chunked_attention(q, k, v, q_pos, k_pos, *, scale: float,
                      causal: bool = True, window: int = 0,
                      cap: float = 0.0, chunk: int = 512,
                      k_valid=None, seg_q=None, seg_k=None,
                      q_chunk: int = 4096) -> jnp.ndarray:
    """Flash-style attention, pure jnp.  q: (B,S,Hq,Dk); k/v: (B,T,H,D*).

    Long sequences are processed in ``q_chunk`` query blocks (unrolled,
    static shapes).  For the causal self-attention layout (T == S, no
    cache) each query block only multiplies against its *reachable* KV
    prefix — and, for sliding-window layers, only the [lo, hi) KV band —
    so HLO FLOPs stay at the banded/causal count instead of the full S·T
    rectangle.  Within a block, a lax.scan streams KV chunks with an
    online-softmax accumulator (O(S·D) memory).
    """
    B, S, Hq, Dk = q.shape
    T = k.shape[1]
    if S > q_chunk and S % q_chunk == 0 and q_pos.ndim == 2:
        outs = []
        for i in range(S // q_chunk):
            sl = slice(i * q_chunk, (i + 1) * q_chunk)
            qi, qpi = q[:, sl], q_pos[:, sl]
            sqi = seg_q[:, sl] if seg_q is not None else None
            if causal and k_valid is None and T == S:
                hi = (i + 1) * q_chunk
                lo = max(0, i * q_chunk - window + 1) if window else 0
                lo = (lo // chunk) * chunk          # chunk-aligned band
                ki, vi, kpi = k[:, lo:hi], v[:, lo:hi], k_pos[:, lo:hi]
                ski = seg_k[:, lo:hi] if seg_k is not None else None
            else:
                ki, vi, kpi, ski = k, v, k_pos, seg_k
            outs.append(_chunked_attention(
                qi, ki, vi, qpi, kpi, scale=scale, causal=causal,
                window=window, cap=cap, chunk=chunk, k_valid=k_valid,
                seg_q=sqi, seg_k=ski))
        return jnp.concatenate(outs, axis=1)
    return _chunked_attention(q, k, v, q_pos, k_pos, scale=scale,
                              causal=causal, window=window, cap=cap,
                              chunk=chunk, k_valid=k_valid, seg_q=seg_q,
                              seg_k=seg_k)


def _chunked_attention(q, k, v, q_pos, k_pos, *, scale: float,
                       causal: bool = True, window: int = 0,
                       cap: float = 0.0, chunk: int = 512,
                       k_valid=None, seg_q=None, seg_k=None) -> jnp.ndarray:
    """q: (B,S,Hq,Dk), k: (B,T,Hkv,Dk), v: (B,T,Hkv,Dv).

    q_pos: (B,S) absolute positions of queries; k_pos: (B,T) of keys.
    k_valid: (B,T) bool — entries that exist (cache fill mask).
    Returns (B,S,Hq,Dv).  All accumulation in fp32.
    """
    B, S, Hq, Dk = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = Hq // Hkv

    c = min(chunk, T)
    n_chunks = -(-T // c)
    pad = n_chunks * c - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        kv_mask = jnp.pad(
            jnp.ones((B, T), bool) if k_valid is None else k_valid,
            ((0, 0), (0, pad)))
        if seg_k is not None:
            seg_k = jnp.pad(seg_k, ((0, 0), (0, pad)), constant_values=-2)
    else:
        kv_mask = jnp.ones((B, T), bool) if k_valid is None else k_valid

    qf = (q * jnp.asarray(scale, q.dtype)).reshape(B, S, Hkv, G, Dk)
    kc = k.reshape(B, n_chunks, c, Hkv, Dk)
    vc = v.reshape(B, n_chunks, c, Hkv, Dv)
    pc = k_pos.reshape(B, n_chunks, c)
    mc = kv_mask.reshape(B, n_chunks, c)
    sc = seg_k.reshape(B, n_chunks, c) if seg_k is not None else None

    def body(carry, xs):
        m_run, l_run, acc = carry
        if sc is None:
            k_i, v_i, p_i, valid_i = xs
            s_i = None
        else:
            k_i, v_i, p_i, valid_i, s_i = xs
        # scores: (B, S, Hkv, G, c) — bf16 operands, fp32 MXU accumulation
        s = jnp.einsum("bshgd,bchd->bshgc", qf, k_i,
                       preferred_element_type=jnp.float32)
        if cap:
            s = cap * jnp.tanh(s / cap)
        mask = valid_i[:, None, :]                     # (B,1,c)
        if causal:
            mask = mask & (p_i[:, None, :] <= q_pos[:, :, None])
        if window:
            mask = mask & (q_pos[:, :, None] - p_i[:, None, :] < window)
        if s_i is not None:
            mask = mask & (s_i[:, None, :] == seg_q[:, :, None])
        mask = mask[:, :, None, None, :]               # (B,S,1,1,c)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None]) * mask       # masked probs
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bshgc,bchd->bshgd", p.astype(v_i.dtype), v_i,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, S, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, S, Hkv, G, Dv), jnp.float32)
    xs = (kc.swapaxes(0, 1), vc.swapaxes(0, 1), pc.swapaxes(0, 1),
          mc.swapaxes(0, 1))
    if sc is not None:
        xs = xs + (sc.swapaxes(0, 1),)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, S, Hq, Dv).astype(q.dtype)


def naive_attention(q, k, v, q_pos, k_pos, *, scale, causal=True, window=0,
                    cap=0.0, k_valid=None, seg_q=None, seg_k=None):
    """Full-score attention (decode path + tiny-shape oracle).

    No fp32 materialization of k/v: the MXU consumes bf16 operands and
    accumulates fp32 (``preferred_element_type``) — casting the KV cache
    to fp32 would otherwise double decode HBM traffic (EXPERIMENTS.md
    §Perf, decode hillclimb).  Probabilities are cast to v's dtype before
    the PV matmul (standard TPU flash practice; exact when v is fp32).
    """
    B, S, Hq, Dk = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qf = (q * jnp.asarray(scale, q.dtype)).reshape(B, S, Hkv, G, Dk)
    s = jnp.einsum("bshgd,bthd->bshgt", qf, k,
                   preferred_element_type=jnp.float32)
    if cap:
        s = cap * jnp.tanh(s / cap)
    mask = jnp.ones((B, S, k.shape[1]), bool)
    if k_valid is not None:
        mask = mask & k_valid[:, None, :]
    if causal:
        mask = mask & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window:
        mask = mask & (q_pos[:, :, None] - k_pos[:, None, :] < window)
    if seg_q is not None:
        mask = mask & (seg_k[:, None, :] == seg_q[:, :, None])
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = p * mask[:, :, None, None, :]
    out = jnp.einsum("bshgt,bthd->bshgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, Hq, -1).astype(q.dtype)


def _run_attention(cfg: ModelConfig, q, k, v, q_pos, k_pos, *, scale, causal,
                   window, cap, k_valid=None, seg_q=None, seg_k=None):
    if cfg.attn_impl == "pallas":
        from repro.kernels import ops as kops
        if seg_q is None and k.shape[-1] == v.shape[-1]:
            if q.shape[1] == 1:   # decode
                return kops.decode_attention(
                    q, k, v, q_pos[:, 0], scale=scale, window=window,
                    cap=cap, interpret=kops.on_cpu())
            if k_valid is None and q.shape[1] == k.shape[1]:
                return kops.flash_attention(
                    q, k, v, scale=scale, causal=causal, window=window,
                    cap=cap, interpret=kops.on_cpu())
        # fall through for unsupported combos
    if cfg.attn_impl == "naive" or q.shape[1] == 1:
        # decode (one query): the full-score einsum IS flash-decode FLOPs-
        # wise, shards cleanly over a length- or head-partitioned cache
        # (psum'd softmax reductions), and avoids lax.scan over a sharded
        # KV axis.
        return naive_attention(q, k, v, q_pos, k_pos, scale=scale,
                               causal=causal, window=window, cap=cap,
                               k_valid=k_valid, seg_q=seg_q, seg_k=seg_k)
    return chunked_attention(q, k, v, q_pos, k_pos, scale=scale,
                             causal=causal, window=window, cap=cap,
                             chunk=cfg.attn_chunk, k_valid=k_valid,
                             seg_q=seg_q, seg_k=seg_k)


# ---------------------------------------------------------------------------
# KV cache helpers
# ---------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int) -> dict:
    """Zeroed cache pytree for one attention layer."""
    dt = L.dtype_of(cfg)
    size = min(max_len, cfg.window_size) if (kind == LOCAL_ATTN and cfg.window_size) else max_len
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "ckv": jnp.zeros((batch, size, m.kv_lora_rank), dt),
            "krope": jnp.zeros((batch, size, m.qk_rope_head_dim), dt),
            "pos": jnp.full((batch, size), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, size, cfg.num_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((batch, size, cfg.num_kv_heads, cfg.head_dim), dt),
        "pos": jnp.full((batch, size), -1, jnp.int32),
    }


def _ring_write(buf: jnp.ndarray, new: jnp.ndarray, offsets: jnp.ndarray):
    """Write `new` (B, P, ...) into ring buffer `buf` (B, T, ...) at
    positions (offsets + arange(P)) mod T, per batch row."""
    B, P = new.shape[:2]
    T = buf.shape[1]
    idx = (offsets[:, None] + jnp.arange(P)[None, :]) % T        # (B,P)
    bidx = jnp.arange(B)[:, None].repeat(P, axis=1)
    return buf.at[bidx, idx].set(new)


def update_cache(cache: dict, new: dict, offsets: jnp.ndarray,
                 positions: jnp.ndarray) -> dict:
    """new: dict of (B,P,...) tensors; positions: (B,P) absolute positions."""
    out = dict(cache)
    for name, val in new.items():
        out[name] = _ring_write(cache[name], val.astype(cache[name].dtype), offsets)
    out["pos"] = _ring_write(cache["pos"], positions.astype(jnp.int32), offsets)
    return out


# ---------------------------------------------------------------------------
# standard GQA attention layer
# ---------------------------------------------------------------------------
def attention_layer(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
                    cfg: ModelConfig, kind: str,
                    cache: Optional[dict] = None,
                    cache_offset: Optional[jnp.ndarray] = None,
                    seg: Optional[jnp.ndarray] = None,
                    causal: bool = True,
                    ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: (B,S,d).  Train/prefill: cache None or appended-to.  Decode: S small
    (usually 1), cache required.  positions: (B,S) or (3,B,S) for M-RoPE."""
    if cfg.mla is not None:
        return _mla_layer(params, x, positions, cfg, cache, cache_offset)
    dt = x.dtype
    B, S, _ = x.shape
    pos2d = positions if positions.ndim == 2 else positions[0]
    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, params["k_norm"], cfg.norm_eps)
    if cfg.use_rope:
        angles = L.rope_angles(positions, cfg.head_dim, cfg.rope_theta,
                               cfg.mrope_sections)
        q = L.apply_rope(q, angles)
        k = L.apply_rope(k, angles)

    scale = cfg.attn_scale or (1.0 / math.sqrt(cfg.head_dim))
    window = cfg.window_size if kind == LOCAL_ATTN else 0

    if cache is None:
        out = _run_attention(cfg, q, k, v, pos2d, pos2d, scale=scale,
                             causal=causal, window=window,
                             cap=cfg.attn_softcap, seg_q=seg, seg_k=seg)
    else:
        cache = update_cache(cache, {"k": k, "v": v}, cache_offset, pos2d)
        k_valid = cache["pos"] >= 0
        out = _run_attention(cfg, q, cache["k"], cache["v"], pos2d,
                             cache["pos"], scale=scale, causal=causal,
                             window=window, cap=cfg.attn_softcap,
                             k_valid=k_valid)
    out = out.reshape(B, S, cfg.q_dim) @ params["wo"].astype(dt)
    return out, cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): expanded for train/prefill, absorbed-MQA for decode
# ---------------------------------------------------------------------------
def _mla_project_q(params, x, cfg, positions):
    m = cfg.mla
    B, S, _ = x.shape
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(
        B, S, cfg.num_heads, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    angles = L.rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = L.apply_rope(q_rope, angles)
    return q_nope, q_rope, angles


def _mla_latent(params, x, cfg, angles):
    m = cfg.mla
    dt = x.dtype
    ckr = x @ params["w_dkv"].astype(dt)
    ckv, k_rope = ckr[..., :m.kv_lora_rank], ckr[..., m.kv_lora_rank:]
    ckv = L.rms_norm(ckv, params["kv_norm"], cfg.norm_eps)
    k_rope = L.apply_rope(k_rope[:, :, None, :], angles)[:, :, 0, :]
    return ckv, k_rope


def _mla_layer(params, x, positions, cfg, cache, cache_offset):
    m = cfg.mla
    B, S, _ = x.shape
    dt = x.dtype
    pos2d = positions if positions.ndim == 2 else positions[0]
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope, angles = _mla_project_q(params, x, cfg, positions)
    ckv, k_rope = _mla_latent(params, x, cfg, angles)

    w_uk = params["w_uk"].astype(dt).reshape(
        m.kv_lora_rank, cfg.num_heads, m.qk_nope_head_dim)
    w_uv = params["w_uv"].astype(dt).reshape(
        m.kv_lora_rank, cfg.num_heads, m.v_head_dim)

    if cache is None:
        # expanded path: materialize per-head k/v from the latent
        k_nope = jnp.einsum("btr,rhd->bthd", ckv, w_uk)
        v = jnp.einsum("btr,rhd->bthd", ckv, w_uv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, S, cfg.num_heads, m.qk_rope_head_dim))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = _run_attention(cfg, q, k, v, pos2d, pos2d, scale=scale,
                             causal=True, window=0, cap=0.0)
        new_cache = None
    else:
        # absorbed path: attention in latent space == MQA with Dk=rank+rope,
        # Dv=rank.  Cache stores only (ckv, k_rope): the MLA memory win.
        cache = update_cache(cache, {"ckv": ckv, "krope": k_rope},
                             cache_offset, pos2d)
        new_cache = cache
        q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)
        q_abs = jnp.concatenate([q_lat, q_rope], axis=-1)
        k_abs = jnp.concatenate([cache["ckv"], cache["krope"]],
                                axis=-1)[:, :, None, :]
        v_abs = cache["ckv"][:, :, None, :]
        k_valid = cache["pos"] >= 0
        ctx = _run_attention(cfg, q_abs, k_abs, v_abs, pos2d, cache["pos"],
                             scale=scale, causal=True, window=0, cap=0.0,
                             k_valid=k_valid)
        out = jnp.einsum("bshr,rhd->bshd", ctx, w_uv)
    out = out.reshape(B, S, cfg.num_heads * m.v_head_dim)
    return out @ params["wo"].astype(dt), new_cache


# ---------------------------------------------------------------------------
# cross attention (whisper decoder)
# ---------------------------------------------------------------------------
def cross_attention_layer(params, x, enc_kv, cfg: ModelConfig):
    """enc_kv: (k, v) precomputed from encoder output, (B,T,H,D)."""
    dt = x.dtype
    B, S, _ = x.shape
    k, v = enc_kv
    q = (x @ params["wq"].astype(dt)).reshape(B, S, cfg.num_heads, cfg.head_dim)
    T = k.shape[1]
    scale = 1.0 / math.sqrt(cfg.head_dim)
    pos_q = jnp.zeros((B, S), jnp.int32)
    pos_k = jnp.zeros((B, T), jnp.int32)
    out = _run_attention(cfg, q, k, v, pos_q, pos_k, scale=scale,
                         causal=False, window=0, cap=0.0)
    return out.reshape(B, S, cfg.q_dim) @ params["wo"].astype(dt)


def encode_cross_kv(params, enc_out, cfg: ModelConfig):
    dt = enc_out.dtype
    B, T, _ = enc_out.shape
    k = (enc_out @ params["wk"].astype(dt)).reshape(B, T, cfg.num_heads, cfg.head_dim)
    v = (enc_out @ params["wv"].astype(dt)).reshape(B, T, cfg.num_heads, cfg.head_dim)
    return k, v
