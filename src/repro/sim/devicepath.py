"""Device-resident sim datapath: jit/scan event loop, vmapped over an
R-replica sweep axis (DESIGN.md §13).

The host datapaths (``sim/engine.py`` event loop, ``sim/fastpath.py``
SoA batch) interpret one scenario at a time in Python.  This module
compiles the *whole* inner loop — arrival ingestion, FMQ push with ECN
mark-before-drop, WLBVT/RR dispatch rounds, budget-clamp kills,
completion bookkeeping, occupancy/BVT folds, EQ emission — as a single
``lax.scan`` over a fixed event-step grid, ``jax.vmap``-ed over replica
lanes, so a whole ``SweepSpec`` runs in one device launch.

Event model (per replica, fixed shapes): the heap of the host loop
degenerates, on the compute-only contract below, to a two-way merge of
the (pre-sorted) arrival array against the PU slot table's min
finish-time.  Arrival seqs are assigned at inject (0..n-1) and
completion seqs start at n, so an arrival always precedes a completion
at equal time and completion ties resolve by lower seq — exactly the
host heap's ``(time, seq)`` order.  Each scan step consumes at most one
event; dead steps (replica drained or past horizon) are masked no-ops,
so ragged replicas ride the same grid.

Device contract — ``device_eligible`` returns the reason a spec needs
the host path: compute-only workloads (``io_kind == "none"``; the
DWRR/AXI/egress machinery never engages), no QoS controller (windows
then carry no decisions, only telemetry flushes), wlbvt/rr scheduling,
no timeline/trace capture.  Inside the contract the device path is
decision/EQ/telemetry **bit-identical** to the host datapaths under
``precision="exact"`` (f64 via a scoped ``enable_x64``); the only
documented drift is the Jain time-average, whose host fold compresses
the active set before summing (DESIGN.md §8).  ``precision="fast"``
trades f64 for f32 lanes (TPU-native, Pallas-eligible) and downgrades
the parity claim to statistical.

The WLBVT eligibility+select round itself lives in
``repro.kernels.wlbvt_select`` (jnp reference + Pallas TPU kernel
behind an ``attn_impl``-style switch).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.osmosis_pspin import PSPIN
from repro.core import sched_generic as G
from repro.core.events import Event, EventKind
from repro.kernels.wlbvt_select import wlbvt_select_rounds

EQ_RING_CAPACITY = 4096   # host EQHub shared-queue retention

# ys codes -> EQ event kinds (0 = no event this step)
_EQ_KINDS = {
    1: EventKind.ECN_MARK,
    2: EventKind.QUEUE_OVERFLOW,
    3: EventKind.CYCLE_BUDGET_EXCEEDED,
    4: EventKind.TOTAL_BUDGET_EXCEEDED,
}


class DevicePathError(ValueError):
    """Spec falls outside the device-path contract."""


def device_eligible(spec) -> Optional[str]:
    """None when ``spec`` fits the device contract, else the reason it
    must run on a host datapath."""
    if getattr(spec, "analytic", ""):
        return "analytic scenario (no datapath at all)"
    if getattr(spec, "num_nics", 0):
        return "fleet spec (switch fabric is host-only)"
    if spec.controller is not None:
        return "QoS controller (host-only control plane)"
    if spec.scheduler not in ("wlbvt", "rr"):
        return f"scheduler {spec.scheduler!r} (device supports wlbvt|rr)"
    if spec.record_timeline:
        return "record_timeline (host-only window capture)"
    for t in spec.tenants:
        wl = t.workload.build()
        if wl.io_kind != "none":
            return (f"tenant {t.name!r} io_kind {wl.io_kind!r} "
                    "(DWRR IO path is host-only)")
    return None


# ---------------------------------------------------------------------------
# traced step (factory-closed over static geometry; jit root = _launch)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=64)
def _build_launch(T: int, P: int, C: int, S: int, scheduler: str,
                  impl: str):
    """One compiled launch per (tenants, PUs, ring, steps, sched, impl)
    geometry.  Returns ``jit(_launch)(state, data) -> (state, ys)``.

    Single-grant theorem (what makes the step cheap): the host dispatch
    loop maintains the quiescence invariant "free_pus == 0 or nothing
    eligible" after every event.  An arrival adds exactly one packet (a
    new non-empty queue only *shrinks* other tenants' ``pu_limit``), a
    completion frees exactly one PU — so every event grants **at most
    one** PU under both wlbvt and rr, and the per-event dispatch is a
    single masked select + branchless apply, no loop.  (The round-level
    ``max_picks > 1`` generality lives in ``wlbvt_select_rounds`` and
    is pinned against ``core.sched_generic.select_round`` in tests.)

    Slot arrays are sized ``P + 1``: index P is an inert pad (t_fin
    ``+inf``, seq sentinel) that masked scatters aim at, so no
    gather-merge is needed on the no-op branch.  Likewise the FIFO ring
    is ``C + 1`` wide with column C as the discard target.
    """
    dma_ns = PSPIN.cycles_ns(PSPIN.dma_setup_cycles)
    ns_per_cycle = PSPIN.ns_per_cycle
    wlbvt = scheduler == "wlbvt"
    sent = np.iinfo(np.int32).max

    def _pre(s, d):
        """Consume one event (or nothing): pick the earliest of the next
        arrival and the earliest slot finish, advance the BVT/Jain
        integrals to it, apply the event, emit the EQ/completion record."""
        na = s["na"]
        ta = d["arr_t"][na]
        tfin = s["s_tf"][:, 0]           # slot pairs: (t_fin, t0)
        tmin = jnp.min(tfin)
        # completion candidate: lowest seq among the min-finish slots
        pc = jnp.argmin(jnp.where(tfin == tmin, s["s_ps"][:, 1], sent))
        is_arr = ta <= tmin            # arrival seqs < completion seqs
        t_ev = jnp.where(is_arr, ta, tmin)
        live = (t_ev <= d["horizon"]) & (t_ev < jnp.inf)
        t = jnp.where(live, t_ev, s["now"])
        prio = d["prio"]
        fdt = prio.dtype
        # --- advance fold (Simulator._advance_to, pre-event state) ----
        dt0 = t - s["last_adv"]
        dt = jnp.where(live & (dt0 > 0.0), dt0, 0.0)
        ql = s["queue_len"]
        co = s["cur_occup"]
        act = (ql > 0) | (co > 0)
        occf = co.astype(fdt)
        to = s["total_occup"] + jnp.where(act, occf * dt, 0.0)
        bvt = s["bvt"] + jnp.where(act, dt, 0.0)
        x = jnp.where(act, occf / prio, 0.0)
        actn, s1, s2 = jnp.sum(                 # one fused reduction
            jnp.stack([act.astype(fdt), x, x * x]), axis=-1)
        jain = jnp.where(s2 > 0.0, s1 * s1 / (actn * s2), 1.0)
        two_act = actn >= 2.0
        jain_acc = s["jain_acc"] + jnp.where(two_act, jain * dt, 0.0)
        jain_t = s["jain_t"] + jnp.where(two_act, dt, 0.0)
        # --- arrival branch (FMQ push: admit -> overflow -> ECN) ------
        ia = d["arr_tenant"][na]
        qa = ql[ia]
        marr = live & is_arr
        full = qa >= d["fifo_cap"]
        acc = marr & (~full)
        drop = marr & full
        mark = acc & ((qa + 1) >= d["ecn_thresh"])
        # --- completion branch (slot meta packed: pkt | kill<<30 |
        # budget-kill<<31; tenant is derivable from the packet id) ------
        mcomp = live & (~is_arr)
        pk = s["s_ps"][pc, 0]            # slot pairs: (pkt-meta, seq)
        jc = pk & jnp.int32((1 << 30) - 1)
        ic = d["arr_tenant"][jc]
        kflag = (((pk >> 30) & 1) != 0) & mcomp
        bkflag = (((pk >> 31) & 1) != 0) & mcomp
        one = jnp.int32(1)
        zero = jnp.int32(0)
        # --- apply (masked scatters aim at the pad slot/column) -------
        ql = ql.at[ia].add(jnp.where(acc, one, zero))
        co = co.at[ic].add(jnp.where(mcomp, -one, zero))
        tail = jnp.mod(s["fifo_head"][ia] + qa, C)
        tail_w = jnp.where(acc, tail, C)
        buf = s["fifo_buf"].at[ia, tail_w].set(na)
        # the freed slot keeps its stale seq: seqs are only consulted
        # among the tfin == tmin slots, and a freed slot sits at +inf
        # until the next grant overwrites both fields
        pc_w = jnp.where(mcomp, pc, P)
        tf2 = s["s_tf"].at[pc_w, 0].set(jnp.inf)
        free = s["free_pus"] + jnp.where(mcomp, one, zero)
        # --- per-step records (step order IS host heap-pop order, so
        # the completion stream needs no carried per-packet arrays; the
        # packed slot meta ships as-is, -1 = no completion) -------------
        # host op order: now - (t0 - dma_ns), NOT now - grant
        ktime = t - (s["s_tf"][pc, 1] - dma_ns)
        comp_meta = jnp.where(mcomp, pk, jnp.int32(-1))
        # --- EQ (at most one event per step; code | tenant<<3 packed) -
        eq_code = jnp.where(drop, jnp.int32(2), jnp.where(mark, one, zero))
        eq_code = jnp.where(
            kflag, jnp.where(bkflag, jnp.int32(4), jnp.int32(3)), eq_code)
        eq_pack = eq_code | (jnp.where(is_arr, ia, ic).astype(jnp.int32)
                             << 3)
        s = {
            **s,
            "na": na + jnp.where(marr, one, zero),
            "now": jnp.where(live, t, s["now"]),
            "last_adv": jnp.where(live, t, s["last_adv"]),
            "queue_len": ql, "cur_occup": co,
            "total_occup": to, "bvt": bvt,
            "fifo_buf": buf, "s_tf": tf2,
            "free_pus": free, "jain_acc": jain_acc, "jain_t": jain_t,
        }
        aux = {
            "t": t,
            "free_k": jnp.where(live, free, zero),
            "eq_pack": eq_pack, "comp_meta": comp_meta,
            "comp_ktime": jnp.where(mcomp, ktime, 0.0),
        }
        return s, aux

    def _rr_pick(ptr, ql, co, free_k):
        """Host `_dispatch` rr arm, single-grant form: the pointer only
        advances on an actual grant (host never probes with 0 free)."""
        idx, ptr1 = G.select_rr(ptr, ql, jnp)
        can = (idx >= 0) & (free_k > 0)
        iv = jnp.where(can, idx, 0)
        lane = lax.broadcasted_iota(jnp.int32, ql.shape, 0)
        hot = (lane == iv) & can
        ql = ql - hot.astype(ql.dtype)
        co = co + hot.astype(co.dtype)
        ptr = jnp.where(can, ptr1, ptr).astype(jnp.int32)
        pick = jnp.where(can, idx, -1).astype(jnp.int32)
        return pick, ptr, ql, co

    def _apply_one(s, d, pick, t):
        """Host ``_pop_and_start`` for the (single) winner: FIFO pop,
        budget clamps (exact op order of the inlined BudgetLedger
        mirror), slot fill, ``(t_fin, seq)`` heap push."""
        won = pick >= 0
        i = jnp.where(won, pick, 0)
        head_i = s["fifo_head"][i]
        j = s["fifo_buf"][i, jnp.mod(head_i, C)]
        head = s["fifo_head"].at[i].add(jnp.where(won, jnp.int32(1),
                                                  jnp.int32(0)))
        comp = d["arr_comp"][j]
        lm = d["lims"][i]                 # (klim, tlim) in one gather
        klim = lm[0]
        kill1 = (klim > 0) & (comp > klim)
        comp = jnp.where(kill1, klim, comp)
        tlim = lm[1]
        remaining = tlim - s["spent"][i]
        bk = (tlim > 0) & (comp > remaining)
        comp = jnp.where(bk, jnp.where(remaining > 0.0, remaining, 0.0),
                         comp)
        spent = s["spent"].at[i].add(jnp.where(won, comp, 0.0))
        slot = jnp.argmax(s["s_tf"][:, 0] == jnp.inf)  # any free slot:
        sw = jnp.where(won, slot, P)                   # order (t_fin, seq)
        t0v = t + dma_ns
        tfv = t0v + comp * ns_per_cycle
        meta = (j | ((kill1 | bk).astype(jnp.int32) << 30)
                | (bk.astype(jnp.int32) << 31))
        return {
            **s,
            "fifo_head": head, "spent": spent,
            "s_tf": s["s_tf"].at[sw].set(
                jnp.stack([jnp.where(won, tfv, jnp.inf), t0v])),
            "s_ps": s["s_ps"].at[sw].set(
                jnp.stack([meta, jnp.where(won, s["seq"], sent)])),
            "seq": s["seq"] + jnp.where(won, jnp.int32(1), jnp.int32(0)),
            "free_pus": s["free_pus"] - jnp.where(won, jnp.int32(1),
                                                  jnp.int32(0)),
        }

    def _step(st, data):
        st, aux = jax.vmap(_pre)(st, data)
        if wlbvt:
            picks, ql2, co2 = wlbvt_select_rounds(
                data["prio"], st["queue_len"], st["cur_occup"],
                st["total_occup"], st["bvt"], aux["free_k"],
                num_pus=P, max_picks=1, impl=impl)
            pick = picks[:, 0]
            st = {**st, "queue_len": ql2, "cur_occup": co2}
        else:
            pick, ptr2, ql2, co2 = jax.vmap(_rr_pick)(
                st["rr_ptr"], st["queue_len"], st["cur_occup"],
                aux["free_k"])
            st = {**st, "rr_ptr": ptr2, "queue_len": ql2, "cur_occup": co2}
        st = jax.vmap(_apply_one)(st, data, pick, aux["t"])
        return st, (aux["eq_pack"], aux["t"], aux["comp_meta"],
                    aux["comp_ktime"])

    def _launch(state, data):
        def body(st, _):
            return _step(st, data)
        return lax.scan(body, state, None, length=S)

    return jax.jit(_launch)


# ---------------------------------------------------------------------------
# host side: spec -> replica arrays -> launch -> results
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DeviceRunResult:
    """Per-replica result with the host ``SimResult`` observables the
    device contract covers (stats are real ``TenantStats``; EQ events
    carry the host ring's last-4096 retention)."""
    spec: object
    time: float
    stats: Dict[int, "object"]
    jain_pu_timeavg: float
    jain_io_timeavg: float
    events: List[Event]
    events_dropped: int
    completions: List[Tuple[int, float]]
    counters: Dict[str, np.ndarray]
    sched_state: dict

    def throughput_gbps(self, tenant: int) -> float:
        st = self.stats[tenant]
        return st.served_payload_bytes * 8.0 / max(self.time, 1e-9)

    def summary_row(self, knobs: Optional[dict] = None) -> dict:
        """Flat JSON-portable sweep report row (RunReport-style)."""
        row = {
            "scenario": self.spec.name,
            "seed": self.spec.seed,
            "knobs": dict(knobs or {}),
            "time_ns": self.time,
            "jain_pu_timeavg": self.jain_pu_timeavg,
            "events": len(self.events),
            "tenants": [],
        }
        for i, t in enumerate(self.spec.tenants):
            st = self.stats[i]
            row["tenants"].append({
                "name": t.name,
                "completed": st.completed,
                "killed": st.killed,
                "drops": st.drops,
                "ecn_marks": int(self.counters["ecn_marks"][i]),
                "throughput_gbps": self.throughput_gbps(i),
                "p50_kernel_ns": st.kernel_time_percentile(50),
                "p99_kernel_ns": st.kernel_time_percentile(99),
            })
        return row


def _spec_arrays(spec, ftype) -> dict:
    """Replica-local host arrays for one spec (trace + per-tenant
    config), with the exact float ops ``BatchedSimulator._inject``
    applies (payload clamp, compute-cycles formula)."""
    from repro.api.runtime import build_traces
    ta = build_traces(spec, arrays=True)
    tn = ta.tenants.astype(np.int64)
    sz = ta.sizes.astype(np.int64)
    payload = np.maximum(0, sz - PSPIN.header_bytes)
    wls = [t.workload.build() for t in spec.tenants]
    spin = np.array([w.spin_factor for w in wls])
    base = np.array([w.compute_base for w in wls])
    cpb = np.array([w.compute_per_byte for w in wls])
    comp = spin[tn] * (base[tn] + cpb[tn] * payload)
    cap = int(spec.fifo_capacity)
    thresh = max(1, (3 * cap) // 4)                          # FMQ default
    horizon = spec.horizon_us * 1e3 if spec.horizon_us else np.inf
    return {
        "n": len(ta),
        "n_live": int(np.sum(ta.times <= horizon)),
        "arr_t": ta.times.astype(np.float64),
        "arr_tenant": tn.astype(np.int32),
        "arr_size": sz.astype(ftype),
        "arr_payload": payload.astype(ftype),
        "arr_comp": comp.astype(ftype),
        "prio": np.array([t.priority for t in spec.tenants], ftype),
        "fifo_cap": np.int32(cap),
        "ecn_thresh": np.int32(thresh),
        "klim": np.array([float(t.kernel_cycle_limit)
                          for t in spec.tenants], ftype),
        "tlim": np.array([float(t.total_cycle_limit)
                          for t in spec.tenants], ftype),
        "horizon": ftype(horizon),
    }


def _stack_data(per_spec: List[dict], ftype) -> Tuple[dict, np.ndarray, int]:
    """Pad ragged replica arrays to a common grid; index NB is the inert
    sentinel row (arrival at +inf / zero-size packet).  Only what the
    traced step reads ships to the device — sizes/payloads stay host-side
    and the counters are reconstructed from the EQ/completion streams."""
    R = len(per_spec)
    NB = max(a["n"] for a in per_spec)
    arr_t = np.full((R, NB + 1), np.inf, np.float64)
    arr_tenant = np.zeros((R, NB + 1), np.int32)
    arr_comp = np.zeros((R, NB + 1), ftype)
    n_arr = np.zeros(R, np.int32)
    for r, a in enumerate(per_spec):
        n = a["n"]
        n_arr[r] = n
        arr_t[r, :n] = a["arr_t"]
        arr_tenant[r, :n] = a["arr_tenant"]
        arr_comp[r, :n] = a["arr_comp"]
    data = {
        "arr_t": arr_t.astype(ftype),
        "arr_tenant": arr_tenant,
        "arr_comp": arr_comp,
        "prio": np.stack([a["prio"] for a in per_spec]),
        "fifo_cap": np.array([a["fifo_cap"] for a in per_spec], np.int32),
        "ecn_thresh": np.array([a["ecn_thresh"] for a in per_spec],
                               np.int32),
        "lims": np.stack([np.stack([a["klim"], a["tlim"]], axis=-1)
                          for a in per_spec]),
        "horizon": np.array([a["horizon"] for a in per_spec], ftype),
    }
    return data, n_arr, NB


def _init_state(R: int, T: int, P: int, C: int, NB: int, n_arr,
                ftype) -> dict:
    """Slot arrays carry an inert pad at index P and the FIFO ring a
    discard column at index C (masked scatters aim there, see
    ``_build_launch``); no per-tenant counters ride the carry — they are
    all recoverable from the EQ/completion streams in ``_materialize``."""
    i32 = np.int32
    return {
        "now": np.zeros(R, ftype),
        "last_adv": np.zeros(R, ftype),
        "na": np.zeros(R, i32),
        "seq": n_arr.astype(i32),          # completion seqs start at n
        "free_pus": np.full(R, P, i32),
        "rr_ptr": np.zeros(R, i32),
        "queue_len": np.zeros((R, T), i32),
        "cur_occup": np.zeros((R, T), i32),
        "total_occup": np.zeros((R, T), ftype),
        "bvt": np.zeros((R, T), ftype),
        "fifo_head": np.zeros((R, T), i32),
        "fifo_buf": np.zeros((R, T, C + 1), i32),
        "spent": np.zeros((R, T), ftype),
        # slot pairs: s_tf = (t_fin, t0) float, s_ps = (pkt-meta, seq)
        # int32 — paired so grant/free are single row scatters
        "s_tf": np.stack([np.full((R, P + 1), np.inf, ftype),
                          np.zeros((R, P + 1), ftype)], axis=-1),
        "s_ps": np.stack([np.full((R, P + 1), NB, i32),
                          np.full((R, P + 1), np.iinfo(np.int32).max,
                                  i32)], axis=-1),
        "jain_acc": np.zeros(R, ftype),
        "jain_t": np.zeros(R, ftype),
    }


def _materialize(spec, a: dict, fin_state, ys, r: int,
                 record_completions: bool) -> DeviceRunResult:
    """Rebuild the host-side result objects for replica ``r`` (``a`` is
    the replica's ``_spec_arrays`` dict)."""
    from repro.sim.engine import TenantStats
    T = len(spec.tenants)
    g = {k: np.asarray(v[r]) for k, v in fin_state.items()}
    (eq_pack, eq_t, comp_meta, comp_ktime) = (np.asarray(y[:, r])
                                              for y in ys)
    eq_code = eq_pack & 7
    eq_ten = eq_pack >> 3
    time = float(g["now"])
    # step order IS the host heap-pop (t_fin, seq) order
    steps = np.flatnonzero(comp_meta != -1)
    meta = comp_meta[steps]
    arr_tenant = a["arr_tenant"].astype(np.int64)
    arr_t = a["arr_t"]
    na = int(g["na"])
    fin = eq_t[steps]
    ktimes = comp_ktime[steps]
    killed = ((meta >> 30) & 1) != 0        # pkt | kill<<30 | bk<<31
    pkts = (meta & ((1 << 30) - 1)).astype(np.int64)
    ten_of = arr_tenant[pkts]
    if record_completions:
        completions = [(int(i), float(t))
                       for i, t in zip(ten_of, fin)]
    else:
        completions = []
    # counters reconstructed from the streams (nothing rides the carry):
    # arrivals/bytes from the first na trace rows, drops/marks from EQ
    # codes, completions from the (packet, killed) stream.  Byte sums are
    # nonnegative integers < 2^53, so order of summation is irrelevant.
    tb = np.arange(T + 1, dtype=np.int64)
    arrivals = np.histogram(arr_tenant[:na], bins=tb)[0]
    bytes_in = np.histogram(arr_tenant[:na], bins=tb,
                            weights=a["arr_size"][:na].astype(np.float64))[0]
    drops = np.histogram(eq_ten[eq_code == 2], bins=tb)[0]
    ecn_marks = np.histogram(eq_ten[eq_code == 1], bins=tb)[0]
    completed = np.histogram(ten_of[~killed], bins=tb)[0]
    n_killed = np.histogram(ten_of[killed], bins=tb)[0]
    payload = a["arr_payload"].astype(np.float64)
    bytes_out = np.histogram(ten_of[~killed], bins=tb,
                             weights=payload[pkts[~killed]])[0]
    counters = {
        "arrivals": arrivals,
        "drops": drops,
        "ecn_marks": ecn_marks,
        "enqueued": arrivals - drops,
        "completed": completed,
        "killed": n_killed,
        "bytes_in": bytes_in,
        "bytes_out": bytes_out,
    }
    stats: Dict[int, TenantStats] = {}
    for i in range(T):
        st = TenantStats(
            completed=int(counters["completed"][i]),
            killed=int(counters["killed"][i]),
            drops=int(counters["drops"][i]),
            served_payload_bytes=float(counters["bytes_out"][i]),
        )
        proc = arr_tenant[:na] == i
        if proc.any():
            st.first_arrival = float(arr_t[:na][proc].min())
        mine = np.flatnonzero(ten_of == i)
        if mine.size:
            st.last_completion = float(fin[mine].max())
            # completion order: exact reservoir replay, vectorized
            st.record_kernel_times(ktimes[mine])
        stats[i] = st
    live = np.flatnonzero(eq_code > 0)
    dropped = max(0, live.size - EQ_RING_CAPACITY)
    live = live[dropped:]                 # trim before materializing
    events = [Event(tenant=int(eq_ten[k]), kind=_EQ_KINDS[int(eq_code[k])],
                    time=float(eq_t[k])) for k in live]
    jt = float(g["jain_t"])
    cap = np.full(T, int(spec.fifo_capacity), np.float64)
    return DeviceRunResult(
        spec=spec,
        time=time,
        stats=stats,
        jain_pu_timeavg=float(g["jain_acc"]) / jt if jt else 1.0,
        jain_io_timeavg=1.0,
        events=events,
        events_dropped=dropped,
        completions=completions,
        counters=counters,
        sched_state={
            "prio": a["prio"].astype(np.float64),
            "total_occup": g["total_occup"].astype(np.float64),
            "bvt": g["bvt"].astype(np.float64),
            "kv_pressure": g["queue_len"].astype(np.float64) / cap,
        },
    )


def run_sweep_specs(specs: Sequence, *, impl: str = "",
                    precision: str = "exact",
                    record_completions: bool = False,
                    ) -> List[DeviceRunResult]:
    """Run every spec as one replica lane of a single device launch.

    All specs must share tenant count and scheduler (one ``SweepSpec``
    expansion always does).  ``precision="exact"`` traces under a scoped
    ``enable_x64`` for bit-exact f64 parity with the host datapaths;
    ``"fast"`` uses f32 lanes (TPU-native).  ``record_completions``
    materializes the per-packet completion list (parity tests); sweeps
    keep it off — the summary rows never read it.
    """
    if not specs:
        return []
    for spec in specs:
        reason = device_eligible(spec)
        if reason:
            raise DevicePathError(
                f"spec {spec.name!r} needs a host datapath: {reason}")
    T = len(specs[0].tenants)
    sched = specs[0].scheduler
    for spec in specs:
        if len(spec.tenants) != T or spec.scheduler != sched:
            raise DevicePathError(
                "sweep replicas must share tenant count and scheduler "
                f"(got T={len(spec.tenants)}/{T}, "
                f"scheduler={spec.scheduler!r}/{sched!r})")
    if precision == "exact":
        from jax.experimental import enable_x64
        with enable_x64():
            return _run_batch(list(specs), np.float64, sched, impl,
                              record_completions)
    if precision == "fast":
        return _run_batch(list(specs), np.float32, sched, impl,
                          record_completions)
    raise ValueError(f"unknown precision {precision!r} (exact|fast)")


def _run_batch(specs, ftype, sched: str, impl: str,
               record_completions: bool):
    T = len(specs[0].tenants)
    P = PSPIN.num_pus
    per_spec = [_spec_arrays(s, ftype) for s in specs]
    data, n_arr, NB = _stack_data(per_spec, ftype)
    if NB >= (1 << 30) - 1:   # slot meta packs pkt | kill<<30 | bk<<31
        raise DevicePathError(f"trace too long for device path ({NB})")
    C = max(1, min(int(max(s.fifo_capacity for s in specs)), NB))
    S = 2 * max(a["n_live"] for a in per_spec) + 2
    state = _init_state(len(specs), T, P, C, NB, n_arr, ftype)
    launch = _build_launch(T, P, C, S, sched, impl)
    fin_state, eq = launch(state, data)
    fin_state = jax.tree_util.tree_map(np.asarray, fin_state)
    eq = jax.tree_util.tree_map(np.asarray, eq)
    return [_materialize(s, per_spec[r], fin_state, eq, r,
                         record_completions)
            for r, s in enumerate(specs)]


def run_device(spec, *, impl: str = "",
               precision: str = "exact",
               record_completions: bool = True) -> DeviceRunResult:
    """Single-scenario convenience wrapper (R=1 sweep)."""
    return run_sweep_specs([spec], impl=impl, precision=precision,
                           record_completions=record_completions)[0]
