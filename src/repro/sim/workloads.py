"""Kernel cost models for the PsPIN datapath simulator (paper §3, §7.4).

Cycle costs are calibrated to the paper's qualitative anchors:
  * Fig. 3: every workload at ≤64B packets exceeds PPB(32PU, P, 400G);
    compute-bound kernels scale linearly with payload and exceed PPB at all
    sizes; IO-bound kernels ≥256B fit PPB.
  * Fig. 7: 4 clusters (32 PUs) sustain Reduce up to 512B packets:
    PPB(32, 512B, 400G) ≈ 327 cycles -> reduce ≈ 0.6 cy/B + base.
Exact constants are estimates; every experiment compares policies under
*identical* cost models, so conclusions track the paper's.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class WorkloadModel:
    name: str
    compute_base: float = 50.0       # handler entry/exit cycles
    compute_per_byte: float = 0.0    # PU cycles per payload byte
    io_kind: str = "none"            # none | dma_read | dma_write | egress
    io_bytes_factor: float = 1.0     # transfer bytes = factor * payload
    io_fixed_bytes: int = 0          # storage-RPC amplification: a small
    #                                  request triggers a fixed-size transfer
    blocking_io: bool = True         # PU held until the transfer completes
    spin_factor: float = 1.0         # synthetic congestor multiplier

    def compute_cycles(self, payload: int) -> float:
        return self.spin_factor * (self.compute_base
                                   + self.compute_per_byte * payload)

    def io_bytes(self, payload: int) -> int:
        if self.io_kind == "none":
            return 0
        if self.io_fixed_bytes:
            return self.io_fixed_bytes
        return int(self.io_bytes_factor * payload)


# -- paper workload set (§7.4) ------------------------------------------------
AGGREGATE = WorkloadModel("aggregate", 40, 0.50)
REDUCE = WorkloadModel("reduce", 50, 0.60, io_kind="egress",
                       io_bytes_factor=1.0)
HISTOGRAM = WorkloadModel("histogram", 60, 1.10)
IO_READ = WorkloadModel("io_read", 80, 0.05, io_kind="dma_read")
IO_WRITE = WorkloadModel("io_write", 80, 0.05, io_kind="dma_write")
FILTERING = WorkloadModel("filtering", 90, 0.30, io_kind="dma_write")
EGRESS_SEND = WorkloadModel("egress_send", 60, 0.05, io_kind="egress")

WORKLOADS: Dict[str, WorkloadModel] = {
    w.name: w for w in (AGGREGATE, REDUCE, HISTOGRAM, IO_READ, IO_WRITE,
                        FILTERING, EGRESS_SEND)
}


def spin_workload(name: str, cycles_per_byte: float,
                  base: float = 40.0) -> WorkloadModel:
    """Pure compute spin loop (paper §7.3 Congestor/Victim)."""
    return WorkloadModel(name, base, cycles_per_byte)


def ppb(num_pus: int, packet_bytes: int, link_gbps: float) -> float:
    """Per-packet budget in cycles at 1 GHz (paper §3): N * P / B."""
    return num_pus * packet_bytes * 8.0 / link_gbps
