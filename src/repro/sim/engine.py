"""Discrete-event, cycle-level simulator of the OSMOSIS/PsPIN datapath.

Models (paper §6-§7 setup): 4 clusters × 8 PUs @ 1 GHz, 400 Gbit/s
ingress/egress, 512 Gbit/s shared AXI for DMA + egress-buffer writes,
per-FMQ FIFOs, WLBVT (or RR) PU scheduling, DWRR IO arbitration with
off/software/hardware transfer fragmentation, per-kernel watchdog budgets,
and an EQ control path served at highest IO priority.

Event timing is exact: WLBVT's per-cycle ``update_tput`` is integrated
lazily over piecewise-constant occupancy intervals (numerically identical
to the per-cycle update).

This is the *reference* event-loop path: one Python callback per event,
trivially auditable against the paper's mechanism descriptions.  The
tenant/budget/EQ/telemetry plumbing lives in ``core/engine_base.py``
(shared with the serving engine), and the array-batched fast path in
``sim/fastpath.py`` reproduces this engine's decisions bit-for-bit at
>=10x the packet rate (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.osmosis_pspin import PSPIN, PsPINConfig
from repro.core import (ECTX, EngineBase, EventKind, Event, FMQ,
                        FragmentationPolicy, MatchingEngine,
                        PacketDescriptor, PushResult, fragment_transfer)
from repro.core.accounting import jain_fairness
from repro.core.engine_base import BudgetLedger
from repro.core import wlbvt as W
from repro.sim.traffic import TracePacket
from repro.sim.workloads import WorkloadModel
from repro.telemetry import G_IDX, GAUGES, Telemetry
from repro.telemetry import trace as TR

KT_RESERVOIR_CAP = 4096   # kernel-time samples retained per tenant
_KT_RNG_SEED = 0xA11CE    # reservoir replacement stream (deterministic)

# module-local copies of the hot trace dispositions: one global load in
# the per-completion path instead of a module-attribute lookup
_D_OK, _D_MARK, _D_KILL = TR.D_OK, TR.D_MARK, TR.D_KILL


@dataclasses.dataclass
class TenantStats:
    completed: int = 0
    killed: int = 0
    drops: int = 0
    served_payload_bytes: float = 0.0
    io_bytes_done: float = 0.0
    first_arrival: float = float("inf")
    last_completion: float = 0.0
    # kernel service times: bounded reservoir (Algorithm R once past the
    # cap) + exact running count/sum — percentiles derive from the
    # reservoir instead of an unbounded list (below the cap the sample
    # is complete, so they are exact)
    kernel_time_count: int = 0
    kernel_time_sum: float = 0.0
    _kt_buf: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False, compare=False)
    _kt_rng: Optional[np.random.Generator] = dataclasses.field(
        default=None, repr=False, compare=False)
    _kt_pcache: Optional[dict] = dataclasses.field(
        default=None, repr=False, compare=False)

    def record_kernel_time(self, value: float) -> None:
        n = self.kernel_time_count
        if self._kt_buf is None:
            self._kt_buf = np.empty(KT_RESERVOIR_CAP)
        if n < KT_RESERVOIR_CAP:
            self._kt_buf[n] = value
        else:
            if self._kt_rng is None:
                self._kt_rng = np.random.default_rng(_KT_RNG_SEED)
            j = int(self._kt_rng.integers(0, n + 1))
            if j < KT_RESERVOIR_CAP:
                self._kt_buf[j] = value
        self.kernel_time_count = n + 1
        self.kernel_time_sum += value
        self._kt_pcache = None

    def record_kernel_times(self, values: np.ndarray) -> None:
        """Bulk replay of ``record_kernel_time`` over ``values`` in
        order, bit-identical to the sequential calls: the fill phase is
        a copy, the sum a ``cumsum`` tail (left-to-right accumulation,
        same rounding as ``+=``), and only samples past the reservoir
        cap walk the replacement rng one draw at a time."""
        values = np.asarray(values, dtype=float)
        if values.size == 0:
            return
        if self.kernel_time_count or self._kt_buf is not None:
            for v in values:              # mid-stream: no shortcut
                self.record_kernel_time(float(v))
            return
        buf = np.empty(KT_RESERVOIR_CAP)
        m = min(values.size, KT_RESERVOIR_CAP)
        buf[:m] = values[:m]
        self._kt_buf = buf
        if values.size > KT_RESERVOIR_CAP:
            rng = np.random.default_rng(_KT_RNG_SEED)
            for k in range(KT_RESERVOIR_CAP, values.size):
                j = int(rng.integers(0, k + 1))
                if j < KT_RESERVOIR_CAP:
                    buf[j] = values[k]
            self._kt_rng = rng
        self.kernel_time_count = int(values.size)
        self.kernel_time_sum = float(values.cumsum()[-1])
        self._kt_pcache = None

    @property
    def kernel_times(self) -> np.ndarray:
        """The retained kernel-time sample (complete below the cap).
        ``kernel_time_count``/``kernel_time_sum`` are always exact."""
        if self._kt_buf is None:
            return np.empty(0)
        return self._kt_buf[:min(self.kernel_time_count, KT_RESERVOIR_CAP)]

    def kernel_time_percentile(self, q: float) -> float:
        """Reservoir percentile, cached until the next sample lands."""
        if self.kernel_time_count == 0:
            return 0.0
        if self._kt_pcache is None:
            self._kt_pcache = {}
        if q not in self._kt_pcache:
            self._kt_pcache[q] = float(np.percentile(self.kernel_times, q))
        return self._kt_pcache[q]

    @property
    def fct(self) -> float:
        """Flow completion time: ``last_completion - first_arrival``.

        Explicitly 0.0 when the tenant saw no arrivals (packets injected
        before registration leave ``first_arrival`` unset) or no
        completions — previously the ``min(first_arrival,
        last_completion)`` guard silently collapsed those to 0."""
        if self.last_completion <= 0 or self.first_arrival == float("inf"):
            return 0.0
        return max(0.0, self.last_completion - self.first_arrival)


@dataclasses.dataclass
class SimResult:
    """Backend-native result bundle.

    Deprecated as a public surface: external consumers should run
    through ``repro.api`` (``SimRuntime``/``run_scenario``) and consume
    the portable, backend-neutral ``RunReport`` instead (DESIGN.md §7).
    """
    time: float
    stats: Dict[int, TenantStats]
    jain_pu_timeavg: float
    jain_io_timeavg: float
    timeline: Optional[dict] = None
    events: List[Event] = dataclasses.field(default_factory=list)
    telemetry: Optional[Telemetry] = None
    sched_state: Optional[dict] = None   # final prio/total_occup/bvt +
    #                                      FIFO pressure, for signal reads
    completions: Optional[list] = None   # (tenant, t) per kernel finish,
    #                                      when record_completions is set

    def throughput_gbps(self, tenant: int) -> float:
        st = self.stats[tenant]
        return st.served_payload_bytes * 8.0 / max(self.time, 1e-9)

    def p50(self, tenant: int) -> float:
        return self.stats[tenant].kernel_time_percentile(50)

    def p99(self, tenant: int) -> float:
        return self.stats[tenant].kernel_time_percentile(99)


class Simulator(EngineBase):
    def __init__(self, tenants: List[ECTX], *,
                 scheduler: str = "wlbvt",
                 frag: Optional[FragmentationPolicy] = None,
                 arb: str = "dwrr",
                 hw: PsPINConfig = PSPIN,
                 fifo_capacity: int = 4096,
                 io_demand_weights=None,
                 record_timeline: bool = False,
                 controller=None,
                 control_interval_ns: float = 8000.0,
                 record_completions: bool = False,
                 trace: bool = False,
                 trace_depth: int = 65536,
                 trace_decision_depth: int = 8192):
        T = len(tenants)
        super().__init__(T, shared_eq=True, trace=trace,
                         trace_depth=trace_depth,
                         trace_decision_depth=trace_decision_depth,
                         trace_pus=hw.num_pus)
        self.hw = hw
        self.sched_kind = scheduler
        self.frag = frag or FragmentationPolicy(mode="off")
        self.record_timeline = record_timeline
        self.record_completions = record_completions

        self.fmqs: List[FMQ] = []
        self.matching = MatchingEngine()
        for i, e in enumerate(tenants):
            self.register_tenant(e, fmq_index=i)
            self.fmqs.append(FMQ(index=i, ectx=e, capacity=fifo_capacity))
        prios = [e.slo.priority for e in tenants]
        self.st = W.WLBVTState.create(prios)
        self.rr_ptr = 0

        self.free_pus = hw.num_pus

        # AXI: per-tenant fragment queues; entries are
        # (Fragment, kind, done_cb|None).  arb: "dwrr" (OSMOSIS) or "fifo"
        # (reference PsPIN — a blocking interconnect with no QoS: grants in
        # strict arrival order => HoL blocking, paper Fig. 5).
        self.arb = arb
        self.axi_q: List[deque] = [deque() for _ in range(T)]
        self.axi_fifo: deque = deque()     # arrival order (fifo mode)
        self.axi_ctrl: deque = deque()     # EQ/control traffic, R5 priority
        self.axi_busy = False
        self.dwrr = W.DWRRState.create(
            [e.slo.dma_priority for e in tenants])
        # egress link: same arbitration discipline as the DMA engine
        self.egress_q: List[deque] = [deque() for _ in range(T)]
        self.egress_fifo: deque = deque()
        self.egress_busy = False
        self.egress_dwrr = W.DWRRState.create(
            [e.slo.egress_priority for e in tenants])

        self._events: list = []
        self._seq = 0
        self.now = 0.0
        self._last_adv = 0.0
        self.stats: Dict[int, TenantStats] = {i: TenantStats()
                                              for i in range(T)}
        self._completions: list = []
        # fairness integrals; IO fairness uses windowed byte counts so the
        # metric reflects per-window shares, not event granularity
        self._jain_pu_acc = 0.0
        self._jain_pu_t = 0.0
        self._jain_io_acc = 0.0
        self._jain_io_t = 0.0
        self.io_window_ns = 2000.0
        self.io_demand_weights = (np.ones(T) if io_demand_weights is None
                                  else np.asarray(io_demand_weights, float))
        self._win_start = 0.0
        self._win_io = np.zeros(T)
        self._win_act = np.zeros(T, bool)
        self._io_bytes_cum = np.zeros(T)
        self._tl: Dict[str, list] = {"t": [], "occup": [], "io_win": [],
                                     "qlen": []}
        # telemetry plane (EngineBase; always on, committed at window
        # boundaries) + optional closed-loop QoS controller
        self.controller = controller
        # SLO-configured base weights per knob: the controller scales
        # these (live = base * boost), never overwrites them
        self._sched_base = (self.st.prio.copy(), self.dwrr.weights.copy(),
                            self.egress_dwrr.weights.copy())
        self._ctrl_every = max(1, int(round(control_interval_ns
                                            / self.io_window_ns)))
        self._win_count = 0
        self._gauges_buf = np.zeros((len(GAUGES), T))
        # trace plane (EngineBase seam; None unless trace=True): uids are
        # assigned in arrival-processing order, and a tracing-only free-slot
        # mirror attributes PU_EXEC spans to slots exactly like the batched
        # datapath's slot table (list(range(P-1,-1,-1)), pop from the end)
        self._tr_uid = 0
        self._tr_free = (list(range(hw.num_pus - 1, -1, -1))
                         if self.trace is not None else None)
        # tracing-only slot columns (uid / grant / t_comp / packet), so
        # the hot paths never allocate per-packet records: pkt.meta is
        # the uid while queued, then the slot index once granted.  A
        # finished slot's packet ref goes stale rather than being
        # cleared — trace_flush walks only busy (non-free) slots
        P = hw.num_pus
        self._tr_s_uid = [0] * P
        self._tr_s_grant = [0.0] * P
        self._tr_s_tcomp = [0.0] * P
        self._tr_s_pkt: List[Optional[PacketDescriptor]] = [None] * P

    # -- event machinery ---------------------------------------------------
    def _post(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._events, (t, self._seq, fn))
        self._seq += 1

    def _advance_to(self, t: float) -> None:
        dt = t - self._last_adv
        if dt <= 0:
            return
        # WLBVT bookkeeping (lazy per-cycle integration)
        W.advance(self.st, dt)
        # fairness integrals over the interval
        occ = self.st.cur_occup.astype(float)
        act = self.st.active
        if act.sum() >= 2:
            prio = self.st.prio
            self._jain_pu_acc += jain_fairness((occ / prio)[act]) * dt
            self._jain_pu_t += dt
        self._win_act |= act
        while t - self._win_start >= self.io_window_ns:
            wa = self._win_act
            if wa.sum() >= 2 and self._win_io.sum() > 0:
                dma_w = np.array([f.ectx.slo.dma_priority
                                  for f in self.fmqs])
                w = dma_w * self.io_demand_weights
                self._jain_io_acc += jain_fairness(
                    (self._win_io / w)[wa]) * self.io_window_ns
                self._jain_io_t += self.io_window_ns
            if self.record_timeline:
                self._tl["t"].append(self._win_start)
                self._tl["occup"].append(occ.copy())
                self._tl["io_win"].append(self._win_io.copy())
                self._tl["qlen"].append(self.st.queue_len.copy())
            self._commit_window(occ)
            self._win_io[:] = 0.0
            self._win_act = self.st.active.copy()
            self._win_start += self.io_window_ns
        self._last_adv = t

    def _kv_pressure_row(self) -> np.ndarray:
        """Per-tenant FIFO pressure (depth / capacity) — the sim analogue
        of the serving engine's KV pressure gauge.  The batched fast path
        overrides this with its SoA depth counters (same values)."""
        return np.array([len(f) / f.capacity for f in self.fmqs])

    def drain_tenant_queue(self, tenant: int) -> List[Tuple[float, int]]:
        """Live-migration drain (DESIGN.md §12.3): pull every queued —
        not yet scheduled — packet out of one tenant's FMQ, returning
        ``(arrival_ns, size_bytes)`` rows in FIFO order for the fleet
        engine to replay on the destination NIC.  Work already
        executing on a PU finishes in place here; only queue state
        migrates.  Call between ``run`` slices, never mid-run."""
        fmq = self.fmqs[tenant]
        out = [(pd.arrival, pd.size_bytes) for pd in fmq.fifo]
        fmq.fifo.clear()
        if out:
            self.st.queue_len[tenant] -= len(out)
        return out

    def _commit_window(self, occ: np.ndarray) -> None:
        """Flush staged telemetry + push gauge samples for one IO window;
        publish the observability frame, then run the QoS control loop
        every ``_ctrl_every`` windows (observe-before-control, so a
        boundary-coincident SLO alert precedes the intervention)."""
        self.tel.commit()
        if self.trace is not None:
            self.trace.maybe_commit()   # batched ring scatter (size-gated)
        gauges = self._gauges_buf    # all rows overwritten below
        gauges[G_IDX["occupancy"]] = occ
        gauges[G_IDX["queue_len"]] = self.st.queue_len
        gauges[G_IDX["service_rate"]] = self._win_io / self.io_window_ns
        gauges[G_IDX["kv_pressure"]] = self._kv_pressure_row()
        self.tel.commit_window(gauges)
        self._win_count += 1
        win_end_ns = self._win_start + self.io_window_ns
        self.observe_tick(
            t=win_end_ns, prio=self.st.prio,
            total_occup=self.st.total_occup, bvt=self.st.bvt,
            kv_pressure=gauges[G_IDX["kv_pressure"]])
        if (self.controller is not None
                and self._win_count % self._ctrl_every == 0):
            pb, db, eb = self._sched_base
            self.qos_tick(
                prio=self.st.prio, total_occup=self.st.total_occup,
                bvt=self.st.bvt,
                kv_pressure=gauges[G_IDX["kv_pressure"]],
                knobs=((self.st.prio, pb), (self.dwrr.weights, db),
                       (self.egress_dwrr.weights, eb)),
                t=win_end_ns)

    # -- ingress -------------------------------------------------------------
    def _arrival(self, pkt: TracePacket) -> None:
        i = pkt.tenant  # tenant id == fmq index (matching by construction)
        fmq = self.fmqs[i]
        st = self.stats[i]
        st.first_arrival = min(st.first_arrival, self.now)
        self.tel.inc("arrivals", i)
        self.tel.inc("bytes_in", i, pkt.size)
        tr = self.trace
        if tr is not None:
            uid = self._tr_uid
            self._tr_uid += 1
        if not self._admit[i]:
            # controller backpressure: source-throttled before the FMQ.
            # Telemetry counts this as "rejected", NOT "drops" — drop_rate
            # feeds the controller's pressure signal, and counting gated
            # arrivals there would latch a paused tenant paused forever.
            st.drops += 1
            self.tel.inc("rejected", i)
            self.eqhub.push(Event(i, EventKind.BACKPRESSURE, self.now))
            if tr is not None:
                tr.span(TR.ST_ARRIVE, uid, i, self.now, self.now,
                        TR.D_REJECT)
                TR.record_admission_reject(tr, self.now, i)
            return
        pd = PacketDescriptor(i, pkt.size, self.now)
        res = fmq.push(pd)
        if res == PushResult.DROPPED:
            st.drops += 1
            self.tel.inc("drops", i)
            self.eqhub.push(Event(i, EventKind.QUEUE_OVERFLOW, self.now))
            if tr is not None:
                tr.span(TR.ST_ARRIVE, uid, i, self.now, self.now,
                        TR.D_DROP)
            return
        if res == PushResult.MARKED:
            # paper's mark-before-drop path: congestion signal surfaced
            # through the tenant EQ and the telemetry plane before losses
            self.tel.inc("ecn_marks", i)
            self.eqhub.push(Event(i, EventKind.ECN_MARK, self.now))
        if tr is not None:
            # all rows (ARRIVE included) are staged whole at
            # completion; the arrive disposition rides on pkt.ecn
            pd.meta = uid
        self.st.queue_len[i] += 1
        self._dispatch()

    # -- PU scheduling ---------------------------------------------------------
    def _pop_and_start(self, idx: int) -> None:
        pkt = self.fmqs[idx].pop()
        assert pkt is not None
        self.free_pus -= 1
        if self.trace is not None:
            slot = self._tr_free.pop()
            self._tr_s_uid[slot] = pkt.meta
            self._tr_s_grant[slot] = self.now
            pkt.meta = slot
            self._tr_s_pkt[slot] = pkt  # rows emitted whole at completion
        self._start_kernel(idx, pkt)

    def _dispatch(self) -> None:
        tr = self.trace
        if self.sched_kind == "rr":
            while self.free_pus > 0:
                idx, self.rr_ptr = W.select_rr(self.rr_ptr,
                                               self.st.queue_len)
                if idx < 0:
                    return
                if tr is not None:
                    TR.record_rr_pick(tr, self.now, TR.K_PU_RR, idx,
                                      self.st.queue_len, self.st.bvt)
                self.st.queue_len[idx] -= 1
                self.st.cur_occup[idx] += 1
                self._pop_and_start(idx)
            return
        if self.free_pus <= 0:
            return
        # one batched WLBVT round fills every free PU (select_k charges
        # queue_len/cur_occup per pick, matching the scalar loop)
        if tr is None:
            for idx in W.select_k(self.st, self.hw.num_pus, self.free_pus):
                if idx < 0:
                    break
                self._pop_and_start(int(idx))
            return
        # provenance: stage the picks + the post-round state; the
        # pre-round arrays are reconstructed at commit (the picks are
        # exactly the charge select_k applied).  The common round frees
        # exactly one PU, so the single-pick case skips the list
        npus = self.hw.num_pus
        first = -1
        picks = None
        for idx in W.select_k(self.st, npus, self.free_pus):
            if idx < 0:
                break
            i = int(idx)
            if first < 0:
                first = i
            elif picks is None:
                picks = [first, i]
            else:
                picks.append(i)
            self._pop_and_start(i)
        if first >= 0:
            TR.record_wlbvt_round(
                tr, self.now, self.st,
                picks if picks is not None else (first,),
                npus, TR.K_PU_WLBVT)

    def _start_kernel(self, idx: int, pkt: PacketDescriptor) -> None:
        fmq = self.fmqs[idx]
        wl: WorkloadModel = fmq.ectx.kernel
        payload = max(0, pkt.size_bytes - self.hw.header_bytes)
        # L2->L1 DMA, hides sched
        t0 = self.now + self.hw.cycles_ns(self.hw.dma_setup_cycles)
        comp = wl.compute_cycles(payload)
        # watchdog budgets (shared clamp semantics: core/engine_base.py) —
        # the per-kernel cycle limit, then the tenant's remaining lifetime
        # allowance (billing, §5.2; exhaustion is permanent)
        comp, killed = BudgetLedger.clamp_kernel(
            comp, fmq.ectx.slo.kernel_cycle_limit)
        comp, budget_killed = self.budget.clamp_total(
            idx, comp, fmq.ectx.slo.total_cycle_limit)
        killed = killed or budget_killed
        io_bytes = 0 if killed else wl.io_bytes(payload)

        if io_bytes and self.frag.mode == "software":
            nfrag = -(-io_bytes // self.frag.fragment_bytes)
            comp += self.frag.sw_overhead_cycles * nfrag

        t_comp = t0 + self.hw.cycles_ns(comp)
        if self.trace is not None:
            self._tr_s_tcomp[pkt.meta] = t_comp

        def fin(t_done: float, was_killed=killed, was_budget=budget_killed):
            self._finish_kernel(idx, pkt, t0, t_done, was_killed, payload,
                                budget_killed=was_budget)

        if io_bytes:
            self._post(t_comp, lambda: self._submit_transfer(
                idx, io_bytes, wl.io_kind,
                lambda t_done: fin(t_done)))
        else:
            self._post(t_comp, lambda: fin(self.now))

    def _finish_kernel(self, idx, pkt, t_start, t_done, killed, payload,
                       budget_killed=False):
        st = self.stats[idx]
        self.st.cur_occup[idx] -= 1
        self.free_pus += 1
        if killed:
            st.killed += 1
            self.tel.inc("killed", idx)
            self.eqhub.push(Event(idx, BudgetLedger.kill_kind(budget_killed),
                                  self.now))
        else:
            st.completed += 1
            st.served_payload_bytes += payload
            self.tel.inc("completed", idx)
            self.tel.inc("bytes_out", idx, payload)
        st.record_kernel_time(
            self.now - (t_start - self.hw.cycles_ns(self.hw.dma_setup_cycles)))
        st.last_completion = self.now
        if self.record_completions:
            self._completions.append((idx, self.now))
        # sojourn (arrival -> completion) latency: queueing included, so
        # the control plane sees congestion the service time alone hides
        self.tel.lat(idx, self.now - pkt.arrival)
        tr = self.trace
        if tr is not None:
            slot = pkt.meta
            tr.span_packet(self._tr_s_uid[slot], idx, slot,
                           _D_KILL if killed else _D_OK,
                           _D_MARK if pkt.ecn else _D_OK,
                           pkt.arrival, self._tr_s_grant[slot],
                           self._tr_s_tcomp[slot], self.now)
            self._tr_free.append(slot)
        self.fmqs[idx].completed += 1
        self._dispatch()

    # -- AXI / DMA / egress ------------------------------------------------------
    def _submit_transfer(self, idx: int, nbytes: int, kind: str,
                         cb: Callable[[float], None]) -> None:
        frags = fragment_transfer(self.frag, idx, transfer_id=self._seq,
                                  nbytes=nbytes)
        if self.frag.mode == "software":
            # kernel issues fragments one by one (blocking wrapper)
            def issue(i: int):
                f = frags[i]
                if i + 1 < len(frags):
                    nxt = lambda _t: issue(i + 1)
                else:
                    nxt = cb
                self._enqueue_axi(idx, f, kind, nxt)
            issue(0)
        else:
            for f in frags:
                self._enqueue_axi(idx, f, kind, cb if f.last else None)

    def _enqueue_axi(self, idx, frag, kind, cb) -> None:
        if self.arb == "fifo":
            self.axi_fifo.append((idx, frag, kind, cb))
        else:
            self.axi_q[idx].append((frag, kind, cb))
        self._kick_axi()

    def submit_control(self, nbytes: int = 64,
                       cb: Optional[Callable] = None) -> None:
        """EQ/control message: highest IO priority (R5)."""
        self.axi_ctrl.append((nbytes, cb))
        self._kick_axi()

    def _axi_pick(self):
        """Next (tenant, frag, kind, cb) per arbitration policy, or None."""
        if self.arb == "fifo":
            return self.axi_fifo.popleft() if self.axi_fifo else None
        pending = np.array([len(q) > 0 for q in self.axi_q])
        if not pending.any():
            return None
        head = np.array([q[0][0].nbytes if q else 0 for q in self.axi_q],
                        float)
        tr = self.trace
        d0 = self.dwrr.deficit.copy() if tr is not None else None
        i = W.dwrr_select(self.dwrr, head, pending,
                          quantum=float(self.frag.fragment_bytes))
        if i < 0:
            return None
        if tr is not None:
            TR.record_dwrr_grant(tr, self.now, TR.K_AXI_DWRR, i, d0,
                                 pending, self.dwrr.weights)
        frag, kind, cb = self.axi_q[i].popleft()
        return i, frag, kind, cb

    def _kick_axi(self) -> None:
        if self.axi_busy:
            return
        ns_per_b = self.hw.wire_ns_per_byte(self.hw.axi_gbps)
        if self.axi_ctrl:
            nbytes, cb = self.axi_ctrl.popleft()
            self.axi_busy = True

            def done_ctrl():
                self.axi_busy = False
                if cb:
                    cb(self.now)
                self._kick_axi()
            self._post(self.now + nbytes * ns_per_b, done_ctrl)
            return
        picked = self._axi_pick()
        if picked is None:
            return
        i, frag, kind, cb = picked
        overhead = (self.frag.hw_overhead_cycles
                    if self.frag.mode == "hardware" else 0)
        dur = frag.nbytes * ns_per_b + self.hw.cycles_ns(overhead)
        self.axi_busy = True

        def done():
            self.axi_busy = False
            if kind == "egress":
                self._egress_enqueue(i, frag, cb)
            else:
                self._io_bytes_cum[i] += frag.nbytes
                self._win_io[i] += frag.nbytes
                self.stats[i].io_bytes_done += frag.nbytes
                if cb is not None:
                    cb(self.now)
            self._kick_axi()

        self._post(self.now + dur, done)

    def _egress_enqueue(self, idx, frag, cb) -> None:
        if self.arb == "fifo":
            self.egress_fifo.append((idx, frag, cb))
        else:
            self.egress_q[idx].append((frag, cb))
        self._kick_egress()

    def _egress_pick(self):
        if self.arb == "fifo":
            return self.egress_fifo.popleft() if self.egress_fifo else None
        pending = np.array([len(q) > 0 for q in self.egress_q])
        if not pending.any():
            return None
        head = np.array([q[0][0].nbytes if q else 0 for q in self.egress_q],
                        float)
        tr = self.trace
        d0 = self.egress_dwrr.deficit.copy() if tr is not None else None
        i = W.dwrr_select(self.egress_dwrr, head, pending,
                          quantum=float(self.frag.fragment_bytes))
        if i < 0:
            return None
        if tr is not None:
            TR.record_dwrr_grant(tr, self.now, TR.K_EGRESS_DWRR, i, d0,
                                 pending, self.egress_dwrr.weights)
        frag, cb = self.egress_q[i].popleft()
        return i, frag, cb

    def _kick_egress(self) -> None:
        if self.egress_busy:
            return
        picked = self._egress_pick()
        if picked is None:
            return
        i, frag, cb = picked
        dur = frag.nbytes * self.hw.wire_ns_per_byte(self.hw.egress_gbps)
        self.egress_busy = True

        def done():
            self.egress_busy = False
            self._io_bytes_cum[i] += frag.nbytes
            self._win_io[i] += frag.nbytes
            self.stats[i].io_bytes_done += frag.nbytes
            if cb is not None:
                cb(self.now)
            self._kick_egress()

        self._post(self.now + dur, done)

    # -- trace plane ---------------------------------------------------------
    def trace_flush(self, t: float) -> None:
        """End-of-run flush: the hot paths record whole lifecycles only
        at completion, so packets still queued or on a PU have no rows
        yet.  Walk the FMQ FIFOs (open FMQ spans) and the in-flight
        slot table (closed FMQ/GRANT plus an open PU or DMA span), in
        uid order so both sim datapaths emit identical flush rows."""
        tr = self.trace
        if tr is None:
            return
        ents = []
        for fmq in self.fmqs:
            for pd in fmq.fifo:
                ents.append((pd.meta, pd.tenant, pd.arrival,
                             TR.D_MARK if pd.ecn else TR.D_OK, None))
        free = set(self._tr_free)
        for slot in range(self.hw.num_pus):
            if slot in free:
                continue
            pd = self._tr_s_pkt[slot]
            ents.append((self._tr_s_uid[slot], pd.tenant, pd.arrival,
                         TR.D_MARK if pd.ecn else TR.D_OK,
                         (slot, self._tr_s_grant[slot],
                          self._tr_s_tcomp[slot])))
        for uid, ten, arr, adisp, m in sorted(ents,
                                              key=lambda e: e[0]):
            tr.span(TR.ST_ARRIVE, uid, ten, arr, arr, adisp)
            if m is None:
                tr.span(TR.ST_FMQ, uid, ten, arr, t, TR.D_OPEN)
                continue
            slot, g, tc = m
            tr.span(TR.ST_FMQ, uid, ten, arr, g, TR.D_OK, pu=slot)
            tr.span(TR.ST_GRANT, uid, ten, g, g, TR.D_OK, pu=slot)
            if t >= tc:
                tr.span(TR.ST_PU, uid, ten, g, tc, TR.D_OK, pu=slot)
                tr.span(TR.ST_DMA, uid, ten, tc, t, TR.D_OPEN, pu=slot)
            else:
                tr.span(TR.ST_PU, uid, ten, g, t, TR.D_OPEN, pu=slot)
        tr.commit()

    # -- main loop -----------------------------------------------------------
    def run(self, trace: List[TracePacket],
            horizon: Optional[float] = None) -> SimResult:
        for pkt in trace:
            self._post(pkt.time, (lambda p: (lambda: self._arrival(p)))(pkt))
        while self._events:
            t = self._events[0][0]
            if horizon is not None and t > horizon:
                break            # leave the event queued for a later run()
            t, _, fn = heapq.heappop(self._events)
            self._advance_to(t)
            self.now = t
            fn()
        tl = None
        if self.record_timeline:
            tl = {k: np.array(v) for k, v in self._tl.items()}
        self.tel.commit()        # flush any partial-window staged samples
        if self.trace is not None:
            self.trace.commit()
        return SimResult(
            time=self.now,
            stats=self.stats,
            jain_pu_timeavg=(self._jain_pu_acc / self._jain_pu_t
                             if self._jain_pu_t else 1.0),
            jain_io_timeavg=(self._jain_io_acc / self._jain_io_t
                             if self._jain_io_t else 1.0),
            timeline=tl,
            events=self.eqhub.drain_all(),
            telemetry=self.tel,
            sched_state={
                "prio": self.st.prio.copy(),
                "total_occup": self.st.total_occup.copy(),
                "bvt": self.st.bvt.copy(),
                "kv_pressure": self._kv_pressure_row(),
            },
            completions=(list(self._completions)
                         if self.record_completions else None),
        )
