"""Packet trace generation (paper §7.2): arrival sequences uniform, sizes
lognormal [10, 81, 97], link fully utilized."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.configs.osmosis_pspin import PSPIN


@dataclasses.dataclass(frozen=True)
class TracePacket:
    time: float          # arrival, virtual ns (wire timing)
    tenant: int
    size: int            # bytes incl. header


@dataclasses.dataclass
class TraceArrays:
    """Structure-of-arrays packet trace (DESIGN.md §8).

    The column-wise twin of a ``List[TracePacket]``: same values, no
    per-packet Python objects, so million-packet traces are cheap to
    build and the batched simulator fast path consumes them directly.
    Row ``i`` of all three arrays is one packet; order is injection
    order (sorted by time for merged traces, exactly like
    ``merge_traces``).
    """
    times: np.ndarray      # (N,) float64
    tenants: np.ndarray    # (N,) int64
    sizes: np.ndarray      # (N,) int64

    def __len__(self) -> int:
        return int(self.times.shape[0])

    @classmethod
    def from_packets(cls, packets) -> "TraceArrays":
        return cls(times=np.array([p.time for p in packets], np.float64),
                   tenants=np.array([p.tenant for p in packets], np.int64),
                   sizes=np.array([p.size for p in packets], np.int64))

    def to_packets(self) -> List[TracePacket]:
        return [TracePacket(float(t), int(i), int(s))
                for t, i, s in zip(self.times, self.tenants, self.sizes)]


def merge_trace_arrays(*traces: TraceArrays) -> TraceArrays:
    """Array twin of ``merge_traces``: concatenate then stable-sort by
    time, so equal-time packets keep argument order (identical to the
    stable Python ``sort`` on packet lists)."""
    times = np.concatenate([t.times for t in traces])
    tenants = np.concatenate([t.tenants for t in traces])
    sizes = np.concatenate([t.sizes for t in traces])
    order = np.argsort(times, kind="stable")
    return TraceArrays(times[order], tenants[order], sizes[order])


def lognormal_sizes(rng: np.random.Generator, n: int, mean_bytes: float,
                    sigma: float = 0.7, lo: int = 64, hi: int = 4096
                    ) -> np.ndarray:
    mu = np.log(mean_bytes) - sigma ** 2 / 2
    s = rng.lognormal(mu, sigma, n)
    return np.clip(s, lo, hi).astype(np.int64)


def make_trace_arrays(tenant: int, n: int = 0, *, size: Optional[int] = None,
                      mean_size: float = 512.0, link_gbps: float = 400.0,
                      share: float = 1.0, start: float = 0.0,
                      duration_ns: Optional[float] = None,
                      seed: int = 0) -> TraceArrays:
    """``make_trace`` without the per-packet objects: same RNG stream,
    same values, returned as a ``TraceArrays`` column bundle."""
    rng = np.random.default_rng(seed + 7919 * tenant)
    if duration_ns is not None:
        mean = float(size) if size is not None else mean_size
        n = max(1, int(duration_ns * link_gbps * share / (8.0 * mean)))
    sizes = (np.full(n, size, np.int64) if size is not None
             else lognormal_sizes(rng, n, mean_size))
    ns_per_byte = 8.0 / (link_gbps * share)
    mean_gaps = sizes * ns_per_byte
    gaps = rng.uniform(0.0, 2.0 * mean_gaps)
    times = start + np.cumsum(gaps) - gaps[0]
    return TraceArrays(times=np.asarray(times, np.float64),
                       tenants=np.full(n, tenant, np.int64),
                       sizes=sizes)


def make_trace(tenant: int, n: int = 0, *, size: Optional[int] = None,
               mean_size: float = 512.0, link_gbps: float = 400.0,
               share: float = 1.0, start: float = 0.0,
               duration_ns: Optional[float] = None,
               seed: int = 0) -> List[TracePacket]:
    """Packets for one tenant at `share` of a fully-utilized link.

    Inter-arrival gaps are sampled from a uniform distribution with the
    mean matched to the byte rate (paper §7.2: "packet arrival sequences
    follow a uniform distribution"); `size=None` samples lognormal sizes.
    """
    return make_trace_arrays(
        tenant, n, size=size, mean_size=mean_size, link_gbps=link_gbps,
        share=share, start=start, duration_ns=duration_ns,
        seed=seed).to_packets()


def merge_traces(*traces: List[TracePacket]) -> List[TracePacket]:
    out = [p for tr in traces for p in tr]
    out.sort(key=lambda p: p.time)
    return out


def equal_share_traces(num_tenants: int, n_each: int = 0, *, sizes=None,
                       mean_size: float = 512.0, seed: int = 0,
                       duration_ns: Optional[float] = None,
                       arrays: bool = False):
    """All tenants push at the same ingress *byte* rate (paper §3 'PU
    contention'): each gets an equal share of the fully utilized link.
    With `duration_ns`, per-tenant packet counts are derived so all flows
    span the same wall-clock window regardless of packet size.  With
    ``arrays=True`` the merged trace is returned as ``TraceArrays``
    (identical packet sequence, no per-packet objects)."""
    traces = []
    for t in range(num_tenants):
        sz = sizes[t] if sizes is not None else None
        traces.append(make_trace_arrays(
            t, n_each, size=sz, mean_size=mean_size,
            link_gbps=PSPIN.ingress_gbps, share=1.0 / num_tenants,
            seed=seed, duration_ns=duration_ns))
    merged = merge_trace_arrays(*traces)
    return merged if arrays else merged.to_packets()
