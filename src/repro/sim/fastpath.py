"""Array-batched simulator fast path (DESIGN.md §8).

``BatchedSimulator`` is a drop-in replacement for the event-loop
``Simulator`` that makes the same scheduling decisions — pinned
bit-for-bit by the sim-level golden trace and the cross-path property
tests — at a large multiple of the packet rate.  Three mechanisms:

  * **SoA packet store** — the trace lives as flat arrays
    (``TraceArrays``): per-packet payloads, compute cycles and IO bytes
    are derived in one vectorized pass at injection instead of one
    ``WorkloadModel`` call per packet; queued packets are integer
    indices into the (append-only) store, in-flight kernels a fixed
    ``num_pus``-row slot table (tenant, packet, t0, kill flags, IO
    bytes).

  * **Window-batched arrivals** — while every PU is busy an arrival
    cannot trigger a dispatch; it only stages bookkeeping.  All such
    arrivals up to the next decision point (kernel completion, IO
    grant, control event or telemetry-window boundary — and never past
    a change of the WLBVT active set) are applied in one vectorized
    pass: FMQ depth/ECN/drop classification, queue-length, stats and
    telemetry counters.  EQ events still materialize per packet in
    exact chronological order (lazily — see ``BlockEventLog``).

  * **Typed event records** — the retained heapq holds plain
    ``(time, seq, code, payload)`` tuples (no per-event closures) and is
    reserved for decision-bearing events: kernel completions, AXI/egress
    grants and control traffic.

Exactness: WLBVT virtual time is integrated with the *same* per-event
fold the event loop performs — scalar intervals reuse the identical
masked ``+= x*dt`` adds, and batches fold through ``np.cumsum``, whose
sequential left-to-right accumulation is IEEE-identical to the
per-event adds.  Scheduling decisions reuse the exact ``sched_generic``
formulas (same masked-argmin tie-breaks as ``select_k``); the per-round
``pu_limit`` is cached and invalidated only when the non-empty FMQ set
or the live priorities change — the same incremental-maintenance
argument ``select_k`` already relies on.  The only quantity that is
mathematically but not bit-wise identical is the Jain *time-integral*
(its moments are delta-maintained and re-derived every telemetry
window; DESIGN.md §8 quantifies the bounded fold drift).
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import List, Optional

import numpy as np

from repro.core import Event, EventKind, fragment_transfer
from repro.core import sched_generic as G
from repro.core.accounting import jain_fairness
from repro.core.engine_base import BudgetLedger
from repro.core import wlbvt as W
from repro.sim.engine import SimResult, Simulator
from repro.sim.traffic import TraceArrays
from repro.telemetry import trace as TR
from repro.telemetry.metrics import C_IDX

MAX_BATCH = 8192        # arrival-batch cap (bounds the fold buffer)
SMALL_BATCH = 4         # below this, scalar folds beat the vector machinery
_INF = float("inf")

class BlockEventLog:
    """Shared-queue EQ log with block pushes and lazy materialization.

    Drop storms push thousands of EQ events per arrival batch, but only
    the last ``capacity`` ever survive to ``drain_all`` (ring
    semantics).  This log stores whole batches as numpy column blocks —
    O(1) python work per batch — and materializes ``Event`` objects only
    for the retained window.  Drained content and the ``dropped``
    counter are identical to an ``EventQueue`` of the same capacity fed
    one ``push`` per event.
    """

    #: small-int kind codes blocks may carry instead of EventKind objects
    CODE_KINDS = {1: EventKind.ECN_MARK, 2: EventKind.QUEUE_OVERFLOW}

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._blocks: deque = deque()   # (tenants, kinds, times) seqs
        self._len = 0
        self.dropped = 0

    def __len__(self) -> int:
        return self._len

    def push(self, ev: Event) -> None:
        self.push_raw(ev.tenant, ev.kind, ev.time, ev.detail)

    def push_raw(self, tenant: int, kind, time: float,
                 detail: str = "") -> None:
        if detail:
            kind = (kind, detail)       # rare: carry detail through
        self._blocks.append(((tenant,), (kind,), (time,)))
        self._advance_len(1)

    def push_block(self, tenants, kinds, times) -> None:
        """One batch of events, chronological: parallel sequences (numpy
        arrays or lists) of tenant ids, ``EventKind``s and times."""
        n = len(tenants)
        if n == 0:
            return
        self._blocks.append((tenants, kinds, times))
        self._advance_len(n)

    def _advance_len(self, n: int) -> None:
        self._len += n
        # evict whole leading blocks once they cannot intersect the
        # retained window (bounds memory; partial eviction at drain)
        while self._blocks and (self._len - len(self._blocks[0][0])
                                >= self.capacity):
            blk = self._blocks.popleft()
            k = len(blk[0])
            self._len -= k
            self.dropped += k

    def _materialize(self) -> tuple:
        out: List[Event] = []
        for tenants, kinds, times in self._blocks:
            if isinstance(tenants, np.ndarray):
                tenants = tenants.tolist()
            if isinstance(times, np.ndarray):
                times = times.tolist()
            if isinstance(kinds, np.ndarray):
                km = self.CODE_KINDS
                kinds = [km[k] for k in kinds.tolist()]
            for t, k, tm in zip(tenants, kinds, times):
                if type(k) is tuple:
                    out.append(Event(t, k[0], tm, k[1]))
                else:
                    out.append(Event(t, k, tm))
        over = len(out) - self.capacity
        if over > 0:
            return out[over:], over
        return out, 0

    def drain_all(self) -> List[Event]:
        out, over = self._materialize()
        self.dropped += over            # partial-window evictions
        self._blocks.clear()
        self._len = 0
        return out

    def snapshot(self, tenant: Optional[int] = None) -> List[Event]:
        evs, _ = self._materialize()    # non-destructive
        return (evs if tenant is None
                else [e for e in evs if e.tenant == tenant])


# typed heap event codes (heap entries: (time, seq, code, payload))
K_FIN = 0      # kernel finished, no IO     payload: slot
K_SUBMIT = 1   # compute done, submit IO    payload: slot
K_AXI = 2      # AXI transfer done          payload: (tenant, frag, kind, cb)
K_EGR = 3      # egress transfer done       payload: (tenant, frag, cb)
K_CTRL = 4     # control message done       payload: user cb | None

# callback codes (cb above): None | ("fin", slot) | ("sw", rec) | callable


class BatchedSimulator(Simulator):
    """Same construction surface and semantics as ``Simulator``; the
    data plane is array-batched (DESIGN.md §8)."""

    def __init__(self, tenants, **kw):
        super().__init__(tenants, **kw)
        T = len(tenants)
        self._T = T
        hw = self.hw
        # SoA FMQ FIFOs: per-tenant deques of packet indices + depth array
        self._fifo: List[deque] = [deque() for _ in range(T)]
        self._fifo_len = np.zeros(T, np.int64)
        self._fifo_cap = np.array([f.capacity for f in self.fmqs], np.int64)
        self._ecn_thresh = np.array([f.ecn_threshold for f in self.fmqs],
                                    np.int64)
        self._fifo_cap_l = self._fifo_cap.tolist()
        self._ecn_thresh_l = self._ecn_thresh.tolist()
        # staged-counter column views (the numpy telemetry backend zeroes
        # the staging array in place, so these stay valid across commits)
        self._st_arrivals = self.tel._staged_counts[:, C_IDX["arrivals"]]
        self._st_bytes_in = self.tel._staged_counts[:, C_IDX["bytes_in"]]
        self._st_drops = self.tel._staged_counts[:, C_IDX["drops"]]
        # bound append on the staged-latency list (commit clears the
        # list in place, so the binding survives) — tel.lat minus two
        # attribute lookups per completion
        self._lat_append = self.tel._staged_lat.append
        # per-tenant workload/SLO parameter rows (vectorized cost models)
        wls = [e.kernel for e in tenants]
        self._wl_spin = np.array([w.spin_factor if w else 1.0 for w in wls])
        self._wl_base = np.array([w.compute_base if w else 0.0 for w in wls])
        self._wl_cpb = np.array([w.compute_per_byte if w else 0.0
                                 for w in wls])
        self._wl_iofix = np.array([w.io_fixed_bytes if w else 0
                                   for w in wls], np.int64)
        self._wl_iofac = np.array([w.io_bytes_factor if w else 0.0
                                   for w in wls])
        self._wl_io_none = np.array([(w.io_kind == "none") if w else True
                                     for w in wls])
        self._wl_io_kind = [w.io_kind if w else "none" for w in wls]
        self._kern_limit = [e.slo.kernel_cycle_limit for e in tenants]
        self._total_limit = [e.slo.total_cycle_limit for e in tenants]
        # in-flight kernel slot table (<= num_pus rows; plain lists —
        # access is purely scalar and list indexing is ~3x cheaper)
        P = hw.num_pus
        self._num_pus = P            # hoisted: hw.num_pus is a property
        self._s_tenant = [0] * P
        self._s_pkt = [0] * P
        self._s_t0 = [0.0] * P
        self._s_killed = [False] * P
        self._s_bkilled = [False] * P
        self._s_payload = [0] * P
        self._s_io = [0] * P
        self._free_slots = list(range(P - 1, -1, -1))
        # tracing-only slot columns + packet-index -> uid lookup (uids
        # are assigned in arrival-processing order, matching the event
        # loop's per-_arrival counter)
        if self.trace is not None:
            self._s_uid = [0] * P
            self._s_grant = [0.0] * P
            self._s_tcomp = [0.0] * P
            self._tr_uid_arr = np.empty(0, np.int64)
            self._tr_adisp = np.empty(0, np.int8)  # ARRIVE disposition
        # append-only packet store (indices stay valid across injections);
        # columns read only scalar at dispatch time are plain lists
        self._p_t = np.empty(0)
        self._p_seq = np.empty(0, np.int64)
        self._p_tenant = np.empty(0, np.int64)
        self._p_size = np.empty(0, np.int64)
        self._p_tenant_l: list = []
        self._p_size_l: list = []
        self._p_payload: list = []
        self._p_comp: list = []
        self._p_io: list = []
        # pending arrivals: store indices in (time, seq) order + cursor;
        # list mirrors serve the scalar hot loop, arrays the batch math
        self._order = np.empty(0, np.int64)
        self._ord_t = np.empty(0)
        self._ord_t_l: list = []
        self._ord_seq_l: list = []
        self._ord_j_l: list = []
        self._cursor = 0
        # cached per-round WLBVT limit + eligibility mask (invalidated on
        # non-empty-set or priority changes — same incremental argument as
        # select_k; between rebuilds only the picked/finished tenant's own
        # eligibility bit can change, and it is patched scalar)
        self._limit = None
        self._limit_l: list = [0.0] * T
        self._limit_dirty = True
        self._elig = np.zeros(T, bool)
        self._elig_n = 0
        self._elig_one = -1
        self._rb_metric = np.empty(T)
        self._rb_masked = np.empty(T)
        self._rb_mask = np.empty(T, bool)
        self._rb_mask2 = np.empty(T, bool)
        # bvt is monotone non-decreasing and frozen at 0 until first
        # active: once every tenant's bvt >= 1, max(bvt, 1) is the
        # identity and the metric drops one ufunc (checked per window)
        self._bvt_all_ge1 = False
        # incrementally-maintained mirrors of st.active (bool, so exact)
        # and the masked occupancy/active floats the advance fold uses —
        # stacked (2, T) so one multiply serves both integrals
        self._act = np.zeros(T, bool)
        self._act_n = 0
        self._advA = np.zeros((2, T))
        self._occF_act = self._advA[0]       # where(act, occ, 0) as float
        self._act_f = self._advA[1]          # act as float
        self._adv_buf = np.zeros((2, T))
        # total_occup/bvt re-tied as rows of one (2, T) array so the
        # per-event advance fold is a single stacked += (the event loop's
        # two masked adds, same values — see _advance_to override)
        self._ob = np.stack([self.st.total_occup, self.st.bvt])
        self.st.total_occup = self._ob[0]
        self.st.bvt = self._ob[1]
        # Jain integrand: incremental moments S1=Σx, S2=Σx² over
        # x = occ/prio of active tenants, delta-updated per occupancy
        # change and re-derived vectorized at every window commit (so
        # float drift is bounded to one window).  The integrand value is
        # mathematically identical to the event loop's per-event
        # jain_fairness; only the float fold differs (DESIGN.md §8).
        self._jx = [0.0] * T
        self._jS1 = 0.0
        self._jS2 = 0.0
        self._prio_l = [float(p) for p in self.st.prio]
        self._jain_cache: Optional[float] = None
        # work-skipping gates
        self._fa_left = T                    # tenants with no arrival yet
        self._admit_all = True               # refreshed at window commits
        self._jr_count = 0                   # windows since jain refresh
        self._horizon: Optional[float] = None
        self._kind_lut = np.zeros(T, np.int8)
        self._kind2 = np.full(MAX_BATCH, 2, np.int8)   # all-drop blocks
        # vector accumulators for per-tenant object counters the engine
        # never reads mid-run (TenantStats.drops, FMQ drops/marks/
        # enqueued) — flushed into the objects at the end of run()
        self._acc_drops = np.zeros(T, np.int64)
        self._acc_fmq_drops = np.zeros(T, np.int64)
        self._acc_marks = np.zeros(T, np.int64)
        self._acc_enq = np.zeros(T, np.int64)
        # scalar-hot-path accumulators (plain Python lists: one list
        # store instead of one numpy scalar-indexed add per event).
        # Telemetry counter stages flush at every window commit — the
        # committed per-window values are identical to per-event inc
        # calls; stats/FMQ/budget mirrors flush at the end of run().
        self._tc_names = ("arrivals", "bytes_in", "completed", "bytes_out")
        self._tc = {n: [0.0] * T for n in self._tc_names}
        self._tc_dirty = {n: False for n in self._tc_names}
        self._c_completed = [0] * T
        self._c_served = [0.0] * T
        self._c_lastcomp = [0.0] * T
        self._c_fmqcomp = [0] * T
        self._spent = [0.0] * T              # BudgetLedger.spent mirror
        # kernel-time samples buffered per tenant and replayed into the
        # TenantStats reservoir at flush: below the cap the fill is one
        # vectorized copy, above it the per-sample Algorithm R replay
        # consumes the identical rng stream — reservoir state, count and
        # sum are bit-identical to per-completion record_kernel_time
        self._kt_pend: List[list] = [[] for _ in range(T)]
        self._fold_buf: Optional[np.ndarray] = None   # (MAX_BATCH+1, 2T)
        # block-based EQ log (same ring semantics, O(1) per batch) —
        # replaces the EngineBase EQHub after tenant registration
        self.eqhub = BlockEventLog(capacity=4096)

    # ------------------------------------------------------------------
    # injection: vectorized per-packet derivations
    # ------------------------------------------------------------------
    def _inject(self, trace) -> None:
        if isinstance(trace, TraceArrays):
            ta = trace
        elif len(trace) == 0:
            return
        else:
            ta = TraceArrays.from_packets(trace)
        n = len(ta)
        if n == 0:
            return
        base = self._p_t.shape[0]
        seqs = self._seq + np.arange(n, dtype=np.int64)
        self._seq += n                      # mirrors one _post per arrival
        tn, sz = ta.tenants, ta.sizes
        payload = np.maximum(0, sz - self.hw.header_bytes)
        # same float ops as the scalar WorkloadModel methods, elementwise
        comp = self._wl_spin[tn] * (self._wl_base[tn]
                                    + self._wl_cpb[tn] * payload)
        scaled = (self._wl_iofac[tn] * payload).astype(np.int64)
        io = np.where(self._wl_iofix[tn] > 0, self._wl_iofix[tn], scaled)
        io = np.where(self._wl_io_none[tn], 0, io)
        self._p_t = np.concatenate([self._p_t, ta.times])
        self._p_seq = np.concatenate([self._p_seq, seqs])
        self._p_tenant = np.concatenate([self._p_tenant, tn])
        self._p_size = np.concatenate([self._p_size, sz])
        self._p_tenant_l.extend(tn.tolist())
        self._p_size_l.extend(sz.tolist())
        self._p_payload.extend(payload.tolist())
        self._p_comp.extend(comp.tolist())
        self._p_io.extend(io.tolist())
        if self.trace is not None:
            self._tr_uid_arr = np.concatenate(
                [self._tr_uid_arr, np.full(n, -1, np.int64)])
            self._tr_adisp = np.concatenate(
                [self._tr_adisp, np.full(n, TR.D_OK, np.int8)])
        # merge the not-yet-arrived tail with the new packets, in the
        # exact heap order the event loop would pop: (time, seq)
        merged = np.concatenate([self._order[self._cursor:],
                                 base + np.arange(n, dtype=np.int64)])
        key_t = self._p_t[merged]
        key_s = self._p_seq[merged]
        merged = merged[np.lexsort((key_s, key_t))]
        self._order = merged
        self._ord_t = self._p_t[merged]
        self._ord_t_l = self._ord_t.tolist()
        self._ord_seq_l = self._p_seq[merged].tolist()
        self._ord_j_l = merged.tolist()
        self._cursor = 0

    # ------------------------------------------------------------------
    # time advancement: the same fold as Simulator._advance_to, applied
    # only over intervals that cannot cross a telemetry-window boundary
    # ------------------------------------------------------------------
    def _jain_cur(self) -> float:
        """Jain's index over x = (occ/prio) of the active set, from the
        incremental moments: (Σx)²/(n·Σx²), 1.0 when the set is empty or
        all-zero — the same definition ``accounting.jain_fairness``
        evaluates per event on the event path (value equal up to the
        bounded fold drift of the moments)."""
        if self._jain_cache is None:
            if self._jS1 == 0.0 or self._jS2 <= 0.0:
                self._jain_cache = 1.0
            else:
                self._jain_cache = (self._jS1 * self._jS1
                                    / (self._act_n * self._jS2))
        return self._jain_cache

    def _jain_upd(self, i: int, x: float) -> None:
        """Delta-update the Jain moments after tenant ``i``'s
        priority-normalized occupancy changed to ``x``."""
        old = self._jx[i]
        self._jS1 += x - old
        self._jS2 += x * x - old * old
        self._jx[i] = x
        self._jain_cache = None

    def _jain_refresh(self) -> None:
        """Re-derive the moments vectorized (window commits: bounds the
        incremental fold drift and absorbs controller prio changes)."""
        st = self.st
        x = np.where(self._act, st.cur_occup / st.prio, 0.0)
        self._jx = x.tolist()
        self._jS1 = float(x.sum())
        self._jS2 = float(np.square(x).sum())
        self._prio_l = [float(p) for p in st.prio]
        self._jain_cache = None

    def _advance_small(self, t: float) -> None:
        """The advance fold for an interval known to stay inside the
        current telemetry window: ``total_occup``/``bvt`` get the event
        loop's identical masked ``+= x*dt`` adds as one stacked multiply
        + add on the ``(2, T)`` store, the Jain integral the incremental
        ``+= j*dt``.  ``_win_act`` catch-up is deferred to the next
        boundary-crossing ``_advance_to`` (the active set cannot have
        changed in between; deactivations patch it eagerly)."""
        dt = t - self._last_adv
        if dt <= 0:
            return
        np.multiply(self._advA, dt, out=self._adv_buf)
        self._ob += self._adv_buf
        if self._act_n >= 2:
            self._jain_pu_acc += self._jain_cur() * dt
            self._jain_pu_t += dt
        self._last_adv = t

    def _advance_to(self, t: float) -> None:
        """The event loop's ``_advance_to`` on the stacked store: the
        same masked integration adds (see ``_advance_small``), with the
        window machinery — IO-fairness sample, timeline row, telemetry
        commit, ``_win_act`` catch-up — run only when ``t`` reaches a
        window boundary.  The Jain PU integrand comes from the
        incremental moments instead of a fresh ``jain_fairness`` call
        (value equal up to the bounded fold drift, DESIGN.md §8)."""
        dt = t - self._last_adv
        if dt <= 0:
            return
        np.multiply(self._advA, dt, out=self._adv_buf)
        self._ob += self._adv_buf
        if self._act_n >= 2:
            self._jain_pu_acc += self._jain_cur() * dt
            self._jain_pu_t += dt
        self._last_adv = t
        if t - self._win_start >= self.io_window_ns:
            self._win_act |= self._act
            occ = self.st.cur_occup.astype(float)
            while t - self._win_start >= self.io_window_ns:
                wa = self._win_act
                if wa.sum() >= 2 and self._win_io.sum() > 0:
                    dma_w = np.array([f.ectx.slo.dma_priority
                                      for f in self.fmqs])
                    w = dma_w * self.io_demand_weights
                    self._jain_io_acc += jain_fairness(
                        (self._win_io / w)[wa]) * self.io_window_ns
                    self._jain_io_t += self.io_window_ns
                if self.record_timeline:
                    self._tl["t"].append(self._win_start)
                    self._tl["occup"].append(occ.copy())
                    self._tl["io_win"].append(self._win_io.copy())
                    self._tl["qlen"].append(self.st.queue_len.copy())
                self._commit_window(occ)
                self._win_io[:] = 0.0
                self._win_act = self._act.copy()
                self._win_start += self.io_window_ns

    _advance = _advance_to

    def _deactivate(self, i: int) -> None:
        """Tenant left the active set (occupancy and queue both zero).
        Patch ``_win_act`` eagerly: the event loop's per-event ``|=``
        would have recorded it active earlier this window."""
        self._win_act[i] = True
        self._act[i] = False
        self._act_n -= 1
        self._act_f[i] = 0.0
        self._occF_act[i] = 0.0
        if self._elig[i]:              # queue empty => never eligible
            self._elig[i] = False
            self._elig_n -= 1
            if self._elig_n == 1:
                self._elig_one = -1
        old = self._jx[i]
        self._jS1 -= old
        self._jS2 -= old * old
        self._jx[i] = 0.0
        self._jain_cache = None

    def drain_tenant_queue(self, tenant: int) -> List[tuple]:
        """Live-migration drain — the SoA twin of the event engine's
        version: the queued packet-store indices are resolved back to
        ``(arrival_ns, size_bytes)`` rows (identical values, identical
        FIFO order), then the incremental caches are patched the same
        way a normal queue-empty transition patches them."""
        q = self._fifo[tenant]
        out = [(float(self._p_t[j]), int(self._p_size_l[j])) for j in q]
        q.clear()
        if out:
            self._fifo_len[tenant] = 0
            self.st.queue_len[tenant] -= len(out)
            if self.st.cur_occup[tenant] == 0 and self._act[tenant]:
                self._deactivate(tenant)
            self._limit_dirty = True
        return out

    # ------------------------------------------------------------------
    # WLBVT decisions: same formulas, cached pu_limit
    # ------------------------------------------------------------------
    def _rebuild_elig(self) -> None:
        """Recompute the WLBVT limit + eligibility mask from scratch —
        on the same triggers ``select_k``'s rebuild fires on (non-empty
        set changed, controller moved prio)."""
        st = self.st
        self._limit = G.pu_limit(st.prio, st.queue_len, self.hw.num_pus, np)
        self._limit_l = self._limit.tolist()
        np.greater(st.queue_len, 0, out=self._rb_mask)
        np.less(st.cur_occup, self._limit, out=self._rb_mask2)
        np.logical_and(self._rb_mask, self._rb_mask2, out=self._elig)
        n = int(np.count_nonzero(self._elig))
        self._elig_n = n
        self._elig_one = int(np.argmax(self._elig)) if n == 1 else -1
        self._limit_dirty = False

    def _wlbvt_round(self, k: int) -> List[int]:
        """The k winners of one round — value-identical to
        ``W.select_k`` (same masked argmin over the same metric).

        The eligibility mask (and its popcount) is carried *across*
        rounds: between rebuild triggers only the picked/finished
        tenant's own bit can change and it is patched scalar at those
        events.  With exactly one eligible tenant — the flood steady
        state, where each completion re-enables only the tenant that
        freed the PU — the argmin is forced and the metric is never
        computed; the metric, when needed, is computed once per round
        (it depends only on ``total_occup/bvt/prio``, which no pick
        changes — the same hoisting ``select_k`` does)."""
        st = self.st
        if self._limit_dirty:
            self._rebuild_elig()
        picks: List[int] = []
        ql, co = st.queue_len, st.cur_occup
        masked = None
        for _ in range(k):
            n_el = self._elig_n
            if n_el == 0:
                break
            if n_el == 1 and masked is None:
                i = self._elig_one
                if i < 0:
                    i = int(np.argmax(self._elig))
                    self._elig_one = i
            else:
                if masked is None:
                    metric = self._rb_metric
                    if self._bvt_all_ge1:   # max(bvt, 1) is the identity
                        np.divide(st.total_occup, st.bvt, out=metric)
                    else:
                        np.maximum(st.bvt, 1.0, out=metric)
                        np.divide(st.total_occup, metric, out=metric)
                    np.divide(metric, st.prio, out=metric)
                    masked = self._rb_masked
                    masked.fill(G.BIG)
                    np.copyto(masked, metric, where=self._elig)
                i = int(masked.argmin())
                if masked[i] >= G.BIG:
                    break
            ql[i] -= 1
            co[i] += 1
            o = int(co[i])
            self._occF_act[i] = o
            self._jain_upd(i, o / self._prio_l[i])
            picks.append(i)
            if ql[i] == 0:          # non-empty set shrank: limits change
                self._rebuild_elig()
                masked = None       # mask stale; the metric is not
            elif o >= self._limit_l[i]:
                self._elig[i] = False
                self._elig_n -= 1
                if self._elig_n == 1:
                    self._elig_one = -1
                if masked is not None:
                    masked[i] = G.BIG
        return picks

    def _dispatch(self) -> None:
        tr = self.trace
        if self.sched_kind == "rr":
            while self.free_pus > 0:
                idx, self.rr_ptr = W.select_rr(self.rr_ptr,
                                               self.st.queue_len)
                if idx < 0:
                    return
                if tr is not None:
                    TR.record_rr_pick(tr, self.now, TR.K_PU_RR, idx,
                                      self.st.queue_len, self.st.bvt)
                self.st.queue_len[idx] -= 1
                self.st.cur_occup[idx] += 1
                self._occF_act[idx] = self.st.cur_occup[idx]
                self._jain_upd(idx, self.st.cur_occup[idx]
                               / self._prio_l[idx])
                self._pop_and_start(idx)
            return
        if self.free_pus <= 0:
            return
        if tr is None:
            for idx in self._wlbvt_round(self.free_pus):
                self._pop_and_start(idx)
            return
        # provenance: stage picks + post-round state (the round charges
        # queue_len/cur_occup in place; commit reconstructs the pre-round
        # arrays) — identical records to the event loop because the
        # picks are pinned bit-identical
        picks = self._wlbvt_round(self.free_pus)
        TR.record_wlbvt_round(tr, self.now, self.st, picks,
                              self._num_pus, TR.K_PU_WLBVT)
        for idx in picks:
            self._pop_and_start(idx)

    def _commit_window(self, occ: np.ndarray) -> None:
        self._flush_tc()             # staged counters land in this window
        super()._commit_window(occ)
        if self.controller is not None:
            self._limit_dirty = True   # the controller may have moved prio
            self._jain_refresh()
            self._admit_all = bool(self._admit.all())
        else:
            # static prios/admission: the incremental caches stay valid;
            # re-derive the Jain moments every few windows so the fold
            # drift stays bounded (DESIGN.md §8)
            self._jr_count += 1
            if self._jr_count >= 16:
                self._jr_count = 0
                self._jain_refresh()
        if not self._bvt_all_ge1:    # bvt is monotone: latches True
            self._bvt_all_ge1 = bool((self.st.bvt >= 1.0).all())

    def _flush_tc(self) -> None:
        """Fold the python-list counter accumulators into the telemetry
        staging area (same committed per-window values as per-event
        ``inc`` calls — integer-valued float sums are exact)."""
        d = self._tc_dirty
        for n in self._tc_names:
            if d[n]:
                self.tel.inc_column(n, self._tc[n])
                self._tc[n] = [0.0] * self._T
                d[n] = False

    def _kv_pressure_row(self) -> np.ndarray:
        return self._fifo_len / self._fifo_cap

    # ------------------------------------------------------------------
    # kernel start/finish on the slot table
    # ------------------------------------------------------------------
    def _pop_and_start(self, idx: int) -> None:
        j = self._fifo[idx].popleft()
        self._fifo_len[idx] -= 1
        self.free_pus -= 1
        t0 = self.now + self.hw.cycles_ns(self.hw.dma_setup_cycles)
        comp = self._p_comp[j]
        # budget clamps, inlined on the python-float spend mirror —
        # identical op sequence to BudgetLedger.clamp_kernel/clamp_total
        # (the mirror is flushed into the ledger at the end of run())
        lim = self._kern_limit[idx]
        killed = False
        if lim and comp > lim:
            comp = float(lim)
            killed = True
        tlim = self._total_limit[idx]
        budget_killed = False
        if tlim:
            remaining = float(tlim) - self._spent[idx]
            if comp > remaining:
                budget_killed = killed = True
                comp = remaining if remaining > 0.0 else 0.0
        self._spent[idx] += comp
        io_bytes = 0 if killed else self._p_io[j]
        if io_bytes and self.frag.mode == "software":
            nfrag = -(-io_bytes // self.frag.fragment_bytes)
            comp += self.frag.sw_overhead_cycles * nfrag
        slot = self._free_slots.pop()
        self._s_tenant[slot] = idx
        self._s_pkt[slot] = j
        self._s_t0[slot] = t0
        self._s_killed[slot] = killed
        self._s_bkilled[slot] = budget_killed
        self._s_payload[slot] = self._p_payload[j]
        self._s_io[slot] = io_bytes
        t_fin = t0 + self.hw.cycles_ns(comp)
        if self.trace is not None:
            # rows emitted whole at completion (span_packet)
            self._s_uid[slot] = int(self._tr_uid_arr[j])
            self._s_grant[slot] = self.now
            self._s_tcomp[slot] = t_fin
        heapq.heappush(self._events,
                       (t_fin, self._seq,
                        K_SUBMIT if io_bytes else K_FIN, slot))
        self._seq += 1

    def _finish_slot(self, slot: int) -> None:
        idx = self._s_tenant[slot]
        wst = self.st
        co = wst.cur_occup
        co[idx] -= 1
        self.free_pus += 1
        o = int(co[idx])
        if o == 0 and wst.queue_len[idx] == 0:
            self._deactivate(idx)
        else:
            self._occF_act[idx] = o
            self._jain_upd(idx, o / self._prio_l[idx])
            if (not self._limit_dirty and not self._elig[idx]
                    and o < self._limit_l[idx] and wst.queue_len[idx] > 0):
                # the freed PU restored this tenant's eligibility
                self._elig[idx] = True
                self._elig_n += 1
                if self._elig_n == 1:
                    self._elig_one = idx
                else:
                    self._elig_one = -1
        now = self.now
        if self._s_killed[slot]:
            st = self.stats[idx]
            st.killed += 1
            self.tel.inc("killed", idx)
            self.eqhub.push_raw(
                idx, BudgetLedger.kill_kind(self._s_bkilled[slot]), now)
        else:
            payload = self._s_payload[slot]
            self._c_completed[idx] += 1
            self._c_served[idx] += payload
            tc = self._tc
            tc["completed"][idx] += 1.0
            tc["bytes_out"][idx] += payload
            d = self._tc_dirty
            d["completed"] = d["bytes_out"] = True
        self._kt_pend[idx].append(
            now - (self._s_t0[slot]
                   - self.hw.cycles_ns(self.hw.dma_setup_cycles)))
        self._c_lastcomp[idx] = now
        if self.record_completions:
            self._completions.append((idx, now))
        self._lat_append((idx, now - self._p_t[self._s_pkt[slot]]))
        self._c_fmqcomp[idx] += 1
        tr = self.trace
        if tr is not None:
            j = self._s_pkt[slot]
            tr.span_packet(self._s_uid[slot], idx, slot,
                           TR.D_KILL if self._s_killed[slot] else TR.D_OK,
                           self._tr_adisp[j], float(self._p_t[j]),
                           self._s_grant[slot], self._s_tcomp[slot], now)
        self._free_slots.append(slot)
        self._dispatch()

    # ------------------------------------------------------------------
    # IO path: same grant order as the event loop, typed records
    # ------------------------------------------------------------------
    def _submit_slot_transfer(self, slot: int) -> None:
        idx = self._s_tenant[slot]
        io_bytes = self._s_io[slot]
        kind = self._wl_io_kind[idx]
        frags = fragment_transfer(self.frag, idx, transfer_id=self._seq,
                                  nbytes=io_bytes)
        if self.frag.mode == "software":
            # kernel issues fragments one by one (blocking wrapper)
            rec = {"frags": frags, "i": 0, "idx": idx, "kind": kind,
                   "slot": slot}
            self._issue_sw(rec)
        else:
            for f in frags:
                self._enqueue_axi(idx, f, kind,
                                  ("fin", slot) if f.last else None)

    def _issue_sw(self, rec: dict) -> None:
        frags, i = rec["frags"], rec["i"]
        cb = ("sw", rec) if i + 1 < len(frags) else ("fin", rec["slot"])
        self._enqueue_axi(rec["idx"], frags[i], rec["kind"], cb)

    def _run_cb(self, cb) -> None:
        if cb is None:
            return
        if isinstance(cb, tuple):
            tag, arg = cb
            if tag == "fin":
                self._finish_slot(arg)
            else:                      # "sw": issue the next fragment
                arg["i"] += 1
                self._issue_sw(arg)
        else:
            cb(self.now)               # user callback (submit_control)

    def _kick_axi(self) -> None:
        if self.axi_busy:
            return
        ns_per_b = self.hw.wire_ns_per_byte(self.hw.axi_gbps)
        if self.axi_ctrl:
            nbytes, cb = self.axi_ctrl.popleft()
            self.axi_busy = True
            heapq.heappush(self._events,
                           (self.now + nbytes * ns_per_b, self._seq,
                            K_CTRL, cb))
            self._seq += 1
            return
        picked = self._axi_pick()
        if picked is None:
            return
        i, frag, kind, cb = picked
        overhead = (self.frag.hw_overhead_cycles
                    if self.frag.mode == "hardware" else 0)
        dur = frag.nbytes * ns_per_b + self.hw.cycles_ns(overhead)
        self.axi_busy = True
        heapq.heappush(self._events, (self.now + dur, self._seq, K_AXI,
                                      (i, frag, kind, cb)))
        self._seq += 1

    def _axi_done(self, payload) -> None:
        i, frag, kind, cb = payload
        self.axi_busy = False
        if kind == "egress":
            self._egress_enqueue(i, frag, cb)
        else:
            self._io_bytes_cum[i] += frag.nbytes
            self._win_io[i] += frag.nbytes
            self.stats[i].io_bytes_done += frag.nbytes
            self._run_cb(cb)
        self._kick_axi()

    def _kick_egress(self) -> None:
        if self.egress_busy:
            return
        picked = self._egress_pick()
        if picked is None:
            return
        i, frag, cb = picked
        dur = frag.nbytes * self.hw.wire_ns_per_byte(self.hw.egress_gbps)
        self.egress_busy = True
        heapq.heappush(self._events, (self.now + dur, self._seq, K_EGR,
                                      (i, frag, cb)))
        self._seq += 1

    def _egress_done(self, payload) -> None:
        i, frag, cb = payload
        self.egress_busy = False
        self._io_bytes_cum[i] += frag.nbytes
        self._win_io[i] += frag.nbytes
        self.stats[i].io_bytes_done += frag.nbytes
        self._run_cb(cb)
        self._kick_egress()

    def _ctrl_done(self, cb) -> None:
        self.axi_busy = False
        if cb:
            cb(self.now)
        self._kick_axi()

    # ------------------------------------------------------------------
    # arrivals
    # ------------------------------------------------------------------
    def _arrival_one(self, j: int) -> None:
        """One arrival, scalar — mirrors ``Simulator._arrival`` on the
        SoA store (used whenever a dispatch or an active-set change is
        possible; the caller has already advanced time to the packet)."""
        i = self._p_tenant_l[j]
        st = self.stats[i]
        if st.first_arrival == _INF:
            st.first_arrival = self.now
            self._fa_left -= 1
        tc = self._tc
        tc["arrivals"][i] += 1.0
        tc["bytes_in"][i] += self._p_size_l[j]
        d = self._tc_dirty
        d["arrivals"] = d["bytes_in"] = True
        fmq = self.fmqs[i]
        tr = self.trace
        if tr is not None:
            uid = self._tr_uid
            self._tr_uid += 1
            self._tr_uid_arr[j] = uid
        if not self._admit[i]:
            st.drops += 1
            self.tel.inc("rejected", i)
            self.eqhub.push_raw(i, EventKind.BACKPRESSURE, self.now)
            if tr is not None:
                tr.span(TR.ST_ARRIVE, uid, i, self.now, self.now,
                        TR.D_REJECT)
                TR.record_admission_reject(tr, self.now, i)
            return
        if self._fifo_len[i] >= self._fifo_cap[i]:
            st.drops += 1
            fmq.drops += 1
            self.tel.inc("drops", i)
            self.eqhub.push_raw(i, EventKind.QUEUE_OVERFLOW, self.now)
            if tr is not None:
                tr.span(TR.ST_ARRIVE, uid, i, self.now, self.now,
                        TR.D_DROP)
            return
        self._fifo[i].append(j)
        self._fifo_len[i] += 1
        fmq.enqueued += 1
        marked = self._fifo_len[i] >= self._ecn_thresh[i]
        if marked:
            fmq.ecn_marks += 1
            self.tel.inc("ecn_marks", i)
            self.eqhub.push_raw(i, EventKind.ECN_MARK, self.now)
            if tr is not None:
                # accepted packets get their ARRIVE row at completion
                # (span_packet); only the disposition is noted here
                self._tr_adisp[j] = TR.D_MARK
        if self.st.queue_len[i] == 0:
            self._limit_dirty = True
            if self.st.cur_occup[i] == 0:      # joins the active set
                self._act[i] = True
                self._act_n += 1
                self._act_f[i] = 1.0
                self._occF_act[i] = self.st.cur_occup[i]
                self._jain_cache = None
        self.st.queue_len[i] += 1
        self._dispatch()

    def _arrival_batch(self, t_ev: float, s_ev: int) -> None:
        """Apply every arrival up to the next decision point in one
        vectorized pass (valid only while ``free_pus == 0``: no arrival
        can dispatch).  The batch never crosses a telemetry-window
        boundary or a WLBVT active-set change, so the integration folds
        see exactly the intervals the event loop folds at."""
        ord_t, order = self._ord_t, self._order
        otl, osl = self._ord_t_l, self._ord_seq_l
        c, n = self._cursor, len(otl)
        b = self._win_start + self.io_window_ns
        bound = t_ev if t_ev < b else b
        hz = self._horizon
        if hz is not None and hz < bound:
            # horizon cut (inclusive: the event loop processes t ==
            # horizon and leaves strictly-later events queued)
            e = int(np.searchsorted(ord_t, hz, side="right"))
        else:
            e = int(np.searchsorted(ord_t, bound, side="left"))
            if t_ev < b:             # same-time heap event: seq decides
                while e < n and otl[e] == t_ev and osl[e] < s_ev:
                    e += 1
        if e > c + MAX_BATCH:        # bound the (m, T) fold buffers; the
            e = c + MAX_BATCH        # main loop re-enters for the rest
        if e > c and self._act_n < self._T:
            # never batch across a WLBVT active-set change: cut before
            # the first arrival that would activate an idle tenant
            inactive = (self.st.queue_len == 0) & (self.st.cur_occup == 0)
            mm = inactive[self._p_tenant[order[c:e]]]
            if mm.any():
                e = c + int(np.argmax(mm))
        if e <= c:
            # boundary-straddling or activating head: scalar path (the
            # shared _advance_to commits any window it crosses first)
            j = self._ord_j_l[c]
            self._cursor = c + 1
            t = otl[c]
            self._advance_to(t)
            self.now = t
            self._arrival_one(j)
            return
        m = e - c
        self._cursor = e
        if m < SMALL_BATCH or not self._admit_all:
            # tiny batch (or admission gating active): the scalar
            # per-arrival path — same ops as the event loop
            ojl = self._ord_j_l
            for k in range(c, e):
                t = otl[k]
                self._advance_small(t)
                self.now = t
                self._arrival_one(ojl[k])
            return
        batch = order[c:e]
        tn = self._p_tenant[batch]
        T = self._T
        st = self.st
        tr = self.trace
        if tr is not None:
            # uids in arrival-processing order, assigned for the whole
            # batch in one vectorized store
            tr_uids = self._tr_uid + np.arange(m, dtype=np.int64)
            self._tr_uid += m
            self._tr_uid_arr[batch] = tr_uids
        # --- integration folds (exact: cumsum == sequential adds) -----
        dts = np.empty(m)
        d0 = otl[c] - self._last_adv
        dts[0] = d0 if d0 > 0.0 else 0.0   # dt<=0: event loop skips it
        np.subtract(ord_t[c + 1:e], ord_t[c:e - 1], out=dts[1:])
        if self._fold_buf is None:
            self._fold_buf = np.empty((MAX_BATCH + 1, 2 * T))
        buf = self._fold_buf
        buf[0] = self._ob.reshape(-1)
        np.multiply(dts[:, None], self._advA.reshape(-1)[None, :],
                    out=buf[1:m + 1])
        # per-lane sequential accumulation == the event loop's += chain
        np.add.accumulate(buf[:m + 1], axis=0, out=buf[:m + 1])
        self._ob.reshape(-1)[:] = buf[m]
        if self._act_n >= 2:
            # the integrand is constant over the batch (occupancies do
            # not change): one fused add per accumulator — within the
            # documented bounded drift of the Jain fold (DESIGN.md §8)
            s = float(np.add.reduce(dts))
            self._jain_pu_acc += self._jain_cur() * s
            self._jain_pu_t += s
        last_t = otl[e - 1]
        self._last_adv = last_t
        self.now = last_t
        # --- counters + first arrivals --------------------------------
        counts = np.bincount(tn, minlength=T)
        self._st_arrivals += counts
        self._st_bytes_in += np.bincount(
            tn, weights=self._p_size[batch], minlength=T)
        if self._fa_left:
            for i in np.flatnonzero(counts).tolist():
                s = self.stats[i]
                if s.first_arrival == _INF:
                    s.first_arrival = otl[c + int(np.argmax(tn == i))]
                    self._fa_left -= 1
        # --- FMQ depth classification ---------------------------------
        # 0 = accepted, 1 = accepted + ECN-marked, 2 = dropped.  Depth
        # only grows inside a batch (no pops: every PU is busy), so a
        # tenant is either all-drop (already full), all-fit (stays below
        # the ECN threshold), or walked scalar through the transition.
        fl = self._fifo_len
        full_t = fl >= self._fifo_cap
        if bool(full_t.all()):
            # flood steady state: every FMQ is full, every arrival drops
            # — no FIFO/queue/scheduler state changes, so the batch
            # reduces to drop counters + the EQ block
            self._acc_drops += counts
            self._acc_fmq_drops += counts
            self._st_drops += counts
            self.eqhub.push_block(tn, self._kind2[:m], ord_t[c:e])
            if tr is not None:
                tr.span_block(TR.ST_ARRIVE, tr_uids, tn, ord_t[c:e],
                              ord_t[c:e], TR.D_DROP)
            return
        open_pos = (~full_t[tn]).nonzero()[0]
        if open_pos.size <= 16:
            # near-full flood — the steady state right after PU pops
            # left a few FMQs a slot below capacity: only the open
            # tenants' few packets walk the scalar accept/mark/drop
            # ladder (identical transitions to FMQ.push), everything
            # else drops in block, chronological order preserved
            kind = np.full(m, 2, np.int8)
            ojl = self._ord_j_l
            ql = st.queue_len
            tn_open = self._p_tenant_l
            open_state: dict = {}
            cap_l, thr_l = self._fifo_cap_l, self._ecn_thresh_l
            n_acc = 0
            any_mark = False
            for k in open_pos.tolist():
                q = ojl[c + k]
                i = tn_open[q]
                s = open_state.get(i)
                if s is None:
                    s = open_state[i] = [int(fl[i]), cap_l[i], thr_l[i], 0]
                if s[0] < s[1]:
                    s[0] = d = s[0] + 1
                    s[3] += 1
                    n_acc += 1
                    self._fifo[i].append(q)
                    if d >= s[2]:          # accepted but ECN-marked
                        kind[k] = 1
                        any_mark = True
                    else:
                        kind[k] = 0
            nd = counts.copy()
            for i, s in open_state.items():
                a = s[3]
                if a:
                    nd[i] -= a
                    if ql[i] == 0:         # non-empty set grew
                        self._limit_dirty = True
                    ql[i] += a
                    fl[i] = s[0]
                    self._acc_enq[i] += a
            if any_mark:
                for k in (kind == 1).nonzero()[0].tolist():
                    i = tn_open[ojl[c + k]]
                    self._acc_marks[i] += 1
                    self.tel.inc("ecn_marks", i, 1)
            self._acc_drops += nd
            self._acc_fmq_drops += nd
            self._st_drops += nd
            if n_acc == 0:
                self.eqhub.push_block(tn, kind, ord_t[c:e])
            else:
                ev_pos = kind.nonzero()[0]
                if ev_pos.size:
                    self.eqhub.push_block(tn[ev_pos], kind[ev_pos],
                                          ord_t[c:e][ev_pos])
            if tr is not None:
                dsel = (kind == 2).nonzero()[0]
                if dsel.size:
                    tr.span_block(TR.ST_ARRIVE, tr_uids[dsel], tn[dsel],
                                  ord_t[c:e][dsel], ord_t[c:e][dsel],
                                  TR.D_DROP)
                msel = (kind == 1).nonzero()[0]
                if msel.size:
                    self._tr_adisp[batch[msel]] = TR.D_MARK
            return
        fit_t = fl + counts < self._ecn_thresh
        kind = None
        if full_t.any() or not fit_t.all():
            lut = self._kind_lut
            np.multiply(full_t, 2, out=lut, casting="unsafe")
            kind = lut[tn]
            trans_t = ~(full_t | fit_t) & (counts > 0)
            if trans_t.any():
                for i in np.flatnonzero(trans_t).tolist():
                    d = int(fl[i])
                    C = int(self._fifo_cap[i])
                    E = int(self._ecn_thresh[i])
                    for k in np.flatnonzero(tn == i).tolist():
                        if d >= C:
                            kind[k] = 2
                        else:
                            d += 1
                            if d >= E:
                                kind[k] = 1
        # --- accepted: FIFO pushes + queue/depth counters -------------
        if kind is None:
            acc_counts = counts
            atn, pkt = tn, batch
        else:
            acc_sel = np.flatnonzero(kind != 2)
            acc_counts = np.bincount(tn[acc_sel], minlength=T)
            atn, pkt = tn[acc_sel], batch[acc_sel]
        if atn.size:
            if not self._limit_dirty and np.any(
                    (st.queue_len == 0) & (acc_counts > 0)):
                self._limit_dirty = True
            fl += acc_counts
            st.queue_len += acc_counts
            self._acc_enq += acc_counts
            o = np.argsort(atn, kind="stable")   # per-tenant time order
            fifo = self._fifo
            for i, q in zip(atn[o].tolist(), pkt[o].tolist()):
                fifo[i].append(q)
        # --- flagged packets: stats, telemetry, EQ events -------------
        if kind is not None:
            flagged = np.flatnonzero(kind)
            if flagged.size:
                ftn = tn[flagged]
                fk = kind[flagged]
                drop_t = ftn[fk == 2]
                mark_t = ftn[fk == 1]
                if drop_t.size:
                    nd = np.bincount(drop_t, minlength=T)
                    self._acc_drops += nd
                    self._acc_fmq_drops += nd
                    self.tel.inc_column("drops", nd)
                if mark_t.size:
                    nm = np.bincount(mark_t, minlength=T)
                    self._acc_marks += nm
                    self.tel.inc_column("ecn_marks", nm)
                # EQ events stay per packet in chronological order; the
                # block log materializes only the retained ring window
                self.eqhub.push_block(ftn, fk, ord_t[c:e][flagged])
        if tr is not None and kind is not None:
            dsel = (kind == 2).nonzero()[0]
            if dsel.size:
                tr.span_block(TR.ST_ARRIVE, tr_uids[dsel], tn[dsel],
                              ord_t[c:e][dsel], ord_t[c:e][dsel],
                              TR.D_DROP)
            msel = (kind == 1).nonzero()[0]
            if msel.size:
                self._tr_adisp[batch[msel]] = TR.D_MARK

    def _flush_accumulators(self) -> None:
        """Fold the batch-side vector counters and the scalar-hot-path
        list accumulators into the per-tenant stat/FMQ/ledger objects
        (same final values as per-event increments)."""
        self._flush_tc()
        for i in np.flatnonzero(self._acc_drops
                                | self._acc_marks | self._acc_enq).tolist():
            self.stats[i].drops += int(self._acc_drops[i])
            fmq = self.fmqs[i]
            fmq.drops += int(self._acc_fmq_drops[i])
            fmq.ecn_marks += int(self._acc_marks[i])
            fmq.enqueued += int(self._acc_enq[i])
        self._acc_drops[:] = 0
        self._acc_fmq_drops[:] = 0
        self._acc_marks[:] = 0
        self._acc_enq[:] = 0
        for i in range(self._T):
            st = self.stats[i]
            c = self._c_completed[i]
            if c:
                st.completed += c
                st.served_payload_bytes += self._c_served[i]
                self._c_completed[i] = 0
                self._c_served[i] = 0.0
            if self._c_lastcomp[i] > st.last_completion:
                st.last_completion = self._c_lastcomp[i]
            fc = self._c_fmqcomp[i]
            if fc:
                self.fmqs[i].completed += fc
                self._c_fmqcomp[i] = 0
            kts = self._kt_pend[i]
            if kts:
                from repro.sim.engine import KT_RESERVOIR_CAP
                n, mv = st.kernel_time_count, len(kts)
                if n + mv <= KT_RESERVOIR_CAP:
                    if st._kt_buf is None:
                        st._kt_buf = np.empty(KT_RESERVOIR_CAP)
                    st._kt_buf[n:n + mv] = kts     # one vectorized fill
                    st.kernel_time_count = n + mv
                    s = st.kernel_time_sum
                    for v in kts:                  # same sequential adds
                        s += v
                    st.kernel_time_sum = s
                    st._kt_pcache = None
                else:                              # straddles the cap:
                    for v in kts:                  # exact replay
                        st.record_kernel_time(v)
                self._kt_pend[i] = []
        self.budget.spent[:] = self._spent

    # ------------------------------------------------------------------
    # trace plane
    # ------------------------------------------------------------------
    def trace_flush(self, t: float) -> None:
        """End-of-run flush mirroring the event loop's override row for
        row: queued packets from the SoA FIFOs, in-flight ones from the
        slot table, in uid order."""
        tr = self.trace
        if tr is None:
            return
        ents = []
        for i, q in enumerate(self._fifo):
            for j in q:
                ents.append((int(self._tr_uid_arr[j]), i,
                             float(self._p_t[j]),
                             int(self._tr_adisp[j]), None))
        busy = set(range(self.hw.num_pus)) - set(self._free_slots)
        for slot in busy:
            j = self._s_pkt[slot]
            ents.append((self._s_uid[slot], self._s_tenant[slot],
                         float(self._p_t[j]), int(self._tr_adisp[j]),
                         (slot, self._s_grant[slot],
                          self._s_tcomp[slot])))
        for uid, ten, arr, adisp, m in sorted(ents, key=lambda e: e[0]):
            tr.span(TR.ST_ARRIVE, uid, ten, arr, arr, adisp)
            if m is None:
                tr.span(TR.ST_FMQ, uid, ten, arr, t, TR.D_OPEN)
                continue
            slot, g, tc = m
            tr.span(TR.ST_FMQ, uid, ten, arr, g, TR.D_OK, pu=slot)
            tr.span(TR.ST_GRANT, uid, ten, g, g, TR.D_OK, pu=slot)
            if t >= tc:
                tr.span(TR.ST_PU, uid, ten, g, tc, TR.D_OK, pu=slot)
                tr.span(TR.ST_DMA, uid, ten, tc, t, TR.D_OPEN, pu=slot)
            else:
                tr.span(TR.ST_PU, uid, ten, g, t, TR.D_OPEN, pu=slot)
        tr.commit()

    # ------------------------------------------------------------------
    # main loop
    # ------------------------------------------------------------------
    def run(self, trace, horizon: Optional[float] = None) -> SimResult:
        self._inject(trace)
        self._admit_all = bool(self._admit.all())
        self._horizon = horizon      # arrival batches must not cross it
        ev = self._events
        inf = _INF
        while True:
            c = self._cursor
            otl = self._ord_t_l
            have_arr = c < len(otl)
            t_arr = otl[c] if have_arr else inf
            if ev:
                t_ev, s_ev = ev[0][0], ev[0][1]
            else:
                t_ev, s_ev = inf, -1
            if not have_arr and not ev:
                break
            arr_first = (t_arr < t_ev
                         or (t_arr == t_ev and self._ord_seq_l[c] < s_ev))
            t_next = t_arr if arr_first else t_ev
            if horizon is not None and t_next > horizon:
                break            # leave the work queued for a later run()
            if arr_first:
                if self.free_pus == 0:
                    self._arrival_batch(t_ev, s_ev)
                else:
                    j = self._ord_j_l[c]
                    self._cursor = c + 1
                    self._advance(t_arr)
                    self.now = t_arr
                    self._arrival_one(j)
            else:
                t, _, code, payload = heapq.heappop(ev)
                self._advance(t)
                self.now = t
                if code == K_FIN:
                    self._finish_slot(payload)
                elif code == K_SUBMIT:
                    self._submit_slot_transfer(payload)
                elif code == K_AXI:
                    self._axi_done(payload)
                elif code == K_EGR:
                    self._egress_done(payload)
                else:
                    self._ctrl_done(payload)
        self._flush_accumulators()
        tl = None
        if self.record_timeline:
            tl = {k: np.array(v) for k, v in self._tl.items()}
        self.tel.commit()        # flush any partial-window staged samples
        if self.trace is not None:
            self.trace.commit()
        return SimResult(
            time=self.now,
            stats=self.stats,
            jain_pu_timeavg=(self._jain_pu_acc / self._jain_pu_t
                             if self._jain_pu_t else 1.0),
            jain_io_timeavg=(self._jain_io_acc / self._jain_io_t
                             if self._jain_io_t else 1.0),
            timeline=tl,
            events=self.eqhub.drain_all(),
            telemetry=self.tel,
            sched_state={
                "prio": self.st.prio.copy(),
                "total_occup": self.st.total_occup.copy(),
                "bvt": self.st.bvt.copy(),
                "kv_pressure": self._kv_pressure_row(),
            },
            completions=(list(self._completions)
                         if self.record_completions else None),
        )


DATAPATHS = {"event": Simulator, "batched": BatchedSimulator}


def build_simulator(tenants, *, datapath: str = "event", **kw) -> Simulator:
    """Factory over the two simulator data planes (same semantics)."""
    try:
        cls = DATAPATHS[datapath]
    except KeyError:
        raise ValueError(f"unknown datapath {datapath!r} "
                         f"(want one of {sorted(DATAPATHS)})") from None
    return cls(tenants, **kw)
