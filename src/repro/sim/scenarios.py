"""Legacy scenario entry points — thin shims over the unified runtime API.

Every scenario here is now a registered declarative ``ScenarioSpec`` in
``repro.api.scenarios``; these functions survive as deprecation shims
that build the spec and run it through ``SimRuntime``, returning the
backend-native ``SimResult`` the old callers consume.  New code should
use the API directly:

    from repro.api import get_scenario, run_scenario
    report = run_scenario(get_scenario("fig9_congestor_victim"), "sim")

or the CLI: ``python -m repro.launch.scenario <name> --backend sim``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.configs.osmosis_pspin import PSPIN
from repro.core import ECTX, FragmentationPolicy, SLOPolicy
from repro.sim.engine import SimResult
from repro.sim.workloads import WORKLOADS, WorkloadModel, ppb


def make_tenants(kernels: List[WorkloadModel],
                 priorities: Optional[List[float]] = None,
                 cycle_limits: Optional[List[int]] = None) -> List[ECTX]:
    out = []
    for i, k in enumerate(kernels):
        slo = SLOPolicy(
            priority=(priorities[i] if priorities else 1.0),
            kernel_cycle_limit=(cycle_limits[i] if cycle_limits else 0))
        out.append(ECTX(tenant_id=i, name=k.name, slo=slo, kernel=k))
    return out


def _run_sim(spec) -> SimResult:
    """Run a spec on the sim backend, returning the legacy SimResult."""
    from repro.api.runtime import SimRuntime
    rt = SimRuntime.from_spec(spec)
    rt.run(spec)
    return rt.result


def run_congestor_victim_compute(scheduler: str, *, cpb_victim: float = 0.6,
                                 cpb_ratio: float = 2.0,
                                 duration_us: float = 300.0,
                                 pkt_size: int = 512, seed: int = 0
                                 ) -> SimResult:
    """Paper Figs. 4 & 9 (shim over ``fig9_congestor_victim``)."""
    from repro.api import get_scenario
    return _run_sim(get_scenario(
        "fig9_congestor_victim", scheduler=scheduler, cpb_victim=cpb_victim,
        cpb_ratio=cpb_ratio, duration_us=duration_us, pkt_size=pkt_size,
        seed=seed))


def run_hol_blocking(frag: FragmentationPolicy, *, congestor_size: int = 4096,
                     victim_size: int = 64, duration_us: float = 150.0,
                     scheduler: str = "wlbvt", arb: str = "dwrr",
                     seed: int = 0) -> SimResult:
    """Paper Figs. 5 & 10 (shim over ``fig10_hol_blocking``)."""
    from repro.api import get_scenario
    return _run_sim(get_scenario(
        "fig10_hol_blocking", frag_mode=frag.mode,
        frag_bytes=frag.fragment_bytes, congestor_size=congestor_size,
        victim_size=victim_size, duration_us=duration_us,
        scheduler=scheduler, arb=arb, seed=seed))


def run_standalone(workload_name: str, *, pkt_size: int,
                   duration_us: float = 100.0,
                   osmosis: bool = True, seed: int = 0) -> SimResult:
    """Paper Fig. 11 (shim over ``fig11_standalone``)."""
    from repro.api import get_scenario
    return _run_sim(get_scenario(
        "fig11_standalone", workload=workload_name, pkt_size=pkt_size,
        duration_us=duration_us, osmosis=osmosis, seed=seed))


def run_qos_closed_loop(controller: bool = True, *,
                        p99_target_ns: float = 2000.0,
                        duration_us: float = 300.0,
                        control_interval_ns: float = 8000.0,
                        seed: int = 0) -> SimResult:
    """Closed-loop QoS, DESIGN.md §6 (shim over ``qos_closed_loop``)."""
    from repro.api import get_scenario
    return _run_sim(get_scenario(
        "qos_closed_loop", controller=controller,
        p99_target_ns=p99_target_ns, duration_us=duration_us,
        control_interval_ns=control_interval_ns, seed=seed))


def run_compute_mixture(scheduler: str, *, duration_us: float = 200.0,
                        seed: int = 0) -> SimResult:
    """Paper Fig. 12 (shim over ``fig12_compute_mixture``)."""
    from repro.api import get_scenario
    return _run_sim(get_scenario(
        "fig12_compute_mixture", scheduler=scheduler,
        duration_us=duration_us, seed=seed))


def run_io_mixture(scheduler: str, *, frag: Optional[FragmentationPolicy]
                   = None, duration_us: float = 200.0,
                   seed: int = 0) -> SimResult:
    """Paper Figs. 13/14 (shim over ``fig13_io_mixture``)."""
    from repro.api import get_scenario
    kw = {}
    if frag is not None:
        kw = {"frag_mode": frag.mode, "frag_bytes": frag.fragment_bytes}
    return _run_sim(get_scenario(
        "fig13_io_mixture", scheduler=scheduler, duration_us=duration_us,
        seed=seed, **kw))


def service_time_vs_ppb(pkt_sizes: List[int]) -> Dict[str, List[Tuple[int, float, float]]]:
    """Paper Fig. 3: per-workload single-packet service time vs PPB
    (analytic; also exposed as the ``ppb_service_time`` scenario)."""
    out: Dict[str, List[Tuple[int, float, float]]] = {}
    for name, wl in WORKLOADS.items():
        rows = []
        for p in pkt_sizes:
            payload = max(0, p - PSPIN.header_bytes)
            service = wl.compute_cycles(payload)
            if wl.io_kind != "none":
                service += wl.io_bytes(payload) * PSPIN.wire_ns_per_byte(
                    PSPIN.axi_gbps)
            budget = ppb(PSPIN.num_pus, p, PSPIN.ingress_gbps)
            rows.append((p, service, budget))
        out[name] = rows
    return out
