"""Scenario builders for the paper's experiments (§3, §7.3, §7.4) plus
the telemetry-plane closed-loop QoS scenario (DESIGN.md §6)."""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.osmosis_pspin import PSPIN
from repro.core import ECTX, FragmentationPolicy, SLOPolicy
from repro.sim.engine import SimResult, Simulator
from repro.sim.traffic import equal_share_traces, make_trace, merge_traces
from repro.sim.workloads import (WORKLOADS, WorkloadModel, ppb,
                                 spin_workload)
from repro.telemetry import QoSController


def make_tenants(kernels: List[WorkloadModel],
                 priorities: Optional[List[float]] = None,
                 cycle_limits: Optional[List[int]] = None) -> List[ECTX]:
    out = []
    for i, k in enumerate(kernels):
        slo = SLOPolicy(
            priority=(priorities[i] if priorities else 1.0),
            kernel_cycle_limit=(cycle_limits[i] if cycle_limits else 0))
        out.append(ECTX(tenant_id=i, name=k.name, slo=slo, kernel=k))
    return out


def run_congestor_victim_compute(scheduler: str, *, cpb_victim: float = 0.6,
                                 cpb_ratio: float = 2.0,
                                 duration_us: float = 300.0,
                                 pkt_size: int = 512, seed: int = 0
                                 ) -> SimResult:
    """Paper Figs. 4 & 9: two compute-bound spin tenants, the Congestor with
    `cpb_ratio`x the compute cost per byte."""
    victim = spin_workload("victim", cpb_victim)
    congestor = spin_workload("congestor", cpb_victim * cpb_ratio)
    tenants = make_tenants([congestor, victim])
    trace = equal_share_traces(2, sizes=[pkt_size, pkt_size],
                               duration_ns=duration_us * 1e3, seed=seed)
    sim = Simulator(tenants, scheduler=scheduler, record_timeline=True)
    return sim.run(trace)


def run_hol_blocking(frag: FragmentationPolicy, *, congestor_size: int = 4096,
                     victim_size: int = 64, duration_us: float = 150.0,
                     scheduler: str = "wlbvt", arb: str = "dwrr",
                     seed: int = 0) -> SimResult:
    """Paper Figs. 5 & 10: storage-read pattern — small request packets
    trigger large blocking egress transfers (paper §5.1 step 5: "kernels
    can pipeline large storage reads").  The congestor's PUs hold up to
    pu_limit concurrent `congestor_size` transfers, so under FIFO (no-QoS)
    arbitration the victim's 64B transfer waits behind the whole in-flight
    burst; DWRR + fragmentation bounds that wait to ~one fragment."""
    victim = WorkloadModel("victim_io", 40, 0.02, io_kind="egress",
                           io_fixed_bytes=victim_size)
    congestor = WorkloadModel("congestor_io", 40, 0.02, io_kind="egress",
                              io_fixed_bytes=congestor_size)
    tenants = make_tenants([congestor, victim])
    trace = merge_traces(
        # congestor: enough 512B requests to keep its PU share saturated
        make_trace(0, size=512, share=0.50, seed=seed,
                   duration_ns=duration_us * 1e3),
        # victim: latency probe at modest rate
        make_trace(1, size=64, share=0.02, seed=seed + 1,
                   duration_ns=duration_us * 1e3))
    sim = Simulator(tenants, scheduler=scheduler, frag=frag, arb=arb)
    return sim.run(trace)


def run_standalone(workload_name: str, *, pkt_size: int,
                   duration_us: float = 100.0,
                   osmosis: bool = True, seed: int = 0) -> SimResult:
    """Paper Fig. 11: single tenant; OSMOSIS (WLBVT + hw frag) vs the
    reference PsPIN (RR, no fragmentation)."""
    wl = WORKLOADS[workload_name]
    tenants = make_tenants([wl])
    trace = make_trace(0, size=pkt_size, link_gbps=PSPIN.ingress_gbps,
                       duration_ns=duration_us * 1e3, seed=seed)
    frag = (FragmentationPolicy(mode="hardware", fragment_bytes=512)
            if osmosis else FragmentationPolicy(mode="off"))
    sim = Simulator(tenants, scheduler="wlbvt" if osmosis else "rr",
                    frag=frag, arb="dwrr" if osmosis else "fifo")
    return sim.run(trace)


def run_qos_closed_loop(controller: bool = True, *,
                        p99_target_ns: float = 2000.0,
                        duration_us: float = 300.0,
                        control_interval_ns: float = 8000.0,
                        seed: int = 0) -> SimResult:
    """Closed-loop QoS (DESIGN.md §6): a latency-SLO victim whose PU
    demand (~17 of 32 PUs) slightly exceeds its static equal-weight share
    (16), against a heavy congestor (~25 PUs demand).

    With static weights the victim's backlog — and so its p99 sojourn
    latency — grows without bound for the whole run.  With the
    ``QoSController`` the telemetry plane's interval p99 signal drives
    AIMD weight boosts until the victim's WLBVT cap covers its demand,
    then decays the boost back; the victim's p99 stabilizes near its
    target while weighted fairness (normalized by the *current* weights)
    stays ~1.
    """
    victim = spin_workload("victim", 2.0)
    congestor = spin_workload("congestor", 2.0)
    tenants = make_tenants([congestor, victim])
    trace = merge_traces(
        # congestor: 1024B packets, ~25 PUs of demand
        make_trace(0, size=1024, share=0.25, seed=seed,
                   duration_ns=duration_us * 1e3),
        # victim: 256B latency probes, ~17 PUs of demand (cap is 16)
        make_trace(1, size=256, share=0.175, seed=seed + 1,
                   duration_ns=duration_us * 1e3))
    ctrl = None
    if controller:
        ctrl = QoSController(base_weights=np.ones(2),
                             p99_targets=[0.0, p99_target_ns])
    sim = Simulator(tenants, scheduler="wlbvt", controller=ctrl,
                    control_interval_ns=control_interval_ns)
    return sim.run(trace)


def _pu_share(wl: WorkloadModel, size: int, target_pus: float) -> float:
    """Ingress link share at which tenant demands `target_pus` PU-cycles/ns."""
    payload = max(1, size - PSPIN.header_bytes)
    cyc = wl.compute_cycles(payload)
    bytes_per_ns_full = PSPIN.ingress_gbps / 8.0
    return target_pus * size / (bytes_per_ns_full * cyc)


def _io_share(wl: WorkloadModel, size: int, target_bytes_per_ns: float) -> float:
    payload = max(1, size - PSPIN.header_bytes)
    io_b = max(1, wl.io_bytes(payload))
    bytes_per_ns_full = PSPIN.ingress_gbps / 8.0
    return target_bytes_per_ns * size / (bytes_per_ns_full * io_b)


def run_compute_mixture(scheduler: str, *, duration_us: float = 200.0,
                        seed: int = 0) -> SimResult:
    """Paper Fig. 12: Reduce + Histogram, each as Victim (64-128B pkts)
    and Congestor (3-4KB pkts).  The paper's traces "saturate the PUs
    within the first couple thousand cycles": we model that burst regime
    with ingress shares summing to ~1.3x (FIFOs draining a burst), which
    keeps every tenant backlogged.  Small packets cost more PU cycles per
    byte (handler base cost amortizes poorly), so under RR — which grants
    per *packet* — the congestors' ~2.5k-cycle kernels monopolize the PUs
    and the victims starve; WLBVT equalizes priority-normalized PU time.
    """
    ks = [WORKLOADS["reduce"], WORKLOADS["reduce"],
          WORKLOADS["histogram"], WORKLOADS["histogram"]]
    sizes = [64, 4096, 96, 3584]
    shares = [0.30, 0.35, 0.30, 0.35]
    tenants = make_tenants(ks)
    for t, name in zip(tenants, ["reduce_victim", "reduce_congestor",
                                 "hist_victim", "hist_congestor"]):
        t.name = name
    traces = [make_trace(i, size=sizes[i], seed=seed + i, share=shares[i],
                         duration_ns=duration_us * 1e3)
              for i in range(4)]
    sim = Simulator(tenants, scheduler=scheduler,
                    frag=FragmentationPolicy(mode="hardware",
                                             fragment_bytes=512),
                    fifo_capacity=1 << 17, record_timeline=True)
    return sim.run(merge_traces(*traces))


def run_io_mixture(scheduler: str, *, frag: Optional[FragmentationPolicy]
                   = None, duration_us: float = 200.0,
                   seed: int = 0) -> SimResult:
    """Paper Fig. 13/14: storage data-path offload mixture.  Read/write
    victims issue small (64B) DMA ops; read/write congestors are
    storage-RPC kernels (512B requests each triggering a 4 KiB DMA,
    paper §7.4 "storage RPCs and TCP segment delivery"), sized so combined
    DMA demand is ~1.1x the AXI.  Under the reference (RR + FIFO bus, no
    fragmentation) victims are HoL-blocked behind the congestors' in-flight
    4 KiB bursts; OSMOSIS (WLBVT + DWRR + hw fragmentation) bounds victim
    latency at ~one fragment while preserving congestor byte throughput."""
    read_v = WorkloadModel("read_victim", 40, 0.02, io_kind="dma_read",
                           io_fixed_bytes=64)
    read_c = WorkloadModel("read_congestor", 40, 0.02, io_kind="dma_read",
                           io_fixed_bytes=4096)
    write_v = WorkloadModel("write_victim", 40, 0.02, io_kind="dma_write",
                            io_fixed_bytes=64)
    write_c = WorkloadModel("write_congestor", 40, 0.02, io_kind="dma_write",
                            io_fixed_bytes=4096)
    ks = [read_v, read_c, write_v, write_c]
    tenants = make_tenants(ks)
    for t, k in zip(tenants, ks):
        t.name = k.name
    # equal ingress shares; the congestors' 8x DMA amplification (512B
    # request -> 4 KiB transfer) pushes combined AXI demand to ~1.4x the
    # bus, and their *blocking* IO holds PUs during transfers — under
    # RR+FIFO that starves the victims of both PUs and bus slots
    shares = [0.10, 0.10, 0.10, 0.10]
    sizes = [64, 512, 64, 512]
    # victims are finite bursts (first 60%); congestors span the full run,
    # regaining exclusive bandwidth after victims drain (paper Fig. 13)
    durs = [0.6, 1.0, 0.6, 1.0]
    traces = [make_trace(i, size=sizes[i], share=shares[i], seed=seed + i,
                         duration_ns=durs[i] * duration_us * 1e3)
              for i in range(4)]
    link_bns = PSPIN.ingress_gbps / 8.0
    demand = [shares[i] * link_bns * ks[i].io_fixed_bytes / sizes[i]
              for i in range(4)]
    osmosis = scheduler == "wlbvt"
    if frag is None:
        frag = (FragmentationPolicy(mode="hardware", fragment_bytes=1024)
                if osmosis else FragmentationPolicy(mode="off"))
    sim = Simulator(tenants, scheduler=scheduler, frag=frag,
                    arb="dwrr" if osmosis else "fifo",
                    io_demand_weights=demand,
                    fifo_capacity=1 << 15, record_timeline=True)
    return sim.run(merge_traces(*traces))


def service_time_vs_ppb(pkt_sizes: List[int]) -> Dict[str, List[Tuple[int, float, float]]]:
    """Paper Fig. 3: per-workload single-packet service time vs PPB."""
    out: Dict[str, List[Tuple[int, float, float]]] = {}
    for name, wl in WORKLOADS.items():
        rows = []
        for p in pkt_sizes:
            payload = max(0, p - PSPIN.header_bytes)
            service = wl.compute_cycles(payload)
            if wl.io_kind != "none":
                service += wl.io_bytes(payload) * PSPIN.wire_ns_per_byte(
                    PSPIN.axi_gbps)
            budget = ppb(PSPIN.num_pus, p, PSPIN.ingress_gbps)
            rows.append((p, service, budget))
        out[name] = rows
    return out
