"""DeepSeek-V2-Lite 16B. [arXiv:2405.04434]

27L, d_model 2048, 16 heads with MLA (kv_lora_rank 512, qk nope/rope
128/64, v 128), vocab 102400.  Layer 0 dense (d_ff 10944); layers 1..26
MoE: 64 routed experts top-6 + 2 shared, expert d_ff 1408.  ~15.9B total.
"""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig, GLOBAL_ATTN

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,       # MLA: all heads share the compressed latent
    head_dim=128,
    d_ff=10944,            # dense layer 0
    vocab_size=102400,
    block_pattern=(GLOBAL_ATTN,),
    rope_theta=10_000.0,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, num_shared_experts=2,
                  expert_d_ff=1408, layer_period=1, first_dense_layers=1),
    tie_embeddings=False,
)
