"""CodeQwen1.5-7B — Qwen1.5 architecture. [hf:Qwen/CodeQwen1.5-7B]

32L, d_model 4096, 32 heads (kv=32 => MHA), SwiGLU d_ff 13440, vocab 92416,
QKV bias (Qwen1.5 signature), RoPE theta 1e6, untied embeddings.
"""
from repro.configs.base import ModelConfig, GLOBAL_ATTN

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    block_pattern=(GLOBAL_ATTN,),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp_act="silu",
    tie_embeddings=False,
)
