"""Qwen2-VL-72B. [arXiv:2409.12191]

80L, d_model 8192, 64 heads GQA kv=8, SwiGLU d_ff 29568, vocab 152064.
M-RoPE with (t, h, w) sections (16, 24, 24) over head_dim/2 = 64.
Vision ViT frontend is a STUB: input_specs() provides precomputed patch
embeddings merged into the token stream (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, GLOBAL_ATTN

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    block_pattern=(GLOBAL_ATTN,),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    mlp_act="silu",
    tie_embeddings=False,
    frontend_stub=True,
    optimizer="adafactor",
)
