"""Llama-4 Maverick 400B-A17B. [hf:meta-llama (family); interpreted]

48L, d_model 5120, 40 heads GQA kv=8, vocab 202048.  MoE every 2nd layer:
128 routed experts top-1 + 1 shared expert, expert d_ff 8192; interleaved
dense layers use d_ff 16384.  This interpretation hits ~401B total /
~17B active parameters, matching the 400b-a17b label (DESIGN.md §5).
Text backbone only; the early-fusion image frontend is stubbed.
Adafactor optimizer (HBM budget for 400B states, DESIGN.md §3).
"""
from repro.configs.base import ModelConfig, MoEConfig, GLOBAL_ATTN

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    serve_keep_fsdp=True,
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,            # dense (non-MoE) layers
    vocab_size=202048,
    block_pattern=(GLOBAL_ATTN,),
    rope_theta=500_000.0,
    mlp_act="silu",
    moe=MoEConfig(num_experts=128, top_k=1, num_shared_experts=1,
                  expert_d_ff=8192, layer_period=2),
    tie_embeddings=False,
    optimizer="adafactor",
)
