"""RecurrentGemma-2B (Griffin). [arXiv:2402.19427]

26L, d_model 2560, pattern = 2x RG-LRU block : 1x local-attention block
(window 2048), 10 heads MQA kv=1 head_dim 256, GeGLU d_ff 7680,
lru_width 2560, vocab 256000.  Windowed + recurrent -> runs long_500k.
"""
from repro.configs.base import ModelConfig, RGLRU, LOCAL_ATTN

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,         # pattern of 3 repeated; last group truncated
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
    window_size=2048,
    lru_width=2560,
    conv1d_width=4,
    mlp_act="gelu",
    scale_embeddings=True,
    tie_embeddings=True,
)
