"""PsPIN / OSMOSIS hardware model constants (paper §6-§7 setup) and the
TPU v5e target constants used for roofline analysis."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PsPINConfig:
    """Cycle-level simulator hardware model (paper experimental setup)."""
    num_clusters: int = 4
    pus_per_cluster: int = 8
    clock_ghz: float = 1.0                  # 1 cycle == 1 ns
    ingress_gbps: float = 400.0             # full-duplex link
    egress_gbps: float = 400.0
    axi_gbps: float = 512.0                 # shared L2/host interconnect
    l2_packet_buf_bytes: int = 4 << 20
    l2_kernel_buf_bytes: int = 4 << 20
    l1_bytes: int = 1 << 20
    max_fmqs: int = 128
    sched_decision_cycles: int = 5          # WLBVT pipeline depth (paper §6.2)
    dma_setup_cycles: int = 13              # 64B packet L2->L1 DMA (paper §6.2)
    header_bytes: int = 28                  # IPv4/UDP header

    @property
    def num_pus(self) -> int:
        return self.num_clusters * self.pus_per_cluster

    @property
    def ns_per_cycle(self) -> float:
        return 1.0 / self.clock_ghz

    def cycles_ns(self, cycles: float) -> float:
        """PU cycles -> virtual nanoseconds.  The event loops advance a
        ns clock; every hardware cost expressed in cycles
        (``dma_setup_cycles``, kernel compute, fragmentation overhead)
        must pass through here before touching it.  At the default
        1 GHz this is an exact ``* 1.0`` — time traces are bit-identical
        to the historical cycles==ns behaviour."""
        return cycles * self.ns_per_cycle

    def wire_ns_per_byte(self, gbps: float) -> float:
        return 8.0 / gbps                   # ns per byte at `gbps`


PSPIN = PsPINConfig()


@dataclasses.dataclass(frozen=True)
class TPUTarget:
    """Roofline constants for the production target (TPU v5e)."""
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12         # per chip
    hbm_bytes_per_s: float = 819e9          # per chip
    ici_bytes_per_s_per_link: float = 50e9  # per link/direction
    hbm_bytes: float = 16e9                 # capacity per chip
    vmem_bytes: float = 128 * 2**20         # ~128 MiB VMEM


V5E = TPUTarget()
