"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401 re-export
    GLOBAL_ATTN, LOCAL_ATTN, RGLRU, SSD,
    MLAConfig, MoEConfig, ModelConfig, SSMConfig, ShapeSpec,
    SHAPES, LONG_CONTEXT_ARCHS, cell_supported, param_count,
)

_ARCH_MODULES: Dict[str, str] = {
    "codeqwen1.5-7b": "codeqwen15_7b",
    "qwen3-8b": "qwen3_8b",
    "gemma2-27b": "gemma2_27b",
    "gemma-7b": "gemma_7b",
    "mamba2-370m": "mamba2_370m",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "whisper-large-v3": "whisper_large_v3",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: small widths, few layers/experts, tiny
    vocab — runnable forward/train step on one CPU device."""
    cfg = get_config(name)
    pat = cfg.block_pattern
    n_layers = max(2, len(pat))            # at least one full pattern group
    repl = dict(
        num_layers=n_layers,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=257,
        attn_chunk=64,
        window_size=min(cfg.window_size, 32) if cfg.window_size else 0,
        scan_layers=True,
        remat="none",
    )
    if cfg.mla is not None:
        repl["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                                qk_nope_head_dim=16, qk_rope_head_dim=8,
                                v_head_dim=16)
    if cfg.moe is not None:
        repl["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8,
            top_k=min(cfg.moe.top_k, 2),
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            expert_d_ff=64)
    if cfg.ssm is not None:
        repl["ssm"] = SSMConfig(state_dim=16, conv_dim=4, expand=2,
                                head_dim=16, n_groups=1, chunk_size=16)
    if cfg.lru_width:
        repl["lru_width"] = 64
    if cfg.is_encoder_decoder:
        repl["encoder_layers"] = 2
        repl["num_audio_frames"] = 16
    return dataclasses.replace(cfg, **repl)
