"""Qwen3-8B. [hf:Qwen/Qwen3-8B]

36L, d_model 4096, 32 heads GQA kv=8, SwiGLU d_ff 12288, vocab 151936,
per-head RMS qk-norm, no bias, RoPE theta 1e6, tied embeddings.
"""
from repro.configs.base import ModelConfig, GLOBAL_ATTN

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    block_pattern=(GLOBAL_ATTN,),
    qk_norm=True,
    rope_theta=1_000_000.0,
    mlp_act="silu",
    tie_embeddings=True,
)
