"""Configuration system: model architecture configs and input-shape specs.

Every assigned architecture is a ``ModelConfig`` instance built by a
``src/repro/configs/<arch>.py`` module exposing ``CONFIG`` (full size) and
``smoke_config()`` (reduced, CPU-runnable).  The registry in
``repro.configs`` resolves ``--arch <id>`` strings.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds used in the per-layer pattern.  The transformer stack scans over
# repeated groups of these kinds (homogeneous scan bodies compile once).
# ---------------------------------------------------------------------------
GLOBAL_ATTN = "global"     # full (causal) attention
LOCAL_ATTN = "local"       # sliding-window attention
RGLRU = "rglru"            # RG-LRU recurrent block (recurrentgemma)
SSD = "ssd"                # Mamba-2 SSD block


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # routed experts
    top_k: int = 1
    num_shared_experts: int = 0
    expert_d_ff: int = 0            # per-expert hidden
    layer_period: int = 1           # MoE every `period` layers (1 = all)
    first_dense_layers: int = 0     # leading dense layers (deepseek style)
    router_dtype: str = "float32"
    capacity_factor: float = 1.25   # train-time token capacity per expert


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0            # 0 = no q compression (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD block parameters."""
    state_dim: int = 128
    conv_dim: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"           # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 2
    num_kv_heads: int = 2
    head_dim: int = 64
    d_ff: int = 256
    vocab_size: int = 512

    # --- attention ---------------------------------------------------------
    block_pattern: Tuple[str, ...] = (GLOBAL_ATTN,)  # repeated to num_layers
    window_size: int = 0            # for LOCAL_ATTN blocks
    attn_softcap: float = 0.0       # gemma2 logit soft-capping (0 = off)
    final_softcap: float = 0.0      # gemma2 final-logit soft-capping
    qk_norm: bool = False           # qwen3 per-head RMS q/k norm
    qkv_bias: bool = False          # qwen1.5-style bias on qkv projections
    attn_scale: float = 0.0         # 0 => 1/sqrt(head_dim); gemma2 overrides
    rope_theta: float = 10_000.0
    use_rope: bool = True           # whisper backbone: sinusoidal abs. pos.
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl M-RoPE (t, h, w) splits
    mla: Optional[MLAConfig] = None

    # --- mlp ----------------------------------------------------------------
    mlp_act: str = "silu"           # "silu" -> SwiGLU, "gelu" -> GeGLU
    moe: Optional[MoEConfig] = None

    # --- ssm / rglru --------------------------------------------------------
    ssm: Optional[SSMConfig] = None
    lru_width: int = 0              # recurrentgemma RG-LRU width
    conv1d_width: int = 4           # recurrentgemma temporal conv

    # --- embeddings / head --------------------------------------------------
    tie_embeddings: bool = True
    scale_embeddings: bool = False  # gemma multiplies embeddings by sqrt(d)
    norm_eps: float = 1e-6
    use_post_norms: bool = False    # gemma2 post-attn/post-ffn norms

    # --- encoder-decoder (whisper) ------------------------------------------
    encoder_layers: int = 0         # >0 => encoder-decoder
    num_audio_frames: int = 1500    # encoder context length (stub frontend)
    frontend_stub: bool = False     # vlm/audio: inputs are embeddings

    # --- numerics / execution ----------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    serve_keep_fsdp: bool = False   # llama4-400B: weights stay data-sharded
    grad_accum: int = 1             # microbatch count per train step
    attn_impl: str = "chunked"      # chunked | pallas | naive
    attn_chunk: int = 512           # KV block for chunked/flash attention
    remat: str = "full"             # full | dots | none
    scan_layers: bool = True
    optimizer: str = "adamw"        # adamw | adafactor
    learning_rate: float = 3e-4

    # ------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return all(k in (SSD, RGLRU) for k in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if no block attends globally over unbounded context."""
        return GLOBAL_ATTN not in self.block_pattern

    def pattern_for_layers(self) -> Tuple[str, ...]:
        reps = -(-self.num_layers // len(self.block_pattern))
        return (self.block_pattern * reps)[: self.num_layers]

    def moe_layer_mask(self) -> Tuple[bool, ...]:
        if self.moe is None:
            return tuple(False for _ in range(self.num_layers))
        out = []
        for i in range(self.num_layers):
            if i < self.moe.first_dense_layers:
                out.append(False)
            else:
                # MoE on the last layer of each period group (llama4 style).
                out.append((i % self.moe.layer_period) == self.moe.layer_period - 1)
        return tuple(out)


# ---------------------------------------------------------------------------
# Input shapes (assigned): every LM arch pairs with all four; skip rules are
# encoded in `cell_supported` below and documented in DESIGN.md.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# Archs allowed to run the 500k-decode cell (sub-quadratic / windowed).
LONG_CONTEXT_ARCHS = ("mamba2-370m", "recurrentgemma-2b", "gemma2-27b")


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(supported, reason-if-not) for an (arch, shape) cell."""
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_ARCHS:
        return False, "pure full-attention arch: 500k decode skipped (DESIGN.md)"
    return True, ""


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: shared + top-k routed only)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    moe_layers = sum(cfg.moe_layer_mask())
    expert_p = 3 * cfg.d_model * m.expert_d_ff
    inactive = moe_layers * (m.num_experts - m.top_k) * expert_p
    return int(total - inactive)


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (matches models.registry init exactly)."""
    d = cfg.d_model
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    total = emb
    pattern = cfg.pattern_for_layers()
    moe_mask = cfg.moe_layer_mask()
    for i, kind in enumerate(pattern):
        total += d if kind == SSD else 2 * d  # pre-norms (SSD has no MLP)
        if cfg.use_post_norms:
            total += 2 * d
        if kind in (GLOBAL_ATTN, LOCAL_ATTN):
            if cfg.mla is not None:
                m = cfg.mla
                qd = cfg.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                total += d * qd if m.q_lora_rank == 0 else d * m.q_lora_rank + m.q_lora_rank + m.q_lora_rank * qd
                total += d * (m.kv_lora_rank + m.qk_rope_head_dim) + m.kv_lora_rank
                total += m.kv_lora_rank * cfg.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                total += cfg.num_heads * m.v_head_dim * d
            else:
                total += d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
                if cfg.qkv_bias:
                    total += cfg.q_dim + 2 * cfg.kv_dim
                if cfg.qk_norm:
                    total += 2 * cfg.head_dim
        elif kind == RGLRU:
            w = cfg.lru_width or d
            total += 2 * d * w + w * d          # x/gate in, out proj
            total += cfg.conv1d_width * w + w   # temporal conv
            total += 5 * w                      # lambda_ + a/i gate w,b diag params
        elif kind == SSD:
            s = cfg.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            conv_ch = d_in + 2 * s.n_groups * s.state_dim
            total += d * (2 * d_in + 2 * s.n_groups * s.state_dim + nheads)  # in_proj
            total += s.conv_dim * conv_ch + conv_ch                          # conv1d
            total += nheads * 2 + nheads                                     # A_log, D, dt_bias
            total += d_in                                                    # norm
            total += d_in * d                                                # out_proj
        # mlp / moe
        if kind in (GLOBAL_ATTN, LOCAL_ATTN, RGLRU):
            if cfg.moe is not None and moe_mask[i]:
                m = cfg.moe
                total += d * m.num_experts                                   # router
                total += m.num_experts * 3 * d * m.expert_d_ff
                total += m.num_shared_experts * 3 * d * m.expert_d_ff
            else:
                total += 3 * d * cfg.d_ff
    total += d  # final norm
    if cfg.is_encoder_decoder:
        # encoder self-attn + mlp + norms, decoder adds cross-attention
        enc = cfg.encoder_layers * (
            4 * d * cfg.q_dim + 3 * d * cfg.d_ff + 2 * d
        ) + d
        cross = cfg.num_layers * (4 * d * cfg.q_dim + d)
        total += enc + cross
    return int(total)
