"""Gemma2-27B. [arXiv:2408.00118]

46L, d_model 4608, 32 heads GQA kv=16, GeGLU d_ff 36864, vocab 256000.
Alternating local(4096)/global attention, attn logit softcap 50, final
softcap 30, pre+post norms, embeddings scaled by sqrt(d_model), tied.
"""
from repro.configs.base import ModelConfig, LOCAL_ATTN, GLOBAL_ATTN

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256_000,
    block_pattern=(LOCAL_ATTN, GLOBAL_ATTN),
    window_size=4096,
    attn_scale=144.0 ** -0.5,  # query_pre_attn_scalar = d_model/num_heads
    attn_softcap=50.0,
    final_softcap=30.0,
    mlp_act="gelu",
    scale_embeddings=True,
    use_post_norms=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
    optimizer="adafactor",
)
