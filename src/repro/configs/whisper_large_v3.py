"""Whisper-large-v3 backbone. [arXiv:2212.04356]

Encoder-decoder: 32L encoder + 32L decoder, d_model 1280, 20 heads
(kv=20 => MHA), d_ff 5120, vocab 51866.  The conv/mel frontend is a STUB:
input_specs() provides 1500 precomputed frame embeddings.  Deviations
(DESIGN.md §5): unified gated-GeGLU MLP stack and RMSNorm instead of
vanilla GELU-MLP/LayerNorm; sinusoidal positions (parameter-free).
Shape interpretation: seq_len = decoder length with a fixed 1500-frame
encoder context.
"""
from repro.configs.base import ModelConfig, GLOBAL_ATTN

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,          # decoder layers
    encoder_layers=32,
    num_audio_frames=1500,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    block_pattern=(GLOBAL_ATTN,),
    mlp_act="gelu",
    tie_embeddings=True,
    frontend_stub=True,
    use_rope=False,
    rope_theta=10_000.0,
)
