"""Mamba2-370M — SSD (state-space duality). [arXiv:2405.21060]

48L, d_model 1024, attention-free, ssm_state 128, expand 2, head_dim 64,
vocab 50280.  Decode state is O(1) in context length -> runs long_500k.
"""
from repro.configs.base import ModelConfig, SSMConfig, SSD

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=1,           # unused (attention-free)
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,                # SSD blocks have no separate MLP
    vocab_size=50280,
    block_pattern=(SSD,),
    ssm=SSMConfig(state_dim=128, conv_dim=4, expand=2, head_dim=64,
                  n_groups=1, chunk_size=256),
    tie_embeddings=True,
    norm_eps=1e-5,
)
