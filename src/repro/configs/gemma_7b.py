"""Gemma-7B. [arXiv:2403.08295]

28L, d_model 3072, 16 heads (kv=16 => MHA), head_dim 256, GeGLU d_ff 24576,
vocab 256000, embeddings scaled by sqrt(d_model), tied.
"""
from repro.configs.base import ModelConfig, GLOBAL_ATTN

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256_000,
    block_pattern=(GLOBAL_ATTN,),
    mlp_act="gelu",
    scale_embeddings=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
)
