"""Sweep plans: a base ``ScenarioSpec`` × knob axes × seeds (DESIGN.md
§13.2).

A ``SweepSpec`` is the declarative unit the device datapath consumes: the
cartesian expansion ``replicas()`` yields one concrete ``ScenarioSpec``
per (axis-value combination, seed) — thousands of replica lanes that
``repro.sim.devicepath.run_sweep_specs`` runs in a single ``jit`` launch.

Knob paths are dotted field references into the frozen spec tree:

* top-level fields            — ``"fifo_capacity"``, ``"scheduler"``
* one tenant's subtree        — ``"tenants.0.priority"``,
  ``"tenants.1.workload.compute_per_byte"``
* every tenant at once        — ``"tenants.*.kernel_cycle_limit"``

Values are applied with ``dataclasses.replace`` down the path, so a typo
raises immediately (frozen dataclasses reject unknown fields) instead of
silently sweeping nothing.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterator, List, Tuple

from repro.api.spec import ScenarioSpec


def apply_knob(spec, path: str, value):
    """Return a copy of ``spec`` with the dotted ``path`` set to
    ``value`` (``tenants.*`` fans out over every tenant)."""
    return _set_path(spec, path.split("."), value)


def _set_path(obj, parts: List[str], value):
    field = parts[0]
    if field == "tenants":
        sel, rest = parts[1], parts[2:]
        tenants = list(obj.tenants)
        idxs = range(len(tenants)) if sel == "*" else [int(sel)]
        for i in idxs:
            tenants[i] = _set_path(tenants[i], rest, value)
        return dataclasses.replace(obj, tenants=tuple(tenants))
    if len(parts) == 1:
        if not any(f.name == field
                   for f in dataclasses.fields(obj)):  # pragma: no cover
            raise KeyError(f"{type(obj).__name__} has no knob {field!r}")
        return dataclasses.replace(obj, **{field: value})
    return dataclasses.replace(
        obj, **{field: _set_path(getattr(obj, field), parts[1:], value)})


@dataclasses.dataclass(frozen=True)
class SweepAxis:
    """One swept knob: a dotted path and the values it takes."""
    knob: str
    values: Tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "values", tuple(self.values))


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Base scenario × knob axes × seeds; ``replicas()`` is the full
    cartesian expansion (axes are the outer loops, seeds the inner)."""
    name: str
    base: ScenarioSpec
    axes: Tuple[SweepAxis, ...] = ()
    seeds: Tuple[int, ...] = (0,)

    def __post_init__(self):
        object.__setattr__(self, "axes", tuple(self.axes))
        object.__setattr__(self, "seeds", tuple(self.seeds))

    def __len__(self) -> int:
        n = len(self.seeds)
        for ax in self.axes:
            n *= len(ax.values)
        return n

    def replicas(self) -> Iterator[Tuple[Dict, ScenarioSpec]]:
        """Yield ``(knobs, spec)`` per replica; ``knobs`` holds each
        swept value plus the seed (the sweep report row key)."""
        grids = [ax.values for ax in self.axes]
        for combo in itertools.product(*grids):
            spec = self.base
            knobs: Dict = {}
            for ax, v in zip(self.axes, combo):
                spec = apply_knob(spec, ax.knob, v)
                knobs[ax.knob] = v
            for seed in self.seeds:
                yield ({**knobs, "seed": seed},
                       dataclasses.replace(spec, seed=seed))

    def specs(self) -> List[ScenarioSpec]:
        return [spec for _, spec in self.replicas()]

    # -- serde --------------------------------------------------------------
    def to_dict(self) -> Dict:
        return {
            "name": self.name,
            "base": self.base.to_dict(),
            "axes": [{"knob": ax.knob, "values": list(ax.values)}
                     for ax in self.axes],
            "seeds": list(self.seeds),
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "SweepSpec":
        return cls(
            name=d["name"],
            base=ScenarioSpec.from_dict(d["base"]),
            axes=tuple(SweepAxis(knob=a["knob"], values=tuple(a["values"]))
                       for a in d.get("axes", ())),
            seeds=tuple(d.get("seeds", (0,))),
        )
