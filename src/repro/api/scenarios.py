"""Built-in scenario catalog: every scenario formerly hand-coded in
``sim/scenarios.py`` plus the serving-native scenarios formerly inlined
in ``launch/serve.py``, ``benchmarks/serving_fairness.py`` and the
examples — all as registered declarative ``ScenarioSpec`` factories.

Run any of them by name:

    PYTHONPATH=src python -m repro.launch.scenario fig9_congestor_victim \
        --backend sim --json /tmp/report.json

The factories take keyword parameters for the knobs the old functions
exposed (scheduler, durations, sizes, seeds), so the legacy functions in
``sim/scenarios.py`` are now thin shims over this catalog.
"""
from __future__ import annotations

from repro.api.registry import register_scenario
from repro.api.spec import (ArrivalSpec, ControllerSpec, ScenarioSpec,
                            ServeSpec, TenantSpec, WorkloadSpec)


def _spin(name: str, cpb: float, base: float = 40.0) -> WorkloadSpec:
    return WorkloadSpec(name=name, compute_base=base, compute_per_byte=cpb)


# ---------------------------------------------------------------------------
# paper scenarios (cycle simulator; two also project onto serving)
# ---------------------------------------------------------------------------
@register_scenario("fig9_congestor_victim")
def fig9_congestor_victim(scheduler: str = "wlbvt", *,
                          cpb_victim: float = 0.6, cpb_ratio: float = 2.0,
                          duration_us: float = 300.0, pkt_size: int = 512,
                          seed: int = 0) -> ScenarioSpec:
    """Paper Figs. 4 & 9: two compute-bound spin tenants, the congestor
    ``cpb_ratio``x the compute cost per byte.  Serving projection: the
    congestor's requests carry 4x the work (long prompts + outputs)."""
    return ScenarioSpec(
        name="fig9_congestor_victim",
        description="PU fairness: 2x-costlier congestor vs victim "
                    "(paper Figs. 4/9)",
        backends=("sim", "serve"),
        tenants=(
            TenantSpec("congestor",
                       workload=_spin("congestor", cpb_victim * cpb_ratio),
                       arrival=ArrivalSpec(size=pkt_size, share=0.5,
                                           requests=24, prompt_len=160,
                                           max_new_tokens=48)),
            TenantSpec("victim", workload=_spin("victim", cpb_victim),
                       arrival=ArrivalSpec(size=pkt_size, share=0.5,
                                           requests=24, prompt_len=16,
                                           max_new_tokens=16)),
        ),
        scheduler=scheduler, duration_us=duration_us, seed=seed,
        record_timeline=True,
        serve=ServeSpec(max_slots=8, max_len=256, prefill_chunk=32,
                        kv_overcommit=2.0))


@register_scenario("fig10_hol_blocking")
def fig10_hol_blocking(*, frag_mode: str = "hardware", frag_bytes: int = 512,
                       congestor_size: int = 4096, victim_size: int = 64,
                       duration_us: float = 150.0, scheduler: str = "wlbvt",
                       arb: str = "dwrr", seed: int = 0) -> ScenarioSpec:
    """Paper Figs. 5 & 10: small request packets trigger large blocking
    egress transfers; fragmentation bounds the victim's HoL wait."""
    return ScenarioSpec(
        name="fig10_hol_blocking",
        description="HoL blocking: 64B victim vs 4KiB egress congestor "
                    "(paper Figs. 5/10)",
        tenants=(
            TenantSpec("congestor_io",
                       workload=WorkloadSpec(name="congestor_io",
                                             compute_base=40,
                                             compute_per_byte=0.02,
                                             io_kind="egress",
                                             io_fixed_bytes=congestor_size),
                       arrival=ArrivalSpec(size=512, share=0.50)),
            TenantSpec("victim_io",
                       workload=WorkloadSpec(name="victim_io",
                                             compute_base=40,
                                             compute_per_byte=0.02,
                                             io_kind="egress",
                                             io_fixed_bytes=victim_size),
                       arrival=ArrivalSpec(size=64, share=0.02,
                                           seed_offset=1)),
        ),
        scheduler=scheduler, arbiter=arb, frag_mode=frag_mode,
        frag_bytes=frag_bytes, duration_us=duration_us, seed=seed)


@register_scenario("fig11_standalone")
def fig11_standalone(*, workload: str = "aggregate", pkt_size: int = 1024,
                     duration_us: float = 100.0, osmosis: bool = True,
                     seed: int = 0) -> ScenarioSpec:
    """Paper Fig. 11: single tenant, OSMOSIS (WLBVT + hw frag + DWRR) vs
    the reference PsPIN (RR, FIFO bus, no fragmentation)."""
    return ScenarioSpec(
        name="fig11_standalone",
        description="single-tenant overhead: OSMOSIS vs reference PsPIN "
                    "(paper Fig. 11)",
        tenants=(TenantSpec(workload,
                            workload=WorkloadSpec(ref=workload),
                            arrival=ArrivalSpec(size=pkt_size, share=1.0)),),
        scheduler="wlbvt" if osmosis else "rr",
        arbiter="dwrr" if osmosis else "fifo",
        frag_mode="hardware" if osmosis else "off", frag_bytes=512,
        duration_us=duration_us, seed=seed)


@register_scenario("fig12_compute_mixture")
def fig12_compute_mixture(scheduler: str = "wlbvt", *,
                          duration_us: float = 200.0,
                          seed: int = 0) -> ScenarioSpec:
    """Paper Fig. 12: Reduce + Histogram, each as victim (small packets)
    and congestor (multi-KiB packets), in the burst-saturation regime."""
    names = ("reduce_victim", "reduce_congestor", "hist_victim",
             "hist_congestor")
    refs = ("reduce", "reduce", "histogram", "histogram")
    sizes = (64, 4096, 96, 3584)
    shares = (0.30, 0.35, 0.30, 0.35)
    return ScenarioSpec(
        name="fig12_compute_mixture",
        description="compute-bound mixture: Reduce+Histogram x "
                    "victim/congestor (paper Fig. 12)",
        tenants=tuple(
            TenantSpec(names[i], workload=WorkloadSpec(ref=refs[i]),
                       arrival=ArrivalSpec(size=sizes[i], share=shares[i],
                                           seed_offset=i))
            for i in range(4)),
        scheduler=scheduler, frag_mode="hardware", frag_bytes=512,
        fifo_capacity=1 << 17, record_timeline=True,
        duration_us=duration_us, seed=seed)


@register_scenario("fig13_io_mixture")
def fig13_io_mixture(scheduler: str = "wlbvt", *, frag_mode: str = "",
                     frag_bytes: int = 1024, duration_us: float = 200.0,
                     seed: int = 0) -> ScenarioSpec:
    """Paper Figs. 13/14: storage data-path offload mixture — 64B DMA
    victims vs storage-RPC congestors (512B request -> 4KiB DMA), with
    combined AXI demand ~1.1x the bus.  ``frag_mode=""`` auto-selects
    the policy the compared system would use (OSMOSIS: hardware/1024B;
    reference: off)."""
    osmosis = scheduler == "wlbvt"
    if not frag_mode:
        frag_mode = "hardware" if osmosis else "off"
    names = ("read_victim", "read_congestor", "write_victim",
             "write_congestor")
    kinds = ("dma_read", "dma_read", "dma_write", "dma_write")
    io_bytes = (64, 4096, 64, 4096)
    sizes = (64, 512, 64, 512)
    durs = (0.6, 1.0, 0.6, 1.0)
    return ScenarioSpec(
        name="fig13_io_mixture",
        description="IO-bound mixture: DMA read/write x victim/congestor "
                    "(paper Figs. 13/14)",
        tenants=tuple(
            TenantSpec(names[i],
                       workload=WorkloadSpec(name=names[i], compute_base=40,
                                             compute_per_byte=0.02,
                                             io_kind=kinds[i],
                                             io_fixed_bytes=io_bytes[i]),
                       arrival=ArrivalSpec(size=sizes[i], share=0.10,
                                           duration_frac=durs[i],
                                           seed_offset=i))
            for i in range(4)),
        scheduler=scheduler, arbiter="dwrr" if osmosis else "fifo",
        frag_mode=frag_mode, frag_bytes=frag_bytes,
        io_demand_weights="demand", fifo_capacity=1 << 15,
        record_timeline=True, duration_us=duration_us, seed=seed)


@register_scenario("qos_closed_loop")
def qos_closed_loop(controller: bool = True, *,
                    p99_target_ns: float = 2000.0,
                    duration_us: float = 300.0,
                    control_interval_ns: float = 8000.0,
                    seed: int = 0) -> ScenarioSpec:
    """Closed-loop QoS (DESIGN.md §6): a latency-SLO victim whose demand
    slightly exceeds its static share, vs a heavy congestor.  The AIMD
    controller boosts the victim's weights until its p99 meets target.
    Serving projection: same shape in engine steps (target scaled)."""
    return ScenarioSpec(
        name="qos_closed_loop",
        description="closed-loop QoS: AIMD weight control holds the "
                    "victim's p99 (DESIGN.md §6)",
        backends=("sim", "serve"),
        tenants=(
            TenantSpec("congestor", workload=_spin("congestor", 2.0),
                       arrival=ArrivalSpec(size=1024, share=0.25,
                                           requests=12, prompt_len=160,
                                           max_new_tokens=48)),
            TenantSpec("victim", workload=_spin("victim", 2.0),
                       arrival=ArrivalSpec(size=256, share=0.175,
                                           seed_offset=1, requests=48,
                                           prompt_len=16, max_new_tokens=8),
                       p99_target=p99_target_ns),
        ),
        controller=(ControllerSpec(interval_ns=control_interval_ns,
                                   interval_steps=16,
                                   target_scale_serve=40.0 / 2000.0)
                    if controller else None),
        duration_us=duration_us, seed=seed,
        serve=ServeSpec(max_slots=8, max_len=512, prefill_chunk=32,
                        kv_overcommit=2.0))


@register_scenario("fleet_sweep")
def fleet_sweep(*, tenants: int = 128, duration_us: float = 10240.0,
                pkt_size: int = 512, fifo_capacity: int = 256,
                congestor_every: int = 4, watchdog_cycles: int = 20000,
                seed: int = 0) -> ScenarioSpec:
    """Tenant-*count* scale sweep on ONE simulated NIC (DESIGN.md §8):
    ``tenants`` flows share a single fully-utilized 400G link against
    32 PUs — a deliberately overloaded consolidation point
    (SuperNIC/Meili-style) where drops, ECN marks and watchdog kills
    all fire at volume.  Despite the name this is NOT the multi-NIC
    fabric family: no switch is modeled and nothing crosses a
    crossbar.  For N NICs exchanging traffic through the modeled
    VOQ/crossbar switch — placement, live migration, global QoS — see
    the ``fleet_fabric`` / ``fleet_incast`` / ``fleet_migrate``
    scenarios (repro.fleet.scenarios, DESIGN.md §12).

    Four service classes cycle across the fleet: light RPC handlers,
    histogram analytics, heavy ML preprocessing, and watchdog-bounded
    batch congestors (every ``congestor_every``-th tenant).  At the
    128-tenant default the trace is ~10^6 packets — built as
    ``TraceArrays`` and meant for the batched datapath (the event loop
    makes identical decisions, ~10x slower).  ``horizon_us`` pins the
    measurement window, fig9-style, instead of draining the backlog.
    """
    classes = (
        ("rpc", _spin("rpc", 3.0)),
        ("analytics", _spin("analytics", 5.0)),
        ("mlprep", _spin("mlprep", 9.0)),
        ("batch", WorkloadSpec(name="batch", compute_base=40.0,
                               compute_per_byte=4.0, spin_factor=4.0)),
    )
    rows = []
    for i in range(tenants):
        if congestor_every and i % congestor_every == congestor_every - 1:
            cname, wl = classes[3]
            limit = watchdog_cycles
        else:
            cname, wl = classes[i % 3]
            limit = 0
        rows.append(TenantSpec(
            f"{cname}{i}", workload=wl,
            kernel_cycle_limit=limit,
            arrival=ArrivalSpec(size=pkt_size, share=1.0 / tenants,
                                seed_offset=i)))
    return ScenarioSpec(
        name="fleet_sweep",
        description=f"{tenants}-tenant fleet flood on 32 PUs: mixed "
                    "service classes, watchdogged congestors, batched "
                    "datapath (DESIGN.md §8)",
        tenants=tuple(rows),
        duration_us=duration_us, horizon_us=duration_us,
        fifo_capacity=fifo_capacity, datapath="batched", seed=seed)


@register_scenario("ppb_service_time")
def ppb_service_time() -> ScenarioSpec:
    """Paper Fig. 3: per-workload single-packet service time vs the
    per-packet budget — analytic (no event loop)."""
    return ScenarioSpec(
        name="ppb_service_time",
        description="service time vs per-packet budget, all workloads "
                    "(paper Fig. 3; analytic)",
        analytic="ppb")


# ---------------------------------------------------------------------------
# serving-native scenarios
# ---------------------------------------------------------------------------
@register_scenario("serve_mixed_slo")
def serve_mixed_slo(*, tenants: int = 3, requests: int = 12,
                    max_slots: int = 8, max_len: int = 256,
                    prefill_chunk: int = 32, scheduler: str = "wlbvt",
                    arbiter: str = "dwrr", vocab: int = 90,
                    seed: int = 0) -> ScenarioSpec:
    """The ``launch/serve.py`` driver workload: tenant 0 at 2x priority,
    tenant 1 the long-prompt congestor, the rest interactive victims."""
    quota = max_len * max(2, max_slots // tenants)
    n = [len(range(t, requests, tenants)) for t in range(tenants)]
    return ScenarioSpec(
        name="serve_mixed_slo",
        description="serving driver workload: priority tenant + congestor "
                    "+ interactive victims",
        backends=("serve",),
        tenants=tuple(
            TenantSpec(f"tenant{t}",
                       priority=2.0 if t == 0 else 1.0,
                       kv_quota_tokens=quota,
                       arrival=ArrivalSpec(
                           requests=n[t],
                           prompt_len=max_len // 2 if t == 1 else 8,
                           max_new_tokens=32 if t == 1 else 8))
            for t in range(tenants)),
        scheduler=scheduler, arbiter=arbiter, seed=seed,
        serve=ServeSpec(max_slots=max_slots, max_len=max_len,
                        prefill_chunk=prefill_chunk, vocab=vocab))


@register_scenario("serve_congestor_victim")
def serve_congestor_victim(*, scheduler: str = "wlbvt",
                           arbiter: str = "dwrr", rounds: int = 30,
                           seed: int = 0) -> ScenarioSpec:
    """The adapted fairness benchmark: two 4x-work congestor tenants vs
    two interactive victims on a 16-slot engine."""
    return ScenarioSpec(
        name="serve_congestor_victim",
        description="serving fairness benchmark: 2 congestors vs 2 "
                    "victims, WLBVT+DWRR vs RR+FIFO",
        backends=("serve",),
        tenants=tuple(
            TenantSpec(name, kv_quota_tokens=256 * 8,
                       arrival=ArrivalSpec(
                           requests=rounds,
                           prompt_len=256 if i < 2 else 16,
                           max_new_tokens=64 if i < 2 else 16))
            for i, name in enumerate(("congestor0", "congestor1",
                                      "victim0", "victim1"))),
        scheduler=scheduler, arbiter=arbiter, seed=seed,
        serve=ServeSpec(max_slots=16, max_len=512, prefill_chunk=64,
                        prefill_slots_per_step=4))


@register_scenario("serve_three_class")
def serve_three_class(*, scheduler: str = "wlbvt", arbiter: str = "dwrr",
                      requests: int = 6, vocab: int = 90,
                      seed: int = 0) -> ScenarioSpec:
    """The multi-tenant serving example: batch congestor (watchdogged),
    interactive victim, and a 2x-priority premium tenant."""
    return ScenarioSpec(
        name="serve_three_class",
        description="three service classes on one engine: batch / "
                    "interactive / premium(2x)",
        backends=("serve",),
        tenants=(
            TenantSpec("batch", kv_quota_tokens=256 * 2,
                       kernel_cycle_limit=240,
                       arrival=ArrivalSpec(requests=requests, prompt_len=160,
                                           max_new_tokens=48)),
            TenantSpec("interactive", kv_quota_tokens=256 * 2,
                       arrival=ArrivalSpec(requests=requests, prompt_len=12,
                                           max_new_tokens=12)),
            TenantSpec("premium", priority=2.0, kv_quota_tokens=256 * 2,
                       arrival=ArrivalSpec(requests=requests, prompt_len=12,
                                           max_new_tokens=12)),
        ),
        scheduler=scheduler, arbiter=arbiter, seed=seed,
        serve=ServeSpec(max_slots=6, max_len=256, prefill_chunk=32,
                        vocab=vocab))


# ---------------------------------------------------------------------------
# fleet-plane scenarios (multi-NIC fabric): registered on import; the
# registry loads only this module, so the fleet catalog hooks in here
# ---------------------------------------------------------------------------
from repro.fleet import scenarios as _fleet_scenarios  # noqa: E402,F401
