"""Portable run reports — the one result schema both backends emit.

``RunReport`` replaces the old ``SimResult``-vs-``Engine.metrics()``
divergence at the API boundary: every field is a plain JSON type, the
per-tenant block has the same keys on both backends (backend-specific
detail goes under ``TenantReport.extra``), and ``from_json(to_json(r))
== r`` holds exactly — reports can be archived, diffed, and compared
across backends and commits.

Units differ by backend and are declared, not implied: ``time_unit`` is
``"ns"`` on the simulator and ``"steps"`` on the serving engine;
``throughput`` is Gbit/s of served payload on the simulator and
tokens/step on the engine.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

SCHEMA_VERSION = 1

# the only report time domains: virtual ns (sim), engine steps (serve).
# Single source of truth — runtime.py declares its per-backend unit from
# this tuple and the static unit checker (repro.analysis, time-unit-flow)
# validates every `time_unit` literal against it.
TIME_UNITS = ("ns", "steps")

# keys every per-tenant block must carry, on either backend
TENANT_FIELDS = ("tenant_id", "name", "arrivals", "completed", "killed",
                 "drops", "rejected", "ecn_marks", "bytes_in", "bytes_out",
                 "throughput", "p50_latency", "p99_latency",
                 "latency_samples", "extra")


def _jsonify(obj: Any) -> Any:
    """Coerce to the exact value a JSON round-trip would produce: numpy
    scalars/arrays -> python numbers/lists, dict keys -> str."""
    if isinstance(obj, dict):
        return {str(k): _jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonify(v) for v in obj]
    if isinstance(obj, bool):
        return obj
    if hasattr(obj, "item"):          # numpy scalar
        return obj.item()
    if hasattr(obj, "tolist"):        # numpy array
        return _jsonify(obj.tolist())
    return obj


@dataclasses.dataclass
class TenantReport:
    tenant_id: int
    name: str
    arrivals: int = 0
    completed: int = 0
    killed: int = 0
    drops: int = 0
    rejected: int = 0
    ecn_marks: int = 0
    bytes_in: float = 0.0
    bytes_out: float = 0.0
    throughput: float = 0.0          # sim: Gbit/s; serve: tokens/step
    p50_latency: float = 0.0         # sojourn, in ``time_unit``
    p99_latency: float = 0.0
    latency_samples: int = 0
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class RunReport:
    scenario: str
    backend: str                     # "sim" | "serve"
    time_unit: str                   # "ns" | "steps"
    duration: float                  # virtual ns (sim) / steps (serve)
    scheduler: str
    arbiter: str
    seed: int
    jain_pu: float                   # PU/slot fairness (time-averaged)
    jain_io: float                   # IO fairness (sim; 1.0 on serve)
    tenants: Dict[int, TenantReport] = dataclasses.field(default_factory=dict)
    events: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    telemetry: Optional[Dict[str, Any]] = None
    spec: Optional[Dict[str, Any]] = None
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)
    schema_version: int = SCHEMA_VERSION

    # -- serde --------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return _jsonify(dataclasses.asdict(self))

    def to_json(self, indent: Optional[int] = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RunReport":
        d = dict(d)
        d["tenants"] = {int(t): TenantReport(**r)
                        for t, r in d.get("tenants", {}).items()}
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "RunReport":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    # -- schema check -------------------------------------------------------
    def validate(self) -> "RunReport":
        """Raise ``ValueError`` on any schema violation; returns self so
        callers can chain ``report.validate().save(path)``."""
        if self.schema_version != SCHEMA_VERSION:
            raise ValueError(f"schema_version {self.schema_version} != "
                             f"{SCHEMA_VERSION}")
        if self.backend not in ("sim", "serve"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.time_unit not in TIME_UNITS:
            raise ValueError(f"unknown time_unit {self.time_unit!r}")
        for field in ("duration", "jain_pu", "jain_io"):
            v = getattr(self, field)
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                raise ValueError(f"{field} must be a number, got {v!r}")
        if not (0.0 <= self.jain_pu <= 1.0 + 1e-9):
            raise ValueError(f"jain_pu {self.jain_pu} outside [0, 1]")
        for t, r in self.tenants.items():
            if not isinstance(t, int):
                raise ValueError(f"tenant key {t!r} must be int")
            rd = dataclasses.asdict(r) if isinstance(r, TenantReport) else r
            missing = [k for k in TENANT_FIELDS if k not in rd]
            if missing:
                raise ValueError(f"tenant {t} missing fields {missing}")
            if rd["tenant_id"] != t:
                raise ValueError(f"tenant {t} key/id mismatch "
                                 f"{rd['tenant_id']}")
        for ev in self.events:
            for k in ("tenant", "kind", "time"):
                if k not in ev:
                    raise ValueError(f"event missing {k!r}: {ev}")
        self._validate_extras()
        # the whole report must survive a JSON round-trip unchanged
        if RunReport.from_json(self.to_json()) != self:
            raise ValueError("report does not round-trip through JSON")
        return self

    def _validate_extras(self) -> None:
        """Known ``extras`` blocks carry their declared schemas: the
        key tuples live next to the producers (single source of truth)
        so the check can never drift from what they emit."""
        sa = self.extras.get("slo_audit")
        if sa is not None:
            from repro.telemetry.slo_audit import (SUMMARY_KEYS,
                                                   TENANT_SUMMARY_KEYS)
            missing = [k for k in SUMMARY_KEYS if k not in sa]
            if missing:
                raise ValueError(f"slo_audit missing keys {missing}")
            if sa["interval_unit"] != self.time_unit:
                raise ValueError(
                    f"slo_audit interval_unit {sa['interval_unit']!r} != "
                    f"report time_unit {self.time_unit!r}")
            for t, row in sa["tenants"].items():
                tmiss = [k for k in TENANT_SUMMARY_KEYS if k not in row]
                if tmiss:
                    raise ValueError(
                        f"slo_audit tenant {t} missing keys {tmiss}")
        ts = self.extras.get("trace_summary")
        if ts is not None:
            from repro.telemetry.trace import TraceRecorder
            missing = [k for k in TraceRecorder.TRACE_SUMMARY_KEYS
                       if k not in ts]
            if missing:
                raise ValueError(f"trace_summary missing keys {missing}")
        fl = self.extras.get("fleet")
        if fl is not None:
            from repro.fleet.engine import FLEET_EXTRAS_KEYS
            missing = [k for k in FLEET_EXTRAS_KEYS if k not in fl]
            if missing:
                raise ValueError(f"fleet extras missing keys {missing}")
            if len(fl["per_nic"]) != fl["num_nics"]:
                raise ValueError(
                    f"fleet per_nic has {len(fl['per_nic'])} reports "
                    f"for {fl['num_nics']} NICs")

    # -- console ------------------------------------------------------------
    def summary(self) -> str:
        unit = self.time_unit
        tput_unit = "Gbit/s" if self.backend == "sim" else "tok/step"
        lines = [f"scenario={self.scenario} backend={self.backend} "
                 f"policy={self.scheduler}+{self.arbiter} "
                 f"duration={self.duration:g}{unit} "
                 f"jain_pu={self.jain_pu:.3f} jain_io={self.jain_io:.3f}",
                 f" {'tenant':<18}{'done':>6}{'kill':>6}{'drop':>6}"
                 f"{'p50':>10}{'p99':>10}  tput({tput_unit})"]
        for t in sorted(self.tenants):
            r = self.tenants[t]
            lines.append(
                f" {r.name[:17]:<18}{r.completed:>6}{r.killed:>6}"
                f"{r.drops:>6}{r.p50_latency:>10.4g}{r.p99_latency:>10.4g}"
                f"  {r.throughput:.4g}")
        return "\n".join(lines)
