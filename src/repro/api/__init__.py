"""Unified OSMOSIS runtime API (DESIGN.md §7): one control-plane surface
over both execution substrates.

``Runtime``       — the protocol (tenant lifecycle, workload injection,
                    clock, telemetry/controller attach, reports);
``SimRuntime``    — adapter over the cycle-level PsPIN simulator;
``ServeRuntime``  — adapter over the TPU serving engine;
``ScenarioSpec``  — declarative scenarios (+ named registry);
``RunReport``     — the portable, JSON round-trippable result schema
                    both backends emit.
"""
from repro.api.registry import (get_scenario, list_scenarios,
                                register_scenario)
from repro.api.report import (SCHEMA_VERSION, TENANT_FIELDS, RunReport,
                              TenantReport)
from repro.api.runtime import (Runtime, ServeRuntime, SimRuntime,
                               build_requests, build_traces, make_runtime,
                               run_scenario)
from repro.api.spec import (ArrivalSpec, ControllerSpec, ScenarioSpec,
                            ServeSpec, TenantSpec, WorkloadSpec)
from repro.api.sweep import SweepAxis, SweepSpec, apply_knob

__all__ = [
    "Runtime", "SimRuntime", "ServeRuntime", "make_runtime", "run_scenario",
    "build_traces", "build_requests",
    "ScenarioSpec", "TenantSpec", "ArrivalSpec", "WorkloadSpec",
    "ControllerSpec", "ServeSpec",
    "SweepSpec", "SweepAxis", "apply_knob",
    "RunReport", "TenantReport", "SCHEMA_VERSION", "TENANT_FIELDS",
    "register_scenario", "get_scenario", "list_scenarios",
]
