"""Declarative scenario specifications — the tenant-facing half of the
unified OSMOSIS runtime API (DESIGN.md §7).

A ``ScenarioSpec`` is pure data: who the tenants are (SLO knobs, cost
model, arrival process), which mechanisms are enabled (scheduler,
arbiter, fragmentation, QoS controller), and how long to run.  The same
spec drives both execution surfaces through the ``Runtime`` adapters in
``api/runtime.py`` — the simulator materializes a packet trace from each
tenant's ``ArrivalSpec``, the serving engine materializes a request
stream from its serving projection fields.

Specs are frozen dataclasses of plain scalars/tuples, so they are
hashable, JSON round-trippable (``to_dict``/``from_dict``) and cheap to
derive variants from (``replace``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.fragmentation import FragmentationPolicy
from repro.core.slo import SLOPolicy


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """A kernel cost model: a named entry in ``sim.workloads.WORKLOADS``
    (``ref``) or inline ``WorkloadModel`` parameters.  Serving runs
    ignore the cost model (the model *is* the cost)."""
    ref: str = ""                    # WORKLOADS name; overrides the rest
    name: str = ""                   # label for an inline model
    compute_base: float = 50.0       # handler entry/exit cycles
    compute_per_byte: float = 0.0    # PU cycles per payload byte
    io_kind: str = "none"            # none | dma_read | dma_write | egress
    io_bytes_factor: float = 1.0
    io_fixed_bytes: int = 0
    spin_factor: float = 1.0         # synthetic congestor multiplier

    def build(self):
        """Materialize the simulator's ``WorkloadModel``."""
        from repro.sim.workloads import WORKLOADS, WorkloadModel
        if self.ref:
            return WORKLOADS[self.ref]
        return WorkloadModel(self.name or "custom", self.compute_base,
                             self.compute_per_byte, io_kind=self.io_kind,
                             io_bytes_factor=self.io_bytes_factor,
                             io_fixed_bytes=self.io_fixed_bytes,
                             spin_factor=self.spin_factor)


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """Per-tenant workload arrival process.

    Sim fields map onto ``sim.traffic.make_trace``; the serving
    projection fields describe the equivalent request stream (one
    request = one "packet", DESIGN.md §2).
    """
    size: int = 512                  # packet bytes incl. header (sim)
    share: float = 0.5               # fraction of the ingress link (sim)
    duration_frac: float = 1.0       # fraction of the scenario duration
    seed_offset: int = 0             # added to the scenario seed
    # serving projection:
    requests: int = 16               # total requests injected
    prompt_len: int = 16             # tokens per prompt
    max_new_tokens: int = 16         # generation budget per request


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant: identity + SLO knobs + cost model + arrival."""
    name: str
    workload: WorkloadSpec = WorkloadSpec()
    arrival: ArrivalSpec = ArrivalSpec()
    priority: float = 1.0
    dma_priority: float = 1.0
    egress_priority: float = 1.0
    kernel_cycle_limit: int = 0
    total_cycle_limit: int = 0
    kv_quota_tokens: int = 0         # 0 = engine default (one slot)
    p99_target: float = 0.0          # controller latency SLO, in the
    #                                  backend's time unit (0 = none)

    def slo(self) -> SLOPolicy:
        return SLOPolicy(priority=self.priority,
                         dma_priority=self.dma_priority,
                         egress_priority=self.egress_priority,
                         kernel_cycle_limit=self.kernel_cycle_limit,
                         total_cycle_limit=self.total_cycle_limit,
                         kv_quota_tokens=self.kv_quota_tokens)


@dataclasses.dataclass(frozen=True)
class ControllerSpec:
    """Closed-loop QoS controller configuration (DESIGN.md §6).

    Per-tenant p99 sojourn targets come from ``TenantSpec.p99_target``
    scaled by ``target_scale[backend]`` so one spec can carry targets
    for both time units (ns on the simulator, steps on the engine)."""
    interval_ns: float = 8000.0      # sim control interval (virtual ns)
    interval_steps: int = 16         # serving control interval (steps)
    target_scale_sim: float = 1.0
    target_scale_serve: float = 1.0

    def p99_targets(self, tenants: Tuple[TenantSpec, ...], backend: str,
                    num_tenants: int):
        scale = (self.target_scale_sim if backend == "sim"
                 else self.target_scale_serve)
        out = [0.0] * num_tenants
        for i, t in enumerate(tenants):
            out[i] = t.p99_target * scale
        return out


@dataclasses.dataclass(frozen=True)
class AuditSpec:
    """SLO burn-rate audit knobs (telemetry/slo_audit.py, DESIGN.md
    §11.3).  ``None`` on the scenario means *auto*: the audit attaches
    whenever a QoS controller with live p99 targets is configured, so
    every closed-loop run ships an ``extras['slo_audit']`` block."""
    enabled: bool = True
    objective: float = 0.9           # good-interval objective (budget =
    #                                  1 - objective)
    fast_windows: int = 2            # acute window, observation intervals
    slow_windows: int = 8            # sustained window
    fast_burn: float = 5.0           # alert thresholds (burn multiples)
    slow_burn: float = 2.0


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Serving-engine projection knobs (EngineConfig subset)."""
    max_slots: int = 8
    max_len: int = 256
    prefill_chunk: int = 32
    prefill_slots_per_step: int = 2
    kv_overcommit: float = 1.0
    steps: int = 0                   # 0 = run until idle
    vocab: int = 90                  # prompt token range for synthesis


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """A complete, declarative multi-tenant scenario."""
    name: str
    description: str = ""
    tenants: Tuple[TenantSpec, ...] = ()
    backends: Tuple[str, ...] = ("sim",)
    scheduler: str = "wlbvt"         # "wlbvt" | "rr"
    arbiter: str = "dwrr"            # "dwrr" | "fifo"
    frag_mode: str = "off"           # "off" | "software" | "hardware"
    frag_bytes: int = 512
    duration_us: float = 150.0       # sim horizon (drains remaining work)
    horizon_us: float = 0.0          # >0: stop the sim clock here instead
    #                                  of draining queued work (fixed
    #                                  measurement window, fig9-style)
    fifo_capacity: int = 4096
    io_demand_weights: str = "uniform"   # "uniform" | "demand"
    record_timeline: bool = False
    controller: Optional[ControllerSpec] = None
    audit: Optional[AuditSpec] = None    # None = auto (see AuditSpec)
    seed: int = 0
    serve: ServeSpec = ServeSpec()
    analytic: str = ""               # "" | "ppb": computed, not simulated
    datapath: str = "event"          # sim backend: "event" | "batched"
    #                                  (same decisions — DESIGN.md §8)

    def frag(self) -> FragmentationPolicy:
        if self.frag_mode == "off":
            return FragmentationPolicy(mode="off")
        return FragmentationPolicy(mode=self.frag_mode,
                                   fragment_bytes=self.frag_bytes)

    def replace(self, **kw) -> "ScenarioSpec":
        return dataclasses.replace(self, **kw)

    # -- serde --------------------------------------------------------------
    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "ScenarioSpec":
        d = dict(d)
        d["tenants"] = tuple(
            TenantSpec(**{**t,
                          "workload": WorkloadSpec(**t["workload"]),
                          "arrival": ArrivalSpec(**t["arrival"])})
            for t in d.get("tenants", ()))
        d["backends"] = tuple(d.get("backends", ("sim",)))
        if d.get("controller") is not None:
            d["controller"] = ControllerSpec(**d["controller"])
        if d.get("audit") is not None:
            d["audit"] = AuditSpec(**d["audit"])
        if "serve" in d:
            d["serve"] = ServeSpec(**d["serve"])
        return cls(**d)
