"""Named scenario registry.

Scenarios register a *factory* — a function of keyword parameters that
returns a ``ScenarioSpec`` — so one name covers a family of variants
(``get_scenario("fig9_congestor_victim", scheduler="rr")``) while the
no-argument call yields the canonical declarative spec.
"""
from __future__ import annotations

from typing import Callable, Dict, List

from repro.api.spec import ScenarioSpec

_REGISTRY: Dict[str, Callable[..., ScenarioSpec]] = {}


def register_scenario(name: str):
    """Decorator: register ``factory(**params) -> ScenarioSpec``."""
    def deco(factory: Callable[..., ScenarioSpec]):
        if name in _REGISTRY:
            raise ValueError(f"scenario {name!r} already registered")
        _REGISTRY[name] = factory
        return factory
    return deco


def scenario_params(name: str) -> set:
    """Names of the keyword parameters a scenario's factory accepts —
    drivers use this to forward only applicable knobs."""
    import inspect
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown scenario {name!r}")
    return set(inspect.signature(_REGISTRY[name]).parameters)


def get_scenario(name: str, **params) -> ScenarioSpec:
    _ensure_loaded()
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; registered: "
                       f"{', '.join(sorted(_REGISTRY))}") from None
    spec = factory(**params)
    if spec.name != name:
        spec = spec.replace(name=name)
    return spec


def list_scenarios() -> List[dict]:
    """[{name, description, backends, tenants, analytic}] for every
    registered scenario (built with default parameters)."""
    _ensure_loaded()
    out = []
    for name in sorted(_REGISTRY):
        spec = get_scenario(name)
        out.append({"name": name, "description": spec.description,
                    "backends": list(spec.backends),
                    "tenants": len(spec.tenants),
                    "analytic": spec.analytic})
    return out


def _ensure_loaded() -> None:
    """Import the built-in scenario catalog exactly once."""
    import repro.api.scenarios  # noqa: F401  (registers on import)
