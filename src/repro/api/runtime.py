"""The unified OSMOSIS runtime protocol + backend adapters (DESIGN.md §7).

One tenant-facing control-plane surface over both execution substrates:

  * ``SimRuntime``   — wraps the cycle-level PsPIN ``Simulator``; the
    clock is virtual nanoseconds, work items are ``TracePacket``s.
  * ``ServeRuntime`` — wraps the TPU serving ``Engine``; the clock is
    engine steps, work items are ``Request``s.

Both expose the same lifecycle: ``create_tenant``/``destroy_tenant``
(ECTX + SLOPolicy), ``inject`` (workload), ``attach_controller`` (QoS),
``run_until`` (clock), ``poll_events`` (EQ), and ``report()`` — a
schema-identical, JSON-portable ``RunReport``.  ``run(spec)`` drives a
whole declarative ``ScenarioSpec`` end to end.

The legacy surfaces stay available as deprecation shims: the simulator
still returns ``SimResult`` (``SimRuntime.result``) and the engine still
answers ``metrics()`` — new code should consume ``RunReport`` instead.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.api.report import RunReport, TenantReport, TIME_UNITS, _jsonify
from repro.api.spec import ScenarioSpec
from repro.core.events import Event
from repro.core.slo import ECTX, SLOPolicy

MAX_REPORT_EVENTS = 512   # EQ events embedded per report; rest summarized

# per-backend time domains, from the report schema's single whitelist
# (api/report.py TIME_UNITS) — never restate these as string literals
NS_UNIT, STEPS_UNIT = TIME_UNITS


@runtime_checkable
class Runtime(Protocol):
    """The one control-plane surface both backends implement."""

    backend: str                                   # "sim" | "serve"
    time_unit: str                                 # "ns" | "steps"

    def create_tenant(self, tenant_id: int, slo: SLOPolicy, *,
                      name: str = "", workload=None) -> ECTX: ...
    def destroy_tenant(self, tenant_id: int) -> List[Event]: ...
    def inject(self, work: Sequence) -> None: ...
    def attach_controller(self, controller) -> None: ...
    def run_until(self, t: Optional[float] = None) -> float: ...
    def now(self) -> float: ...
    def poll_events(self, tenant_id: int) -> List[Event]: ...
    def report(self, spec: Optional[ScenarioSpec] = None) -> RunReport: ...


def _build_audit(spec: ScenarioSpec, backend: str, num_tenants: int,
                 time_unit: str):
    """Materialize the spec's ``SLOAudit`` (or None).

    ``spec.audit is None`` means auto: attach exactly when a QoS
    controller with at least one live p99 target is configured — the
    audit then watches the same targets the controller acts on, so
    every closed-loop run gets alert -> intervention attribution for
    free.  An explicit ``AuditSpec`` works without a controller too
    (targets fall back to the raw ``TenantSpec.p99_target`` values)."""
    a = spec.audit
    if a is not None and not a.enabled:
        return None
    if spec.controller is not None:
        targets = spec.controller.p99_targets(spec.tenants, backend,
                                              num_tenants)
    else:
        targets = [0.0] * num_tenants
        for i, t in enumerate(spec.tenants):
            targets[i] = t.p99_target
    if not any(targets):
        return None
    if a is None and spec.controller is None:
        return None
    from repro.telemetry.slo_audit import SLOAudit, SLOAuditConfig
    cfg = SLOAuditConfig() if a is None else SLOAuditConfig(
        objective=a.objective, fast_windows=a.fast_windows,
        slow_windows=a.slow_windows, fast_burn=a.fast_burn,
        slow_burn=a.slow_burn)
    return SLOAudit(targets, config=cfg, time_unit=time_unit)


def _events_block(events: List[Event], extras: dict) -> List[dict]:
    """Serialize EQ events (bounded; the total count is always recorded)."""
    extras["events_total"] = len(events)
    return _jsonify([
        {"tenant": e.tenant, "kind": e.kind.value, "time": float(e.time),
         "detail": e.detail} for e in events[:MAX_REPORT_EVENTS]])


# ---------------------------------------------------------------------------
# simulator adapter
# ---------------------------------------------------------------------------
class SimRuntime:
    """Runtime adapter over the cycle-level PsPIN simulator.

    The underlying ``Simulator`` binds its tenant set at construction,
    so the adapter stages ``create_tenant`` calls and builds the
    simulator lazily on first ``inject``/``run_until`` (the "seal").
    ``destroy_tenant`` is not supported on this backend — a sim tenant
    lives for the whole scenario.
    """

    backend = "sim"
    time_unit = NS_UNIT

    def __init__(self, *, scheduler: str = "wlbvt", frag=None,
                 arb: str = "dwrr", fifo_capacity: int = 4096,
                 io_demand_weights=None, record_timeline: bool = False,
                 control_interval_ns: float = 8000.0,
                 datapath: str = "event", trace: bool = False,
                 trace_depth: int = 65536,
                 trace_decision_depth: int = 8192):
        self._kw = dict(scheduler=scheduler, frag=frag, arb=arb,
                        fifo_capacity=fifo_capacity,
                        io_demand_weights=io_demand_weights,
                        record_timeline=record_timeline,
                        control_interval_ns=control_interval_ns,
                        trace=trace, trace_depth=trace_depth,
                        trace_decision_depth=trace_decision_depth)
        self._datapath = datapath
        self._tenants: List[ECTX] = []
        self._controller = None
        self._bus = None
        self._audit = None
        self._sim = None
        self._events: List[Event] = []
        self._pending: List = []      # injected, not yet run packets
        self.result = None            # last SimResult (deprecated surface)

    @classmethod
    def from_spec(cls, spec: ScenarioSpec, **overrides) -> "SimRuntime":
        weights = None
        if spec.io_demand_weights == "demand":
            weights = _io_demand(spec)
        kw = dict(scheduler=spec.scheduler, frag=spec.frag(),
                  arb=spec.arbiter, fifo_capacity=spec.fifo_capacity,
                  io_demand_weights=weights,
                  record_timeline=spec.record_timeline,
                  control_interval_ns=(spec.controller.interval_ns
                                       if spec.controller else 8000.0),
                  datapath=spec.datapath or "event")
        kw.update(overrides)
        return cls(**kw)

    # -- lifecycle ----------------------------------------------------------
    def create_tenant(self, tenant_id: int, slo: SLOPolicy, *,
                      name: str = "", workload=None) -> ECTX:
        if self._sim is not None:
            raise RuntimeError("sim backend binds tenants at seal time; "
                               "create_tenant before the first inject/run")
        if tenant_id != len(self._tenants):
            raise ValueError(f"sim tenant ids are dense: expected "
                             f"{len(self._tenants)}, got {tenant_id}")
        e = ECTX(tenant_id=tenant_id, name=name or f"tenant{tenant_id}",
                 slo=slo, kernel=workload)
        self._tenants.append(e)
        return e

    def destroy_tenant(self, tenant_id: int) -> List[Event]:
        raise NotImplementedError(
            "the cycle simulator has no mid-run tenant teardown; "
            "use the serve backend for lifecycle churn")

    def attach_controller(self, controller) -> None:
        if self._sim is not None:
            raise RuntimeError("attach_controller before the first run")
        self._controller = controller

    def attach_bus(self, bus) -> None:
        """Attach a ``MetricsBus``: the simulator publishes one
        ``BusFrame`` per committed IO window (DESIGN.md §11.1)."""
        self._bus = bus
        if self._sim is not None:
            self._sim.attach_bus(bus)

    def attach_slo_audit(self, audit) -> None:
        """Attach an ``SLOAudit``: burn-rate alerts land in the EQ
        stream / trace plane and ``report().extras['slo_audit']``."""
        self._audit = audit
        if self._sim is not None:
            self._sim.attach_slo_audit(audit)

    def _seal(self):
        if self._sim is None:
            from repro.sim.fastpath import build_simulator
            if not self._tenants:
                raise RuntimeError("no tenants created")
            self._sim = build_simulator(
                self._tenants, datapath=self._datapath,
                controller=self._controller, **self._kw)
            if self._bus is not None:
                self._sim.attach_bus(self._bus)
            if self._audit is not None:
                self._sim.attach_slo_audit(self._audit)
        return self._sim

    # -- clock + work -------------------------------------------------------
    def inject(self, work: Sequence) -> None:
        """Queue work: a ``TracePacket`` sequence, or a ``TraceArrays``
        column bundle (the SoA twin — cheap at million-packet scale)."""
        self._seal()                  # tenant set is bound from here on
        from repro.sim.traffic import TraceArrays
        if isinstance(work, TraceArrays):
            self._pending.append(work)
        else:
            self._pending.extend(work)

    def run_until(self, t: Optional[float] = None) -> float:
        from repro.sim.traffic import (TraceArrays, TracePacket,
                                       merge_trace_arrays)
        sim = self._seal()
        pending, self._pending = self._pending, []
        if any(isinstance(p, TraceArrays) for p in pending):
            # normalize mixed injections: lift loose packets into one
            # column bundle, then merge chronologically
            packets = [p for p in pending if isinstance(p, TracePacket)]
            bundles = [p for p in pending if isinstance(p, TraceArrays)]
            if packets:
                bundles.append(TraceArrays.from_packets(packets))
            pending = merge_trace_arrays(*bundles)
            if self._datapath == "event":    # event loop wants packets
                pending = pending.to_packets()
        self.result = sim.run(pending, horizon=t)
        self._events.extend(self.result.events)
        return sim.now

    def now(self) -> float:
        return self._seal().now

    @property
    def trace(self):
        """The flight recorder, or None (tracing off / not sealed)."""
        return self._sim.trace if self._sim is not None else None

    def flush_trace(self) -> None:
        """Flush in-flight trace state (open spans / queued packets)
        into the recorder — call once after the run, before export."""
        if self._sim is not None:
            self._sim.trace_flush(self._sim.now)

    def poll_events(self, tenant_id: int) -> List[Event]:
        out = [e for e in self._events if e.tenant == tenant_id]
        self._events = [e for e in self._events if e.tenant != tenant_id]
        return out

    # -- scenario driver ----------------------------------------------------
    def run(self, spec: ScenarioSpec) -> RunReport:
        for i, t in enumerate(spec.tenants):
            self.create_tenant(i, t.slo(), name=t.name,
                               workload=t.workload.build())
        if spec.controller is not None and self._controller is None:
            from repro.telemetry import QoSController
            T = len(spec.tenants)
            self.attach_controller(QoSController(
                base_weights=np.ones(T),
                p99_targets=spec.controller.p99_targets(
                    spec.tenants, "sim", T)))
        if self._audit is None:
            audit = _build_audit(spec, "sim", len(spec.tenants), NS_UNIT)
            if audit is not None:
                self.attach_slo_audit(audit)
        self.inject(build_traces(spec, arrays=spec.datapath == "batched"))
        # horizon_us > 0: fixed measurement window (queued work is cut
        # off); default drains every queued event
        self.run_until(spec.horizon_us * 1e3 if spec.horizon_us else None)
        return self.report(spec)

    # -- report -------------------------------------------------------------
    def report(self, spec: Optional[ScenarioSpec] = None) -> RunReport:
        if self.result is None:
            self.run_until(None)
        res = self.result
        from repro.telemetry import tenant_report
        from repro.telemetry.metrics import C_IDX
        snap = res.telemetry.snapshot()
        tenants: Dict[int, TenantReport] = {}
        for i, e in enumerate(self._tenants):
            st = res.stats[i]
            counts = snap["counts"][i]
            tenants[i] = TenantReport(
                tenant_id=i, name=e.name,
                arrivals=int(counts[C_IDX["arrivals"]]),
                completed=int(st.completed), killed=int(st.killed),
                drops=int(st.drops),
                rejected=int(counts[C_IDX["rejected"]]),
                ecn_marks=int(counts[C_IDX["ecn_marks"]]),
                bytes_in=float(counts[C_IDX["bytes_in"]]),
                bytes_out=float(counts[C_IDX["bytes_out"]]),
                throughput=float(res.throughput_gbps(i)),
                p50_latency=float(res.p50(i)),
                p99_latency=float(res.p99(i)),
                latency_samples=len(st.kernel_times),
                extra=_jsonify({
                    "fct": float(st.fct),
                    "io_bytes_done": float(st.io_bytes_done),
                    "served_payload_bytes": float(st.served_payload_bytes),
                }))
        extras: dict = {}
        if self.trace is not None:
            extras["trace_summary"] = self.trace.trace_summary()
        if self._audit is not None:
            extras["slo_audit"] = self._audit.summary()
        events = _events_block(self._events, extras)
        names = {i: e.name for i, e in enumerate(self._tenants)}
        return RunReport(
            scenario=spec.name if spec else "",
            backend="sim", time_unit=NS_UNIT, duration=float(res.time),
            scheduler=self._kw["scheduler"], arbiter=self._kw["arb"],
            seed=int(spec.seed) if spec else 0,
            jain_pu=float(res.jain_pu_timeavg),
            jain_io=float(res.jain_io_timeavg),
            tenants=tenants, events=events,
            telemetry=_jsonify(tenant_report(res.telemetry, names=names)),
            spec=_jsonify(spec.to_dict()) if spec else None,
            extras=_jsonify(extras))


def build_traces(spec: ScenarioSpec, *, arrays: bool = False):
    """Materialize the per-tenant packet traces a spec describes.

    ``arrays=True`` returns the ``TraceArrays`` column bundle instead of
    ``TracePacket`` objects — identical packet sequence, no per-packet
    Python objects (the batched datapath consumes it directly)."""
    from repro.sim.traffic import (make_trace_arrays, merge_trace_arrays)
    traces = []
    for i, t in enumerate(spec.tenants):
        a = t.arrival
        traces.append(make_trace_arrays(
            i, size=a.size, share=a.share, seed=spec.seed + a.seed_offset,
            duration_ns=a.duration_frac * spec.duration_us * 1e3))
    merged = merge_trace_arrays(*traces)
    return merged if arrays else merged.to_packets()


def _io_demand(spec: ScenarioSpec) -> List[float]:
    """Per-tenant IO byte demand (bytes/ns) — the denominator weights of
    windowed IO fairness under heterogeneous DMA amplification."""
    from repro.configs.osmosis_pspin import PSPIN
    link_bns = PSPIN.ingress_gbps / 8.0
    out = []
    for t in spec.tenants:
        wl = t.workload.build()
        payload = max(1, t.arrival.size - PSPIN.header_bytes)
        out.append(t.arrival.share * link_bns * wl.io_bytes(payload)
                   / t.arrival.size)
    return out


# ---------------------------------------------------------------------------
# serving adapter
# ---------------------------------------------------------------------------
class ServeRuntime:
    """Runtime adapter over the multi-tenant TPU serving engine."""

    backend = "serve"
    time_unit = STEPS_UNIT

    def __init__(self, ecfg=None, executor=None, **cfg_overrides):
        """``executor`` is either an executor instance or a factory
        ``(EngineConfig) -> executor`` — the factory form exists because
        real executors (``ModelExecutor``) need the very EngineConfig
        this constructor derives (None = scheduling-only NullExecutor)."""
        from repro.serving.engine import Engine, EngineConfig
        if ecfg is None:
            ecfg = EngineConfig(**cfg_overrides)
        elif cfg_overrides:
            ecfg = dataclasses.replace(ecfg, **cfg_overrides)
        self.ecfg = ecfg
        if callable(executor) and not hasattr(executor, "decode"):
            executor = executor(ecfg)
        self.engine = Engine(ecfg, executor=executor)
        self._names: Dict[int, str] = {}
        self._events: List[Event] = []

    @classmethod
    def from_spec(cls, spec: ScenarioSpec, executor=None,
                  **cfg_overrides) -> "ServeRuntime":
        s = spec.serve
        kw = dict(max_slots=s.max_slots, max_len=s.max_len,
                  prefill_chunk=s.prefill_chunk,
                  prefill_slots_per_step=s.prefill_slots_per_step,
                  kv_overcommit=s.kv_overcommit,
                  scheduler=spec.scheduler, arbiter=spec.arbiter,
                  max_tenants=max(len(spec.tenants), 2),
                  qos_interval=(spec.controller.interval_steps
                                if spec.controller else 0))
        kw.update(cfg_overrides)
        return cls(executor=executor, **kw)

    # -- lifecycle ----------------------------------------------------------
    def create_tenant(self, tenant_id: int, slo: SLOPolicy, *,
                      name: str = "", workload=None) -> ECTX:
        e = self.engine.create_ectx(tenant_id, slo, name=name)
        self._names[tenant_id] = e.name
        return e

    def destroy_tenant(self, tenant_id: int) -> List[Event]:
        evs = self.engine.destroy_ectx(tenant_id)
        self._events.extend(evs)
        return evs

    def attach_controller(self, controller) -> None:
        self.engine.attach_controller(controller)

    def attach_bus(self, bus) -> None:
        """Attach a ``MetricsBus``: the engine publishes one
        ``BusFrame`` per observation interval (steps)."""
        self.engine.attach_bus(bus)

    def attach_slo_audit(self, audit) -> None:
        self.engine.attach_slo_audit(audit)

    # -- clock + work -------------------------------------------------------
    def inject(self, work: Sequence) -> None:
        for req in work:
            self.engine.submit(req)

    def run_until(self, t: Optional[float] = None) -> float:
        if t is None:
            self.engine.run_until_idle()
        else:
            while self.engine.step_count < t:
                self.engine.step()
        return float(self.engine.step_count)

    def now(self) -> float:
        return float(self.engine.step_count)

    @property
    def trace(self):
        """The flight recorder, or None (tracing off)."""
        return self.engine.trace

    def flush_trace(self) -> None:
        """Flush in-flight trace state (open spans / queued requests)
        into the recorder — call once after the run, before export."""
        self.engine.trace_flush(float(self.engine.step_count))

    def poll_events(self, tenant_id: int) -> List[Event]:
        mine = [e for e in self._events if e.tenant == tenant_id]
        self._events = [e for e in self._events if e.tenant != tenant_id]
        if tenant_id in self.engine.eq:
            mine.extend(self.engine.poll_events(tenant_id))
        return mine

    # -- scenario driver ----------------------------------------------------
    def run(self, spec: ScenarioSpec) -> RunReport:
        quota_default = spec.serve.max_len * max(
            1, spec.serve.max_slots // max(len(spec.tenants), 1))
        for i, t in enumerate(spec.tenants):
            slo = t.slo()
            if slo.kv_quota_tokens == 0:
                slo = dataclasses.replace(slo, kv_quota_tokens=quota_default)
            self.create_tenant(i, slo, name=t.name)
        if spec.controller is not None:
            from repro.telemetry import QoSController
            T = self.ecfg.max_tenants
            self.attach_controller(QoSController(
                base_weights=np.ones(T),
                p99_targets=spec.controller.p99_targets(
                    spec.tenants, "serve", T)))
        if self.engine.slo_audit is None:
            audit = _build_audit(spec, "serve", self.ecfg.max_tenants,
                                 STEPS_UNIT)
            if audit is not None:
                self.attach_slo_audit(audit)
        self.inject(build_requests(spec))
        if spec.serve.steps > 0:
            self.run_until(spec.serve.steps)
        else:
            self.run_until(None)
        return self.report(spec)

    # -- report -------------------------------------------------------------
    def report(self, spec: Optional[ScenarioSpec] = None) -> RunReport:
        eng = self.engine
        m = eng.metrics()
        steps = max(eng.step_count, 1)
        tel = eng.tel
        if tel is not None:
            tel.commit()
            snap = tel.snapshot()
            from repro.telemetry.metrics import C_IDX, hist_quantile
            p50 = hist_quantile(snap["hist"], 0.50, np)
            p99 = hist_quantile(snap["hist"], 0.99, np)
        # non-destructive (matching SimRuntime.report): poll_events still
        # delivers these to the tenant afterwards
        pending = list(self._events)
        for t in sorted(eng.eq):
            pending.extend(eng.eq[t].snapshot())
        tenant_ids = sorted(set(self._names) | set(m["tenants"]))
        tenants: Dict[int, TenantReport] = {}
        for t in tenant_ids:
            d = m["tenants"].get(
                t, {"done": 0, "killed": 0, "mean_fct": 0.0, "tokens": 0})
            if tel is not None:
                counts = snap["counts"][t]
                row = dict(
                    arrivals=int(counts[C_IDX["arrivals"]]),
                    rejected=int(counts[C_IDX["rejected"]]),
                    ecn_marks=int(counts[C_IDX["ecn_marks"]]),
                    drops=int(counts[C_IDX["drops"]]),
                    bytes_in=float(counts[C_IDX["bytes_in"]]),
                    bytes_out=float(counts[C_IDX["bytes_out"]]),
                    throughput=float(counts[C_IDX["tokens"]]) / steps,
                    p50_latency=float(p50[t]), p99_latency=float(p99[t]),
                    latency_samples=int(snap["hist"][t].sum()))
            else:
                row = dict(arrivals=int(d["done"] + d["killed"]),
                           rejected=0, ecn_marks=0, drops=0,
                           bytes_in=0.0, bytes_out=0.0,
                           throughput=float(d["tokens"]) / steps,
                           p50_latency=0.0, p99_latency=0.0,
                           latency_samples=0)
            tenants[t] = TenantReport(
                tenant_id=t, name=self._names.get(t, f"tenant{t}"),
                completed=int(d["done"]), killed=int(d["killed"]),
                extra=_jsonify({"mean_fct": float(d["mean_fct"]),
                                "tokens": float(d["tokens"])}),
                **row)
        extras = {"decode_steps": m["decode_steps"],
                  "prefill_chunks": m["prefill_chunks"]}
        if eng.trace is not None:
            extras["trace_summary"] = eng.trace.trace_summary()
        if eng.slo_audit is not None:
            extras["slo_audit"] = eng.slo_audit.summary()
        events = _events_block(pending, extras)
        return RunReport(
            scenario=spec.name if spec else "",
            backend="serve", time_unit=STEPS_UNIT,
            duration=float(eng.step_count),
            scheduler=self.ecfg.scheduler, arbiter=self.ecfg.arbiter,
            seed=int(spec.seed) if spec else 0,
            jain_pu=float(m["jain_timeavg"]), jain_io=1.0,
            tenants=tenants, events=events,
            telemetry=(_jsonify(eng.telemetry_report())
                       if tel is not None else None),
            spec=_jsonify(spec.to_dict()) if spec else None,
            extras=_jsonify(extras))


def build_requests(spec: ScenarioSpec):
    """Materialize the request stream a spec's serving projection
    describes: round-robin across tenants, one shared RNG (matching the
    hand-written drivers this replaces)."""
    from repro.serving.request import Request
    rng = np.random.RandomState(spec.seed)
    vocab = spec.serve.vocab
    out = []
    rounds = max((t.arrival.requests for t in spec.tenants), default=0)
    for j in range(rounds):
        for i, t in enumerate(spec.tenants):
            if j >= t.arrival.requests:
                continue
            a = t.arrival
            out.append(Request(
                i, rng.randint(1, vocab, size=a.prompt_len).astype(np.int32),
                max_new_tokens=a.max_new_tokens))
    return out


# ---------------------------------------------------------------------------
# one-call driver
# ---------------------------------------------------------------------------
def make_runtime(spec: ScenarioSpec, backend: str, *, executor=None,
                 **overrides) -> Runtime:
    if backend == "sim":
        return SimRuntime.from_spec(spec, **overrides)
    if backend == "serve":
        return ServeRuntime.from_spec(spec, executor=executor, **overrides)
    raise ValueError(f"unknown backend {backend!r} (want 'sim' or 'serve')")


def run_scenario(spec: ScenarioSpec, backend: str = "sim", *,
                 executor=None, validate: bool = True) -> RunReport:
    """Run a declarative scenario on either backend -> ``RunReport``."""
    if spec.analytic:
        return _run_analytic(spec)
    from repro.fleet.spec import FleetSpec
    if isinstance(spec, FleetSpec):
        # multi-NIC scenarios run the fleet engine (N per-NIC sims over
        # the modeled switch) and return the aggregated report
        from repro.fleet.engine import run_fleet
        return run_fleet(spec, backend, validate=validate)
    rt = make_runtime(spec, backend, executor=executor)
    rep = rt.run(spec)
    return rep.validate() if validate else rep


def _run_analytic(spec: ScenarioSpec) -> RunReport:
    """Closed-form scenarios (no event loop): currently ``ppb`` — the
    paper's Fig. 3 service-time-vs-budget classification."""
    if spec.analytic != "ppb":
        raise ValueError(f"unknown analytic scenario {spec.analytic!r}")
    from repro.sim.scenarios import service_time_vs_ppb
    sizes = [64, 128, 256, 512, 1024, 2048, 4096]
    table = service_time_vs_ppb(sizes)
    rows = [[w, int(p), float(svc), float(budget), int(svc <= budget)]
            for w, lst in table.items() for (p, svc, budget) in lst]
    return RunReport(
        scenario=spec.name, backend="sim", time_unit=NS_UNIT, duration=0.0,
        scheduler=spec.scheduler, arbiter=spec.arbiter, seed=spec.seed,
        jain_pu=1.0, jain_io=1.0, tenants={}, events=[],
        telemetry=None, spec=_jsonify(spec.to_dict()),
        extras=_jsonify({"analytic": "ppb",
                         "columns": ["workload", "pkt_bytes", "service_ns",
                                     "ppb_ns", "fits"],
                         "table": rows})).validate()
