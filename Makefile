PY ?= python
export PYTHONPATH := src

.PHONY: lint analysis baseline test test-fast bench

# repo-aware static checkers (jit-purity, time-unit flow, EQ-event
# exhaustiveness, frozen-spec/fixed-shape) + ruff/mypy when installed
lint: analysis
	@command -v ruff >/dev/null && ruff check . || echo "ruff not installed; skipped"
	@command -v mypy >/dev/null && mypy || echo "mypy not installed; skipped"

analysis:
	$(PY) -m repro.analysis.check

# re-pin current findings (each new pin needs a written justification)
baseline:
	$(PY) -m repro.analysis.check --fix-baseline

test:
	$(PY) -m pytest -x -q

test-fast:
	$(PY) -m pytest -x -q -m "not slow"

bench:
	$(PY) -m benchmarks.run
