"""Per-architecture smoke tests + model-level invariants.

Every assigned arch instantiates a REDUCED same-family config and runs
one forward/train step on CPU asserting output shapes + finiteness; the
chunked-prefill == single-shot exactness test covers the serving path for
all block kinds (attention/local/MLA/SSD/RG-LRU).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (SHAPES, cell_supported, get_config, list_archs,
                           param_count, smoke_config)
from repro.models.registry import build_model, input_names

ARCHS = list_archs()


def _train_batch(cfg, B=2, S=24):
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    names = input_names(cfg, "train")
    if "frames" in names:
        batch["frames"] = jnp.zeros((B, cfg.num_audio_frames, cfg.d_model),
                                    jnp.float32)
    if "vis_embeds" in names:
        batch["vis_embeds"] = jnp.zeros((B, S, cfg.d_model), jnp.float32)
        batch["vis_mask"] = jnp.zeros((B, S), bool)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _train_batch(cfg)
    logits, aux = m.forward(params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_decreases_nothing_nan(arch):
    from repro.training.trainer import build_trainer
    cfg = smoke_config(arch)
    tr = build_trainer(cfg, total_steps=10, donate=False)
    state = tr.init_state(jax.random.PRNGKey(0))
    batch = _train_batch(cfg, B=2, S=16)
    state, metrics = tr.train_step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state.step) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_path(arch):
    cfg = smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, max_len = 2, 48
    cache = m.init_cache(B, max_len)
    lengths = jnp.zeros((B,), jnp.int32)
    kw = {}
    if cfg.is_encoder_decoder:
        kw["frames"] = jnp.zeros((B, cfg.num_audio_frames, cfg.d_model),
                                 jnp.float32)
    logits, cache = m.prefill(params, jnp.ones((B, 8), jnp.int32), cache,
                              lengths, **kw)
    logits, cache = m.decode_step(params, jnp.ones((B, 1), jnp.int32),
                                  cache, lengths + 8)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_init(arch):
    """The analytic param_count used for rooflines must equal the real
    initialized tree exactly."""
    cfg = smoke_config(arch)
    m = build_model(cfg)
    sds = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(sds))
    assert actual == param_count(cfg), (arch, actual, param_count(cfg))


@pytest.mark.parametrize("arch", ["qwen3-8b", "gemma2-27b", "mamba2-370m",
                                  "recurrentgemma-2b",
                                  "deepseek-v2-lite-16b"])
def test_chunked_prefill_matches_single_shot(arch):
    """Ragged chunked prefill (the OSMOSIS fragmentation data plane) is
    exact: same last logits and same post-prefill decode as one shot."""
    cfg = smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, P, C, max_len = 2, 23, 8, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                              cfg.vocab_size)
    cache_a = m.init_cache(B, max_len)
    logits_a, cache_a = m.prefill(params, toks, cache_a,
                                  jnp.zeros(B, jnp.int32))
    cache_b = m.init_cache(B, max_len)
    lengths = jnp.zeros(B, jnp.int32)
    off = 0
    while off < P:
        n = min(C, P - off)
        chunk = jnp.zeros((B, C), jnp.int32).at[:, :n].set(
            toks[:, off:off + n])
        valid = jnp.broadcast_to(jnp.arange(C)[None] < n, (B, C))
        logits_b, cache_b = m.prefill(params, chunk, cache_b, lengths,
                                      valid=valid)
        lengths = lengths + n
        off += n
    err = float(jnp.max(jnp.abs(logits_a[:, -1] - logits_b[:, n - 1])))
    assert err < 5e-3, err
    nxt = jnp.argmax(logits_a[:, -1], -1)[:, None].astype(jnp.int32)
    la, _ = m.decode_step(params, nxt, cache_a, jnp.full(B, P, jnp.int32))
    lb, _ = m.decode_step(params, nxt, cache_b, lengths)
    assert float(jnp.max(jnp.abs(la - lb))) < 5e-3


def test_local_attention_ring_cache_is_o_window():
    """Gemma2-style local layers keep O(window) cache regardless of
    context (DESIGN.md long-context claim)."""
    cfg = smoke_config("gemma2-27b")
    m = build_model(cfg)
    cache = m.init_cache(2, 1024)
    leaves = jax.tree.leaves(cache)
    # at least one leaf (local layers) capped at window, one at 1024
    sizes = {x.shape[-3] if x.ndim >= 3 else x.shape[-1] for x in leaves
             if x.ndim >= 2}
    assert cfg.window_size in sizes or any(
        s <= cfg.window_size for s in sizes)


def test_long_context_skip_rules():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §5)."""
    allowed = {a for a in ARCHS
               if cell_supported(get_config(a), SHAPES["long_500k"])[0]}
    assert allowed == {"mamba2-370m", "recurrentgemma-2b", "gemma2-27b"}
    for a in ARCHS:
        ok, reason = cell_supported(get_config(a), SHAPES["train_4k"])
        assert ok, (a, reason)


def test_moe_ragged_matches_gshard_when_no_drops():
    """With generous capacity the two MoE dispatch impls agree."""
    import dataclasses
    from repro.models import moe as M
    cfg = smoke_config("deepseek-v2-lite-16b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0),
        dtype="float32", param_dtype="float32")
    params = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y1, a1 = M.apply_moe(params, x, cfg, "gshard")
    y2, a2 = M.apply_moe(params, x, cfg, "ragged")
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-4
    assert float(jnp.abs(a1 - a2)) < 1e-5


def test_mla_absorbed_decode_matches_expanded():
    """DeepSeek MLA: the absorbed (latent MQA) decode path must agree with
    the expanded training path on the same tokens.  fp32: in bf16 the two
    contraction orders legitimately diverge (documented in DESIGN.md)."""
    import dataclasses
    cfg = dataclasses.replace(smoke_config("deepseek-v2-lite-16b"),
                              dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    logits_train, _ = m.forward(params, {"tokens": toks})
    cache = m.init_cache(B, 32)
    logits_serve, _ = m.prefill(params, toks, cache,
                                jnp.zeros(B, jnp.int32))
    err = float(jnp.max(jnp.abs(logits_train - logits_serve)))
    assert err < 5e-3, err


def test_moe_grouped_dispatch_padding_exact():
    """Grouped gshard with T not divisible by group_size: padded tokens
    are dropped (keep=False) and outputs match the ungrouped semantics."""
    import dataclasses
    from repro.models import moe as M
    cfg = dataclasses.replace(
        smoke_config("deepseek-v2-lite-16b"), dtype="float32",
        param_dtype="float32")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 21, cfg.d_model),
                          jnp.float32)   # T=42, group 16 -> pad 6
    y_small, _ = M.apply_moe_gshard(params, x, cfg, group_size=16)
    y_big, _ = M.apply_moe_gshard(params, x, cfg, group_size=4096)
    # generous capacity => no drops in either grouping => identical
    assert float(jnp.max(jnp.abs(y_small - y_big))) < 1e-4
