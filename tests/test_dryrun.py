"""Dry-run machinery: HLO collective parser + one real cell in subprocess."""
import json
import os
import subprocess
import sys

import pytest

from repro.launch.dryrun import _shape_bytes, collective_bytes

HLO = """
HloModule test
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[128,1024]{1,0} all-gather(%p0), replica_groups=[2,4]<=[8]
  %conv = bf16[128,1024]{1,0} convert(%ag)
  %ar = bf16[128,1024]{1,0} all-reduce(%conv), to_apply=%sum
  %t = (f32[64]{0}, f32[32]{0}) tuple-thing
  %cp = f32[64]{0} collective-permute(%p0), source_target_pairs={{0,1}}
  ROOT %done = f32[] constant(0)
"""


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(f32[64]{0}, f32[32]{0})") == (64 + 32) * 4
    assert _shape_bytes("pred[]") == 1


def test_collective_parser_sums_operand_bytes():
    out = collective_bytes(HLO)
    assert out["all-gather"]["bytes"] == 128 * 256 * 4       # operand %p0
    assert out["all-gather"]["count"] == 1
    assert out["all-reduce"]["bytes"] == 128 * 1024 * 2      # operand %conv
    assert out["collective-permute"]["bytes"] == 128 * 256 * 4
    assert out["total_bytes"] == (128 * 256 * 4 * 2 + 128 * 1024 * 2)


@pytest.mark.slow
def test_one_cell_compiles_on_production_mesh(tmp_path):
    """Full 512-device single-pod lower+compile for the smallest cell —
    the minimum proof that the distribution config is coherent."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)          # let dryrun force 512 devices
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-370m", "--shape", "decode_32k",
         "--mesh", "single"],
        capture_output=True, text=True, timeout=1200, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "[ ok ]" in r.stdout


HLO_INPLACE = """
HloModule t2
%upd_comp (p0: bf16[4,64], p1: bf16[1,64], p2: s32[]) -> bf16[4,64] {
  %p0 = bf16[4,64]{1,0} parameter(0)
  %c0 = f32[4,64]{1,0} convert(%p0)
  %p1 = bf16[1,64]{1,0} parameter(1)
  %c1 = f32[1,64]{1,0} convert(%p1)
  %p2 = s32[] parameter(2)
  %cz = s32[] constant(0)
  %dus = f32[4,64]{1,0} dynamic-update-slice(%c0, %c1, %p2, %cz)
  ROOT %out = bf16[4,64]{1,0} convert(%dus)
}
%mv_comp (p3: bf16[4,64]) -> f32[4,64] {
  %p3 = bf16[4,64]{1,0} parameter(0)
  ROOT %cv = f32[4,64]{1,0} convert(%p3)
}
ENTRY %main (a: bf16[4,64], u: bf16[1,64], i: s32[]) -> bf16[4,64] {
  %a = bf16[4,64]{1,0} parameter(0)
  %u = bf16[1,64]{1,0} parameter(1)
  %i = s32[] parameter(2)
  %mv = f32[4,64]{1,0} fusion(%a), kind=kLoop, calls=%mv_comp
  ROOT %f = bf16[4,64]{1,0} fusion(%a, %u, %i), kind=kLoop, calls=%upd_comp
}
"""


def test_analyzer_inplace_update_and_movement_fusions():
    """DUS-through-convert fusions count ~2x the update slice (at the
    in-fusion dtype) instead of the whole buffer; pure data-movement
    (convert) fusions count zero HBM bytes."""
    from repro.launch.hlo_stats import analyze
    st = analyze(HLO_INPLACE)
    # update slice inside the fusion is f32[1,64] = 256B -> 2x = 512;
    # non-buffer operands: u 128 + i 4; the 512B buffer + the %mv convert
    # fusion contribute nothing
    assert st["bytes"] == 2 * 256 + 128 + 4, st["bytes"]
    # full-buffer accounting would have been >= 3x larger
    assert st["bytes"] < 1024


def test_analyzer_scan_trip_counts():
    """While bodies multiply by known_trip_count (the cost_analysis gap)."""
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_stats import analyze

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()

    comp = jax.jit(f).lower(jnp.ones((8, 16)), jnp.ones((16, 16))).compile()
    st = analyze(comp.as_text())
    dot_flops = 2 * 8 * 16 * 16 * 7
    assert dot_flops <= st["flops"] <= dot_flops * 1.2
    # cost_analysis() returns a dict on new jax, a one-element list of
    # dicts on older releases
    cost = comp.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    assert (cost or {}).get("flops", 0) < dot_flops
