"""Static-checker suite (repro.analysis): fixture findings with exact
locations, the repo-wide run vs the checked-in baseline, and the CLI
gate exit codes."""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import Baseline, RepoIndex, RULE_REGISTRY, run_rules
from repro.analysis.check import BASELINE_NAME
from repro.analysis.events import EventExhaustivenessRule
from repro.analysis.frozen import FixedShapeRule, FrozenSpecRule
from repro.analysis.purity import JitPurityRule
from repro.analysis.metrics_names import MetricNamesRule
from repro.analysis.spans import SpanBalanceRule
from repro.analysis.units import TimeUnitFlowRule

HERE = os.path.dirname(os.path.abspath(__file__))
FIXTURES = os.path.join(HERE, "data", "analysis")
REPO_ROOT = os.path.dirname(HERE)


def run_rule(rule, paths, root=FIXTURES):
    index = RepoIndex.load(root, paths=paths, excludes=())
    return rule.run(index)


def locs(findings):
    """(line, first-words-of-message) pairs, order-independent."""
    return {(f.line, f.message.split(";")[0].split(" (")[0])
            for f in findings}


# ---------------------------------------------------------------------------
# pass 1: jit-purity
# ---------------------------------------------------------------------------
def test_purity_good_is_clean():
    assert run_rule(JitPurityRule(scope=("*",)), ["purity_good.py"]) == []


def test_purity_bad_exact_findings():
    fs = run_rule(JitPurityRule(scope=("*",)), ["purity_bad.py"])
    assert all(f.rule == "jit-purity" and f.severity == "error" for f in fs)
    got = {(f.line, f.symbol) for f in fs}
    assert got == {
        (13, "inplace_at"),       # np.add.at in-place scatter
        (13, "inplace_at"),       # (the bare np.add ref is also flagged)
        (20, "subscript_store"),
        (26, "mixes_numpy"),
        (32, "traced_branch"),
        (39, "dynamic_shape"),
        (44, "one_arg_where"),
    }
    # line 13 carries both the in-place and the backend-mixing finding
    assert len([f for f in fs if f.line == 13]) == 2
    assert len(fs) == 7


def test_devicepath_good_is_clean():
    assert run_rule(JitPurityRule(scope=("*",)),
                    ["devicepath_good.py"]) == []


def test_devicepath_bad_exact_findings():
    fs = run_rule(JitPurityRule(scope=("*",)), ["devicepath_bad.py"])
    assert all(f.rule == "jit-purity" and f.severity == "error" for f in fs)
    assert {(f.line, f.symbol) for f in fs} == {
        (14, "branch_on_pick"),        # traced `if` on a pick value
        (22, "host_counter_in_step.body"),  # np call inside a scan body
        (29, "ragged_completions"),    # data-dependent shape
        (34, "inplace_ring"),          # subscript store
    }


def test_devicepath_modules_in_default_scope():
    """The device datapath and the WLBVT kernel must sit inside the
    repo gate's reachability scope (ISSUE 10 satellite)."""
    from repro.analysis.purity import DEFAULT_SCOPE
    import fnmatch
    for path in ("src/repro/sim/devicepath.py",
                 "src/repro/kernels/wlbvt_select.py"):
        assert any(fnmatch.fnmatch(path, pat) for pat in DEFAULT_SCOPE), path


# ---------------------------------------------------------------------------
# pass 2: time-unit flow
# ---------------------------------------------------------------------------
def test_units_good_is_clean():
    assert run_rule(TimeUnitFlowRule(scope=("*",)), ["units_good.py"]) == []


def test_units_bad_exact_findings():
    fs = run_rule(TimeUnitFlowRule(scope=("*",)), ["units_bad.py"])
    assert all(f.rule == "time-unit-flow" for f in fs)
    assert locs(fs) == {
        (9, "`+` mixes time units: ns and us"),
        (14, "assigns a us value to `duration_ns`"),
        (19, "keyword `window_us=` declares us but the value carries ns"),
        (23, "time_unit='seconds' is not one of ['ns', 'steps']"),
        (27, "`comparison` mixes time units: cycles and ns"),
        (31, "cycles_ns() applied to a ns value"),
    }


# ---------------------------------------------------------------------------
# pass 3: EQ-event exhaustiveness
# ---------------------------------------------------------------------------
def test_events_good_is_clean():
    fs = run_rule(EventExhaustivenessRule(scope=("*",)), ["."],
                  root=os.path.join(FIXTURES, "events_good"))
    assert fs == []


def test_events_bad_exact_findings():
    fs = run_rule(EventExhaustivenessRule(scope=("*",)), ["."],
                  root=os.path.join(FIXTURES, "events_bad"))
    assert all(f.rule == "eq-event-exhaustiveness" for f in fs)
    assert locs(fs) == {
        (23, "EVENT_DISPOSITIONS[EventKind.DROP] must be a non-empty "
             "string naming the consumer"),
        (24, "EVENT_DISPOSITIONS lists EventKind.RETIRED, which is not a "
             "declared member"),
        (17, "EventKind.ORPHAN has no EVENT_DISPOSITIONS entry: declare "
             "where this event is consumed"),
        (17, "EventKind.ORPHAN is emitted but never consumed and has no "
             "EVENT_DISPOSITIONS entry"),
        (18, "EventKind.GHOST has no EVENT_DISPOSITIONS entry: declare "
             "where this event is consumed"),
        (18, "EventKind.GHOST is declared but never emitted"),
    }
    warnings = [f for f in fs if f.severity == "warning"]
    assert [w.message.split(";")[0] for w in warnings] == \
        ["EventKind.GHOST is declared but never emitted"]


def test_events_fleet_good_is_clean():
    """The fleet-plane kinds (MIGRATE_START/MIGRATE_DONE/SWITCH_DROP)
    with named consumers pass the exhaustiveness rule."""
    fs = run_rule(EventExhaustivenessRule(scope=("*",)), ["."],
                  root=os.path.join(FIXTURES, "events_fleet_good"))
    assert fs == []


def test_events_fleet_bad_exact_findings():
    fs = run_rule(EventExhaustivenessRule(scope=("*",)), ["."],
                  root=os.path.join(FIXTURES, "events_fleet_bad"))
    assert all(f.rule == "eq-event-exhaustiveness" for f in fs)
    assert locs(fs) == {
        (23, "EVENT_DISPOSITIONS[EventKind.MIGRATE_DONE] must be a "
             "non-empty string naming the consumer"),
        (24, "EVENT_DISPOSITIONS lists EventKind.DRAINED, which is not a "
             "declared member"),
        (17, "EventKind.SWITCH_DROP has no EVENT_DISPOSITIONS entry: "
             "declare where this event is consumed"),
        (18, "EventKind.MIGRATE_ABORT has no EVENT_DISPOSITIONS entry: "
             "declare where this event is consumed"),
        (18, "EventKind.MIGRATE_ABORT is declared but never emitted"),
    }


# ---------------------------------------------------------------------------
# pass 4: frozen-spec + fixed-shape
# ---------------------------------------------------------------------------
def test_frozen_good_is_clean():
    assert run_rule(FrozenSpecRule(scope=("*",)), ["frozen_good.py"]) == []
    assert run_rule(FixedShapeRule(scope=("*",)), ["frozen_good.py"]) == []


def test_frozen_bad_exact_findings():
    fs = run_rule(FrozenSpecRule(scope=("*",)), ["frozen_bad.py"])
    assert locs(fs) == {
        (9, "assignment to frozen spec attribute `spec.duration_us`"),
        (14, "in-place update of frozen spec attribute `spec.num_tenants`"),
        (18, "setattr on frozen spec `spec`"),
        (19, "`object.__setattr__` bypasses the frozen spec contract on "
             "`spec`"),
    }


def test_fixed_shape_bad_exact_findings():
    fs = run_rule(FixedShapeRule(scope=("*",)), ["frozen_bad.py"])
    assert locs(fs) == {
        (23, "`nonzero` allocates a data-dependent shape in a telemetry "
             "collector kernel"),
        (24, "boolean-mask indexing yields a data-dependent shape in a "
             "telemetry collector kernel"),
        (25, "one-argument `where` is data-dependent"),
    }


# ---------------------------------------------------------------------------
# pass 5: trace span-balance
# ---------------------------------------------------------------------------
def test_spans_good_is_clean():
    assert run_rule(SpanBalanceRule(scope=("*",)), ["span_good.py"]) == []


def test_spans_bad_exact_findings():
    fs = run_rule(SpanBalanceRule(scope=("*",)), ["span_bad.py"])
    assert all(f.rule == "span-balance" for f in fs)
    assert {(f.line, f.severity) for f in fs} == {
        (7, "error"),    # span_begin(ST_PU) never closed: leaks to OPEN
        (11, "warning"),  # span_end(ST_DMA) without a begin
        (16, "error"),   # span_abandon with non-terminal D_OK
        (20, "error"),   # numeric stage code defeats the pairing
    }
    by_line = {f.line: f.message for f in fs}
    assert "leaks to flush_open" in by_line[7]
    assert "without a span_begin" in by_line[11]
    assert "D_DROP/D_REJECT/D_KILL, got D_OK" in by_line[16]
    assert "must be an ST_* constant" in by_line[20]


def test_spans_rule_skips_the_recorder_module():
    # the recorder defines the API; its own internal span() calls are
    # not client pairing sites
    index = RepoIndex.load(REPO_ROOT,
                           paths=["src/repro/telemetry/trace.py"],
                           excludes=())
    assert SpanBalanceRule().run(index) == []


# ---------------------------------------------------------------------------
# pass 6: exported-metric names
# ---------------------------------------------------------------------------
def test_metrics_good_is_clean():
    assert run_rule(MetricNamesRule(scope=("*",)),
                    ["metrics_good.py"]) == []


def test_metrics_bad_exact_findings():
    fs = run_rule(MetricNamesRule(scope=("*",)), ["metrics_bad.py"])
    assert all(f.rule == "metric-names" and f.severity == "error"
               for f in fs)
    by_line = {f.line: f.message for f in fs}
    assert len(fs) == len(by_line) == 7
    assert "not snake_case" in by_line[5]
    assert "declares unit 'seconds'" in by_line[7]
    assert "does not end in its declared unit suffix `_steps`" in by_line[9]
    assert "kind 'histogram'" in by_line[11]
    assert "must end `_total`" in by_line[13]
    assert "duplicate metric 'osmosis_arrivals_total'" in by_line[17]
    assert "must be string literals" in by_line[19]


def test_metrics_rule_accepts_the_real_registry():
    # the shipped exporter registry must satisfy its own lint (with the
    # whitelist read from the real api/report.py TIME_UNITS)
    index = RepoIndex.load(REPO_ROOT,
                           paths=["src/repro/telemetry/export.py",
                                  "src/repro/api/report.py"],
                           excludes=())
    assert MetricNamesRule().run(index) == []


# ---------------------------------------------------------------------------
# repo-wide run must match the checked-in baseline
# ---------------------------------------------------------------------------
def test_repo_wide_run_matches_baseline():
    index = RepoIndex.load(REPO_ROOT)
    findings = run_rules(index)
    baseline = Baseline.load(os.path.join(REPO_ROOT, BASELINE_NAME))
    new, stale = baseline.diff(findings)
    assert new == [], "un-baselined findings:\n" + "\n".join(
        f.format() for f in new)
    assert stale == [], f"stale baseline entries: {stale}"
    # every pin needs a real, human-written justification
    for key, just in baseline.entries.items():
        assert just.strip() and not just.startswith("TODO"), (
            f"baseline entry lacks a justification: {key}")


def test_all_passes_registered():
    assert set(RULE_REGISTRY) >= {"jit-purity", "time-unit-flow",
                                  "eq-event-exhaustiveness", "frozen-spec",
                                  "fixed-shape", "span-balance",
                                  "metric-names"}


# ---------------------------------------------------------------------------
# CLI gate: exit codes, --json, --fix-baseline round-trip
# ---------------------------------------------------------------------------
def _check_cli(*args, cwd):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.check", *args],
        capture_output=True, text=True, timeout=600, cwd=cwd, env=env)


@pytest.fixture
def violation_repo(tmp_path):
    """A minimal repo with one deliberately-injected unit violation."""
    (tmp_path / "pyproject.toml").write_text("[project]\nname='x'\n")
    src = tmp_path / "src"
    src.mkdir()
    (src / "clock.py").write_text(
        "def deadline(start_ns, timeout_us):\n"
        "    return start_ns + timeout_us\n")
    return tmp_path


def test_cli_gate_fails_on_injected_violation(violation_repo):
    r = _check_cli("--json", "--root", str(violation_repo),
                   cwd=str(violation_repo))
    assert r.returncode == 1, r.stdout + r.stderr
    payload = json.loads(r.stdout)
    assert not payload["ok"]
    (finding,) = payload["new"]
    assert finding["rule"] == "time-unit-flow"
    assert finding["path"] == "src/clock.py"
    assert finding["line"] == 2
    assert "mixes time units" in finding["message"]


def test_cli_fix_baseline_round_trip(violation_repo):
    # absorb the violation into the baseline...
    r = _check_cli("--fix-baseline", "--root", str(violation_repo),
                   cwd=str(violation_repo))
    assert r.returncode == 0, r.stdout + r.stderr
    baseline = json.loads((violation_repo / BASELINE_NAME).read_text())
    (entry,) = baseline["entries"]
    assert entry["justification"].startswith("TODO")
    # ...after which the gate passes
    r2 = _check_cli("--json", "--root", str(violation_repo),
                    cwd=str(violation_repo))
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert json.loads(r2.stdout)["ok"]
    # fixing the code makes the pin stale -> gate fails again
    (violation_repo / "src" / "clock.py").write_text(
        "def deadline(start_ns, timeout_us):\n"
        "    return start_ns + timeout_us * 1e3\n")
    r3 = _check_cli("--json", "--root", str(violation_repo),
                    cwd=str(violation_repo))
    assert r3.returncode == 1
    assert json.loads(r3.stdout)["stale_baseline"]


def test_cli_unknown_rule_exits_2(violation_repo):
    r = _check_cli("--rule", "no-such-rule", "--root", str(violation_repo),
                   cwd=str(violation_repo))
    assert r.returncode == 2


def test_cli_single_rule_filter(violation_repo):
    r = _check_cli("--json", "--rule", "frozen-spec", "--root",
                   str(violation_repo), cwd=str(violation_repo))
    assert r.returncode == 0, r.stdout + r.stderr
    assert json.loads(r.stdout)["findings"] == []
