"""Optimizers, grad accumulation, loss, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.training import optimizer as OPT
from repro.training.data import MemmapCorpus, Prefetcher, SyntheticLM
from repro.training.trainer import build_trainer, cross_entropy


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------
def test_adamw_first_step_matches_closed_form():
    opt = OPT.adamw(lambda s: 0.1, b1=0.9, b2=0.99, eps=1e-8,
                    weight_decay=0.0, max_grad_norm=1e9)
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -0.5])}
    st = opt.init(p)
    u, st = opt.update(g, st, p)
    # bias-corrected first step: m_hat = g, v_hat = g^2 -> u = -lr*sign(g)
    np.testing.assert_allclose(np.asarray(u["w"]),
                               [-0.1 * 1.0, 0.1 * 1.0], rtol=1e-4)


def test_adamw_converges_on_quadratic():
    opt = OPT.adamw(lambda s: 0.05, weight_decay=0.0)
    p = {"w": jnp.array([3.0, -2.0])}
    st = opt.init(p)
    for _ in range(300):
        g = {"w": 2.0 * p["w"]}
        u, st = opt.update(g, st, p)
        p = OPT.apply_updates(p, u)
    assert float(jnp.max(jnp.abs(p["w"]))) < 1e-2


def test_adafactor_factored_state_shapes_and_convergence():
    opt = OPT.adafactor(lambda s: 0.05)
    p = {"w": jnp.ones((8, 16)), "b": jnp.ones((16,))}
    st = opt.init(p)
    assert st["slots"]["w"]["v_row"].shape == (8,)
    assert st["slots"]["w"]["v_col"].shape == (16,)
    assert st["slots"]["b"]["v"].shape == (16,)
    for _ in range(300):
        g = {"w": 2.0 * p["w"], "b": 2.0 * p["b"]}
        u, st = opt.update(g, st, p)
        p = OPT.apply_updates(p, u)
    assert float(jnp.max(jnp.abs(p["w"]))) < 5e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = OPT.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(OPT.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    lr = OPT.cosine_schedule(1e-3, 1000, warmup_steps=100)
    assert float(lr(0)) == 0.0
    assert float(lr(100)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(1000)) == pytest.approx(1e-4, rel=1e-3)
    assert float(lr(550)) < float(lr(200))


# ---------------------------------------------------------------------------
# loss / grad accumulation
# ---------------------------------------------------------------------------
def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 10), jnp.float32)
    labels = jnp.array([[1, 2, -1, -1]], jnp.int32)
    loss_sum, n = cross_entropy(logits, labels)
    assert int(n) == 2
    # uniform logits -> nll = log(10) per token (+ z-loss on lse)
    per_tok = float(loss_sum) / 2
    assert per_tok == pytest.approx(np.log(10), rel=1e-2)


def test_grad_accum_equivalent_to_full_batch():
    cfg = smoke_config("qwen3-8b")
    tr1 = build_trainer(cfg, total_steps=10, grad_accum=1, donate=False)
    tr4 = build_trainer(cfg, total_steps=10, grad_accum=4, donate=False)
    s1 = tr1.init_state(jax.random.PRNGKey(0))
    s4 = tr4.init_state(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16),
                                          0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16),
                                          0, cfg.vocab_size)}
    s1, m1 = tr1.train_step(s1, batch)
    s4, m4 = tr4.train_step(s4, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-4)
    # parameters after one step agree to fp32 accumulation tolerance
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     s1.params, s4.params)
    assert max(jax.tree.leaves(d)) < 5e-5


def test_loss_decreases_on_learnable_data():
    import dataclasses
    cfg = dataclasses.replace(smoke_config("codeqwen1.5-7b"),
                              learning_rate=1e-3)
    tr = build_trainer(cfg, total_steps=80, warmup_steps=10, donate=False)
    state = tr.init_state(jax.random.PRNGKey(0))
    pipe = SyntheticLM(cfg, seq_len=32, global_batch=8, seed=0)
    first = last = None
    for i in range(80):
        b = next(pipe)
        state, m = tr.train_step(state, {k: jnp.asarray(v)
                                         for k, v in b.items()})
        if i < 5:
            first = float(m["loss"]) if first is None else first
        last = float(m["loss"])
    assert last < first - 0.3, (first, last)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_synthetic_stream_deterministic_resume():
    cfg = smoke_config("qwen3-8b")
    a = SyntheticLM(cfg, 16, 4, seed=3)
    for _ in range(5):
        next(a)
    st = a.state()
    want = next(a)
    b = SyntheticLM(cfg, 16, 4, seed=3)
    b.restore(st)
    got = next(b)
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


def test_host_sharding_disjoint_streams():
    cfg = smoke_config("qwen3-8b")
    h0 = SyntheticLM(cfg, 16, 8, seed=0, host_index=0, num_hosts=2)
    h1 = SyntheticLM(cfg, 16, 8, seed=0, host_index=1, num_hosts=2)
    b0, b1 = next(h0), next(h1)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_memmap_corpus_roundtrip(tmp_path):
    cfg = smoke_config("qwen3-8b")
    toks = np.arange(1000, dtype=np.int32) % cfg.vocab_size
    p = tmp_path / "corpus.bin"
    toks.tofile(p)
    c = MemmapCorpus(str(p), cfg, seq_len=32, global_batch=4, seed=1)
    b = next(c)
    assert b["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_prefetcher_counts_consumed_batches():
    cfg = smoke_config("qwen3-8b")
    pf = Prefetcher(SyntheticLM(cfg, 8, 2, seed=0))
    next(pf)
    next(pf)
    st = pf.state()
    assert st["step"] == 2          # consumer view, not producer read-ahead
    pf.close()
