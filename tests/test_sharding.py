"""Sharding-rule invariants across all archs x modes (+ cache placement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs, smoke_config, SHAPES
from repro.distributed import sharding as SH
from repro.models.registry import build_model

ARCHS = list_archs()


def _axis_sizes(mesh):
    return dict(mesh.shape)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("mode", ["train", "serve"])
def test_param_specs_valid_for_full_configs(arch, mode, host_mesh):
    """Full-size configs: every spec uses each mesh axis at most once and
    only on divisible dims — so NamedSharding construction never fails."""
    cfg = get_config(arch)
    m = build_model(cfg)
    sds = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    specs = SH.param_pspecs(cfg, sds, host_mesh, mode)
    sizes = _axis_sizes(host_mesh)
    flat_sds = jax.tree.leaves(sds)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_sds) == len(flat_specs)
    n_sharded = 0
    for x, spec in zip(flat_sds, flat_specs):
        seen = set()
        for dim, entry in zip(x.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                assert a not in seen, (arch, spec)
                seen.add(a)
                assert dim % sizes[a] == 0, (arch, x.shape, spec)
            n_sharded += 1
    assert n_sharded > 0, "no parameter sharded at all"


@pytest.mark.parametrize("arch", ["qwen3-8b", "llama4-maverick-400b-a17b",
                                  "deepseek-v2-lite-16b"])
def test_train_mode_shards_ffn_and_experts(arch, host_mesh):
    cfg = get_config(arch)
    m = build_model(cfg)
    sds = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    specs = SH.param_pspecs(cfg, sds, host_mesh, "train")
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    by_name = {"/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path): s for path, s in flat}
    if cfg.moe is not None:
        e_specs = [s for n, s in by_name.items()
                   if "moe/w_gate" in n or "moe/w_down" in n]
        assert e_specs and all("model" in tuple(s) for s in e_specs), \
            "experts must shard over the model axis (EP)"
    else:
        ffn = [s for n, s in by_name.items() if "mlp/w_gate" in n]
        assert ffn and all("model" in tuple(s) for s in ffn)


def test_serve_mode_drops_fsdp_unless_opted_in(host_mesh):
    cfg = get_config("qwen3-8b")
    m = build_model(cfg)
    sds = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    specs = SH.param_pspecs(cfg, sds, host_mesh, "serve")
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert "data" not in tuple(s), s
    # llama4 opts in: weights stay data-sharded in serving
    cfg4 = get_config("llama4-maverick-400b-a17b")
    m4 = build_model(cfg4)
    sds4 = jax.eval_shape(m4.init, jax.random.PRNGKey(0))
    specs4 = SH.param_pspecs(cfg4, sds4, host_mesh, "serve")
    assert any("data" in tuple(s) for s in
               jax.tree.leaves(specs4, is_leaf=lambda x: isinstance(x, P)))


def test_batch_axes_fallback(host_mesh, pod_mesh):
    assert SH.batch_axes(host_mesh, 8) == "data"
    assert SH.batch_axes(host_mesh, 7) is None       # indivisible
    assert SH.batch_axes(pod_mesh, 8) == ("pod", "data")
    assert SH.batch_axes(pod_mesh, 2) == "data"      # falls back


def test_cache_pspecs_head_or_length_over_model(host_mesh):
    """kv=8 over model=4 -> heads shard; kv=2 over model=4 -> length
    shards instead (the qwen3-on-16-way case, scaled down)."""
    cfg = get_config("qwen3-8b")          # kv 8 % 4 == 0 on host mesh
    m = build_model(cfg)
    sds = jax.eval_shape(lambda: m.init_cache(8, 64))
    specs = SH.cache_pspecs(cfg, sds, host_mesh)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    kv = [s for path, s in flat
          if str(path[-1].key) in ("k", "v")]
    assert all("model" in tuple(s) for s in kv)
    assert all("data" in tuple(s) for s in kv)

    import dataclasses
    cfg2 = dataclasses.replace(cfg, num_kv_heads=2)   # 2 % 4 != 0
    m2 = build_model(cfg2)
    sds2 = jax.eval_shape(lambda: m2.init_cache(8, 64))
    specs2 = SH.cache_pspecs(cfg2, sds2, host_mesh)
    flat2 = jax.tree_util.tree_flatten_with_path(
        specs2, is_leaf=lambda x: isinstance(x, P))[0]
    for path, s in flat2:
        if str(path[-1].key) in ("k", "v"):
            t = tuple(s)
            assert "model" in t, s
            # length dim (index ndim-3) carries it, not the head dim
            assert t[-3] == "model"


def test_cache_pspecs_long_context_shards_length_over_data(host_mesh):
    cfg = get_config("gemma2-27b")
    m = build_model(cfg)
    sds = jax.eval_shape(lambda: m.init_cache(1, 4096 * 4))
    specs = SH.cache_pspecs(cfg, sds, host_mesh, shard_length=True)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    global_kv = [s for path, s in flat
                 if str(path[-1].key) in ("k", "v")
                 and None is not s]
    assert any("data" in tuple(s) for s in global_kv)


def test_constrain_drops_indivisible(host_mesh):
    x = jnp.zeros((6, 5))
    y = SH.constrain(x, host_mesh, "data", "model")   # 6%2==0, 5%4!=0
    assert y.sharding.spec == P("data", None)
