"""OSMOSIS serving engine: fairness, quotas, watchdog, isolation (R1-R6)."""
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.admission import AdmissionError
from repro.core.events import EventKind
from repro.core.slo import SLOPolicy
from repro.serving.engine import (Engine, EngineConfig, ModelExecutor,
                                  NullExecutor)
from repro.serving.request import Request, RequestStatus


def _cfg(**kw):
    base = dict(max_slots=8, max_len=256, prefill_chunk=32,
                prefill_slots_per_step=2, max_tenants=4)
    base.update(kw)
    return EngineConfig(**base)


def _flood(eng, tenant, n, plen, new, vocab=90, seed=0):
    rng = np.random.RandomState(seed + tenant)
    for _ in range(n):
        eng.submit(Request(tenant,
                           rng.randint(1, vocab, size=plen).astype(np.int32),
                           max_new_tokens=new))


# ---------------------------------------------------------------------------
# R1: fair slot allocation, cost-invariant
# ---------------------------------------------------------------------------
def _run_cv(scheduler):
    eng = Engine(_cfg(scheduler=scheduler, kv_overcommit=2.0))
    eng.create_ectx(0, SLOPolicy(kv_quota_tokens=256 * 8))
    eng.create_ectx(1, SLOPolicy(kv_quota_tokens=256 * 8))
    _flood(eng, 0, 24, plen=160, new=48)   # congestor: 4x the work/request
    _flood(eng, 1, 24, plen=16, new=16)    # victim
    eng.run_until_idle()
    return eng.metrics()


def test_wlbvt_fairer_than_rr_serving():
    m_rr = _run_cv("rr")
    m_wl = _run_cv("wlbvt")
    assert m_wl["jain_timeavg"] >= m_rr["jain_timeavg"] - 1e-9
    assert m_wl["jain_timeavg"] > 0.93


def test_victim_fct_protected_under_wlbvt():
    m = _run_cv("wlbvt")
    assert m["tenants"][1]["mean_fct"] < m["tenants"][0]["mean_fct"] / 2


def test_priority_gives_proportional_slots():
    # priorities 3:1 on 8 slots -> WLBVT caps ceil(8*3/4)=6 / ceil(8/4)=2,
    # summing exactly to the slot count: stable [6, 2] split
    eng = Engine(_cfg(kv_overcommit=2.0))
    eng.create_ectx(0, SLOPolicy(priority=3.0, kv_quota_tokens=256 * 8))
    eng.create_ectx(1, SLOPolicy(priority=1.0, kv_quota_tokens=256 * 8))
    _flood(eng, 0, 40, plen=64, new=32)
    _flood(eng, 1, 40, plen=64, new=32)
    occ = np.zeros(2)
    for _ in range(250):
        eng.step()
        if (eng.st.queue_len[:2] > 0).all():   # measure under contention
            occ += eng.st.cur_occup[:2]
    assert occ[0] / max(occ[1], 1) == pytest.approx(3.0, rel=0.3)


def test_work_conservation_single_tenant_takes_all_slots():
    eng = Engine(_cfg(kv_overcommit=2.0))
    eng.create_ectx(0, SLOPolicy(kv_quota_tokens=256 * 8))
    eng.create_ectx(1, SLOPolicy(kv_quota_tokens=256 * 8))
    _flood(eng, 0, 20, plen=32, new=64)
    for _ in range(30):
        eng.step()
    assert eng.st.cur_occup[0] == eng.cfg.max_slots


# ---------------------------------------------------------------------------
# R3: static KV quotas / admission
# ---------------------------------------------------------------------------
def test_kv_quota_caps_concurrent_slots():
    eng = Engine(_cfg())
    eng.create_ectx(0, SLOPolicy(kv_quota_tokens=256 * 2))   # 2 slots max
    _flood(eng, 0, 20, plen=32, new=64)
    for _ in range(30):
        eng.step()
    assert eng.st.cur_occup[0] <= 2


def test_admission_rejects_pool_exhaustion():
    eng = Engine(_cfg())
    eng.create_ectx(0, SLOPolicy(kv_quota_tokens=256 * 7))
    with pytest.raises(AdmissionError):
        eng.create_ectx(1, SLOPolicy(kv_quota_tokens=256 * 2))


def test_oversized_request_rejected_with_event():
    eng = Engine(_cfg())
    eng.create_ectx(0, SLOPolicy(kv_quota_tokens=256 * 2))
    r = eng.submit(Request(0, np.ones(250, np.int32), max_new_tokens=32))
    assert r.status == RequestStatus.REJECTED
    kinds = {e.kind for e in eng.poll_events(0)}
    assert EventKind.MEMORY_FAULT in kinds


# ---------------------------------------------------------------------------
# watchdog (kernel budget) + EQ (R5)
# ---------------------------------------------------------------------------
def test_watchdog_kills_runaway_request():
    eng = Engine(_cfg())
    eng.create_ectx(0, SLOPolicy(kv_quota_tokens=256 * 8,
                                 kernel_cycle_limit=40))
    eng.submit(Request(0, np.ones(16, np.int32), max_new_tokens=200))
    eng.run_until_idle()
    assert eng.metrics()["tenants"][0]["killed"] == 1
    assert EventKind.REQUEST_KILLED in {e.kind for e in eng.poll_events(0)}


# ---------------------------------------------------------------------------
# R2: chunked prefill prevents HoL blocking of decode tenants
# ---------------------------------------------------------------------------
def test_chunked_prefill_protects_decoder_latency():
    """A tenant decoding short requests must keep making progress while a
    32x longer prefill streams through (fragmentation, paper Fig. 10)."""
    eng = Engine(_cfg(max_len=2048, prefill_chunk=64))
    eng.create_ectx(0, SLOPolicy(kv_quota_tokens=2048 * 4))
    eng.create_ectx(1, SLOPolicy(kv_quota_tokens=2048 * 4))
    _flood(eng, 0, 4, plen=1024, new=8)    # heavy prefill congestor
    _flood(eng, 1, 8, plen=8, new=8)       # interactive victim
    eng.run_until_idle()
    m = eng.metrics()
    assert m["tenants"][1]["mean_fct"] < 60
    assert m["tenants"][0]["done"] == 4


def test_fifo_arbiter_is_worse_for_victim():
    def run(arb):
        eng = Engine(_cfg(max_len=2048, prefill_chunk=64,
                          prefill_slots_per_step=1, arbiter=arb))
        eng.create_ectx(0, SLOPolicy(kv_quota_tokens=2048 * 6))
        eng.create_ectx(1, SLOPolicy(kv_quota_tokens=2048 * 2))
        _flood(eng, 0, 6, plen=1024, new=4)
        _flood(eng, 1, 6, plen=64, new=4)
        eng.run_until_idle()
        return eng.metrics()["tenants"][1]["mean_fct"]
    assert run("fifo") > run("dwrr") * 1.2


# ---------------------------------------------------------------------------
# R3 isolation at the cache level: slot reuse must not leak KV state
# ---------------------------------------------------------------------------
def test_slot_reuse_does_not_leak_kv_between_tenants():
    cfg = smoke_config("qwen3-8b")
    ecfg = _cfg(max_slots=2, max_len=64, prefill_chunk=16, max_tenants=4,
                kv_overcommit=2.0)

    def generate(polluted: bool):
        exe = ModelExecutor(cfg, ecfg, rng_seed=0)
        eng = Engine(ecfg, executor=exe)
        eng.create_ectx(0, SLOPolicy(kv_quota_tokens=64 * 2))
        if polluted:   # run a different tenant's request through the slots
            eng.create_ectx(1, SLOPolicy(kv_quota_tokens=64 * 2))
            eng.submit(Request(1, np.full(30, 7, np.int32),
                               max_new_tokens=10))
            eng.run_until_idle()
        eng.submit(Request(0, np.arange(1, 13, dtype=np.int32),
                           max_new_tokens=8))
        eng.run_until_idle()
        done = [r for r in eng.done if r.tenant_id == 0]
        return done[0].generated

    assert generate(False) == generate(True)


def test_submit_rejects_request_that_cannot_fit_cycle_budget():
    """Watchdog admission check: a prompt that alone blows the kernel
    cycle budget would be killed at its first decode token — it must be
    rejected at submit with a CYCLE_BUDGET_EXCEEDED event, not admitted
    and have prefill burned on it."""
    eng = Engine(_cfg())
    eng.create_ectx(0, SLOPolicy(kv_quota_tokens=256 * 8,
                                 kernel_cycle_limit=40))
    r = eng.submit(Request(0, np.ones(40, np.int32), max_new_tokens=8))
    assert r.status == RequestStatus.REJECTED
    assert EventKind.CYCLE_BUDGET_EXCEEDED in {
        e.kind for e in eng.poll_events(0)}
    # boundary: a prompt that can still emit >= 1 token is admitted (the
    # runtime watchdog takes over from there)
    r2 = eng.submit(Request(0, np.ones(39, np.int32), max_new_tokens=8))
    assert r2.status == RequestStatus.QUEUED
    eng.run_until_idle()
    assert eng.metrics()["tenants"][0]["killed"] == 1   # r2, at token 2


def test_destroy_ectx_rejects_queued_and_retires_event_queue():
    eng = Engine(_cfg())
    eng.create_ectx(0, SLOPolicy(kv_quota_tokens=256 * 8))
    _flood(eng, 0, 12, plen=64, new=64)     # 8 slots -> 4 stay queued
    for _ in range(5):
        eng.step()
    queued = [r for q in [eng.queues[0]] for r in q]
    assert queued, "scenario must leave requests queued"
    events = eng.destroy_ectx(0)
    assert all(r.status == RequestStatus.REJECTED for r in queued)
    assert 0 not in eng.eq, "EventQueue entry must not leak"
    assert 0 not in eng.queues
    kinds = {e.kind for e in events}
    assert EventKind.EVICTED in kinds
    assert EventKind.REQUEST_KILLED in kinds    # the in-flight ones
    evicted_rids = {int(e.detail.split()[0].split("=")[1])
                    for e in events if e.kind == EventKind.EVICTED
                    and e.detail}
    assert evicted_rids == {r.rid for r in queued}


class _CountingExecutor(NullExecutor):
    def __init__(self, cfg):
        super().__init__(cfg)
        self.reset_calls = []

    def reset(self, keep):
        self.reset_calls.append(np.asarray(keep).copy())


def test_assign_slots_batches_cache_reset_into_one_call():
    """Filling S slots in a step must invalidate them in ONE reset call
    (one XLA invocation), with every assigned slot in the mask."""
    exe = _CountingExecutor(_cfg())
    eng = Engine(_cfg(kv_overcommit=2.0), executor=exe)
    eng.create_ectx(0, SLOPolicy(kv_quota_tokens=256 * 8))
    eng.create_ectx(1, SLOPolicy(kv_quota_tokens=256 * 8))
    _flood(eng, 0, 10, plen=32, new=8)
    _flood(eng, 1, 10, plen=32, new=8)
    eng.step()
    assert len(exe.reset_calls) == 1
    keep = exe.reset_calls[0]
    assigned = np.array([r is not None for r in eng.slot_req])
    assert assigned.sum() == eng.cfg.max_slots
    assert (~keep == assigned).all()


def test_default_config_scales_to_128_tenants():
    """The default FMQ table now has 128-tenant headroom and the batched
    scheduler serves the full population end-to-end."""
    cfg = EngineConfig(kv_overcommit=16.0)   # pool: 8*512*16 = 128 quotas
    assert cfg.max_tenants == 128
    eng = Engine(cfg)
    for t in range(128):
        eng.create_ectx(t, SLOPolicy(kv_quota_tokens=512))
    rng = np.random.RandomState(0)
    for t in range(0, 128, 7):
        eng.submit(Request(t, rng.randint(1, 90, 12).astype(np.int32),
                           max_new_tokens=4))
    eng.run_until_idle()
    m = eng.metrics()
    done = sum(d["done"] for d in m["tenants"].values())
    assert done == len(range(0, 128, 7))


def test_destroy_ectx_frees_quota_and_kills_inflight():
    eng = Engine(_cfg())
    eng.create_ectx(0, SLOPolicy(kv_quota_tokens=256 * 8))
    _flood(eng, 0, 4, plen=64, new=64)
    for _ in range(10):
        eng.step()
    eng.destroy_ectx(0)
    assert eng.st.cur_occup[0] == 0
    # pool is free again
    eng.create_ectx(2, SLOPolicy(kv_quota_tokens=256 * 8))
