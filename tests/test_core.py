"""OSMOSIS core-mechanism tests: fragmentation, admission, matching, EQ."""
import numpy as np
import pytest
from _prop import given, settings, st  # hypothesis or seeded fallback

from repro.core import (AdmissionError, Event, EventKind, EventQueue, FMQ,
                        FragmentationPolicy, MatchingEngine, MatchRule,
                        PacketDescriptor, SegmentAllocator, ECTX, SLOPolicy,
                        fragment_tokens, fragment_transfer)
from repro.core.accounting import (TimeAveragedJain, jain_fairness,
                                   weighted_jain)


# ---------------------------------------------------------------------------
# fragmentation (paper §6.2)
# ---------------------------------------------------------------------------
@settings(max_examples=200, deadline=None)
@given(nbytes=st.integers(1, 1 << 20), frag=st.integers(16, 8192))
def test_fragment_transfer_invariants(nbytes, frag):
    pol = FragmentationPolicy(mode="hardware", fragment_bytes=frag)
    frags = fragment_transfer(pol, tenant=0, transfer_id=1, nbytes=nbytes)
    assert sum(f.nbytes for f in frags) == nbytes
    assert all(f.nbytes <= frag for f in frags)
    assert all(f.nbytes > 0 for f in frags)
    assert [f.seq for f in frags] == list(range(len(frags)))
    assert [f.last for f in frags] == [False] * (len(frags) - 1) + [True]


@settings(max_examples=100, deadline=None)
@given(total=st.integers(1, 100_000), chunk=st.integers(1, 4096))
def test_fragment_tokens_partition(total, chunk):
    parts = list(fragment_tokens(total, chunk))
    assert sum(n for _, n in parts) == total
    offs = [o for o, _ in parts]
    assert offs == sorted(offs) and offs[0] == 0
    assert all(n <= chunk for _, n in parts)


def test_fragmentation_off_is_identity():
    pol = FragmentationPolicy(mode="off", fragment_bytes=64)
    frags = fragment_transfer(pol, 0, 0, nbytes=10_000)
    assert len(frags) == 1 and frags[0].nbytes == 10_000


# ---------------------------------------------------------------------------
# static memory admission (R3)
# ---------------------------------------------------------------------------
@settings(max_examples=100, deadline=None)
@given(st.data())
def test_allocator_segments_never_overlap(data):
    pool = data.draw(st.integers(64, 1 << 16))
    alloc = SegmentAllocator(pool_size=pool)
    segs = {}
    for t in range(data.draw(st.integers(1, 10))):
        size = data.draw(st.integers(1, pool // 2))
        try:
            off, sz = alloc.allocate(t, size)
        except AdmissionError:
            continue
        segs[t] = (off, sz)
        assert off + sz <= pool
    items = sorted(segs.values())
    for (o1, s1), (o2, _) in zip(items, items[1:]):
        assert o1 + s1 <= o2, "segments overlap"


def test_allocator_rejects_over_quota_and_bounds_checks():
    alloc = SegmentAllocator(pool_size=1024)
    alloc.allocate(0, 512)
    alloc.allocate(1, 512)
    with pytest.raises(AdmissionError):
        alloc.allocate(2, 1)
    assert alloc.check_access(0, 0, 512)
    assert not alloc.check_access(0, 0, 513)      # PMP: out of segment
    assert not alloc.check_access(2, 0, 1)        # PMP: no segment
    alloc.free(0)
    alloc.allocate(2, 256)                        # reuse freed space


# ---------------------------------------------------------------------------
# matching engine / FMQ / EQ
# ---------------------------------------------------------------------------
def test_matching_three_tuple():
    eng = MatchingEngine()
    eng.install(MatchRule(dst_ip=10, dst_port=80), fmq_index=3)
    eng.install(MatchRule(dst_ip=10), fmq_index=4)
    assert eng.match({"dst_ip": 10, "dst_port": 80}) == 3
    assert eng.match({"dst_ip": 10, "dst_port": 81}) == 4
    assert eng.match({"dst_ip": 11}) == -1  # conventional NIC path


def test_fmq_overflow_drops():
    e = ECTX(0, "t", SLOPolicy())
    q = FMQ(index=0, ectx=e, capacity=2)
    assert q.push(PacketDescriptor(0, 64, 0.0))
    assert q.push(PacketDescriptor(0, 64, 1.0))
    assert not q.push(PacketDescriptor(0, 64, 2.0))
    assert q.drops == 1 and len(q) == 2


def test_event_queue_bounded():
    eq = EventQueue(capacity=2)
    for i in range(4):
        eq.push(Event(0, EventKind.KERNEL_ERROR, float(i)))
    assert eq.dropped == 2
    evs = eq.drain()
    assert len(evs) == 2 and evs[-1].time == 3.0


def test_slo_rejects_nonpositive_priority():
    with pytest.raises(ValueError):
        SLOPolicy(priority=0.0)


# ---------------------------------------------------------------------------
# fairness metrics
# ---------------------------------------------------------------------------
def test_jain_bounds_and_known_values():
    assert jain_fairness([1, 1, 1, 1]) == pytest.approx(1.0)
    assert jain_fairness([1, 0, 0, 0]) == pytest.approx(0.25)
    # paper Fig. 4 situation: congestor gets 2x the PUs
    assert jain_fairness([2, 1]) == pytest.approx(0.9)


def test_weighted_jain_priority_adjusts():
    # 2x service at 2x priority is perfectly fair
    assert weighted_jain([2, 1], [2, 1]) == pytest.approx(1.0)


def test_time_averaged_jain():
    j = TimeAveragedJain()
    j.update([1, 1], dt=1.0)
    j.update([1, 0], dt=1.0)
    assert j.value == pytest.approx((1.0 + 0.5) / 2)
