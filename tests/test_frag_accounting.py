"""Dedicated coverage for core/fragmentation.py and core/accounting.py
(ISSUE 2 satellite): last-fragment remainders, mode="off", per-fragment
overhead accounting, and Jain edge cases."""
import numpy as np
import pytest

from repro.core.accounting import (FCTTracker, TimeAveragedJain,
                                   jain_fairness, weighted_jain)
from repro.core.fragmentation import (FragmentationPolicy, fragment_tokens,
                                      fragment_transfer)


# ---------------------------------------------------------------------------
# fragmentation
# ---------------------------------------------------------------------------
def test_last_fragment_carries_remainder():
    pol = FragmentationPolicy(mode="hardware", fragment_bytes=512)
    frags = fragment_transfer(pol, tenant=1, transfer_id=7, nbytes=1200)
    assert [f.nbytes for f in frags] == [512, 512, 176]
    assert [f.last for f in frags] == [False, False, True]
    assert [f.seq for f in frags] == [0, 1, 2]
    assert all(f.tenant == 1 and f.transfer_id == 7 for f in frags)


def test_exact_multiple_has_no_empty_tail():
    pol = FragmentationPolicy(mode="hardware", fragment_bytes=512)
    frags = fragment_transfer(pol, 0, 0, nbytes=1024)
    assert [f.nbytes for f in frags] == [512, 512]
    assert frags[-1].last


def test_mode_off_never_splits():
    pol = FragmentationPolicy(mode="off", fragment_bytes=64)
    for n in (1, 64, 65, 1 << 20):
        frags = fragment_transfer(pol, 0, 0, nbytes=n)
        assert len(frags) == 1
        assert frags[0].nbytes == n and frags[0].last and frags[0].seq == 0
    assert pol.per_fragment_overhead == 0


def test_per_fragment_overhead_by_mode():
    sw = FragmentationPolicy(mode="software", sw_overhead_cycles=95,
                             hw_overhead_cycles=2)
    hw = FragmentationPolicy(mode="hardware", sw_overhead_cycles=95,
                             hw_overhead_cycles=2)
    assert sw.per_fragment_overhead == 95     # PU issue cost per fragment
    assert hw.per_fragment_overhead == 2      # bus re-arbitration constant


def test_sim_charges_software_overhead_per_fragment():
    """A software-fragmented transfer pays sw_overhead_cycles * nfrags on
    the PU: kernel completion time grows by exactly that."""
    from repro.configs.osmosis_pspin import PSPIN
    from repro.sim.engine import Simulator
    from repro.sim.scenarios import make_tenants
    from repro.sim.traffic import TracePacket
    from repro.sim.workloads import WorkloadModel
    wl = WorkloadModel("w", 40, 0.0, io_kind="dma_write",
                       io_fixed_bytes=2048)
    times = {}
    for mode in ("off", "software"):
        pol = FragmentationPolicy(mode=mode, fragment_bytes=512,
                                  sw_overhead_cycles=95)
        sim = Simulator(make_tenants([wl]), frag=pol)
        res = sim.run([TracePacket(0.0, 0, 256)])
        times[mode] = res.stats[0].kernel_times[0]
    nfrag = 2048 // 512
    # compute phase grows by 95 * 4; IO service time is unchanged (same
    # total bytes over the same bus) up to per-fragment arbitration
    assert times["software"] - times["off"] == pytest.approx(95 * nfrag)


def test_fragment_tokens_last_chunk_remainder():
    assert list(fragment_tokens(100, 32)) == [(0, 32), (32, 32), (64, 32),
                                              (96, 4)]
    assert list(fragment_tokens(5, 32)) == [(0, 5)]


# ---------------------------------------------------------------------------
# accounting (Jain edge cases)
# ---------------------------------------------------------------------------
def test_jain_empty_and_all_zero_are_neutral():
    assert jain_fairness([]) == 1.0
    assert jain_fairness([0.0, 0.0, 0.0]) == 1.0
    assert jain_fairness([np.nan, np.inf]) == 1.0   # non-finite filtered


def test_jain_single_tenant_is_perfect():
    assert jain_fairness([42.0]) == pytest.approx(1.0)


def test_jain_scale_invariant():
    x = [1.0, 2.0, 3.0]
    assert jain_fairness(x) == pytest.approx(
        jain_fairness([1e6 * v for v in x]))


def test_weighted_jain_edge_cases():
    # zero weight guarded (no division blowup), empty weighted input
    assert weighted_jain([1.0, 0.0], [1.0, 0.0]) < 1.0
    assert weighted_jain([], []) == 1.0
    # proportional service at 4:2:1 weights is perfectly fair
    assert weighted_jain([4, 2, 1], [4, 2, 1]) == pytest.approx(1.0)


def test_time_averaged_jain_weighted_updates():
    j = TimeAveragedJain()
    j.update([2, 1], dt=2.0, weights=[2, 1])   # fair under weights
    j.update([1, 1], dt=1.0, weights=[2, 1])   # unfair under weights
    assert j.value == pytest.approx(
        (1.0 * 2.0 + jain_fairness([0.5, 1.0]) * 1.0) / 3.0)
    assert TimeAveragedJain().value == 1.0     # no samples: neutral


def test_fct_tracker_flows_and_percentiles():
    tr = FCTTracker()
    tr.flow_started(0, 10.0)
    tr.flow_started(0, 12.0)                   # first start wins
    tr.flow_finished(0, 50.0)
    assert tr.fct[0] == 40.0
    tr.flow_finished(1, 5.0)                   # never started: ignored
    assert 1 not in tr.fct
    for v in (1.0, 2.0, 3.0, 4.0):
        tr.kernel_done(2, v)
    assert tr.percentile(2, 50) == pytest.approx(2.5)
    assert tr.percentile(9, 99) == 0.0         # unknown tenant