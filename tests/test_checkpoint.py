"""Sharded checkpointing: roundtrip, elastic resharding, atomicity, GC."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.training import checkpoint as CKPT


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "w": jax.random.normal(k, (16, 8), jnp.float32),
        "nested": {"b": jnp.arange(8, dtype=jnp.int32),
                   "scalar": jnp.float32(3.5)},
        "lst": [jnp.ones((4,)), jnp.zeros((2, 2))],
    }


def test_roundtrip_unsharded(tmp_path):
    state = _tree()
    CKPT.save(state, str(tmp_path), step=7)
    assert CKPT.latest_step(str(tmp_path)) == 7
    loaded, extra = CKPT.load(str(tmp_path), jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_extra_payload_roundtrip(tmp_path):
    CKPT.save(_tree(), str(tmp_path), step=1,
              extra={"step": 1, "data": {"kind": "synthetic", "step": 5,
                                         "seed": 0}})
    _, extra = CKPT.load(str(tmp_path), jax.eval_shape(_tree))
    assert extra["data"]["step"] == 5


def test_sharded_save_and_elastic_reshard(tmp_path, host_mesh):
    """Save on (data=2, model=4); restore onto a different layout."""
    w = jax.device_put(
        jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
        NamedSharding(host_mesh, P("data", "model")))
    state = {"w": w}
    CKPT.save(state, str(tmp_path), step=3)

    # (a) restore unsharded
    loaded, _ = CKPT.load(str(tmp_path), jax.eval_shape(lambda: state))
    np.testing.assert_array_equal(np.asarray(loaded["w"]),
                                  np.asarray(w))
    # (b) restore with TRANSPOSED axis assignment (elastic reshard)
    tgt = NamedSharding(host_mesh, P("model", "data"))
    loaded2, _ = CKPT.load(str(tmp_path), state,
                           shardings={"w": tgt})
    np.testing.assert_array_equal(np.asarray(loaded2["w"]), np.asarray(w))
    assert loaded2["w"].sharding.spec == P("model", "data")


def test_gc_keeps_last_k(tmp_path):
    for s in (1, 2, 3, 4):
        CKPT.save(_tree(), str(tmp_path), step=s, keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]
    assert CKPT.latest_step(str(tmp_path)) == 4


def test_commit_is_atomic_no_partial_dirs(tmp_path):
    CKPT.save(_tree(), str(tmp_path), step=1)
    names = os.listdir(tmp_path)
    assert not any(n.endswith(".tmp0") for n in names)
    assert "LATEST" in names


def test_async_checkpointer_snapshot_consistency(tmp_path):
    """The async writer must snapshot state at save() time, not at write
    time — mutating the live state afterwards must not corrupt the save."""
    ck = CKPT.AsyncCheckpointer(str(tmp_path))
    state = {"w": jnp.ones((32,))}
    ck.save(state, 1, extra={"step": 1})
    state["w"] = state["w"] * 0.0     # mutate after scheduling
    ck.wait()
    loaded, _ = CKPT.load(str(tmp_path), jax.eval_shape(lambda: state))
    np.testing.assert_array_equal(np.asarray(loaded["w"]), np.ones((32,)))


def test_load_rejects_shape_mismatch(tmp_path):
    CKPT.save({"w": jnp.ones((4, 4))}, str(tmp_path), step=1)
    with pytest.raises(ValueError):
        CKPT.load(str(tmp_path), {"w": jnp.ones((4, 5))})


def test_trainstate_roundtrip_with_optimizer(tmp_path):
    from repro.configs import smoke_config
    from repro.training.trainer import build_trainer
    cfg = smoke_config("mamba2-370m")
    tr = build_trainer(cfg, total_steps=10, donate=False)
    state = tr.init_state(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    state, _ = tr.train_step(state, batch)
    CKPT.save(state, str(tmp_path), step=1)
    restored, _ = CKPT.load(str(tmp_path), jax.eval_shape(lambda: state))
    # continuing training from the restored state is bit-identical
    s_a, m_a = tr.train_step(state, batch)
    s_b, m_b = tr.train_step(restored, batch)
    assert float(m_a["loss"]) == float(m_b["loss"])
