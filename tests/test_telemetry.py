"""Telemetry plane + closed-loop QoS controller (DESIGN.md §6, ISSUE 2).

Covers: collector kernels, numpy<->jnp recording parity (kernel level
and engine level), signal derivation, AIMD/hysteresis controller unit
behavior, both engines' event wiring (ECN, lifetime budget,
backpressure), the closed-loop congestor-vs-victim acceptance demo, and
the <3% recording-overhead budget.
"""
import numpy as np
import pytest

from repro.core.events import EventKind
from repro.core.slo import SLOPolicy
from repro.telemetry import (C_IDX, G_IDX, GAUGES, QoSConfig, QoSController,
                             SignalFrame, Telemetry, bucket_index,
                             compute_signals, create_state, hist_add,
                             hist_quantile, record_step, record_window,
                             ring_mean, tenant_report, wlbvt_service_debt)


# ---------------------------------------------------------------------------
# collector kernels
# ---------------------------------------------------------------------------
def test_bucket_index_log_spacing():
    idx = bucket_index(np.array([0.5, 1.0, 2.0, 3.0, 1024.0, 1e12]), 32, np)
    assert idx.tolist() == [0, 0, 1, 1, 10, 31]   # clipped at both ends


def test_hist_quantile_recovers_percentiles():
    rng = np.random.RandomState(0)
    vals = rng.lognormal(5.0, 1.0, size=4000)
    hist = np.zeros((1, 32))
    for v in vals:
        hist = hist_add(hist, np.array([v]), np.array([True]), np)
    assert hist.sum() == len(vals)
    for q in (0.5, 0.99):
        est = hist_quantile(hist, q, np)[0]
        exact = np.percentile(vals, 100 * q)
        # log2 buckets: estimate within one bucket (2x) of the truth
        assert exact / 2 <= est <= exact * 2


def test_ring_mean_ignores_unwritten_slots():
    st = create_state(2, window=4, xp=np)
    st = record_window(st, np.full((len(GAUGES), 2), 3.0), np)
    st = record_window(st, np.full((len(GAUGES), 2), 5.0), np)
    m = ring_mean(st["ring"], int(st["ptr"]), np)
    assert np.allclose(m, 4.0)                    # not diluted by zeros


def test_telemetry_wrapper_stages_and_commits():
    tel = Telemetry(3)
    tel.inc("arrivals", 0)
    tel.inc("arrivals", 0)
    tel.inc("bytes_in", 1, 512)
    tel.lat(0, 12.0)
    tel.lat(0, 100.0)                             # same tenant, two samples
    tel.commit()
    snap = tel.snapshot()
    assert snap["counts"][0, C_IDX["arrivals"]] == 2
    assert snap["counts"][1, C_IDX["bytes_in"]] == 512
    assert snap["hist"][0].sum() == 2
    assert tel.counter("arrivals")[0] == 2


# ---------------------------------------------------------------------------
# numpy <-> jnp parity (acceptance: recording identical on both backends)
# ---------------------------------------------------------------------------
# avoid exact bucket-boundary latencies only in the *random* sweep; the
# deterministic engine test below covers integer (incl. power-of-2) values
_LAT_POOL = [3.0, 5.0, 7.0, 12.0, 50.0, 100.0, 999.0, 12345.0]


def test_record_step_parity_numpy_vs_jit():
    import jax
    import jax.numpy as jnp
    rng = np.random.RandomState(1)
    T = 8
    st_np = create_state(T, xp=np)
    st_j = create_state(T, xp=jnp)
    step_j = jax.jit(lambda s, c, v, m: record_step(s, c, v, m, jnp))
    win_j = jax.jit(lambda s, g: record_window(s, g, jnp))
    for _ in range(20):
        ci = rng.randint(0, 5, size=st_np["counts"].shape).astype(float)
        vals = np.array([_LAT_POOL[i] for i in
                         rng.randint(0, len(_LAT_POOL), T)])
        mask = rng.rand(T) < 0.6
        g = rng.randint(0, 100, size=(len(GAUGES), T)).astype(float)
        st_np = record_step(st_np, ci, vals, mask, np)
        st_np = record_window(st_np, g, np)
        st_j = step_j(st_j, ci, vals, mask)
        st_j = win_j(st_j, g)
    assert np.array_equal(st_np["counts"], np.asarray(st_j["counts"]))
    assert np.array_equal(st_np["hist"], np.asarray(st_j["hist"]))
    assert np.array_equal(st_np["ring"], np.asarray(st_j["ring"]))
    assert int(st_np["ptr"]) == int(st_j["ptr"])


def test_telemetry_wrapper_parity_numpy_vs_jnp_backend():
    """The numpy in-place fast path and the jitted jnp path must commit
    identical state for the same staged event sequence."""
    tels = [Telemetry(6, backend=b) for b in ("numpy", "jnp")]
    for step in range(12):
        for tel in tels:
            rng2 = np.random.RandomState(100 + step)
            for t in range(6):
                n = rng2.randint(0, 3)
                for _ in range(n):
                    tel.inc("arrivals", t)
                    tel.lat(t, _LAT_POOL[rng2.randint(0, len(_LAT_POOL))])
                tel.inc("tokens", t, float(rng2.randint(0, 64)))
            tel.commit()
            tel.commit_window(np.full((len(GAUGES), 6), float(step)))
    s_np, s_j = tels[0].snapshot(), tels[1].snapshot()
    assert np.array_equal(s_np["counts"], s_j["counts"])
    assert np.array_equal(s_np["hist"], s_j["hist"])
    assert np.array_equal(s_np["ring"], s_j["ring"])


def test_engine_telemetry_parity_under_jit():
    """End-to-end: the serving engine records the same telemetry whether
    commits run eagerly on numpy or under jax.jit (jnp backend)."""
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.request import Request

    def run(backend):
        ecfg = EngineConfig(max_slots=4, max_len=128, prefill_chunk=32,
                            max_tenants=4, kv_overcommit=2.0,
                            telemetry_backend=backend)
        eng = Engine(ecfg)
        for t in range(2):
            eng.create_ectx(t, SLOPolicy(kv_quota_tokens=128 * 4))
        rng = np.random.RandomState(0)
        for i in range(12):
            t = i % 2
            plen = 40 if t == 0 else 8
            eng.submit(Request(t, rng.randint(1, 90, plen).astype(np.int32),
                               max_new_tokens=16 if t == 0 else 4))
        eng.run_until_idle()
        return eng.tel.snapshot()

    s_np, s_j = run("numpy"), run("jnp")
    assert np.array_equal(s_np["counts"], s_j["counts"])
    assert np.array_equal(s_np["hist"], s_j["hist"])
    # gauges include fp ratios (kv pressure): fp32 vs fp64 tolerance
    assert np.allclose(s_np["ring"], s_j["ring"], rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# signals
# ---------------------------------------------------------------------------
def test_wlbvt_service_debt_sign():
    # tenant 0 got 2x the normalized service of tenant 1
    debt = wlbvt_service_debt(total_occup=[200.0, 100.0], bvt=[100.0, 100.0],
                              prio=[1.0, 1.0])
    assert debt[0] < 0 < debt[1]                  # 1 is owed service
    assert abs(debt.sum()) < 1e-9


def test_compute_signals_baseline_differencing():
    tel = Telemetry(2)
    tel.inc("arrivals", 0, 10)
    tel.inc("ecn_marks", 0, 5)
    tel.lat(0, 1000.0)
    tel.commit()
    base = tel.snapshot()
    tel.inc("arrivals", 0, 10)                    # clean second interval
    tel.lat(0, 3.0)
    tel.commit()
    kw = dict(prio=np.ones(2), total_occup=np.zeros(2), bvt=np.ones(2))
    cum = compute_signals(tel, **kw)
    itv = compute_signals(tel, baseline=base, **kw)
    assert cum.ecn_rate[0] == pytest.approx(0.25)  # 5 / 20 lifetime
    assert itv.ecn_rate[0] == pytest.approx(0.0)   # interval only
    assert itv.p99[0] < cum.p99[0]                 # old slow sample excluded


# ---------------------------------------------------------------------------
# controller
# ---------------------------------------------------------------------------
def _frame(p99, ecn=0.0, kv=0.0, T=2):
    z = np.zeros(T)
    return SignalFrame(p50=np.asarray(p99, float) / 2,
                       p99=np.asarray(p99, float),
                       ecn_rate=z + ecn, drop_rate=z,
                       service_debt=z, kv_pressure=z + kv,
                       occupancy_mean=z + 1, queue_mean=z,
                       jain_weighted=1.0)


def test_controller_aimd_boosts_then_decays():
    c = QoSController(np.ones(2), p99_targets=[0.0, 100.0])
    a1 = c.update(_frame([500.0, 500.0]))          # tenant 1 violating
    assert a1.weights[1] > 1.0
    assert a1.weights[0] == 1.0                    # no SLO => static weight
    boosted = a1.weights[1]
    for _ in range(20):                            # SLO met: decay to base
        a = c.update(_frame([10.0, 10.0]))
    assert a.weights[1] < boosted
    assert a.weights[1] == pytest.approx(1.0, abs=0.01)


def test_controller_weight_clamped():
    cfg = QoSConfig(ai=10.0, w_max_scale=4.0)
    c = QoSController(np.ones(1), p99_targets=[100.0], cfg=cfg)
    for _ in range(10):
        a = c.update(_frame([1e6], T=1))
    assert a.weights[0] == pytest.approx(4.0)


def test_controller_admission_hysteresis():
    cfg = QoSConfig(pause_hi=0.8, resume_lo=0.4)
    c = QoSController(np.ones(1), p99_targets=[0.0], cfg=cfg)
    assert c.update(_frame([0.0], kv=0.9, T=1)).admit[0] == False  # noqa: E712
    # pressure between lo and hi: stays paused (no chattering)
    assert c.update(_frame([0.0], kv=0.6, T=1)).admit[0] == False  # noqa: E712
    assert c.update(_frame([0.0], kv=0.3, T=1)).admit[0] == True   # noqa: E712
    # and from admitted, mid pressure does not pause
    assert c.update(_frame([0.0], kv=0.6, T=1)).admit[0] == True   # noqa: E712


# ---------------------------------------------------------------------------
# event wiring satellites: ECN, lifetime budget, backpressure
# ---------------------------------------------------------------------------
def test_fmq_marks_before_dropping():
    from repro.core import ECTX, FMQ, PacketDescriptor, PushResult
    q = FMQ(index=0, ectx=ECTX(0, "t", SLOPolicy()), capacity=4)
    assert q.ecn_threshold == 3
    res = [q.push(PacketDescriptor(0, 64, float(i))) for i in range(5)]
    assert res == [PushResult.OK, PushResult.OK, PushResult.MARKED,
                   PushResult.MARKED, PushResult.DROPPED]
    assert q.ecn_marks == 2 and q.drops == 1
    assert q.fifo[2].ecn and not q.fifo[0].ecn


def test_sim_surfaces_ecn_events_and_telemetry():
    from repro.sim.engine import Simulator
    from repro.sim.scenarios import make_tenants
    from repro.sim.traffic import make_trace
    from repro.sim.workloads import spin_workload
    wl = spin_workload("hog", cycles_per_byte=200.0)
    sim = Simulator(make_tenants([wl]), fifo_capacity=8)
    res = sim.run(make_trace(0, size=256, share=0.5, duration_ns=30_000))
    kinds = {e.kind for e in res.events}
    assert EventKind.ECN_MARK in kinds
    marks = res.telemetry.counter("ecn_marks")[0]
    assert marks > 0 and marks == sim.fmqs[0].ecn_marks


def test_sim_total_cycle_budget_kills_with_event():
    from repro.sim.engine import Simulator
    from repro.sim.scenarios import make_tenants
    from repro.sim.traffic import make_trace
    from repro.sim.workloads import spin_workload
    wl = spin_workload("spin", cycles_per_byte=1.0, base=0.0)  # 228 cyc/pkt
    tenants = make_tenants([wl])
    tenants[0].slo = SLOPolicy(total_cycle_limit=800)          # ~3.5 kernels
    sim = Simulator(tenants)
    res = sim.run(make_trace(0, size=256, share=0.2, duration_ns=20_000))
    st = res.stats[0]
    assert st.completed == 3                    # 3*228=684; 4th would be 912
    assert st.killed > 0
    kinds = {e.kind for e in res.events}
    assert EventKind.TOTAL_BUDGET_EXCEEDED in kinds
    assert EventKind.CYCLE_BUDGET_EXCEEDED not in kinds   # distinct cause


def test_serving_total_budget_kills_and_rejects():
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.request import Request, RequestStatus
    eng = Engine(EngineConfig(max_slots=4, max_len=128, prefill_chunk=32,
                              max_tenants=2))
    eng.create_ectx(0, SLOPolicy(kv_quota_tokens=128 * 4,
                                 total_cycle_limit=30))
    r1 = eng.submit(Request(0, np.ones(16, np.int32), max_new_tokens=8))
    eng.run_until_idle()
    assert r1.status == RequestStatus.DONE      # 24 tokens <= 30
    r2 = eng.submit(Request(0, np.ones(16, np.int32), max_new_tokens=8))
    eng.run_until_idle()
    assert r2.status == RequestStatus.KILLED    # crosses 30 mid-prefill
    r3 = eng.submit(Request(0, np.ones(16, np.int32), max_new_tokens=8))
    assert r3.status == RequestStatus.REJECTED  # budget exhausted up front
    kinds = [e.kind for e in eng.poll_events(0)]
    assert kinds.count(EventKind.TOTAL_BUDGET_EXCEEDED) == 2


def test_apply_to_scheduler_scales_distinct_bases():
    """The controller contributes a boost; each scheduler knob keeps its
    own SLO-configured base weights (prio vs dma vs egress)."""
    from repro.telemetry import apply_to_scheduler
    c = QoSController(np.ones(2), p99_targets=[0.0, 100.0])
    act = c.update(_frame([500.0, 500.0]))        # tenant 1 boosted 1.5x
    prio = np.array([2.0, 1.0])
    dma = np.array([4.0, 8.0])
    apply_to_scheduler(act, (prio, np.array([2.0, 1.0])),
                       (dma, np.array([4.0, 8.0])))
    assert prio.tolist() == [2.0, 1.5]            # bases kept, boost scaled
    assert dma.tolist() == [4.0, 12.0]


def test_counters_are_integer_accumulators():
    """fp32 accumulators saturate at 2^24; counters must be integers on
    both backends so long-run counts keep advancing."""
    tel_np, tel_j = Telemetry(1), Telemetry(1, backend="jnp")
    for tel in (tel_np, tel_j):
        assert np.issubdtype(np.asarray(tel.state["counts"]).dtype,
                             np.integer)
        assert np.issubdtype(np.asarray(tel.state["hist"]).dtype,
                             np.integer)
        tel.inc("bytes_in", 0, float(1 << 24))
        tel.commit()
        tel.inc("bytes_in", 0, 1.0)
        tel.commit()
        assert int(tel.counter("bytes_in")[0]) == (1 << 24) + 1


def test_telemetry_reset_tenant_clears_history():
    tel = Telemetry(2)
    tel.inc("arrivals", 0, 5)
    tel.lat(0, 9.0)
    tel.inc("arrivals", 1, 3)
    tel.commit()
    tel.commit_window(np.ones((len(GAUGES), 2)))
    tel.lat(0, 2.0)                               # staged, uncommitted
    tel.reset_tenant(0)
    tel.commit()
    snap = tel.snapshot()
    assert snap["counts"][0].sum() == 0 and snap["hist"][0].sum() == 0
    assert np.all(snap["ring"][:, 0, :] == 0)
    assert snap["counts"][1, C_IDX["arrivals"]] == 3   # others untouched


def test_controller_reset_tenant_forgets_boost_and_pause():
    c = QoSController(np.ones(2), p99_targets=[0.0, 100.0])
    c.update(_frame([500.0, 500.0]))              # boost tenant 1
    c.paused[1] = True
    c.reset_tenant(1)
    assert c.weights[1] == 1.0 and not c.paused[1]


def test_destroy_ectx_resets_controller_row():
    from repro.serving.engine import Engine, EngineConfig
    from repro.telemetry import QoSController as QC
    eng = Engine(EngineConfig(max_slots=4, max_len=128, max_tenants=2,
                              qos_interval=8))
    ctrl = QC(np.ones(2), p99_targets=[0.0, 10.0])
    eng.attach_controller(ctrl)
    eng.create_ectx(0, SLOPolicy(kv_quota_tokens=128))
    ctrl.weights[0] = 4.0
    ctrl.paused[0] = True
    eng.destroy_ectx(0)
    assert ctrl.weights[0] == 1.0 and not ctrl.paused[0]


def test_attach_controller_rejects_inert_config():
    from repro.serving.engine import Engine, EngineConfig
    from repro.telemetry import QoSController as QC
    eng = Engine(EngineConfig(max_slots=4, max_tenants=2))  # qos_interval=0
    with pytest.raises(ValueError):
        eng.attach_controller(QC(np.ones(2)))
    eng2 = Engine(EngineConfig(max_slots=4, max_tenants=2, telemetry=False,
                               qos_interval=8))
    with pytest.raises(ValueError):
        eng2.attach_controller(QC(np.ones(2)))


def test_sim_backpressure_does_not_poison_drop_signal():
    """Gated arrivals count as 'rejected' in telemetry, not 'drops' —
    drop_rate feeds the controller's pressure signal, and polluting it
    would latch a paused tenant paused forever."""
    from repro.sim.engine import Simulator
    from repro.sim.scenarios import make_tenants
    from repro.sim.traffic import make_trace
    from repro.sim.workloads import spin_workload
    sim = Simulator(make_tenants([spin_workload("w", 0.1)]))
    sim._admit[0] = False
    res = sim.run(make_trace(0, size=256, share=0.05, duration_ns=5_000))
    assert res.stats[0].drops > 0                 # surfaced to the user...
    assert res.telemetry.counter("drops")[0] == 0  # ...not to the signal
    assert res.telemetry.counter("rejected")[0] == res.stats[0].drops


def test_serving_backpressure_gate_rejects_with_event():
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.request import Request, RequestStatus
    eng = Engine(EngineConfig(max_slots=4, max_len=128, max_tenants=2))
    eng.create_ectx(0, SLOPolicy(kv_quota_tokens=128 * 4))
    eng._admit[0] = False                       # as the controller would
    r = eng.submit(Request(0, np.ones(8, np.int32), max_new_tokens=4))
    assert r.status == RequestStatus.REJECTED
    assert EventKind.BACKPRESSURE in {e.kind for e in eng.poll_events(0)}
    assert eng.tel.staged("rejected")[0] == 1


# ---------------------------------------------------------------------------
# closed loop (acceptance): victim p99 improves, weighted Jain holds
# ---------------------------------------------------------------------------
def test_closed_loop_improves_victim_p99_without_fairness_regression():
    from repro.sim.scenarios import run_qos_closed_loop
    static = run_qos_closed_loop(False, duration_us=200.0)
    closed = run_qos_closed_loop(True, duration_us=200.0)
    p99_s = hist_quantile(static.telemetry.snapshot()["hist"], 0.99, np)
    p99_c = hist_quantile(closed.telemetry.snapshot()["hist"], 0.99, np)
    # victim (tenant 1) p99 sojourn latency improves substantially...
    assert p99_c[1] < 0.55 * p99_s[1]
    # ...and time-averaged weighted Jain fairness does not regress
    assert closed.jain_pu_timeavg >= static.jain_pu_timeavg - 0.05
    # same offered load in both runs
    assert (static.stats[1].completed + static.stats[1].drops
            == closed.stats[1].completed + closed.stats[1].drops)


def test_serving_controller_adapts_weights_and_protects_victim():
    import examples.qos_controller_demo as demo
    static = demo.run(False, rounds=80)
    closed = demo.run(True, rounds=80)
    p99_s = hist_quantile(static.tel.snapshot()["hist"], 0.99, np)
    p99_c = hist_quantile(closed.tel.snapshot()["hist"], 0.99, np)
    assert len(closed.controller.history) > 0
    assert max(a.weights[1] for a in closed.controller.history) > 1.0
    assert p99_c[1] < p99_s[1]


# ---------------------------------------------------------------------------
# report + overhead budget
# ---------------------------------------------------------------------------
def test_tenant_report_structure():
    tel = Telemetry(4)
    tel.inc("arrivals", 2, 7)
    tel.lat(2, 40.0)
    tel.commit()
    rep = tenant_report(tel, names={2: "victim"})
    assert list(rep["tenants"]) == [2]            # only active tenants
    row = rep["tenants"][2]
    assert row["arrivals"] == 7 and row["name"] == "victim"
    assert row["p99_latency"] > 0
    import json
    json.dumps(rep)                               # JSON-able


def test_recording_overhead_within_budget():
    """Acceptance: telemetry recording costs <3% of a model-backed
    engine step (measured directly; see benchmarks/telemetry_overhead)."""
    from benchmarks.telemetry_overhead import BUDGET_PCT, measure
    step_s, commit_np, _ = measure(use_model=True, steps=24)
    assert 100.0 * commit_np / step_s < BUDGET_PCT

def test_format_console_labels_time_columns():
    from repro.telemetry import format_console
    tel = Telemetry(2)
    tel.inc("arrivals", 0, 3)
    tel.lat(0, 40.0)
    tel.commit()
    rep = tenant_report(tel)
    header = format_console(rep, time_unit="ns").splitlines()[0]
    assert "p50(ns)" in header and "p99(ns)" in header
    header = format_console(rep, time_unit="steps").splitlines()[0]
    assert "p50(steps)" in header
    # the report's own declared unit wins when none is passed
    rep["latency_unit"] = "steps"
    assert "p99(steps)" in format_console(rep).splitlines()[0]
    with pytest.raises(ValueError):
        format_console(rep, time_unit="seconds")


def test_dump_json_refuses_to_clobber(tmp_path):
    from repro.telemetry import dump_json
    path = str(tmp_path / "report.json")
    dump_json({"a": 1}, path)
    with pytest.raises(FileExistsError):
        dump_json({"a": 2}, path)
    dump_json({"a": 2}, path, overwrite=True)
    import json
    with open(path) as fh:
        assert json.load(fh) == {"a": 2}
