"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,Hq,Hkv,D,win,cap", [
    (128, 4, 4, 64, 0, 0.0),       # MHA
    (256, 8, 2, 64, 0, 0.0),       # GQA 4:1
    (192, 4, 1, 128, 0, 0.0),      # MQA, unaligned S
    (256, 8, 4, 64, 96, 0.0),      # sliding window
    (128, 4, 4, 64, 0, 50.0),      # gemma2 softcap
    (320, 2, 2, 32, 64, 30.0),     # window + cap + unaligned
])
def test_flash_attention_sweep(dtype, S, Hq, Hkv, D, win, cap):
    ks = jax.random.split(jax.random.PRNGKey(S + Hq), 3)
    B = 2
    q = _rand(ks[0], (B, S, Hq, D), dtype)
    k = _rand(ks[1], (B, S, Hkv, D), dtype)
    v = _rand(ks[2], (B, S, Hkv, D), dtype)
    scale = 1.0 / np.sqrt(D)
    out = ops.flash_attention(q, k, v, scale=scale, causal=True, window=win,
                              cap=cap, bq=64, bk=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, scale=scale, causal=True,
                                   window=win, cap=cap)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    assert err < TOL[dtype], err


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (1, 64, 2, 32), jnp.float32)
    k = _rand(ks[1], (1, 96, 2, 32), jnp.float32)
    v = _rand(ks[2], (1, 96, 2, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, scale=0.2, causal=False,
                              interpret=True)
    # non-causal oracle: plain softmax over all keys
    s = jnp.einsum("bshd,bthd->bhst", q * 0.2, k)
    p = jax.nn.softmax(s, -1)
    want = jnp.einsum("bhst,bthd->bshd", p, v)
    assert float(jnp.max(jnp.abs(out - want))) < 2e-5


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,Hq,Hkv,D,win,bk", [
    (256, 8, 8, 64, 0, 128),
    (300, 8, 2, 64, 0, 128),       # GQA + unaligned T
    (512, 4, 1, 128, 128, 256),    # MQA + window
    (64, 2, 2, 32, 0, 512),        # bk > T
])
def test_decode_attention_sweep(dtype, T, Hq, Hkv, D, win, bk):
    ks = jax.random.split(jax.random.PRNGKey(T + D), 3)
    B = 3
    q = _rand(ks[0], (B, 1, Hq, D), dtype)
    k = _rand(ks[1], (B, T, Hkv, D), dtype)
    v = _rand(ks[2], (B, T, Hkv, D), dtype)
    lens = jnp.array([1, T // 2, T], jnp.int32)
    scale = 1.0 / np.sqrt(D)
    out = ops.decode_attention(q, k, v, lens, scale=scale, window=win,
                               bk=bk, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lens, scale=scale, window=win)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    assert err < TOL[dtype], err


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,H,P,G,N,chunk", [
    (128, 4, 32, 1, 16, 64),
    (200, 4, 32, 2, 16, 64),       # groups + ragged chunks
    (96, 2, 64, 1, 32, 32),
])
def test_ssd_scan_sweep(dtype, S, H, P, G, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(S + N), 4)
    B = 2
    x = _rand(ks[0], (B, S, H, P), dtype) * 0.5
    dt = jax.nn.softplus(_rand(ks[1], (B, S, H), jnp.float32))
    A_log = jnp.log(jnp.linspace(1.0, 4.0, H))
    Bm = _rand(ks[2], (B, S, G, N), jnp.float32) * 0.3
    Cm = _rand(ks[3], (B, S, G, N), jnp.float32) * 0.3
    y, st = ops.ssd_scan(x, dt, A_log, Bm, Cm, chunk=chunk, interpret=True)
    yr, sr = ref.ssd_scan_ref(x, dt, A_log, Bm, Cm)
    ey = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                               - yr.astype(jnp.float32))))
    es = float(jnp.max(jnp.abs(st - sr)))
    tol = 1e-3 if dtype == jnp.float32 else 5e-2
    assert ey < tol and es < tol, (ey, es)


def test_ssd_scan_matches_model_chunked_path():
    """Kernel == the model's lax.scan SSD implementation (ssm.ssd_chunked)."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    B, S, H, P, G, N = 2, 128, 4, 32, 1, 16
    x = _rand(ks[0], (B, S, H, P), jnp.float32) * 0.5
    dt = jax.nn.softplus(_rand(ks[1], (B, S, H), jnp.float32))
    A_log = jnp.log(jnp.linspace(1.0, 4.0, H))
    Bm = _rand(ks[2], (B, S, G, N), jnp.float32) * 0.3
    Cm = _rand(ks[3], (B, S, G, N), jnp.float32) * 0.3
    y1, s1 = ops.ssd_scan(x, dt, A_log, Bm, Cm, chunk=64, interpret=True)
    y2, s2 = ssd_chunked(x, dt, A_log, Bm, Cm, chunk=64)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-3
    assert float(jnp.max(jnp.abs(s1 - s2))) < 1e-3


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,W,bs,bw", [
    (128, 128, 64, 128),
    (100, 96, 256, 128),           # padding both dims
    (64, 256, 32, 64),             # multiple width tiles
])
def test_rglru_scan_sweep(S, W, bs, bw):
    ks = jax.random.split(jax.random.PRNGKey(S + W), 3)
    B = 2
    a = jax.nn.sigmoid(_rand(ks[0], (B, S, W), jnp.float32))
    b = _rand(ks[1], (B, S, W), jnp.float32) * 0.1
    h0 = _rand(ks[2], (B, W), jnp.float32)
    h, hl = ops.rglru_scan(a, b, h0, interpret=True)
    hr, hlr = ref.rglru_scan_ref(a, b, h0)
    assert float(jnp.max(jnp.abs(h - hr))) < 1e-5
    assert float(jnp.max(jnp.abs(hl - hlr))) < 1e-5


def test_rglru_kernel_matches_model_scan():
    """Kernel == the model's associative_scan implementation."""
    ks = jax.random.split(jax.random.PRNGKey(9), 2)
    B, S, W = 2, 64, 128
    a = jax.nn.sigmoid(_rand(ks[0], (B, S, W), jnp.float32))
    b = _rand(ks[1], (B, S, W), jnp.float32) * 0.1

    def combine(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])
    A, Bc = jax.lax.associative_scan(combine, (a, b), axis=1)
    h, _ = ops.rglru_scan(a, b, interpret=True)
    assert float(jnp.max(jnp.abs(h - Bc))) < 1e-4
