"""Fleet plane (DESIGN.md §12): multi-NIC co-simulation over the
modeled VOQ/crossbar fabric.

Pins the PR's acceptance properties:

* an ``N=1`` ideal-fabric fleet run is byte-identical to the plain
  single-NIC datapath (the fleet wrapper adds nothing to the physics);
* ``fleet_incast`` shows per-output VOQs preventing HoL blocking — the
  quiet pair's fabric latency stays at serialization + propagation
  while output 0 saturates;
* ``fleet_migrate`` shows the global QoS tier draining an SLO victim
  off a congested NIC and replaying it across the fabric, with the
  victim's sojourn p99 improving and fleet-wide Jain fairness holding;
* fabric conservation: every injected packet is exactly once
  delivered, dropped (with a ``SWITCH_DROP`` EQ event), or in-flight —
  property-tested over randomized fabrics;
* the fleet results are byte-identical across the event and batched
  sim datapaths.
"""
import dataclasses
import json

import numpy as np
import pytest

from _prop import given, settings, st


def _get(name, **kw):
    from repro.api import get_scenario
    return get_scenario(name, **kw)


def _run(spec, **kw):
    from repro.fleet import run_fleet
    return run_fleet(spec, **kw)


# ---------------------------------------------------------------------------
# registry + report schema
# ---------------------------------------------------------------------------
def test_fleet_scenarios_registered():
    from repro.api import list_scenarios
    names = {s["name"] for s in list_scenarios()}
    assert {"fleet_fabric", "fleet_incast", "fleet_migrate"} <= names


def test_fleet_report_validates_and_carries_fleet_block():
    from repro.fleet.engine import FLEET_EXTRAS_KEYS
    rep = _run(_get("fleet_fabric", duration_us=40.0))
    rep.validate()
    fl = rep.extras["fleet"]
    assert all(k in fl for k in FLEET_EXTRAS_KEYS)
    assert len(fl["per_nic"]) == fl["num_nics"] == 4
    # per-tenant home-NIC labels ride in TenantReport.extra
    assert all(r.extra["nic"].startswith("nic") for r in rep.tenants.values())


def test_fleet_block_schema_is_enforced():
    rep = _run(_get("fleet_fabric", duration_us=40.0))
    del rep.extras["fleet"]["jain_fleet"]
    with pytest.raises(ValueError, match="fleet extras missing"):
        rep.validate()


def test_fleet_rejects_serve_backend():
    with pytest.raises(ValueError, match="sim backend"):
        _run(_get("fleet_fabric", duration_us=40.0), backend="serve")


# ---------------------------------------------------------------------------
# acceptance: N=1 ideal fabric == the plain single-NIC datapath
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("datapath", ["event", "batched"])
def test_n1_ideal_fabric_bit_identical_to_single_nic(datapath):
    from repro.api import run_scenario
    from repro.api.spec import ScenarioSpec
    from repro.fleet.spec import FleetSpec
    base = _get("qos_closed_loop", duration_us=60.0)
    fs = FleetSpec(**{f.name: getattr(base, f.name)
                      for f in dataclasses.fields(ScenarioSpec)},
                   num_nics=1, link_gbps=0.0, prop_delay_ns=0.0)
    fleet = _run(fs.replace(datapath=datapath))
    ref = run_scenario(fs.plain().replace(datapath=datapath), "sim")
    assert (json.dumps(fleet.extras["fleet"]["per_nic"][0], sort_keys=True)
            == json.dumps(ref.to_dict(), sort_keys=True))


# ---------------------------------------------------------------------------
# acceptance: VOQ prevents HoL blocking under 16-NIC incast
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def incast_report():
    return _run(_get("fleet_incast"))


def test_incast_saturates_hot_output_only(incast_report):
    sw = incast_report.extras["fleet"]["switch"]
    util = sw["link_utilization"]
    assert util[0] > 0.9                       # incast output saturated
    assert util[-1] < 0.1                      # quiet output nearly idle


def test_incast_voq_keeps_quiet_pair_flat(incast_report):
    spec = incast_report.spec
    n = spec["num_nics"]
    sw = incast_report.extras["fleet"]["switch"]
    lat = np.asarray(sw["pair_latency_mean"])
    quiet_size = spec["tenants"][-1]["arrival"]["size"]
    ideal = quiet_size * 8.0 / spec["link_gbps"] + spec["prop_delay_ns"]
    quiet = lat[n - 1, n - 1]
    congested = lat[:n - 1, 0]
    assert 0.0 < quiet < 3.0 * ideal           # flat: no HoL from output 0
    assert congested.mean() > 10.0 * quiet     # hot pairs queue heavily


# ---------------------------------------------------------------------------
# acceptance: global QoS migrates the victim; p99 improves, Jain holds
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def migrate_reports():
    return {mig: _run(_get("fleet_migrate", migrate=mig))
            for mig in (True, False)}


def test_migration_fires_with_eq_events(migrate_reports):
    fl = migrate_reports[True].extras["fleet"]
    assert fl["migrations_total"] >= 1
    m = fl["migrations"][0]
    assert m["tenant"] == 2 and m["src"] == 0 and m["dst"] == 1
    assert fl["placement_final"][2] == 1
    kinds = [e["kind"] for e in migrate_reports[True].events]
    assert "migrate_start" in kinds and "migrate_done" in kinds
    # causality: the victim's SLO alert precedes the migration decision
    t_alert = min(e["time"] for e in migrate_reports[True].events
                  if e["kind"] == "slo_alert" and e["tenant"] == 2)
    t_mig = min(e["time"] for e in migrate_reports[True].events
                if e["kind"] == "migrate_start")
    assert t_alert < t_mig
    # the control arm never migrates
    assert migrate_reports[False].extras["fleet"]["migrations_total"] == 0


def test_migration_improves_victim_p99_and_jain_holds(migrate_reports):
    with_mig = migrate_reports[True].extras["fleet"]
    without = migrate_reports[False].extras["fleet"]
    # victim (tenant 2) arrival->completion p99 on its final NIC
    assert with_mig["sojourn_p99"][2] < 0.5 * without["sojourn_p99"][2]
    # ...and the victim still meets its SLO target after re-homing
    target = migrate_reports[True].spec["tenants"][2]["p99_target"]
    assert with_mig["sojourn_p99"][2] < target
    # fleet-wide weighted Jain fairness does not regress
    assert with_mig["jain_fleet"] >= without["jain_fleet"] - 0.05
    # same offered load lands in both arms (arrivals differ by the
    # replayed in-flight packets, which re-arrive on the new home NIC)
    t2m = migrate_reports[True].tenants[2]
    t2s = migrate_reports[False].tenants[2]
    assert t2m.completed + t2m.drops == t2s.completed + t2s.drops


def test_migration_delay_scales_with_drained_bytes(migrate_reports):
    # default migration_gbps=0 keeps the legacy fixed handoff cost
    fixed = migrate_reports[True].spec["migration_delay_ns"]
    m0 = migrate_reports[True].extras["fleet"]["migrations"][0]
    assert m0["done_t"] - m0["t"] == fixed
    # a finite state-transfer link adds the serialized drained bytes
    spec = dataclasses.replace(
        _get("fleet_migrate", migrate=True, datapath="batched"),
        migration_gbps=1.0)
    rep = _run(spec)
    m1 = rep.extras["fleet"]["migrations"][0]
    assert m1["packets"] > 0
    size = rep.spec["tenants"][m1["tenant"]]["arrival"]["size"]
    assert (m1["done_t"] - m1["t"]
            == fixed + m1["packets"] * size * 8.0 / spec.migration_gbps)


# ---------------------------------------------------------------------------
# acceptance: byte-identical across the event and batched datapaths
# ---------------------------------------------------------------------------
def _drift_free(rep):
    """Everything except the time-averaged Jain accumulators (known
    last-ulp float drift between the datapaths, matching the repo-wide
    idiom of pinning identity on the drift-free blocks) and the spec
    echoes (which differ in the ``datapath`` field by construction)."""
    d = rep.to_dict()
    d.pop("spec")
    d.pop("jain_pu"), d.pop("jain_io")
    for pn in d["extras"]["fleet"]["per_nic"]:
        pn.pop("spec")
        pn.pop("jain_pu"), pn.pop("jain_io")
    return json.dumps(d, sort_keys=True)


@pytest.mark.parametrize("name,kw", [
    ("fleet_fabric", {"duration_us": 40.0}),
    ("fleet_incast", {"duration_us": 40.0}),
    ("fleet_migrate", {}),
])
def test_fleet_results_identical_across_datapaths(name, kw):
    a = _run(_get(name, datapath="event", **kw))
    b = _run(_get(name, datapath="batched", **kw))
    assert _drift_free(a) == _drift_free(b)


# ---------------------------------------------------------------------------
# conservation: injected == delivered + dropped(+EQ event) + in-flight
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.data())
def test_switch_packet_conservation(data):
    from repro.core.events import EventKind
    from repro.fleet.switch import CrossbarSwitch
    n = data.draw(st.integers(min_value=2, max_value=4))
    sw = CrossbarSwitch(
        n, num_tenants=n,
        link_gbps=data.draw(st.floats(min_value=10.0, max_value=400.0)),
        prop_delay_ns=data.draw(st.floats(min_value=0.0, max_value=100.0)),
        voq_depth=data.draw(st.integers(min_value=1, max_value=4)),
        arbiter=("rr" if data.draw(st.booleans()) else "mdrr"),
        quantum_bytes=4096, track_ids=True)
    t = 0.0
    npkts = data.draw(st.integers(min_value=1, max_value=60))
    for k in range(npkts):
        t += data.draw(st.floats(min_value=0.0, max_value=40.0))
        sw.inject(t,
                  data.draw(st.integers(min_value=0, max_value=n - 1)),
                  data.draw(st.integers(min_value=0, max_value=n - 1)),
                  data.draw(st.integers(min_value=0, max_value=n - 1)),
                  data.draw(st.integers(min_value=64, max_value=2048)))
        if k % 5 == 0:
            sw.advance(t)
            # counts conservation holds mid-run, with packets in flight
            assert (int(sw.injected.sum())
                    == int(sw.delivered.sum()) + int(sw.dropped.sum())
                    + sw.inflight)
    for _ in range(64):                        # drain the fabric
        if sw.idle:
            break
        t += 1e6
        sw.advance(t)
    assert sw.idle and sw.inflight == 0
    # full id-set conservation: delivered/dropped disjoint, union == injected
    assert sw.conservation_ok()
    drops = [e for e in sw.events if e.kind == EventKind.SWITCH_DROP]
    assert len(drops) == int(sw.dropped.sum())


def test_fleet_run_conserves_packets_under_tiny_voqs():
    # short run: every drop must fit in the report's event cap so the
    # EQ-event count can be compared against the drop counters exactly
    rep = _run(_get("fleet_incast", voq_depth=4, duration_us=12.0),
               track_switch_ids=True)
    sw = rep.extras["fleet"]["switch"]
    assert sw["drops_total"] > 0               # tiny VOQs must drop
    assert (sum(sw["injected"]) + sum(sw["replayed"])
            == sum(sw["delivered"]) + sw["drops_total"] + sw["inflight"])
    eq_drops = [e for e in rep.events if e["kind"] == "switch_drop"]
    assert len(eq_drops) == sw["drops_total"]
    # the switch drops are attributed on the tenant reports too
    per_tenant = [r.extra["switch_drops"] for r in rep.tenants.values()]
    assert sum(per_tenant) == sw["drops_total"]


# ---------------------------------------------------------------------------
# observability: fleet export schema golden (CI gate companion)

def test_fleet_openmetrics_schema_matches_golden(tmp_path):
    """A fleet export run (per-NIC frames on a shared bus + fabric
    extra_rows) must keep the declared schema: every family labeled
    ``{backend,nic}`` or ``{backend,nic,tenant}`` plus the three
    fleet-only families.  Regenerate via ``schema_lines`` after an
    intentional metrics change."""
    import os
    from repro.launch.scenario import run_one
    from repro.telemetry.export import schema_lines
    run_one("fleet_fabric", "sim", {}, fast=True, export_dir=str(tmp_path))
    text = (tmp_path / "fleet_fabric.sim.om.txt").read_text()
    golden = os.path.join(os.path.dirname(__file__), "data",
                          "openmetrics_schema.fleet.golden")
    assert schema_lines(text) == open(golden).read().splitlines()
    # every per-NIC sample carries a concrete nic label; the empty-nic
    # (single-engine) form must not appear in a fleet exposition
    assert 'nic=""' not in text
    assert 'nic="nic0"' in text
