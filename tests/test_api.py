"""Unified runtime API: spec serde, registry, RunReport schema + JSON
round-trip, cross-backend parity, SLOPolicy validation, scenario CLI."""
import dataclasses
import json

import numpy as np
import pytest

from repro.api import (RunReport, Runtime, ScenarioSpec, ServeRuntime,
                       SimRuntime, TENANT_FIELDS, get_scenario,
                       list_scenarios, run_scenario)
from repro.core.slo import SLOPolicy


# ---------------------------------------------------------------------------
# SLOPolicy validation (satellite)
# ---------------------------------------------------------------------------
def test_slo_policy_validates_all_knobs():
    SLOPolicy()                                     # defaults are legal
    SLOPolicy(priority=2.0, dma_priority=0.5, egress_priority=3.0,
              kernel_cycle_limit=0, total_cycle_limit=10,
              kv_quota_tokens=512)
    for bad in (dict(priority=0.0), dict(priority=-1.0),
                dict(dma_priority=0.0), dict(dma_priority=-2.0),
                dict(egress_priority=0.0), dict(egress_priority=-0.5),
                dict(kernel_cycle_limit=-1), dict(total_cycle_limit=-5),
                dict(memory_bytes=-1), dict(kv_quota_tokens=-64),
                dict(max_chunk_tokens=-8)):
        with pytest.raises(ValueError):
            SLOPolicy(**bad)


# ---------------------------------------------------------------------------
# ScenarioSpec + registry
# ---------------------------------------------------------------------------
def test_scenario_spec_round_trips_through_dict():
    spec = get_scenario("fig13_io_mixture", scheduler="rr")
    assert ScenarioSpec.from_dict(spec.to_dict()) == spec
    assert ScenarioSpec.from_dict(
        json.loads(json.dumps(spec.to_dict()))) == spec


def test_registry_covers_all_legacy_scenarios():
    names = {s["name"] for s in list_scenarios()}
    # every scenario formerly in sim/scenarios.py, runnable by name
    assert {"fig9_congestor_victim", "fig10_hol_blocking",
            "fig11_standalone", "fig12_compute_mixture",
            "fig13_io_mixture", "qos_closed_loop",
            "ppb_service_time"} <= names
    # at least two sim scenarios also project onto the serving backend
    dual = [s for s in list_scenarios()
            if {"sim", "serve"} <= set(s["backends"])]
    assert len(dual) >= 2


def test_get_scenario_unknown_name_lists_registered():
    with pytest.raises(KeyError, match="fig9_congestor_victim"):
        get_scenario("no_such_scenario")


def test_runtime_adapters_satisfy_protocol():
    spec = get_scenario("fig9_congestor_victim")
    assert isinstance(SimRuntime.from_spec(spec), Runtime)
    assert isinstance(ServeRuntime.from_spec(spec), Runtime)


# ---------------------------------------------------------------------------
# RunReport schema
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def fig9_reports():
    spec = get_scenario("fig9_congestor_victim", duration_us=40.0)
    return (run_scenario(spec, "sim"), run_scenario(spec, "serve"))


def test_run_report_json_round_trip(fig9_reports):
    for rep in fig9_reports:
        again = RunReport.from_json(rep.to_json())
        assert again == rep
        # and the round-tripped report still validates
        again.validate()


def test_run_report_validate_catches_schema_violations(fig9_reports):
    rep = RunReport.from_json(fig9_reports[0].to_json())
    rep.backend = "fpga"
    with pytest.raises(ValueError, match="backend"):
        rep.validate()
    rep = RunReport.from_json(fig9_reports[0].to_json())
    rep.tenants[0].tenant_id = 7
    with pytest.raises(ValueError, match="mismatch"):
        rep.validate()
    rep = RunReport.from_json(fig9_reports[0].to_json())
    rep.jain_pu = 3.0
    with pytest.raises(ValueError, match="jain_pu"):
        rep.validate()


# ---------------------------------------------------------------------------
# cross-backend parity (satellite): one spec, two runtimes, one schema
# ---------------------------------------------------------------------------
def test_cross_backend_parity(fig9_reports):
    sim, serve = fig9_reports
    assert sim.backend == "sim" and serve.backend == "serve"
    assert sim.time_unit == "ns" and serve.time_unit == "steps"
    # identical tenant sets with identical per-tenant schema
    assert set(sim.tenants) == set(serve.tenants) == {0, 1}
    for t in sim.tenants:
        a = dataclasses.asdict(sim.tenants[t])
        b = dataclasses.asdict(serve.tenants[t])
        assert set(a) == set(b) == set(TENANT_FIELDS)
        assert sim.tenants[t].name == serve.tenants[t].name
    # identical top-level schema
    assert {f.name for f in dataclasses.fields(sim)} == \
        {f.name for f in dataclasses.fields(serve)}
    # both did real work and carry the spec they ran
    for rep in (sim, serve):
        assert sum(r.completed for r in rep.tenants.values()) > 0
        assert rep.spec["name"] == "fig9_congestor_victim"
        assert rep.jain_pu > 0.9   # WLBVT keeps the victim protected


def test_same_spec_runs_both_backends_via_run_until():
    """Drive both runtimes through the shared protocol surface only."""
    from repro.api import build_requests, build_traces
    spec = get_scenario("fig9_congestor_victim", duration_us=20.0)
    for backend, work, horizon in (
            ("sim", build_traces(spec), 10_000.0),
            ("serve", build_requests(spec), 50)):
        rt = (SimRuntime.from_spec(spec) if backend == "sim"
              else ServeRuntime.from_spec(spec))
        for i, t in enumerate(spec.tenants):
            slo = t.slo()
            if backend == "serve" and slo.kv_quota_tokens == 0:
                slo = dataclasses.replace(slo, kv_quota_tokens=1024)
            rt.create_tenant(i, slo, name=t.name,
                             workload=t.workload.build())
        rt.inject(work)
        now = rt.run_until(horizon)
        assert now >= horizon or backend == "sim"
        assert rt.now() == now
        rep = rt.report(spec).validate()
        assert rep.duration >= 0


# ---------------------------------------------------------------------------
# legacy shims still behave
# ---------------------------------------------------------------------------
def test_legacy_shim_matches_direct_api_run():
    from repro.sim.scenarios import run_congestor_victim_compute
    res = run_congestor_victim_compute("wlbvt", duration_us=30.0)
    rep = run_scenario(
        get_scenario("fig9_congestor_victim", scheduler="wlbvt",
                     duration_us=30.0), "sim")
    assert rep.jain_pu == pytest.approx(res.jain_pu_timeavg)
    assert rep.tenants[0].completed == res.stats[0].completed
    assert rep.tenants[1].p99_latency == pytest.approx(res.p99(1))
    assert rep.duration == pytest.approx(res.time)


def test_serve_runtime_lifecycle_and_events():
    spec = get_scenario("serve_three_class", requests=2)
    rt = ServeRuntime.from_spec(spec)
    rep = rt.run(spec)
    assert {e["kind"] for e in rep.events} >= {"admitted", "evicted"} or \
        rep.extras["events_total"] >= 0   # events drained into the report
    # lifecycle churn is a serve-only capability
    rt2 = ServeRuntime.from_spec(spec)
    rt2.create_tenant(0, SLOPolicy(kv_quota_tokens=256), name="t0")
    evs = rt2.destroy_tenant(0)
    assert any(e.kind.value == "evicted" for e in evs)
    sim_rt = SimRuntime.from_spec(get_scenario("fig9_congestor_victim"))
    with pytest.raises(NotImplementedError):
        sim_rt.destroy_tenant(0)


def test_report_is_non_destructive_on_both_backends():
    """report() must not consume EQ events: poll_events still delivers
    them afterwards, identically on sim and serve (protocol parity)."""
    spec = get_scenario("serve_three_class", requests=1)
    rt = ServeRuntime.from_spec(spec)
    rep1 = rt.run(spec)
    rep2 = rt.report(spec)
    assert rep1.events == rep2.events
    assert rep1.extras["events_total"] > 0
    polled = {t: rt.poll_events(t) for t in range(3)}
    assert sum(len(v) for v in polled.values()) == \
        rep1.extras["events_total"]
    # once polled, events are the tenant's: gone from later reports
    assert rt.report(spec).extras["events_total"] == 0
    # sim side: same contract
    sspec = get_scenario("fig9_congestor_victim", duration_us=20.0)
    srt = SimRuntime.from_spec(sspec)
    srep = srt.run(sspec)
    assert srt.report(sspec).events == srep.events


def test_analytic_scenario_produces_table_report():
    rep = run_scenario(get_scenario("ppb_service_time"))
    assert rep.extras["analytic"] == "ppb"
    assert len(rep.extras["table"]) > 20
    assert RunReport.from_json(rep.to_json()) == rep


# ---------------------------------------------------------------------------
# scenario CLI (satellite)
# ---------------------------------------------------------------------------
def test_scenario_cli_runs_and_dumps_validated_report(tmp_path, capsys):
    from repro.launch.scenario import main
    out = tmp_path / "fig11.json"
    assert main(["fig11_standalone", "--backend", "sim", "--fast",
                 "--set", "pkt_size=512", "--json", str(out)]) == 0
    rep = RunReport.from_json(out.read_text())
    rep.validate()
    assert rep.scenario == "fig11_standalone"
    assert rep.spec["tenants"][0]["arrival"]["size"] == 512
    assert "jain_pu" in capsys.readouterr().out


def test_scenario_cli_list(capsys):
    from repro.launch.scenario import main
    assert main(["--list"]) == 0
    out = capsys.readouterr().out
    assert "fig9_congestor_victim" in out and "serve_mixed_slo" in out


def test_scenario_cli_rejects_unsupported_backend():
    from repro.launch.scenario import main
    with pytest.raises(SystemExit):
        main(["fig10_hol_blocking", "--backend", "serve"])


def test_scenario_cli_all_writes_reports(tmp_path):
    """The CI smoke path: every registered scenario runs on every backend
    it supports, each producing a schema-valid RunReport JSON."""
    from repro.launch.scenario import main
    outdir = tmp_path / "reports"
    assert main(["--all", "--fast", "--out-dir", str(outdir)]) == 0
    files = sorted(p.name for p in outdir.glob("*.json"))
    expected_min = {"fig9_congestor_victim.sim.json",
                    "fig9_congestor_victim.serve.json",
                    "qos_closed_loop.sim.json",
                    "qos_closed_loop.serve.json",
                    "serve_congestor_victim.serve.json",
                    "ppb_service_time.sim.json"}
    assert expected_min <= set(files)
    for p in outdir.glob("*.json"):
        RunReport.from_json(p.read_text()).validate()
