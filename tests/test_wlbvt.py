"""WLBVT / DWRR scheduler unit + property tests (paper Listing 1)."""
import numpy as np
import pytest
from _prop import given, settings, st  # hypothesis or seeded fallback

from repro.core import wlbvt as W

import jax.numpy as jnp


def _mk(prios, queue, occup, total, bvt):
    st_ = W.WLBVTState.create(prios)
    st_.queue_len[:] = queue
    st_.cur_occup[:] = occup
    st_.total_occup[:] = total
    st_.bvt[:] = bvt
    return st_


# ---------------------------------------------------------------------------
# numpy <-> jnp equivalence (the simulator and the serving engine share
# numerics by construction; this is the guarantee)
# ---------------------------------------------------------------------------
@settings(max_examples=200, deadline=None)
@given(st.data())
def test_select_np_jnp_equivalent(data):
    T = data.draw(st.integers(2, 8))
    prios = data.draw(st.lists(st.floats(0.1, 8.0), min_size=T, max_size=T))
    queue = data.draw(st.lists(st.integers(0, 5), min_size=T, max_size=T))
    occup = data.draw(st.lists(st.integers(0, 4), min_size=T, max_size=T))
    total = data.draw(st.lists(st.floats(0, 1e4), min_size=T, max_size=T))
    bvt = data.draw(st.lists(st.floats(0, 1e4), min_size=T, max_size=T))
    num_pus = data.draw(st.integers(1, 16))

    s_np = _mk(prios, queue, occup, total, bvt)
    got_np = W.select(s_np, num_pus)

    s_j = W.init_state_jnp(prios)
    s_j["queue_len"] = jnp.asarray(queue, jnp.int32)
    s_j["cur_occup"] = jnp.asarray(occup, jnp.int32)
    s_j["total_occup"] = jnp.asarray(total, jnp.float32)
    s_j["bvt"] = jnp.asarray(bvt, jnp.float32)
    got_j = int(W.select_jnp(s_j, num_pus))
    # fp32 vs fp64 metric ties can differ; accept equal-metric alternatives
    if got_np != got_j:
        lim = W.pu_limit(s_np, num_pus)
        elig = (s_np.queue_len > 0) & (s_np.cur_occup < lim)
        metric = np.where(elig, s_np.tput() / s_np.prio, W.BIG)
        assert got_j >= 0 and elig[got_j]
        assert metric[got_j] == pytest.approx(metric[got_np], rel=1e-5)


@settings(max_examples=200, deadline=None)
@given(st.data())
def test_select_respects_weighted_cap_and_eligibility(data):
    T = data.draw(st.integers(2, 8))
    prios = data.draw(st.lists(st.floats(0.1, 8.0), min_size=T, max_size=T))
    queue = data.draw(st.lists(st.integers(0, 5), min_size=T, max_size=T))
    occup = data.draw(st.lists(st.integers(0, 4), min_size=T, max_size=T))
    num_pus = data.draw(st.integers(1, 16))
    s = _mk(prios, queue, occup, np.zeros(T), np.zeros(T))
    got = W.select(s, num_pus)
    lim = W.pu_limit(s, num_pus)
    if got >= 0:
        assert s.queue_len[got] > 0
        assert s.cur_occup[got] < lim[got]
    else:
        assert not ((s.queue_len > 0) & (s.cur_occup < lim)).any()


def test_select_prefers_lowest_normalized_throughput():
    # tenant 1 has been served twice as much -> tenant 0 must be picked
    s = _mk([1.0, 1.0], [3, 3], [0, 0], [100.0, 200.0], [100.0, 100.0])
    assert W.select(s, 8) == 0
    # but with 2x priority, tenant 1's normalized usage matches -> still 0
    s = _mk([1.0, 2.0], [3, 3], [0, 0], [100.0, 200.0], [100.0, 100.0])
    assert W.select(s, 8) in (0, 1)
    # priority 4x -> tenant 1 is now under-served
    s = _mk([1.0, 4.0], [3, 3], [0, 0], [100.0, 200.0], [100.0, 100.0])
    assert W.select(s, 8) == 1


def test_pu_limit_work_conservation():
    """Empty queues release their share (paper line 4-5: prio_sum over
    non-empty FMQs) — one active tenant may take ALL PUs."""
    s = _mk([1.0, 1.0], [5, 0], [0, 0], [0, 0], [0, 0])
    lim = W.pu_limit(s, 8)
    assert lim[0] == 8


def test_advance_integrates_active_only():
    s = _mk([1.0, 1.0], [1, 0], [1, 0], [0, 0], [0, 0])
    W.advance(s, 10.0)
    assert s.total_occup[0] == 100.0 * 0 + 10.0  # 1 PU x 10 cycles
    assert s.bvt[0] == 10.0
    assert s.bvt[1] == 0.0  # inactive tenant's virtual time frozen


def test_rr_baseline_cycles():
    q = np.array([1, 1, 1])
    idx, ptr = W.select_rr(0, q)
    assert (idx, ptr) == (0, 1)
    idx, ptr = W.select_rr(ptr, q)
    assert (idx, ptr) == (1, 2)


# ---------------------------------------------------------------------------
# DWRR
# ---------------------------------------------------------------------------
@settings(max_examples=100, deadline=None)
@given(st.data())
def test_dwrr_only_picks_pending(data):
    Q = data.draw(st.integers(2, 6))
    weights = data.draw(st.lists(st.floats(0.5, 4.0), min_size=Q, max_size=Q))
    pending = np.array(
        data.draw(st.lists(st.booleans(), min_size=Q, max_size=Q)))
    head = np.array(
        data.draw(st.lists(st.integers(1, 4096), min_size=Q, max_size=Q)),
        float)
    st_ = W.DWRRState.create(weights)
    got = W.dwrr_select(st_, head, pending, quantum=512.0)
    if pending.any():
        assert got >= 0 and pending[got]
    else:
        assert got == -1


def test_dwrr_weighted_share():
    """Over many grants with equal head sizes, grants ~ weights."""
    st_ = W.DWRRState.create([1.0, 3.0])
    head = np.array([512.0, 512.0])
    pending = np.array([True, True])
    counts = np.zeros(2)
    for _ in range(400):
        i = W.dwrr_select(st_, head, pending, quantum=512.0)
        counts[i] += 1
    ratio = counts[1] / counts[0]
    assert 2.5 < ratio < 3.5


def test_dwrr_byte_fair_with_large_heads():
    """A huge head transfer is served only after peers received ~the same
    BYTES (byte-fairness), and it is served eventually (no starvation).
    Conversely the small queue is never blocked waiting for the big one —
    the HoL-blocking resolution property."""
    st_ = W.DWRRState.create([1.0, 1.0])
    head = np.array([65536.0, 64.0])
    pending = np.array([True, True])
    small_bytes = 0.0
    first_big = None
    for n in range(5000):
        i = W.dwrr_select(st_, head, pending, quantum=512.0)
        assert i >= 0
        if i == 1:
            small_bytes += 64.0
        else:
            first_big = n
            break
    assert first_big is not None, "big transfer starved"
    # small queue received within ~2 quanta of the big head's bytes first
    assert abs(small_bytes - 65536.0) < 2 * 512.0
