"""Device datapath (repro.sim.devicepath) + sweep API tests.

The contract under test (DESIGN.md §13): the jit/scan device datapath is
bit-identical to the host ``BatchedSimulator`` on decisions, the EQ
event stream, and telemetry sums — in ``precision="exact"`` mode there
is no tolerance anywhere except the Jain index (whose device fold sums
in a different association order; documented drift, pinned to 1e-9).
The Pallas WLBVT select kernel must match its dense ``jnp_ref`` oracle
bit-exactly, and both must replay ``core.sched_generic.select_round``.
"""
import dataclasses

import numpy as np
import pytest
from _prop import given, settings, st  # hypothesis or seeded fallback

from repro.api import (ArrivalSpec, ScenarioSpec, SweepAxis, SweepSpec,
                       TenantSpec, WorkloadSpec, apply_knob, get_scenario)
from repro.sim.devicepath import (DevicePathError, device_eligible,
                                  run_device, run_sweep_specs)


def _host_run(spec):
    """The device's oracle: the same spec on the host batched datapath."""
    from repro.api.runtime import build_traces
    from repro.core.slo import ECTX
    from repro.sim.fastpath import build_simulator
    tenants = [ECTX(tenant_id=i, name=t.name, slo=t.slo(),
                    kernel=t.workload.build())
               for i, t in enumerate(spec.tenants)]
    sim = build_simulator(tenants, datapath="batched",
                          scheduler=spec.scheduler, frag=spec.frag(),
                          arb=spec.arbiter,
                          fifo_capacity=spec.fifo_capacity,
                          record_completions=True)
    ta = build_traces(spec, arrays=True)
    horizon = spec.horizon_us * 1e3 if spec.horizon_us else None
    return sim.run(ta, horizon=horizon)


_STAT_FIELDS = ("completed", "killed", "drops", "served_payload_bytes",
                "first_arrival", "last_completion", "kernel_time_count",
                "kernel_time_sum")


def _assert_parity(spec, h, d):
    assert d.time == h.time
    assert d.completions == h.completions
    assert ([(e.tenant, e.kind, e.time) for e in d.events]
            == [(e.tenant, e.kind, e.time) for e in h.events])
    for i in range(len(spec.tenants)):
        hs, ds = h.stats[i], d.stats[i]
        for f in _STAT_FIELDS:
            assert getattr(ds, f) == getattr(hs, f), (i, f)
        assert (ds.kernel_time_percentile(99)
                == hs.kernel_time_percentile(99)), i
    for k in ("prio", "total_occup", "bvt", "kv_pressure"):
        np.testing.assert_array_equal(np.asarray(d.sched_state[k]),
                                      np.asarray(h.sched_state[k]), k)
    assert abs(d.jain_pu_timeavg - h.jain_pu_timeavg) <= 1e-9


def _fig9(**kw):
    spec = get_scenario("fig9_congestor_victim",
                        duration_us=kw.pop("duration_us", 30.0),
                        **{k: kw.pop(k) for k in ("scheduler",)
                           if k in kw})
    return dataclasses.replace(spec, record_timeline=False, **kw)


# ---------------------------------------------------------------------------
# golden parity: device == host batched, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("leg,impl,kw", [
    ("wlbvt", "jnp", {}),
    ("wlbvt_ref", "jnp_ref", {}),
    ("wlbvt_pallas", "pallas", {}),
    ("rr", "jnp", {"scheduler": "rr"}),
    ("fifo8", "jnp", {"fifo_capacity": 8}),
    ("horizon", "jnp", {"duration_us": 40.0, "horizon_us": 20.0}),
])
def test_fig9_parity(leg, impl, kw):
    spec = _fig9(**kw)
    _assert_parity(spec, _host_run(spec), run_device(spec, impl=impl))


def test_budget_kill_parity():
    spec = _fig9()
    ten = tuple(dataclasses.replace(t, kernel_cycle_limit=300,
                                    total_cycle_limit=20000)
                for t in spec.tenants)
    spec = dataclasses.replace(spec, tenants=ten)
    h, d = _host_run(spec), run_device(spec)
    assert sum(s.killed for s in h.stats.values()) > 0  # kills exercised
    _assert_parity(spec, h, d)


def test_sweep_batch_matches_single_replica_runs():
    """vmap correctness: an R=3 batch equals three R=1 launches."""
    base = _fig9(duration_us=15.0)
    specs = [dataclasses.replace(base, seed=s) for s in (0, 1, 2)]
    batch = run_sweep_specs(specs, record_completions=True)
    for spec, br in zip(specs, batch):
        sr = run_device(spec)
        assert br.time == sr.time
        assert br.completions == sr.completions
        for i in range(len(spec.tenants)):
            for f in _STAT_FIELDS:
                assert (getattr(br.stats[i], f)
                        == getattr(sr.stats[i], f)), (spec.seed, i, f)


def test_sweep_rejects_mixed_scheduler():
    a, b = _fig9(), _fig9(scheduler="rr")
    with pytest.raises(DevicePathError):
        run_sweep_specs([a, b])


# ---------------------------------------------------------------------------
# randomized sweep parity (geometry held constant so the compiled launch
# is reused across examples; knobs vary data, not shapes)
# ---------------------------------------------------------------------------
def _mix(prios, slopes, limits, scheduler, seeds):
    T = len(prios)
    tens = tuple(
        TenantSpec(f"t{i}",
                   workload=WorkloadSpec(name=f"w{i}", compute_base=40.0,
                                         compute_per_byte=slopes[i]),
                   arrival=ArrivalSpec(size=512, share=1.0 / T,
                                       seed_offset=i),
                   priority=prios[i], kernel_cycle_limit=limits[i])
        for i in range(T))
    base = ScenarioSpec(name="prop_mix", tenants=tens, duration_us=4.0,
                        scheduler=scheduler)
    return [dataclasses.replace(base, seed=s) for s in seeds]


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_random_sweep_parity(data):
    T = 3
    prios = [data.draw(st.floats(0.5, 4.0)) for _ in range(T)]
    slopes = [data.draw(st.floats(0.0, 0.8)) for _ in range(T)]
    limits = [data.draw(st.integers(0, 1)) * data.draw(
        st.integers(200, 2000)) for _ in range(T)]
    sched = "wlbvt" if data.draw(st.booleans()) else "rr"
    specs = _mix(prios, slopes, limits, sched, seeds=(0, 1))
    device = run_sweep_specs(specs, record_completions=True)
    for spec, d in zip(specs, device):
        h = _host_run(spec)
        assert d.time == h.time
        assert d.completions == h.completions
        assert ([(e.tenant, e.kind, e.time) for e in d.events]
                == [(e.tenant, e.kind, e.time) for e in h.events])
        for i in range(T):
            for f in _STAT_FIELDS:
                assert (getattr(d.stats[i], f)
                        == getattr(h.stats[i], f)), (spec.seed, i, f)


# ---------------------------------------------------------------------------
# WLBVT select kernel: jnp == jnp_ref == pallas == scalar oracle
# ---------------------------------------------------------------------------
def _rand_round(rng, R, T, num_pus):
    prio = rng.uniform(0.5, 4.0, (R, T)).astype(np.float32)
    ql = rng.randint(0, 6, (R, T)).astype(np.int32)
    co = rng.randint(0, 3, (R, T)).astype(np.int32)
    to = (rng.uniform(0.0, 5e4, (R, T))).astype(np.float32)
    bvt = (rng.uniform(0.0, 2e4, (R, T))).astype(np.float32)
    free = rng.randint(0, num_pus + 1, (R,)).astype(np.int32)
    return prio, ql, co, to, bvt, free


@pytest.mark.parametrize("max_picks", [1, 4, 16])
def test_select_rounds_impls_bit_exact(max_picks):
    from repro.kernels.wlbvt_select import wlbvt_select_rounds
    rng = np.random.RandomState(7)
    args = _rand_round(rng, R=11, T=5, num_pus=32)
    outs = {}
    for impl in ("jnp", "jnp_ref", "pallas"):
        picks, ql, co = wlbvt_select_rounds(
            *args, num_pus=32, max_picks=max_picks, impl=impl,
            interpret=True)
        outs[impl] = (np.asarray(picks), np.asarray(ql), np.asarray(co))
    for impl in ("jnp", "pallas"):
        for a, b in zip(outs[impl], outs["jnp_ref"]):
            np.testing.assert_array_equal(a, b, err_msg=impl)


def test_select_rounds_matches_scalar_oracle():
    """Row-by-row replay of core.sched_generic.select_round — the same
    sequential kernel the host scheduler steps through."""
    from repro.core import sched_generic as G
    from repro.kernels.wlbvt_select import wlbvt_select_rounds
    rng = np.random.RandomState(3)
    num_pus, max_picks = 16, 8
    prio, ql, co, to, bvt, free = _rand_round(rng, R=9, T=4, num_pus=num_pus)
    picks, qlo, coo = wlbvt_select_rounds(
        prio, ql, co, to, bvt, free, num_pus=num_pus, max_picks=max_picks,
        impl="jnp_ref")
    picks = np.asarray(picks)
    for r in range(prio.shape[0]):
        q, c = ql[r].copy(), co[r].copy()
        for k in range(max_picks):
            if k < free[r]:
                idx, q, c = G.select_round(prio[r], q, c, to[r], bvt[r],
                                           num_pus, np)
            else:
                idx = -1
            assert picks[r, k] == idx, (r, k)
        np.testing.assert_array_equal(np.asarray(qlo)[r], q)
        np.testing.assert_array_equal(np.asarray(coo)[r], c)


def test_select_rounds_rejects_oversize():
    from repro.kernels.wlbvt_select import wlbvt_select_rounds
    rng = np.random.RandomState(0)
    args = _rand_round(rng, R=2, T=200, num_pus=8)
    with pytest.raises(ValueError):
        wlbvt_select_rounds(*args, num_pus=8, max_picks=1, impl="pallas",
                            interpret=True)


# ---------------------------------------------------------------------------
# sweep spec API
# ---------------------------------------------------------------------------
def _base2():
    return _fig9(duration_us=10.0)


def test_apply_knob_paths():
    spec = _base2()
    assert apply_knob(spec, "fifo_capacity", 64).fifo_capacity == 64
    s = apply_knob(spec, "tenants.1.priority", 9.0)
    assert s.tenants[1].priority == 9.0 and s.tenants[0].priority \
        == spec.tenants[0].priority
    s = apply_knob(spec, "tenants.*.kernel_cycle_limit", 123)
    assert all(t.kernel_cycle_limit == 123 for t in s.tenants)
    s = apply_knob(spec, "tenants.0.workload.compute_per_byte", 0.25)
    assert s.tenants[0].workload.compute_per_byte == 0.25
    with pytest.raises(KeyError):
        apply_knob(spec, "no_such_field", 1)


def test_sweep_spec_expansion_and_serde():
    sw = SweepSpec(
        name="s", base=_base2(),
        axes=(SweepAxis("fifo_capacity", (64, 4096)),
              SweepAxis("tenants.0.priority", (1.0, 2.0, 4.0))),
        seeds=(0, 1))
    assert len(sw) == 12
    pairs = list(sw.replicas())
    assert len(pairs) == 12
    # axes outer (first axis slowest), seeds innermost
    assert [k["seed"] for k, _ in pairs[:2]] == [0, 1]
    assert pairs[0][0]["fifo_capacity"] == 64
    assert pairs[-1][0] == {"fifo_capacity": 4096,
                            "tenants.0.priority": 4.0, "seed": 1}
    for knobs, spec in pairs:
        assert spec.fifo_capacity == knobs["fifo_capacity"]
        assert spec.tenants[0].priority == knobs["tenants.0.priority"]
        assert spec.seed == knobs["seed"]
    rt = SweepSpec.from_dict(sw.to_dict())
    assert rt == sw and rt.specs() == sw.specs()


def test_device_eligible_gates():
    spec = _base2()
    assert device_eligible(spec) is None
    assert device_eligible(
        dataclasses.replace(spec, record_timeline=True)) is not None
    assert device_eligible(
        dataclasses.replace(spec, scheduler="drr")) is not None
    io_t = dataclasses.replace(
        spec.tenants[0], workload=WorkloadSpec(name="io",
                                               io_kind="dma_read"))
    assert device_eligible(dataclasses.replace(
        spec, tenants=(io_t,) + spec.tenants[1:])) is not None
    with pytest.raises(DevicePathError):
        run_sweep_specs([dataclasses.replace(spec, record_timeline=True)])


def test_summary_row_shape():
    spec = _base2()
    row = run_device(spec, precision="fast").summary_row({"seed": 3})
    assert row["scenario"] == spec.name and row["knobs"] == {"seed": 3}
    assert len(row["tenants"]) == len(spec.tenants)
    for t in row["tenants"]:
        for k in ("name", "completed", "drops", "killed", "ecn_marks",
                  "throughput_gbps", "p50_kernel_ns", "p99_kernel_ns"):
            assert k in t
