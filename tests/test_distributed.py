"""Collectives overlap, gradient compression, pipeline parallelism."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import collectives as C
from repro.distributed.compat import shard_map
from repro.distributed import compression as Q
from repro.distributed import pipeline as PP


# ---------------------------------------------------------------------------
# collective matmul (all-gather <-> matmul overlap)
# ---------------------------------------------------------------------------
def test_collective_matmul_ag_matches_dense(host_mesh):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    d_in, d_out, B = 32, 24, 8
    x = jax.random.normal(k1, (B, d_in), jnp.float32)
    w = jax.random.normal(k2, (d_in, d_out), jnp.float32)
    n = host_mesh.shape["model"]

    fn = shard_map(
        functools.partial(C.collective_matmul_ag, axis_name="model"),
        mesh=host_mesh,
        in_specs=(P(), P("model", None)),
        out_specs=P(), check_vma=False)
    got = fn(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               rtol=1e-5, atol=1e-5)


def test_reduce_scatter_matmul_matches_dense(host_mesh):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    d_in, d_out, B = 32, 24, 8
    x = jax.random.normal(k1, (B, d_in), jnp.float32)
    w = jax.random.normal(k2, (d_in, d_out), jnp.float32)

    # row-parallel: contraction dim sharded on both operands; output
    # columns end up scattered over the axis
    fn = shard_map(
        functools.partial(C.reduce_scatter_matmul, axis_name="model"),
        mesh=host_mesh,
        in_specs=(P(None, "model"), P("model", None)),
        out_specs=P(None, "model"), check_vma=False)
    got = fn(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback
# ---------------------------------------------------------------------------
def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,), jnp.float32) * 3.0
    c = Q.quantize(x)
    back = Q.dequantize(c)
    # per-block max / 127 quantization step
    step = 3.0 * 4 / 127          # generous bound on |x|max/127
    assert float(jnp.max(jnp.abs(back - x))) < step
    assert c.q.dtype == jnp.int8


def test_error_feedback_carries_residual():
    g = {"w": jnp.array([0.3, -0.2, 0.7])}
    err = Q.init_error(g)
    comp, err1 = Q.compress_with_feedback(g, err)
    # residual = g - dequant(comp)
    np.testing.assert_allclose(
        np.asarray(err1["w"]),
        np.asarray(g["w"] - Q.dequantize(comp["w"])), rtol=1e-6)


def test_compressed_psum_approximates_mean(host_mesh):
    """Across the data axis, the int8 all-reduce mean tracks the true mean
    within quantization error."""
    n = host_mesh.shape["data"]
    xs = jax.random.normal(jax.random.PRNGKey(2), (n, 512), jnp.float32)

    def body(x):
        comp, _ = Q.compress_with_feedback({"g": x}, {"g": jnp.zeros_like(x)})
        return Q.psum_compressed(comp, "data")["g"]

    fn = shard_map(body, mesh=host_mesh,
                       in_specs=P("data"), out_specs=P("data"),
                       check_vma=False)
    got = fn(xs.reshape(n, -1)).reshape(n, -1)[0]
    want = xs.mean(0).reshape(-1)[: got.shape[0]]
    # mean-scale approximation error is bounded by ~2 quant steps
    scale = float(jnp.max(jnp.abs(xs))) / 127
    assert float(jnp.max(jnp.abs(got - want))) < 4 * scale


def test_error_feedback_converges_running_sum():
    """Repeatedly compressing the same gradient with feedback: the running
    decompressed sum converges to the true sum (unbiasedness)."""
    g = jnp.array([0.01, -0.003, 0.25, 1.7], jnp.float32)
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for i in range(50):
        comp, err = Q.compress_with_feedback({"g": g}, {"g": err})
        err = err["g"]
        total = total + Q.dequantize(comp["g"])
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g),
                               atol=1e-3)


# ---------------------------------------------------------------------------
# pipeline parallelism (GPipe over 'pod')
# ---------------------------------------------------------------------------
def test_gpipe_matches_sequential(pod_mesh):
    S = pod_mesh.shape["pod"]
    L, d = 4 * S, 16
    ks = jax.random.split(jax.random.PRNGKey(3), L)
    Ws = jnp.stack([jax.random.normal(k, (d, d)) * 0.2 for k in ks])

    def layer_stack(ws, x):          # apply this stage's layers
        def body(xc, w):
            return jnp.tanh(xc @ w), None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    staged = PP.stage_params(Ws, S)
    M, mb = 8, 4
    xs = jax.random.normal(jax.random.PRNGKey(4), (M, mb, d))

    pipelined = PP.gpipe(layer_stack, pod_mesh, axis="pod")
    got = pipelined(staged, xs)

    want = xs
    for i in range(L):
        want = jnp.tanh(want @ Ws[i])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_bubble_fraction():
    assert PP.bubble_fraction(8, 2) == pytest.approx(1 / 9)
    assert PP.bubble_fraction(1, 4) == pytest.approx(3 / 4)
