"""Unified scheduling core (DESIGN.md §3): numpy↔jnp parity of
select_k/advance/pu_limit/DWRR on randomized states, and equivalence of
the batched round API against the sequential scalar loop it replaced.

Seeded-random sweeps (no hypothesis dependency): the parity tests use
integer-valued throughput/credit states so fp32 (data plane) and fp64
(control plane) round identically and decisions must agree exactly; the
continuous-value test tolerates the documented CEIL_EPS/metric-tie
epsilon instead.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import sched_generic as G
from repro.core import wlbvt as W


def _rand_state(rng, T, integral=True):
    st = W.WLBVTState.create(rng.choice([0.5, 1.0, 2.0, 4.0], size=T)
                             if integral else rng.uniform(0.1, 8.0, T))
    st.queue_len[:] = rng.randint(0, 5, T)
    st.cur_occup[:] = rng.randint(0, 4, T)
    if integral:
        st.total_occup[:] = rng.randint(0, 100, T).astype(float)
        st.bvt[:] = rng.randint(0, 50, T).astype(float)
    else:
        st.total_occup[:] = rng.uniform(0, 1e4, T)
        st.bvt[:] = rng.uniform(0, 1e4, T)
    return st


def _to_jnp(st):
    return {
        "prio": jnp.asarray(st.prio, jnp.float32),
        "total_occup": jnp.asarray(st.total_occup, jnp.float32),
        "bvt": jnp.asarray(st.bvt, jnp.float32),
        "cur_occup": jnp.asarray(st.cur_occup, jnp.int32),
        "queue_len": jnp.asarray(st.queue_len, jnp.int32),
    }


# ---------------------------------------------------------------------------
# numpy <-> jnp parity (randomized sweep)
# ---------------------------------------------------------------------------
def test_pu_limit_parity_randomized():
    rng = np.random.RandomState(0)
    for case in range(60):
        T = int(rng.randint(2, 40))
        num_pus = int(rng.randint(1, 64))
        st = _rand_state(rng, T, integral=False)
        lim_np = W.pu_limit(st, num_pus)
        lim_j = np.asarray(W.pu_limit_jnp(_to_jnp(st), num_pus))
        # fp32 may land on the other side of a ceil boundary only when the
        # fp64 value is within ~1e-5 of an integer (the documented epsilon)
        psum = st.prio[st.queue_len > 0].sum()
        if psum > 0:
            v = num_pus * st.prio / psum
            boundary = np.abs(v - np.round(v)) < 1e-4
        else:
            boundary = np.zeros(T, bool)
        mismatch = lim_np != lim_j
        assert not (mismatch & ~boundary).any(), (case, lim_np, lim_j)


def test_advance_parity_randomized():
    rng = np.random.RandomState(1)
    for _ in range(40):
        T = int(rng.randint(2, 40))
        st = _rand_state(rng, T, integral=False)
        sj = _to_jnp(st)
        dt = float(rng.uniform(0.1, 50.0))
        W.advance(st, dt)
        sj = W.advance_jnp(sj, dt)
        np.testing.assert_allclose(st.total_occup,
                                   np.asarray(sj["total_occup"]), rtol=1e-5)
        np.testing.assert_allclose(st.bvt, np.asarray(sj["bvt"]), rtol=1e-5)


def test_select_k_parity_integral_states():
    """Integer-valued states: fp32 and fp64 must make IDENTICAL pick
    sequences (no rounding ambiguity), including the -1 padding and the
    post-round queue/occupancy state."""
    rng = np.random.RandomState(2)
    for case in range(60):
        # draw shapes from small sets so the jitted select_k is traced a
        # handful of times, not once per case
        T = int(rng.choice([3, 8, 32]))
        num_pus = int(rng.choice([2, 8, 32]))
        k = int(rng.choice([1, 4, 8]))
        st = _rand_state(rng, T, integral=True)
        sj = _to_jnp(st)
        use_cap = bool(rng.randint(0, 2))
        cap = rng.randint(0, 5, T) if use_cap else None
        picks_np = W.select_k(st, num_pus, k, cap=cap)
        picks_j, sj2 = W.select_k_jnp(
            sj, num_pus, k,
            cap=jnp.asarray(cap, jnp.int32) if use_cap else None)
        assert picks_np.tolist() == np.asarray(picks_j).tolist(), case
        assert st.queue_len.tolist() == np.asarray(sj2["queue_len"]).tolist()
        assert st.cur_occup.tolist() == np.asarray(sj2["cur_occup"]).tolist()


def test_select_parity_continuous_states_tie_tolerant():
    """Continuous random states (the seed property test, hypothesis-free):
    fp32 may legitimately flip equal-metric ties — accept any pick whose
    fp64 metric matches the fp64 winner's to 1e-5."""
    rng = np.random.RandomState(3)
    for case in range(120):
        T = int(rng.randint(2, 16))
        num_pus = int(rng.randint(1, 16))
        st = _rand_state(rng, T, integral=False)
        got_np = W.select(st, num_pus)
        got_j = int(W.select_jnp(_to_jnp(st), num_pus))
        if got_np == got_j:
            continue
        lim = W.pu_limit(st, num_pus)
        elig = (st.queue_len > 0) & (st.cur_occup < lim)
        metric = np.where(elig, st.tput() / st.prio, G.BIG)
        assert got_j >= 0 and elig[got_j], case
        assert metric[got_j] == pytest.approx(metric[got_np], rel=1e-5)


# ---------------------------------------------------------------------------
# batch round == sequential scalar loop (the refactor's core guarantee)
# ---------------------------------------------------------------------------
def test_select_k_equals_sequential_select_loop():
    rng = np.random.RandomState(4)
    for case in range(80):
        T = int(rng.randint(2, 64))
        num_pus = int(rng.randint(1, 32))
        k = int(rng.randint(1, 16))
        st = _rand_state(rng, T, integral=False)
        use_cap = bool(rng.randint(0, 2))
        cap = rng.randint(0, 5, T) if use_cap else None
        ref = W.WLBVTState.create(st.prio)
        ref.queue_len[:] = st.queue_len
        ref.cur_occup[:] = st.cur_occup
        ref.total_occup[:] = st.total_occup
        ref.bvt[:] = st.bvt

        picks = W.select_k(st, num_pus, k, cap=cap)
        expected = np.full(k, -1, np.int64)
        for j in range(k):  # the pre-refactor per-pick loop
            i = W.select(ref, num_pus, cap=cap)
            if i < 0:
                break
            ref.queue_len[i] -= 1
            ref.cur_occup[i] += 1
            expected[j] = i
        assert picks.tolist() == expected.tolist(), case
        assert st.queue_len.tolist() == ref.queue_len.tolist()
        assert st.cur_occup.tolist() == ref.cur_occup.tolist()


def test_select_k_respects_caps_and_padding():
    rng = np.random.RandomState(5)
    for _ in range(40):
        T = int(rng.randint(2, 32))
        st = _rand_state(rng, T, integral=False)
        cap = rng.randint(0, 4, T)
        start = st.cur_occup.copy()
        picks = W.select_k(st, 16, 10, cap=cap)
        assert (st.cur_occup <= np.maximum(cap, start)).all()
        assert (st.queue_len >= 0).all()
        seen_neg = False
        for p in picks:  # -1s only as a suffix
            if p < 0:
                seen_neg = True
            else:
                assert not seen_neg


def test_select_rr_matches_reference_loop():
    rng = np.random.RandomState(6)
    for _ in range(80):
        T = int(rng.randint(2, 24))
        q = rng.randint(0, 3, T)
        mask = rng.randint(0, 2, T).astype(bool) \
            if rng.randint(0, 2) else None
        ptr = int(rng.randint(0, T))
        got_i, got_p = W.select_rr(ptr, q, mask=mask)
        exp_i, exp_p = -1, ptr          # the pre-refactor Python scan
        for j in range(T):
            i = (ptr + j) % T
            if q[i] > 0 and (mask is None or mask[i]):
                exp_i, exp_p = i, (i + 1) % T
                break
        assert (got_i, got_p) == (exp_i, exp_p)


# ---------------------------------------------------------------------------
# DWRR: batch/scalar equivalence and numpy <-> jnp parity
# ---------------------------------------------------------------------------
def test_dwrr_select_k_equals_sequential_loop():
    rng = np.random.RandomState(7)
    for case in range(60):
        Q = int(rng.randint(2, 24))
        weights = rng.choice([0.5, 1.0, 2.0, 4.0], size=Q)
        head = rng.randint(1, 65, Q).astype(float) * 64.0
        counts = rng.randint(0, 4, Q)
        k = int(rng.randint(1, 10))
        quantum = 512.0
        st_a = W.DWRRState.create(weights)
        st_b = W.DWRRState.create(weights)
        counts_a = counts.copy()
        counts_b = counts.copy()

        picks = W.dwrr_select_k(st_a, head, counts_a, quantum, k)
        expected = np.full(k, -1, np.int64)
        for j in range(k):  # the pre-refactor per-grant loop
            i = W.dwrr_select(st_b, head, counts_b > 0, quantum)
            if i < 0:
                break
            counts_b[i] -= 1
            expected[j] = i
        assert picks.tolist() == expected.tolist(), case
        np.testing.assert_allclose(st_a.deficit, st_b.deficit)
        assert st_a.ptr == st_b.ptr
        assert counts_a.tolist() == counts_b.tolist()


def test_dwrr_parity_np_jnp_grant_sequence():
    """Integer byte counts stay exact in fp32 (< 2^24), so the numpy and
    jitted jnp arbiters must issue the same grant sequence and deficits."""
    rng = np.random.RandomState(8)
    for case in range(30):
        Q = int(rng.choice([3, 8]))  # few shapes -> few jit traces
        weights = rng.choice([0.5, 1.0, 2.0, 4.0], size=Q)
        head = rng.randint(1, 33, Q).astype(float) * 64.0
        counts = rng.randint(0, 5, Q)
        st_np = W.DWRRState.create(weights)
        st_j = W.dwrr_state_jnp(weights)
        counts_j = counts.copy()
        for step in range(12):
            i_np = W.dwrr_select(st_np, head, counts > 0, 512.0)
            i_j, st_j = W.dwrr_select_jnp(st_j, head, counts_j > 0, 512.0)
            assert i_np == int(i_j), (case, step)
            if i_np < 0:
                break
            counts[i_np] -= 1
            counts_j[int(i_j)] -= 1
        np.testing.assert_allclose(st_np.deficit,
                                   np.asarray(st_j["deficit"]))
        assert st_np.ptr == int(st_j["ptr"])
