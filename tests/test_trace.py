"""Trace plane (DESIGN.md §10): ring eviction semantics, tail readout,
WLBVT decision replay fidelity vs a sequential reference, cross-backend
provenance identity, span/latency reconciliation, and the Perfetto
``trace_event`` export schema."""
import json

import numpy as np
import pytest

from repro.core import sched_generic as G
from repro.core import wlbvt as W
from repro.telemetry import trace as TR
from repro.telemetry.trace import TraceRecorder, record_wlbvt_round
from repro.telemetry.traceview import (
    PID_PU, PID_SCHED, PID_TENANTS, to_perfetto,
)

FULL_LIFECYCLE = (TR.ST_ARRIVE, TR.ST_FMQ, TR.ST_GRANT, TR.ST_PU,
                  TR.ST_DMA, TR.ST_EQ)
DROP_UID_BASE = 1_000_000


def _flood(tr, n):
    """n packet lifecycles (6 rows each) with an eager drop row every
    10th packet, so packet records and plain rows interleave."""
    for i in range(n):
        t = float(i)
        tr.span_packet(i, i % 3, i % 4, TR.D_OK, TR.D_OK,
                       t, t + 1.0, t + 2.0, t + 2.5)
        if i % 10 == 9:
            tr.span(TR.ST_ARRIVE, DROP_UID_BASE + i, i % 3,
                    t + 0.5, t + 0.5, TR.D_DROP)


def _by_uid(rows):
    """uid -> stage list, in retained write order."""
    per = {}
    for uid, stage in zip(rows["uid"].tolist(), rows["stage"].tolist()):
        per.setdefault(uid, []).append(stage)
    return per


# ---------------------------------------------------------------------------
# ring eviction
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("commit_every", [7, None],
                         ids=["incremental", "one-big-commit"])
def test_ring_eviction_keeps_lifecycles_paired(commit_every):
    depth = 64
    tr = TraceRecorder(3, depth=depth, decision_depth=16)
    ref = TraceRecorder(3, depth=1 << 16, decision_depth=16)
    n = 50
    for i in range(n):
        for rec in (tr, ref):
            t = float(i)
            rec.span_packet(i, i % 3, i % 4, TR.D_OK, TR.D_OK,
                            t, t + 1.0, t + 2.0, t + 2.5)
            if i % 10 == 9:
                rec.span(TR.ST_ARRIVE, DROP_UID_BASE + i, i % 3,
                         t + 0.5, t + 0.5, TR.D_DROP)
        if commit_every and i % commit_every == 0:
            tr.commit()
    rows = tr.rows()
    total = n * 6 + n // 10
    assert tr.span_count == total
    assert len(rows["uid"]) == depth

    # eviction == the newest `depth` rows of the unbounded stream
    full = ref.rows()
    for k in rows:
        np.testing.assert_array_equal(rows[k], full[k][total - depth:],
                                      err_msg=k)

    # rows are written complete: no OPEN disposition, no negative spans
    assert not np.any(rows["disp"] == TR.D_OPEN)
    assert np.all(rows["t1"] >= rows["t0"])

    # pairing: every retained lifecycle is a suffix of the full stage
    # sequence, and only the oldest retained packet may be cut
    per = _by_uid(rows)
    partial = []
    for uid, stages in per.items():
        if uid >= DROP_UID_BASE:
            assert stages == [TR.ST_ARRIVE]
            continue
        k = len(stages)
        assert tuple(stages) == FULL_LIFECYCLE[6 - k:], uid
        if k < 6:
            partial.append(uid)
    assert len(partial) <= 1
    if partial:
        assert partial[0] == min(u for u in per if u < DROP_UID_BASE)


def test_tail_matches_rows_suffix():
    tr = TraceRecorder(3, depth=128, decision_depth=16)
    _flood(tr, 40)
    rows = tr.rows()
    m = len(rows["uid"])
    for n in (0, 1, 10, m, m + 50):
        t = tr.tail(n)
        k = min(n, m)
        for c in rows:
            np.testing.assert_array_equal(t[c], rows[c][m - k:],
                                          err_msg=f"tail({n}).{c}")


# ---------------------------------------------------------------------------
# WLBVT decision replay vs a sequential reference
# ---------------------------------------------------------------------------
def _reference_round(pre, picks, num_pus, cap):
    """Replay one round pick-by-pick from the pre-round state with the
    scheduler's own formulas (``sched_generic``)."""
    ql = pre["queue_len"].copy()
    co = pre["cur_occup"].copy()
    prio = pre["prio"]
    metric = G.tput(pre["total_occup"], pre["bvt"], np) / prio
    out = []
    for p in picks:
        limit = G.pu_limit(prio, ql, num_pus, np)
        elig = (ql > 0) & (co < limit)
        if cap is not None:
            elig = elig & (co < cap)
        ne = int(elig.sum())
        pmax = np.where(elig, prio, -np.inf).max()
        reason = (TR.R_FORCED_SINGLE if ne <= 1 else
                  TR.R_PRIORITY if prio[p] >= pmax else TR.R_DEBT)
        out.append((p, reason, ne, float(metric[p]), elig.copy(),
                    pre["bvt"].copy()))
        ql[p] -= 1
        co[p] += 1
    return out


def test_wlbvt_replay_matches_sequential_reference():
    rng = np.random.RandomState(7)
    for trial in range(30):
        T = int(rng.randint(2, 6))
        num_pus = int(rng.randint(2, 33))
        cap = (rng.randint(1, 6, T).astype(np.float64)
               if trial % 3 == 0 else None)
        tr = TraceRecorder(T)
        st = W.WLBVTState.create(rng.uniform(0.5, 4.0, T))
        st.queue_len[:] = rng.randint(0, 8, T)
        st.cur_occup[:] = rng.randint(0, 3, T)
        st.total_occup[:] = rng.uniform(0.0, 50.0, T)
        st.bvt[:] = rng.uniform(0.0, 30.0, T)
        refs = []
        for rnd in range(int(rng.randint(1, 6))):
            pre = {f: getattr(st, f).copy() for f in
                   ("prio", "queue_len", "cur_occup", "total_occup",
                    "bvt")}
            k = int(rng.randint(1, num_pus + 1))
            picks = [int(p) for p in W.select_k(st, num_pus, k, cap=cap)
                     if p >= 0]
            record_wlbvt_round(tr, float(rnd), st, picks, num_pus,
                               TR.K_PU_WLBVT, cap=cap)
            refs.extend(_reference_round(pre, picks, num_pus, cap))
            # perturb between rounds: arrivals, completions, time
            st.queue_len += rng.randint(0, 4, T)
            done = np.minimum(st.cur_occup, rng.randint(0, 3, T))
            st.cur_occup -= done
            W.advance(st, float(rng.uniform(0.0, 5.0)))
        d = tr.decision_rows()
        assert len(d["time"]) == len(refs), (trial, T, num_pus)
        assert np.all(d["kind"] == TR.K_PU_WLBVT)
        for i, (p, reason, ne, met, elig, bvt) in enumerate(refs):
            ctx = (trial, i)
            assert int(d["winner"][i]) == p, ctx
            assert int(d["reason"][i]) == reason, ctx
            assert int(d["n_elig"][i]) == ne, ctx
            assert d["metric"][i] == pytest.approx(met), ctx
            np.testing.assert_array_equal(d["elig"][i], elig,
                                          err_msg=str(ctx))
            np.testing.assert_allclose(d["snapshot"][i],
                                       bvt.astype(np.float32),
                                       err_msg=str(ctx))


# ---------------------------------------------------------------------------
# end-to-end: cross-backend identity + reconciliation
# ---------------------------------------------------------------------------
def _fig9_spec(duration_us=20.0):
    from repro.api import get_scenario
    spec = get_scenario("fig9_congestor_victim")
    kw = {"duration_us": duration_us}
    if spec.horizon_us:
        kw["horizon_us"] = duration_us
    return spec.replace(**kw)


def _traced_run(spec, datapath):
    from repro.api.runtime import make_runtime
    rt = make_runtime(spec, "sim", trace=True, datapath=datapath)
    rep = rt.run(spec)
    rt.flush_trace()
    return rep, rt.trace


def _reconcile(rows):
    """max |(FMQ+PU+DMA durations) - (EQ.t1 - ARRIVE.t0)| per packet."""
    uids, inv = np.unique(rows["uid"], return_inverse=True)
    n = len(uids)
    dur = rows["t1"] - rows["t0"]
    staged = np.isin(rows["stage"],
                     (TR.ST_FMQ, TR.ST_PU, TR.ST_DMA))
    sums = np.bincount(inv, np.where(staged, dur, 0.0), minlength=n)
    t_arr = np.full(n, np.nan)
    t_eq = np.full(n, np.nan)
    am = rows["stage"] == TR.ST_ARRIVE
    em = (rows["stage"] == TR.ST_EQ)
    t_arr[inv[am]] = rows["t0"][am]
    t_eq[inv[em]] = rows["t1"][em]
    both = ~np.isnan(t_arr) & ~np.isnan(t_eq)
    assert both.any()
    return float(np.abs(sums[both] - (t_eq[both] - t_arr[both])).max())


def test_cross_backend_provenance_identity():
    """Same ScenarioSpec -> bit-identical span rows and the same
    decision winner/reason sequence on the event loop vs the batched
    datapath (the replay is engine-independent by construction)."""
    spec = _fig9_spec()
    _, tr_ev = _traced_run(spec, "event")
    _, tr_ba = _traced_run(spec, "batched")
    rows_ev, rows_ba = tr_ev.rows(), tr_ba.rows()
    assert len(rows_ev["uid"]) > 0
    for k in rows_ev:
        np.testing.assert_array_equal(rows_ev[k], rows_ba[k], err_msg=k)
    d_ev, d_ba = tr_ev.decision_rows(), tr_ba.decision_rows()
    assert len(d_ev["time"]) > 0
    for k in ("time", "kind", "winner", "reason", "n_elig", "metric",
              "snapshot", "elig"):
        np.testing.assert_array_equal(d_ev[k], d_ba[k], err_msg=k)


def test_span_sums_reconcile_with_completion_latency():
    _, tr = _traced_run(_fig9_spec(), "event")
    assert _reconcile(tr.rows()) <= 1.0  # within 1 virtual-ns
    # ARRIVE predates the grant: it must never carry a PU slot
    rows = tr.rows()
    assert np.all(rows["pu"][rows["stage"] == TR.ST_ARRIVE] == -1)


def test_trace_summary_extras_and_off_parity():
    """Tracing on adds exactly the ``trace_summary`` extras block and
    changes no reported metric."""
    from repro.api.runtime import make_runtime
    spec = _fig9_spec()
    rep_on, tr = _traced_run(spec, "event")
    rt_off = make_runtime(spec, "sim", trace=False)
    rep_off = rt_off.run(spec)
    s = rep_on.extras["trace_summary"]
    assert s["spans_recorded"] == tr.span_count
    assert s["open_spans"] == 0
    assert "trace_summary" not in rep_off.extras
    assert rep_on.duration == rep_off.duration
    assert rep_on.jain_pu == rep_off.jain_pu
    for t in rep_off.tenants:
        a, b = rep_on.tenants[t], rep_off.tenants[t]
        assert (a.completed, a.killed, a.drops) == \
               (b.completed, b.killed, b.drops)
        assert a.p99_latency == b.p99_latency


def test_serving_backend_trace_smoke():
    """The serving engine shares the recorder seam: spans reconcile in
    step units and WLBVT grants carry provenance."""
    from repro.api import get_scenario
    from repro.api.runtime import make_runtime
    spec = get_scenario("qos_closed_loop")
    rt = make_runtime(spec, "serve", trace=True)
    rt.run(spec)
    rt.flush_trace()
    tr = rt.trace
    assert tr.span_count > 0
    assert _reconcile(tr.rows()) <= 1.0
    kinds = set(tr.decision_rows()["kind"].tolist())
    assert TR.K_PU_WLBVT in kinds


# ---------------------------------------------------------------------------
# Perfetto export
# ---------------------------------------------------------------------------
def _span_events(doc):
    return [e for e in doc["traceEvents"]
            if e["ph"] != "M" and e.get("cat") != "decision"]


def test_perfetto_export_schema():
    _, tr = _traced_run(_fig9_spec(), "event")
    doc = to_perfetto(tr, time_unit="ns",
                      tenant_names={0: "congestor", 1: "victim"})
    json.dumps(doc)  # must be directly serializable
    ev = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["spans_recorded"] == tr.span_count
    for e in ev:
        assert e["ph"] in ("M", "i", "X", "b", "e"), e
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)

    # one named thread per PU slot and per tenant
    threads = {(e["pid"], e["tid"]): e["args"]["name"] for e in ev
               if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {t for (p, t) in threads if p == PID_PU} == set(range(tr.P))
    assert threads[(PID_TENANTS, 0)] == "congestor"
    assert threads[(PID_TENANTS, 1)] == "victim"

    # PU_EXEC rows render as complete events on the PU track
    xs = [e for e in ev if e["ph"] == "X"]
    assert xs
    for e in xs:
        assert e["pid"] == PID_PU and 0 <= e["tid"] < tr.P
        assert e["dur"] >= 0.0

    # async FMQ/DMA spans are begin/end balanced per packet id
    from collections import Counter
    b = Counter((e["cat"], e["id"]) for e in ev if e["ph"] == "b")
    e_ = Counter((e["cat"], e["id"]) for e in ev if e["ph"] == "e")
    assert b == e_

    # every retained decision lands on the scheduler track
    d = tr.decision_rows()
    sched = [e for e in ev if e.get("cat") == "decision"]
    assert len(sched) == len(d["time"])
    assert all(e["pid"] == PID_SCHED and e["name"] in TR.REASONS
               for e in sched)


def test_perfetto_last_n_is_suffix_of_full_export():
    _, tr = _traced_run(_fig9_spec(), "event")
    full = _span_events(to_perfetto(tr, time_unit="ns"))
    part = _span_events(to_perfetto(tr, time_unit="ns", last=500))
    assert 0 < len(part) < len(full)
    assert part == full[len(full) - len(part):]
