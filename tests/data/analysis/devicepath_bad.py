"""Known-bad jit-purity fixture: device-datapath violations.

tests/test_analysis.py asserts the exact line of every finding — keep
line numbers stable when editing.
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


@jax.jit
def branch_on_pick(tfin, pick):
    if pick >= 0:                           # line 14: traced `if`
        return tfin.at[pick].set(jnp.inf)
    return tfin


@jax.jit
def host_counter_in_step(state, x):
    def body(s, v):
        c = np.cumsum(v)                    # line 22: np in scan body
        return s + c[0], c[0]
    return lax.scan(body, state, x)


@jax.jit
def ragged_completions(comp_pkt):
    return jnp.flatnonzero(comp_pkt >= 0)   # line 29: dynamic shape


@jax.jit
def inplace_ring(buf, tail, v):
    buf[tail] = v                           # line 34: subscript store
    return buf
